import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder devices and extract the roofline terms.

The two lines above MUST stay first — jax locks device count on first
init, and only this entry point may see 512 devices (tests/benches see 1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 cells x 2 meshes
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  ... --variant q115            # §Perf quantized variant
  ... --override heads=         # §Perf sharding-rule override

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>[__<tag>].json
(incremental: existing files are skipped unless --force), containing
memory_analysis, cost_analysis, parsed per-collective traffic and the
three roofline terms (TPU v5e constants).
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.distributed import partitioning
from repro.launch import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim import adam, chain_clip
from repro.train.loop import TrainState, make_train_step

# ----------------------------------------------------------- constants
PEAK_FLOPS = 197e12  # bf16 FLOP/s per v5e chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective traffic from post-SPMD optimized HLO.

    Traffic model (ring algorithms, per participating device):
      all-gather:         result_bytes * (g-1)/g
      reduce-scatter:     result_bytes * (g-1)        (~input bytes)
      all-reduce:         2 * result_bytes * (g-1)/g  (RS + AG)
      all-to-all:         result_bytes * (g-1)/g
      collective-permute: result_bytes
    """
    ops: Dict[str, Dict[str, float]] = {}
    total_traffic = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            if gb:
                g = len(gb.group(1).split(","))
        if not g or g <= 1:
            traffic = size if kind == "collective-permute" else 0.0
        elif kind == "all-gather":
            traffic = size * (g - 1) / g
        elif kind == "reduce-scatter":
            traffic = size * (g - 1)
        elif kind == "all-reduce":
            traffic = 2.0 * size * (g - 1) / g
        elif kind == "all-to-all":
            traffic = size * (g - 1) / g
        else:  # collective-permute
            traffic = size
        rec = ops.setdefault(
            kind, {"count": 0, "result_bytes": 0.0, "traffic_bytes": 0.0}
        )
        rec["count"] += 1
        rec["result_bytes"] += size
        rec["traffic_bytes"] += traffic
        total_traffic += traffic
    return {"ops": ops, "traffic_bytes": total_traffic}


# ----------------------------------------------------------- cell build
def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd-only)."""
    sp = shp.SHAPES[shape_name]
    model = Model(cfg)
    n_active = model.active_param_count()
    if sp.kind == "train":
        tokens = sp.global_batch * sp.seq_len
        return 6.0 * n_active * tokens
    if sp.kind == "prefill":
        tokens = sp.global_batch * sp.seq_len
        return 2.0 * n_active * tokens
    tokens = sp.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def build_lowered(
    cfg,
    shape_name: str,
    mesh,
    rules: Optional[partitioning.PartitionRules] = None,
    accum_steps: int = 1,
):
    """Lower the cell's step function with production shardings."""
    model = Model(cfg)
    params_shapes, axes = model.abstract()
    rules = rules or partitioning.PartitionRules()
    param_sh = partitioning.tree_shardings(params_shapes, axes, mesh, rules)
    kind, inputs, in_axes = shp.batch_specs(cfg, shape_name)
    input_sh = partitioning.tree_shardings(inputs, in_axes, mesh, rules)
    repl = partitioning.replicated(mesh)

    if kind == "train":
        opt = chain_clip(adam(5e-4), 1.0)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        opt_sh = partitioning.opt_state_specs(opt_shapes, param_sh, mesh)
        step = make_train_step(model, opt, accum_steps=accum_steps)
        state_shapes = TrainState(
            params_shapes, opt_shapes, jax.ShapeDtypeStruct((), jnp.int32)
        )
        state_sh = TrainState(param_sh, opt_sh, repl)
        jf = jax.jit(
            step,
            in_shardings=(state_sh, input_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        return jf.lower(state_shapes, inputs)

    if kind == "prefill":
        sp = shp.SHAPES[shape_name]
        cache_shapes = model.abstract_cache(sp.global_batch, sp.seq_len)
        cache_axes = partitioning.cache_logical_axes(cache_shapes)
        cache_sh = partitioning.tree_shardings(
            cache_shapes, cache_axes, mesh, rules
        )

        def prefill_fn(params, batch):
            return model.prefill(params, batch, sp.seq_len)

        jf = jax.jit(
            prefill_fn,
            in_shardings=(param_sh, input_sh),
            out_shardings=(None, cache_sh),
        )
        return jf.lower(params_shapes, inputs)

    # decode
    sp = shp.SHAPES[shape_name]
    cache_shapes = model.abstract_cache(sp.global_batch, sp.seq_len)
    cache_axes = partitioning.cache_logical_axes(cache_shapes)
    cache_sh = partitioning.tree_shardings(
        cache_shapes, cache_axes, mesh, rules
    )
    jf = jax.jit(
        model.decode_step,
        in_shardings=(
            param_sh, input_sh["token"], input_sh["pos"], cache_sh,
        ),
        out_shardings=(None, cache_sh),
        donate_argnums=(3,),
    )
    return jf.lower(
        params_shapes, inputs["token"], inputs["pos"], cache_shapes
    )


def _pattern_len(cfg) -> int:
    from repro.models import transformer

    plan = transformer.layer_plan(cfg)
    return len(plan[0][1])


def _cost_point(cfg, n_layers: int, shape_name: str, mesh, rules):
    """Compile an unrolled reduced-depth variant and return raw costs.

    XLA's cost_analysis counts a while-loop body ONCE (verified on this
    jax/XLA build), so scanned-layer compiles undercount flops/bytes/
    collectives by the trip count.  Cost extraction therefore compiles
    *unrolled* stacks at two depths and the caller differences them.
    """
    cfg_c = dataclasses.replace(
        cfg, num_layers=n_layers, scan_layers=False, attn_chunk_unroll=True
    )
    with partitioning.activation_sharding(mesh, rules):
        lowered = build_lowered(cfg_c, shape_name, mesh, rules)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": colls,
    }


def _extrapolate(c1, c2, l1: int, l2: int, L: int) -> Dict[str, Any]:
    """Two-point linear extrapolation in depth: cost(L) = base + L*slope."""

    def lin(v1, v2):
        slope = (v2 - v1) / (l2 - l1)
        return max(v1 + slope * (L - l1), 0.0)

    ops = {}
    kinds = set(c1["collectives"]["ops"]) | set(c2["collectives"]["ops"])
    zero = {"count": 0, "result_bytes": 0.0, "traffic_bytes": 0.0}
    for k in kinds:
        o1 = c1["collectives"]["ops"].get(k, zero)
        o2 = c2["collectives"]["ops"].get(k, zero)
        ops[k] = {
            f: lin(o1[f], o2[f]) for f in ("count", "result_bytes", "traffic_bytes")
        }
    return {
        "flops": lin(c1["flops"], c2["flops"]),
        "bytes": lin(c1["bytes"], c2["bytes"]),
        "collectives": {
            "ops": ops,
            "traffic_bytes": lin(
                c1["collectives"]["traffic_bytes"],
                c2["collectives"]["traffic_bytes"],
            ),
        },
    }


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    variant: Optional[str] = None,
    rule_overrides: Optional[Dict[str, tuple]] = None,
    mesh_override=None,
    cfg_override=None,
    accum_steps: int = 1,
) -> Dict[str, Any]:
    cfg = cfg_override or configs.get(arch)
    if variant in ("q115", "q115_int", "q1_7_int"):
        cfg = dataclasses.replace(cfg, quant=variant)
    elif variant == "kvq":
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    elif variant and variant.startswith("combo:"):
        # e.g. combo:q1_7_int+kvq
        parts = variant.split(":", 1)[1].split("+")
        kw = {}
        if "kvq" in parts:
            kw["kv_cache_quant"] = True
        for p_ in parts:
            if p_ != "kvq":
                kw["quant"] = p_
        cfg = dataclasses.replace(cfg, **kw)
    ok, reason = shp.runnable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    if mesh_override is not None:
        mesh = mesh_override
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    rules = partitioning.PartitionRules()
    if rule_overrides:
        rules = rules.override(**rule_overrides)

    # 1) full-depth scanned compile: proves shardability + memory fit
    # (grad-accum applies here — the memory truth; cost points below use
    # accum=1 so the microbatch scan body is not undercounted)
    t0 = time.time()
    with partitioning.activation_sharding(mesh, rules):
        lowered = build_lowered(
            cfg, shape_name, mesh, rules, accum_steps=accum_steps
        )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()

    # 2) cost extraction via two-point depth differencing (unrolled)
    plen = _pattern_len(cfg)
    l1, l2 = plen, 3 * plen
    c1 = _cost_point(cfg, l1, shape_name, mesh, rules)
    c2 = _cost_point(cfg, l2, shape_name, mesh, rules)
    ext = _extrapolate(c1, c2, l1, l2, cfg.num_layers)

    flops_dev = ext["flops"]
    bytes_dev = ext["bytes"]
    colls = ext["collectives"]
    traffic_dev = float(colls["traffic_bytes"])
    compute_term = flops_dev / PEAK_FLOPS
    memory_term = bytes_dev / HBM_BW
    collective_term = traffic_dev / LINK_BW
    terms = {
        "compute_s": compute_term,
        "memory_s": memory_term,
        "collective_s": collective_term,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape_name)
    mf_dev = mf / n_chips
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "accum_steps": accum_steps,
        "status": "ok",
        "chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_live_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "cost_analysis": {
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "method": f"two-point depth differencing (unrolled L={l1},{l2})",
        },
        "collectives": colls,
        "roofline": {
            **terms,
            "dominant": dominant,
            "bound_s": max(terms.values()),
            "model_flops_global": mf,
            "model_flops_per_device": mf_dev,
            "useful_flops_ratio": (mf_dev / flops_dev) if flops_dev else 0.0,
        },
    }
    return result


ALL_SHAPES = list(shp.SHAPES)


# ------------------------------------------------- paper's own SNN at scale
def run_snn_cell(mesh_kind: str) -> Dict[str, Any]:
    """11th config: the paper's 4096-512-2 LIF SNN train step sharded on
    the production mesh (batch DP over (pod, data), hidden-layer TP over
    model) — the paper's technique as a first-class distributed feature.

    Global batch 16384 rate-coded 64x64 images x 25 time steps.
    """
    import jax.numpy as jnp

    from repro.core import snn as snn_mod
    from repro.configs.collision_snn import CONFIG as SNN_CFG
    from repro.optim import adam as adam_opt, chain_clip
    from repro.optim.adam import apply_updates

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    rules = partitioning.PartitionRules()
    B_GLOBAL = 16384
    cfg = SNN_CFG

    def init_fn(key):
        return snn_mod.init_params(key, cfg)

    params_shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    # logical axes: w (fan_in, fan_out) -> hidden dims TP over model
    axes = {
        name: {
            "w": ("snn_in" if i == 0 else "snn_hidden",
                  "snn_hidden" if i == 0 else "snn_out"),
            "b": ("snn_hidden" if i == 0 else "snn_out",),
            "beta_raw": ("snn_hidden" if i == 0 else "snn_out",),
            "threshold": ("snn_hidden" if i == 0 else "snn_out",),
        }
        for i, name in enumerate(["layer0", "layer1"])
    }
    rules = rules.override(
        snn_in=("data",), snn_hidden=("model",), snn_out=()
    )
    param_sh = partitioning.tree_shardings(params_shapes, axes, mesh, rules)
    opt = chain_clip(adam_opt(5e-4), 1.0)
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    opt_sh = partitioning.opt_state_specs(opt_shapes, param_sh, mesh)
    repl = partitioning.replicated(mesh)

    spikes_sds = jax.ShapeDtypeStruct(
        (cfg.num_steps, B_GLOBAL, cfg.layer_sizes[0]), jnp.float32
    )
    labels_sds = jax.ShapeDtypeStruct((B_GLOBAL,), jnp.int32)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    spikes_sh = partitioning.tree_shardings(
        {"s": spikes_sds}, {"s": ("act_seq", "batch", "snn_in")}, mesh, rules
    )["s"]
    labels_sh = partitioning.tree_shardings(
        {"l": labels_sds}, {"l": ("batch",)}, mesh, rules
    )["l"]

    def train_step(params, opt_state, spikes, labels, key):
        (loss, aux), grads = jax.value_and_grad(
            snn_mod.loss_fn, has_aux=True
        )(params, spikes, labels, cfg, train=True, dropout_key=key)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss, aux

    t0 = time.time()
    with partitioning.activation_sharding(mesh, rules):
        lowered = jax.jit(
            train_step,
            in_shardings=(param_sh, opt_sh, spikes_sh, labels_sh, repl),
            out_shardings=(param_sh, opt_sh, repl, repl),
            donate_argnums=(0, 1),
        ).lower(
            params_shapes, opt_shapes, spikes_sds, labels_sds, key_sds
        )
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text())
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": float(colls["traffic_bytes"]) / LINK_BW,
    }
    n_params = sum(
        int(jnp.prod(jnp.asarray(l.shape)))
        for l in jax.tree_util.tree_leaves(params_shapes)
    )
    # SNN model flops: T steps x (fwd 2*N*B) x 3 (train) — time scan is a
    # while loop, so apply the same trip-count correction analytically
    mf_dev = 6.0 * n_params * B_GLOBAL * cfg.num_steps / n_chips
    return {
        "arch": "collision-snn", "shape": "train_16k_batch",
        "mesh": mesh_kind, "status": "ok", "chips": n_chips,
        "compile_s": round(t_compile, 2),
        "note": (
            "cost_analysis counts the 25-step time scan once; terms below "
            "are raw (x25 for true per-step totals)"
        ),
        "memory_analysis": {
            "peak_live_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "cost_analysis": {
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
        },
        "collectives": colls,
        "roofline": {
            **terms,
            "dominant": max(terms, key=terms.get),
            "model_flops_per_device": mf_dev,
        },
    }


def cell_path(outdir, arch, shape, mesh_kind, tag):
    suffix = f"__{tag}" if tag else ""
    return os.path.join(outdir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=ALL_SHAPES + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--variant", default=None,
        help="q115: fake-quant QAT; q115_int/q1_7_int: true int weight "
        "storage; kvq: int8 KV cache; combo:<a>+<b> to compose",
    )
    ap.add_argument("--tag", default=None)
    ap.add_argument("--mesh-shape", default=None,
                    help="e.g. 32,8 — §Perf mesh remap within the pod")
    ap.add_argument("--mesh-axes", default="data,model")
    ap.add_argument(
        "--override", action="append", default=[],
        help="logical=axis1+axis2 partitioning-rule override (axis empty -> replicate)",
    )
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args(argv)

    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes_ = ALL_SHAPES if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = tuple(a for a in v.split("+") if a)
    tag = args.tag or (args.variant if args.variant else None)
    if overrides and not tag:
        tag = "override"
    mesh_override = None
    if args.mesh_shape:
        from repro.launch.mesh import make_production_mesh as _mpm

        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        axes = tuple(args.mesh_axes.split(","))
        mesh_override = _mpm(shape=shape, axes=axes)
        if not tag:
            tag = f"mesh{'x'.join(map(str, shape))}"

    os.makedirs(args.outdir, exist_ok=True)
    failures = []
    if args.arch == "collision-snn":
        for mesh_kind in meshes:
            res = run_snn_cell(mesh_kind)
            path = os.path.join(
                args.outdir, f"collision-snn__train__{mesh_kind}.json"
            )
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            r = res["roofline"]
            print(
                f"collision-snn x {mesh_kind}: ok compile={res['compile_s']}s "
                f"compute={r['compute_s']*1e3:.2f}ms "
                f"memory={r['memory_s']*1e3:.2f}ms "
                f"coll={r['collective_s']*1e3:.2f}ms "
                f"peak={res['memory_analysis']['peak_live_bytes']/2**30:.2f}GiB"
            )
        return
    for arch in archs:
        for shape_name in shapes_:
            for mesh_kind in meshes:
                path = cell_path(args.outdir, arch, shape_name, mesh_kind, tag)
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {path}")
                    continue
                print(f"[cell] {arch} x {shape_name} x {mesh_kind}", flush=True)
                try:
                    res = run_cell(
                        arch, shape_name, mesh_kind,
                        variant=args.variant,
                        rule_overrides=overrides or None,
                        mesh_override=mesh_override,
                    )
                except Exception as e:  # noqa
                    traceback.print_exc()
                    res = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                    }
                    failures.append((arch, shape_name, mesh_kind, str(e)))
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if res["status"] == "ok":
                    r = res["roofline"]
                    print(
                        f"   ok: compile={res['compile_s']}s "
                        f"compute={r['compute_s']*1e3:.2f}ms "
                        f"memory={r['memory_s']*1e3:.2f}ms "
                        f"coll={r['collective_s']*1e3:.2f}ms "
                        f"dom={r['dominant']} "
                        f"useful={r['useful_flops_ratio']:.2f}",
                        flush=True,
                    )
                elif res["status"] == "skipped":
                    print(f"   {res['reason']}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_)
        sys.exit(1)
    print("\ndry-run complete")


if __name__ == "__main__":
    main()
