"""Training launcher: any assigned arch (reduced or full) or the paper's
SNN, with checkpoint/restart, straggler watchdog and host-mesh sharding.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 50 --ckpt /tmp/ckpt --resume auto

Event-driven SNN training (surrogate gradients through the AER gather
path, synthetic DVS collision workload, energy-aware loss):

  PYTHONPATH=src python -m repro.launch.train --snn-events --steps 100 \
      --batch 32 --image-hw 32 --snn-steps 15 --energy-lambda 0.05 \
      [--polarity two_channel|signed|on_only] [--ckpt /tmp/snn_ev]

Observability (any mode, mirroring launch/serve.py): ``--metrics-json``
dumps the trainer's registry snapshot (step-time/loss/grad-norm
histograms, per-layer spike + energy counters for --snn-events),
``--trace-out`` writes the per-window span trace as Perfetto-loadable
Chrome trace JSON, ``--timeseries-out`` the per-window time series as
JSONL.

On a real TPU pod this same entry point runs under
`make_production_mesh()`; on this CPU container it uses the host mesh
(1 device) with identical code paths — the production mesh is exercised
by launch/dryrun.py.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.data.tokens import MarkovTokenStream, TokenStreamConfig
from repro.launch.mesh import make_host_mesh
from repro.models.model import CLIP_EMBED_DIM, Model
from repro.optim import adamw, chain_clip, warmup_cosine
from repro.train.loop import Trainer


def batches(cfg, batch_size, seq_len):
    stream = MarkovTokenStream(
        TokenStreamConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len, batch_size=batch_size
        )
    )
    import numpy as np

    rng = np.random.default_rng(0)
    for x, y in stream.batches():
        if cfg.num_codebooks:
            x = np.stack([x] * cfg.num_codebooks, -1)
            y = np.stack([y] * cfg.num_codebooks, -1)
        b = {"tokens": jnp.asarray(x), "targets": jnp.asarray(y)}
        if cfg.num_image_tokens:
            b["img_embeds"] = jnp.asarray(
                rng.normal(0, 1, (batch_size, cfg.num_image_tokens,
                                  CLIP_EMBED_DIM)).astype(np.float32)
            )
        yield b


def _train_snn_events(args) -> None:
    from repro.sparse_train import trainer as ev_trainer

    tcfg = ev_trainer.EventTrainConfig(
        image_hw=args.image_hw,
        num_steps=args.snn_steps,
        hidden=args.hidden,
        polarity_mode=args.polarity,
        quant_q115=(args.quant == "q115"),
    )
    trainer = ev_trainer.EventTrainer(
        tcfg,
        energy_lambda=args.energy_lambda,
        lr=args.lr if args.lr is not None else 5e-4,
        ckpt_dir=args.ckpt,
        ckpt_every=25,
        accum_steps=args.accum,
        seed=args.seed,
    )
    print(
        f"snn-events: {tcfg.input_size}-{tcfg.hidden}-2 "
        f"(dvs {tcfg.image_hw}x{tcfg.image_hw}, "
        f"polarity={tcfg.polarity_mode}, T={tcfg.num_steps}, "
        f"energy_lambda={args.energy_lambda}, "
        f"params={trainer.model.param_count()/1e3:.1f}K)"
    )
    if args.ckpt and args.resume == "auto":
        state = trainer.restore_or_init(jax.random.PRNGKey(args.seed))
        if int(state.step):
            print(f"resumed at step {int(state.step)}")
    else:
        state = trainer.init_state(jax.random.PRNGKey(args.seed))

    mesh = make_host_mesh()
    with mesh:
        # fast-forward the data stream to the restored step so a resumed
        # run sees bit-identical batches to an uninterrupted one
        state, metrics = trainer.run(
            state,
            ev_trainer.dvs_batches(
                args.seed, args.batch, tcfg, start_step=int(state.step)
            ),
            args.steps,
        )
    print("final:", metrics)
    trainer.export_obs(
        metrics_json=args.metrics_json,
        trace_out=args.trace_out,
        timeseries_out=args.timeseries_out,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None,
                    help="learning rate (default: 3e-4 for LM archs, the "
                         "paper's 5e-4 for --snn-events)")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default="auto", choices=["auto", "never"])
    ap.add_argument("--quant", default=None, choices=[None, "q115"])
    ap.add_argument("--seed", type=int, default=0)
    # event-driven SNN training mode
    ap.add_argument("--snn-events", action="store_true",
                    help="train the SNN event-drivenly on synthetic DVS "
                         "collision streams (sparse_train subsystem)")
    ap.add_argument("--image-hw", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--snn-steps", type=int, default=15,
                    help="SNN coding window (time steps)")
    ap.add_argument("--energy-lambda", type=float, default=0.0,
                    help="weight of the energy regularizer (loss/nJ)")
    ap.add_argument("--polarity", default="two_channel",
                    choices=["two_channel", "signed", "on_only"],
                    help="how DVS ON/OFF events map onto input weights")
    # observability (any mode; mirrors launch/serve.py)
    ap.add_argument("--metrics-json", default=None,
                    help="write the trainer's metrics-registry snapshot "
                         "(histograms/counters/gauges) to this path")
    ap.add_argument("--trace-out", default=None,
                    help="write per-window train spans as Chrome "
                         "trace-event JSON (Perfetto-loadable)")
    ap.add_argument("--timeseries-out", default=None,
                    help="write the per-window time series (counter "
                         "deltas, windowed rates) as JSONL")
    args = ap.parse_args(argv)

    if args.snn_events:
        _train_snn_events(args)
        return

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant:
        import dataclasses

        cfg = dataclasses.replace(cfg, quant=args.quant)
    model = Model(cfg)
    print(f"arch={args.arch} params={model.param_count()/1e6:.1f}M "
          f"(active {model.active_param_count()/1e6:.1f}M)")

    opt = chain_clip(
        adamw(warmup_cosine(args.lr if args.lr is not None else 3e-4,
                            10, max(args.steps, 11))), 1.0
    )
    trainer = Trainer(
        model, opt, ckpt_dir=args.ckpt, ckpt_every=25, accum_steps=args.accum
    )
    if args.ckpt and args.resume == "auto":
        state = trainer.restore_or_init(jax.random.PRNGKey(0))
        if int(state.step):
            print(f"resumed at step {int(state.step)}")
    else:
        state = trainer.init_state(jax.random.PRNGKey(0))

    mesh = make_host_mesh()
    with mesh:
        state, metrics = trainer.run(
            state, batches(cfg, args.batch, args.seq), args.steps
        )
    print("final:", metrics)
    trainer.export_obs(
        metrics_json=args.metrics_json,
        trace_out=args.trace_out,
        timeseries_out=args.timeseries_out,
    )


if __name__ == "__main__":
    main()
