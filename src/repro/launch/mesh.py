"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — smoke tests see 1 CPU device;
only launch/dryrun.py forces 512 placeholder devices via XLA_FLAGS.

Production topology (TPU v5e target):
  single pod : (16, 16)    axes (data, model)   = 256 chips
  multi pod  : (2, 16, 16) axes (pod, data, model) = 512 chips
    pod   — pure data parallelism (one cross-pod grad all-reduce / step,
            DCN-friendly; gradient compression hooks apply here)
    data  — FSDP + batch DP (intra-pod ICI)
    model — tensor parallel (heads/mlp/experts/vocab)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """jax-version shim: ``AxisType`` (and ``make_mesh``'s ``axis_types``
    kwarg) only exist on newer jax; older versions default to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(
    *,
    multi_pod: bool = False,
    shape: Optional[Tuple[int, ...]] = None,
    axes: Optional[Tuple[str, ...]] = None,
):
    """Build the production mesh.  `shape`/`axes` overrides exist for the
    §Perf hillclimb (e.g. (32, 8) data/model remapping for yi-34b) and for
    small-device tests; the defaults are the assignment's meshes."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    assert axes is not None and len(axes) == len(shape)
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(model: int = 1):
    """Whatever-fits mesh for single-host smoke runs: (n_dev/model, model)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return jax.make_mesh(
        (data, model), ("data", "model"), **_mesh_kwargs(2)
    )
