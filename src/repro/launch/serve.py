"""Serving launcher: batched LM generation or streaming SNN inference.

LM zoo (token decode, continuous batching over prompts):
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
      --reduced --requests 8 --new-tokens 16 [--quant q115]

SNN streaming (event-driven, persistent membrane state, measured energy;
async admission with open-loop Poisson arrivals, deadlines, priorities):
  PYTHONPATH=src python -m repro.launch.serve --snn --requests 16 \
      --batch 4 --chunk-steps 5 --image-hw 32 [--dvs] \
      [--arrival-rate 20] [--deadline-ms 500] \
      [--max-queue 8] [--shed] [--drain-timeout 60] \
      [--inject-faults 4 --fault-seed 0] \
      [--snapshot-dir /tmp/snn-snap --snapshot-every 5 --restore] \
      [--preempt] \
      [--metrics-json metrics.json] [--trace-out trace.json] \
      [--profile-ticks 20 --profile-dir /tmp/snn-profile]

Crash safety (with --snn): ``--snapshot-dir D --snapshot-every S``
writes a rotating atomic engine snapshot every S seconds (resident
membranes, AER rings, queue, parked + preempt-parked requests);
``--restore`` warm-restarts from the latest intact one — in-flight
windows resume mid-window, bit-exactly, and checksum-corrupt snapshots
fall back to the previous save.  ``--preempt`` enables deadline-aware
slot preemption (see ``SNNStreamEngine(preempt=True)``).

Fault tolerance (with --snn): ``--max-queue N`` bounds the admission
queue (overflow sheds priority-0 requests, parks higher priorities) and
``--shed`` turns on the EDF feasibility shedder — both via
``repro.faults.AdmissionPolicy``.  ``--drain-timeout S`` bounds the
closed-loop drain and prints the per-slot stuck diagnostic on expiry
instead of hanging.  ``--inject-faults N`` runs the request load under a
seeded chaos schedule (NaN membranes, corrupted rings, transient chunk
exceptions) from ``repro.faults.inject`` — faulted requests come back
``disposition="quarantined"`` while the other slots keep serving, and
the summary prints the fault-plane counters plus ``engine.health()``'s
diagnosis verdict.

Observability (with --snn): ``--metrics-json`` dumps the engine's full
instrument snapshot, ``--trace-out`` writes per-request + per-tick-phase
spans as Perfetto-loadable Chrome trace JSON, ``--timeseries-out`` the
per-tick time series as JSONL, and ``--profile-ticks N`` wraps N
steady-state ticks in a programmatic ``jax.profiler`` capture.  Both
open- and closed-loop modes report the trailing-window miss-rate /
events/s / ticks/s and the SLO burn-rate verdict
(healthy/degraded/breach) from ``engine.health()``.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

import repro.configs as configs
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine


def _serve_lm(args) -> None:
    cfg = configs.get(args.arch).reduced()
    if args.quant:
        cfg = dataclasses.replace(cfg, quant=args.quant)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params, batch_size=args.batch, cache_len=args.cache_len
    )
    rng = np.random.default_rng(0)

    def prompt():
        L = int(rng.integers(4, 24))
        if cfg.num_codebooks:
            return rng.integers(0, cfg.vocab_size, (L, cfg.num_codebooks)).astype(np.int32)
        return rng.integers(0, cfg.vocab_size, L).astype(np.int32)

    reqs = [
        Request(prompt=prompt(), max_new_tokens=args.new_tokens,
                temperature=args.temperature)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outs = engine.generate(reqs)
    dt = time.time() - t0
    n = sum(len(o) for o in outs)
    print(f"{args.arch}: served {len(reqs)} reqs / {n} tokens in {dt:.2f}s "
          f"({n/dt:.1f} tok/s on CPU, quant={cfg.quant})")


def _serve_snn(args) -> None:
    import jax.numpy as jnp

    from repro.core import snn
    from repro.events import aer
    from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

    if args.requests <= 0:
        print("snn: nothing to serve (--requests 0)")
        return
    hw = args.image_hw
    # polarity-aware input layer: DVS ON/OFF events get their own input
    # channels (or signed weights); frame-camera mode keeps hw*hw inputs
    input_size = (
        aer.input_size_for(hw * hw, args.polarity) if args.dvs else hw * hw
    )
    cfg = snn.SNNConfig(
        layer_sizes=(input_size, args.hidden, 2), num_steps=args.num_steps
    )
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    # SLOs: the latency target follows the requested deadline budget
    # (default 1 s without one); the deadline-miss error budget is 5%
    from repro.obs import default_slos

    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms > 0 else None

    # fault-tolerance plane (all opt-in, default off: unbounded queue,
    # no shedding, no chaos)
    admission = None
    if args.max_queue > 0 or args.shed:
        from repro.faults import AdmissionPolicy

        admission = AdmissionPolicy(
            max_queue_depth=args.max_queue if args.max_queue > 0 else None,
            shed_unmeetable=args.shed,
        )
    injector = None
    if args.inject_faults > 0:
        from repro.faults import FaultInjector, FaultSchedule

        chunks = -(-cfg.num_steps // args.chunk_steps)
        horizon = max(
            2 * args.requests * chunks // max(args.batch, 1), 8
        )
        injector = FaultInjector(FaultSchedule.generate(
            args.fault_seed, args.inject_faults, ticks=horizon,
            num_slots=args.batch, num_layers=cfg.num_layers,
            kinds=("nan_membrane", "corrupt_ring", "chunk_exception"),
        ))

    engine = SNNStreamEngine(
        params, cfg, num_slots=args.batch, chunk_steps=args.chunk_steps,
        seed=1, backend=args.snn_backend,
        pipeline_depth=0 if args.no_pipeline else 1,
        slos=default_slos(p99_target_s=deadline_s or 1.0),
        admission=admission, injector=injector,
        preempt=args.preempt,
    )

    # crash safety: warm-restart from the latest intact snapshot under
    # --snapshot-dir (corrupt/partial ones are skipped with a warning),
    # then keep snapshotting on the --snapshot-every cadence below
    if args.restore:
        if not args.snapshot_dir:
            raise SystemExit("--restore requires --snapshot-dir")
        restored = engine.restore_latest_snapshot(args.snapshot_dir)
        if restored is not None:
            print(f"snn: warm-restarted from {restored} "
                  f"(resident slots resume mid-window)")
        else:
            print(f"snn: no usable snapshot under {args.snapshot_dir}; "
                  f"cold start")
    snap_state = {"t": time.perf_counter()}

    def _maybe_snapshot():
        if not args.snapshot_dir or args.snapshot_every <= 0:
            return
        if time.perf_counter() - snap_state["t"] >= args.snapshot_every:
            engine.snapshot_auto(args.snapshot_dir)
            snap_state["t"] = time.perf_counter()

    key = jax.random.PRNGKey(2)
    reqs = []
    if args.dvs:
        # DVS event-camera input: densify each synthetic recording into
        # polarity-aware input planes behind the EventStream interface
        stream, labels = aer.dvs_collision_batch(
            key, args.requests, image_hw=hw, num_steps=cfg.num_steps,
            capacity=8 * hw * hw,
        )
        planes = aer.input_planes(
            stream, cfg.num_steps, hw * hw, polarity_mode=args.polarity
        )
        for i in range(args.requests):
            reqs.append(StreamRequest(spikes=np.asarray(planes[:, i])))
    else:
        from repro.data import collision

        data_cfg = collision.CollisionConfig(
            image_hw=hw, num_train=0, num_test=args.requests
        )
        _, _, test_x, _ = collision.generate(data_cfg)
        for x in test_x:
            reqs.append(StreamRequest(image=x.reshape(-1)))

    if deadline_s is not None:
        reqs = [dataclasses.replace(r, deadline_s=deadline_s) for r in reqs]

    profile = None
    if args.profile_ticks > 0:
        from repro.obs import profile_ticks

        profile = profile_ticks(
            engine, args.profile_dir, num_ticks=args.profile_ticks
        )

    t0 = time.time()
    if args.arrival_rate > 0:
        # open-loop: Poisson arrivals at the requested rate, submitted to
        # the async engine while earlier requests' chunks are in flight
        gaps = np.random.default_rng(3).exponential(
            1.0 / args.arrival_rate, len(reqs)
        )
        arrivals = np.cumsum(gaps)
        results, i = [], 0
        start = time.perf_counter()
        while i < len(reqs) or not engine.idle():
            now = time.perf_counter() - start
            while i < len(reqs) and arrivals[i] <= now:
                engine.submit(reqs[i])
                i += 1
            if engine.idle() and i < len(reqs):
                time.sleep(
                    max(arrivals[i] - (time.perf_counter() - start), 0.0)
                )
                continue
            results.extend(engine.poll())
            _maybe_snapshot()
        results.sort(key=lambda r: r.request_id)
    elif args.snapshot_dir and args.snapshot_every > 0:
        # closed-loop with a live snapshot cadence: poll manually so the
        # engine can checkpoint between ticks (drain() would block)
        for r in reqs:
            engine.submit(r)
        results, t_start = [], time.perf_counter()
        while not engine.idle():
            if (args.drain_timeout > 0
                    and time.perf_counter() - t_start > args.drain_timeout):
                print(f"snn: STALLED after {args.drain_timeout:.1f}s — "
                      f"stuck slots: {engine.stall_snapshot()['slots']}")
                break
            results.extend(engine.poll())
            _maybe_snapshot()
    elif args.drain_timeout > 0:
        # bounded closed-loop drain: a wedged tick loop surfaces as the
        # per-slot stuck diagnostic instead of hanging the launcher
        from repro.serving.snn_engine import EngineStallError

        for r in reqs:
            engine.submit(r)
        try:
            results = engine.drain(timeout_s=args.drain_timeout)
        except EngineStallError as e:
            print(f"snn: STALLED after {args.drain_timeout:.1f}s — "
                  f"stuck slots: {e.snapshot['slots']}")
            results = list(e.results)
    else:
        results = engine.run(reqs)
    dt = time.time() - t0
    if profile is not None:
        profile.stop()
    # latency / energy / throughput aggregate over *served* requests
    # only — shed requests never ran and quarantined ones carry no
    # trustworthy outputs (their fault code is the result)
    ok = [r for r in results if r.disposition == "ok"]
    n_shed = sum(r.disposition == "shed" for r in results)
    n_quar = sum(r.disposition == "quarantined" for r in results)
    rate = np.array([r.spike_rate for r in ok]) if ok else np.zeros(1)
    events_total = float(sum(r.events_per_layer.sum() for r in ok))
    src = f"dvs-events/{args.polarity}" if args.dvs else "rate-coded"
    loop = (
        f"open-loop {args.arrival_rate:.0f} req/s"
        if args.arrival_rate > 0
        else "closed-loop"
    )
    disp = (
        f" (ok {len(ok)} | shed {n_shed} | quarantined {n_quar})"
        if (n_shed or n_quar) else ""
    )
    print(
        f"snn[{input_size}->{args.hidden}->2, T={cfg.num_steps}, {src}]: "
        f"served {len(results)} reqs in {dt:.2f}s on {args.batch} slots "
        f"({loop}){disp}"
    )
    # report from the metrics snapshot: the engine-lifetime request
    # histograms and counters span every episode an open-loop trace with
    # arrival gaps crosses, so both modes read the same instruments
    snap = engine.metrics_snapshot()
    lat, qw, en = (
        snap["engine.request.latency_s"],
        snap["engine.request.queue_wait_s"],
        snap["engine.request.energy_pj"],
    )
    misses = int(snap["engine.requests.deadline_missed"]["value"])
    served = int(snap["engine.requests.completed"]["value"])
    print(
        f"  latency p50/p99: {lat['p50']*1e3:.1f}/{lat['p99']*1e3:.1f} ms"
        f" | queue wait p50: {qw['p50']*1e3:.1f} ms | "
        f"throughput: {events_total/max(dt, 1e-9):.0f} events/s | "
        f"input rate: {rate.mean():.3f}"
    )
    budget = (
        f"{args.deadline_ms:.0f} ms" if deadline_s is not None else "none"
    )
    print(
        f"  deadline budget {budget}: missed {misses}/{served} "
        f"({misses/max(served, 1):.1%})"
    )
    # windowed signals + SLO verdict: the evolving view (trailing-window
    # counter deltas from the per-tick time series), not lifetime means,
    # plus the multi-window burn-rate judgement over the same series
    health = engine.health()
    ts = engine.timeseries
    win_s = 1.0
    print(
        f"  windowed ({win_s:.0f}s): miss-rate "
        f"{engine.windowed_miss_rate(win_s):.1%} | "
        f"{ts.rate('engine.episode.events', win_s):.0f} events/s | "
        f"{ts.rate('engine.tick.dispatch_s.count', win_s):.1f} ticks/s "
        f"({len(ts)} samples over {ts.span_s():.2f}s)"
    )
    fired = [
        f"{s['name']}:{s['status']}"
        for s in health["slos"] if s["status"] != "healthy"
    ]
    print(
        f"  health: {health['status'].upper()}"
        + (f" ({', '.join(fired)})" if fired else "")
        + f" — {len(health['slos'])} SLOs, burn-rate rules over "
        f"{health['span_s']:.2f}s of samples"
    )
    diag = health["diagnosis"]
    print(f"  diagnosis: {diag['verdict'].upper()} — {diag['hint']}")
    if admission is not None or injector is not None or n_shed or n_quar:
        print(
            f"  fault plane: shed {n_shed} "
            f"({engine.shed_rate():.1%} of submitted) | parked served "
            f"{int(sum(r.parked for r in ok))} | quarantined {n_quar} | "
            f"injected "
            f"{int(snap['engine.faults.injected']['value'])} | retries "
            f"{int(snap['engine.faults.chunk_retries']['value'])} | "
            f"demotions "
            f"{int(snap['engine.faults.backend_demoted']['value'])}"
        )
    print(
        f"  measured energy/inference: mean {en['mean']/1e3:.1f} nJ, "
        f"p99 {en['p99']/1e3:.1f} nJ (model estimate from counted events)"
    )
    tb = engine.tick_breakdown()
    print(
        f"  tick breakdown (pipeline_depth={tb['pipeline_depth']}, "
        f"{tb['ticks']} ticks): host prep {tb['host_prep_us']:.0f} us | "
        f"dispatch {tb['dispatch_us']:.0f} us "
        f"(p99 {tb['dispatch_p99_us']:.0f} us) | "
        f"stats fetch {tb['stats_fetch_us']:.0f} us "
        f"(spike trains stay device-resident; the fetch is the tick's "
        f"only host transfer)"
    )
    if args.metrics_json:
        engine.metrics.write_json(args.metrics_json)
        print(f"  metrics snapshot -> {args.metrics_json}")
    if args.trace_out:
        engine.export_trace(args.trace_out)
        print(
            f"  chrome trace ({len(engine.trace)} spans) -> "
            f"{args.trace_out} (load in ui.perfetto.dev)"
        )
    if args.timeseries_out:
        engine.timeseries.write_jsonl(args.timeseries_out)
        print(
            f"  time series ({len(engine.timeseries)} samples) -> "
            f"{args.timeseries_out}"
        )
    if profile is not None:
        if profile.error:
            print(f"  jax.profiler capture FAILED: {profile.error}")
        else:
            print(
                f"  jax.profiler capture ({args.profile_ticks} "
                f"steady-state ticks) -> {args.profile_dir}"
            )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant", default=None, choices=[None, "q115"])
    # streaming SNN mode
    ap.add_argument("--snn", action="store_true",
                    help="serve the event-driven SNN instead of an LM")
    ap.add_argument("--dvs", action="store_true",
                    help="synthetic DVS event-camera input (with --snn)")
    ap.add_argument("--polarity", default="two_channel",
                    choices=["two_channel", "signed", "on_only"],
                    help="DVS ON/OFF event mapping onto the input layer")
    ap.add_argument("--image-hw", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--num-steps", type=int, default=25)
    ap.add_argument("--chunk-steps", type=int, default=5)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate in req/s "
                         "(0 = closed-loop batch, with --snn)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request latency budget in ms "
                         "(0 = no deadline, with --snn)")
    ap.add_argument("--snn-backend", default="auto",
                    choices=["auto", "jnp", "fused"],
                    help="chunk hot path: fused Pallas kernel, jnp "
                         "oracle, or auto (fused on TPU)")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="synchronous ticks (disable the one-deep "
                         "stats-future pipeline; debugging aid)")
    # fault tolerance (with --snn)
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the admission queue at N (overflow "
                         "sheds priority-0 requests, parks higher "
                         "priorities; 0 = unbounded)")
    ap.add_argument("--shed", action="store_true",
                    help="EDF feasibility shedding: reject requests "
                         "whose deadline is provably unmeetable at the "
                         "measured tick rate")
    ap.add_argument("--drain-timeout", type=float, default=0.0,
                    help="closed-loop drain timeout in seconds; on "
                         "expiry print the per-slot stuck diagnostic "
                         "instead of hanging (0 = wait forever)")
    ap.add_argument("--inject-faults", type=int, default=0,
                    help="chaos mode: inject N seeded faults (NaN "
                         "membranes, corrupted rings, transient chunk "
                         "exceptions) during the run")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for --inject-faults schedules")
    # crash safety / preemption (with --snn)
    ap.add_argument("--snapshot-dir", default=None,
                    help="directory for rotating engine snapshots "
                         "(atomic snap_* dirs, keep-3)")
    ap.add_argument("--snapshot-every", type=float, default=0.0,
                    help="snapshot cadence in seconds during the serve "
                         "loop (0 = never; requires --snapshot-dir)")
    ap.add_argument("--restore", action="store_true",
                    help="warm-restart from the latest intact snapshot "
                         "under --snapshot-dir before serving (corrupt "
                         "snapshots are skipped with a fallback)")
    ap.add_argument("--preempt", action="store_true",
                    help="deadline-aware slot preemption: a tighter-"
                         "deadline arrival with no free slot parks the "
                         "loosest resident window and resumes it later, "
                         "bit-exactly")
    # observability (with --snn)
    ap.add_argument("--metrics-json", default=None,
                    help="write the engine's metrics-registry snapshot "
                         "(counters/gauges/histograms) to this path")
    ap.add_argument("--trace-out", default=None,
                    help="write per-request + per-tick-phase spans as "
                         "Chrome trace-event JSON (Perfetto-loadable)")
    ap.add_argument("--timeseries-out", default=None,
                    help="write the per-tick time series (counter "
                         "deltas, windowed rates) as JSONL")
    ap.add_argument("--profile-ticks", type=int, default=0,
                    help="capture a jax.profiler trace around N "
                         "steady-state ticks (0 = off)")
    ap.add_argument("--profile-dir", default="/tmp/snn-jax-profile",
                    help="output directory for --profile-ticks")
    args = ap.parse_args(argv)

    if args.snn:
        _serve_snn(args)
    else:
        _serve_lm(args)


if __name__ == "__main__":
    main()
