"""Serving launcher: batched generation for any arch (reduced on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
      --reduced --requests 8 --new-tokens 16 [--quant q115]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

import repro.configs as configs
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant", default=None, choices=[None, "q115"])
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch).reduced()
    if args.quant:
        cfg = dataclasses.replace(cfg, quant=args.quant)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params, batch_size=args.batch, cache_len=args.cache_len
    )
    rng = np.random.default_rng(0)

    def prompt():
        L = int(rng.integers(4, 24))
        if cfg.num_codebooks:
            return rng.integers(0, cfg.vocab_size, (L, cfg.num_codebooks)).astype(np.int32)
        return rng.integers(0, cfg.vocab_size, L).astype(np.int32)

    reqs = [
        Request(prompt=prompt(), max_new_tokens=args.new_tokens,
                temperature=args.temperature)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outs = engine.generate(reqs)
    dt = time.time() - t0
    n = sum(len(o) for o in outs)
    print(f"{args.arch}: served {len(reqs)} reqs / {n} tokens in {dt:.2f}s "
          f"({n/dt:.1f} tok/s on CPU, quant={cfg.quant})")


if __name__ == "__main__":
    main()
