"""Assigned input shapes and per-(arch x shape) input specs.

Four LM shapes (assignment):
  train_4k    : seq 4096,   global batch 256   -> train_step
  prefill_32k : seq 32768,  global batch 32    -> prefill
  decode_32k  : seq 32768,  global batch 128   -> serve_step (1 new token)
  long_500k   : seq 524288, global batch 1     -> serve_step; only runnable
                for sub-quadratic archs (SSM / hybrid / SWA) — skips are
                recorded, per DESIGN.md §5.

`input_specs(cfg, shape)` returns ShapeDtypeStruct pytrees plus logical
axes for every model input — weak-type-correct, shardable, no allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import CLIP_EMBED_DIM, Model

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def runnable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch, shape) cell."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "skip(full-attn): 500k decode needs sub-quadratic attention"
    return True, ""


def _token_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.num_codebooks:
        return (batch, seq, cfg.num_codebooks)
    return (batch, seq)


def batch_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStructs + logical axes for the given cell's inputs.

    Returns (kind, inputs, axes); `inputs` matches the signature of the
    lowered function's data argument(s).
    """
    sp = SHAPES[shape_name]
    B, L = sp.global_batch, sp.seq_len

    if sp.kind == "train":
        L_text = L - cfg.num_image_tokens
        tok = SDS(_token_shape(cfg, B, L_text), jnp.int32)
        inputs = {"tokens": tok, "targets": tok}
        axes = {
            "tokens": ("batch", "act_seq") + (("codebook",) if cfg.num_codebooks else ()),
            "targets": ("batch", "act_seq") + (("codebook",) if cfg.num_codebooks else ()),
        }
        if cfg.num_image_tokens:
            inputs["img_embeds"] = SDS(
                (B, cfg.num_image_tokens, CLIP_EMBED_DIM), jnp.bfloat16
            )
            axes["img_embeds"] = ("batch", "act_seq", "clip")
        return "train", inputs, axes

    if sp.kind == "prefill":
        L_text = L - cfg.num_image_tokens
        tok = SDS(_token_shape(cfg, B, L_text), jnp.int32)
        inputs = {"tokens": tok}
        axes = {
            "tokens": ("batch", "act_seq") + (("codebook",) if cfg.num_codebooks else ()),
        }
        if cfg.num_image_tokens:
            inputs["img_embeds"] = SDS(
                (B, cfg.num_image_tokens, CLIP_EMBED_DIM), jnp.bfloat16
            )
            axes["img_embeds"] = ("batch", "act_seq", "clip")
        return "prefill", inputs, axes

    # decode: one new token against a cache of length L
    tok = SDS(_token_shape(cfg, B, 1), jnp.int32)
    inputs = {
        "token": tok,
        "pos": SDS((B,), jnp.int32),
    }
    axes = {
        "token": ("batch", "act_seq") + (("codebook",) if cfg.num_codebooks else ()),
        "pos": ("batch",),
    }
    return "decode", inputs, axes


def abstract_cache(cfg: ModelConfig, shape_name: str):
    sp = SHAPES[shape_name]
    model = Model(cfg)
    return model.abstract_cache(sp.global_batch, sp.seq_len)
