"""Streaming SNN serving engine: device-resident spike trains, async
admission, deadline-aware scheduling, pipelined ticks.

The LM ``ServeEngine`` batches token sequences; spiking workloads stream
*time*: each request is a spike train (rate-coded image or DVS event
stream) that must be integrated over its coding window while the neuron
membranes persist between chunks.  The paper's case study — collision
avoidance — is a latency-critical, always-on workload, so the engine is an
*async* scheduler rather than a one-shot batch loop:

- **submit()/poll()/drain().** Requests arrive at any time, including
  while chunks are in flight.  ``submit`` enqueues (returning a request
  id); ``poll`` admits queued requests into free slots and advances every
  active slot by one chunk, returning whatever finished; ``drain`` polls
  until the engine is idle.  ``run(requests)`` survives as a thin
  batch-compatibility wrapper.
- **EDF admission.** Each request carries an optional relative
  ``deadline_s`` and an integer ``priority``.  The queue is ordered by
  (priority desc, earliest absolute deadline first, FIFO); every result
  reports its queue wait and whether its deadline was missed, and the
  engine tracks an episode-level miss rate.
- **Device-resident, event-compressed spike trains.** Admission uploads a
  request's input exactly once: images are rate-encoded *on device* (no
  host-side encode + re-upload), dense trains are event-compressed on
  device into a packed per-step AER table (int16 addresses, int8 signed
  values — ``events.aer.StepEventTable``) and staged into a per-slot ring
  buffer that lives in device memory for the request's whole lifetime.
  The jitted chunk function ``dynamic_slice``s each slot's next ``Tc``
  steps by its on-device ``done`` offset and feeds them straight to
  ``runtime.run_chunk_events`` — no per-chunk host assembly, no per-chunk
  H2D transfer, no re-extraction of layer-0 events.  At the collision
  config's autotuned capacity the staged table is a measured ~4.7x
  smaller than the dense float32 planes the pre-residency engine shipped
  every chunk (``BENCH_snn.json`` host_overhead.resident_chunk_bytes).
- **Pipelined ticks.** The chunk's per-slot scheduling metadata (``done``
  offsets, window lengths, admit flags) lives on device and is advanced
  *inside* the chunk, so a steady-state tick passes no host arrays at
  all; state and metadata buffers are donated.  Completion stats land in
  a one-deep future queue: chunk N+1 dispatches before chunk N's stats
  are fetched, overlapping host bookkeeping and the single D2H stats
  fetch with device compute (``pipeline_depth=0`` restores the
  synchronous tick for debugging).  Ticks whose dispatch completes a
  request's window retire eagerly, so completion — and the deadline
  verdict — never waits an extra poll round.  A steady mid-window tick
  performs exactly one host transfer — the stats fetch — which
  ``tests/test_snn_resident.py`` pins down under ``jax.transfer_guard``.
- **Slots.** A fixed micro-batch of ``num_slots`` concurrent requests
  shares one compiled event-driven chunk step.  Per-slot membrane +
  refractory state lives across chunks; slot shapes are static so nothing
  recompiles.  Slot turnover (zeroing state on admit) happens *inside*
  the jitted chunk function via a device-side admit flag.
- **Sharded slots.** Pass ``mesh=`` to shard the slot axis — states,
  rings, metadata and stats alike — over the mesh
  (``distributed.partitioning`` slot/ring rules + ``shard_map``), scaling
  ``num_slots`` past one device while keeping the single-compiled-chunk
  invariant and jnp/fused backend parity.
- **Measured energy.** Every chunk reports per-step, per-layer event
  counts.  A request's energy estimate is priced from the events it
  *actually* generated via ``core.energy.snn_ops_from_events`` — not from
  an assumed spike rate.
- **Observability.** The engine carries a ``repro.obs`` metrics registry
  (``engine.metrics``) and span recorder (``engine.trace``) instead of
  ad-hoc scalar accumulators: per-request latency / queue-wait / energy
  histograms, episode-scoped counters (events, steps, completions,
  deadline misses — reset when an episode opens, so nothing goes stale
  across episodes), per-tick phase histograms, and a span per request
  lifecycle stage (submit -> queue -> stage -> per-chunk ticks ->
  complete) plus per-tick host_prep / dispatch / stats_fetch phase spans.
  ``metrics_snapshot()`` exports JSON-able instrument state;
  ``export_trace(path)`` writes a Perfetto-loadable Chrome trace.  The
  recording cost is host-side only (the jitted chunk is untouched) and
  ``benchmarks/stream_bench.py`` pins it under 2% of a tick.
- **Time series + SLOs.** A ``TimeSeriesSampler`` (``engine.timeseries``)
  captures a registry delta on every tick and admission, turning the
  lifetime counters into *windowed* rates — events/s, ticks/s,
  ``windowed_miss_rate()`` — and ``health()`` judges the engine's SLO
  specs (deadline-miss error budget, p99 latency target; override via
  the ``slos=`` init arg) with multi-window burn-rate rules over that
  series, publishing ``healthy``/``degraded``/``breach`` as the
  ``engine.slo.status`` gauge.  These windowed signals are what the
  fleet/admission-plane work (ROADMAP item 1) sheds load against.
- **Fault tolerance** (``repro.faults``).  Pass ``admission=`` an
  ``AdmissionPolicy`` to enable load shedding: a bounded admission
  queue sheds (or parks, for ``priority > 0``) at ``submit()`` once
  full, and an EDF feasibility check at admission-pop time sheds
  requests whose deadline is provably unmeetable from the measured
  trailing-window tick rate — both surface as ``StreamResult``s with
  ``disposition="shed"`` instead of guaranteed misses.  With
  ``fault_checks=True`` (default) the chunk carries in-graph NaN/inf
  membrane checks, staged-ring count/address range checks, and a
  staging capacity-overflow check; a poisoned request is *quarantined*
  (``disposition="quarantined"`` + fault code, slot freed, state
  sanitized in-graph) while the other S-1 slots keep ticking
  bit-identically.  Chunk dispatch runs under a retry supervisor
  (capped exponential backoff) that permanently demotes
  ``backend="fused"`` to ``"jnp"`` after persistent failures — one
  ``RuntimeWarning``, counted in ``engine.faults.backend_demoted``.
  ``drain(timeout_s=...)`` raises ``EngineStallError`` with a
  per-slot diagnostic snapshot instead of looping forever on a wedged
  engine, and ``health()`` gains a ``diagnosis`` block separating
  "overloaded and shedding correctly" from "faulty".  A seeded
  ``faults.FaultInjector`` (``injector=``) drives the chaos suite in
  ``tests/test_faults.py`` and the bench's ``fault_tolerance`` block.
- **Crash-safe state.** ``snapshot(path)`` serializes the engine's
  *complete* serving state — per-slot membrane/refractory rows, packed
  AER rings, on-device scheduling metadata, host bookkeeping, the
  admission queue, parked requests, the preemption parking buffer, and
  undelivered results — through the checkpoint plane's atomic
  tmp-dir+rename+checksum discipline.  ``restore(path)`` on a freshly
  built engine (same params/config) resumes every in-flight window
  **bit-exactly**: float32 membranes and int8/int16 event tables round-
  trip through npz unchanged, so a warm-restarted engine's results are
  bit-identical to an uninterrupted run (``tests/test_recovery.py``).
  ``snapshot_auto``/``restore_latest_snapshot`` add a keep-N rotation
  with corrupt-snapshot fallback (checksum failure -> loud warning +
  ``engine.faults.checkpoint_fallback`` counter, previous snapshot
  restored).  Absolute wall-clock state (deadlines, submit times) is
  persisted as remaining-budget/ages and re-anchored at restore —
  ``perf_counter`` values are meaningless across processes.
- **Deadline-aware preemption** (``preempt=True``).  When a strictly
  tighter-urgency request arrives with every slot busy, the loosest
  resident window is *parked* — state rows, staged ring row, and
  accumulators move to a host-side parking buffer — the urgent window
  runs, and the parked window resumes from the exact step it stopped
  at (admit flag stays 0, so the chunk does not zero the restored
  membranes; mid-window park/restore is bit-exact).  Parking costs one
  D2H + one H2D of a single slot's rows, measured per event in
  ``engine.preempt.park_s`` / ``restore_s`` histograms and the
  ``engine.preempt.parked_events`` counter; ``health()`` flags
  ``preempt_thrash`` when the park rate outruns completions.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
import os
import shutil
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import (
    CheckpointCorruptError,
    gc_orphan_tmpdirs,
    load_array_dir,
    publish_array_dir,
)
from repro.core import coding, energy, neuron, snn
from repro.distributed import partitioning
from repro.events import aer, runtime
from repro.events import capacity as cap_mod
from repro.faults import shedding as shed_mod
from repro.faults.supervisor import ChunkSupervisor, RetryPolicy
from repro.obs import MetricsRegistry, TimeSeriesSampler, TraceRecorder
from repro.obs import slo as slo_mod

Array = jax.Array

# chunk fault bitmask (device-side detection -> host quarantine codes)
FAULT_NONFINITE_STATE = 1
FAULT_RING_CORRUPT = 2
FAULT_CAPACITY_OVERFLOW = 4
_FAULT_NAMES = {
    FAULT_NONFINITE_STATE: "nonfinite_state",
    FAULT_RING_CORRUPT: "ring_corrupt",
    FAULT_CAPACITY_OVERFLOW: "capacity_overflow",
}


def fault_code_names(code: int) -> str:
    """Human-readable ``+``-joined names of a chunk fault bitmask."""
    names = [n for bit, n in sorted(_FAULT_NAMES.items()) if code & bit]
    return "+".join(names) if names else f"unknown({code})"


class EngineStallError(RuntimeError):
    """``drain(timeout_s=...)`` expired with the engine not idle.

    ``snapshot`` is the per-slot diagnostic state at expiry
    (``SNNStreamEngine.stall_snapshot()``); ``results`` holds whatever
    completed before the stall.
    """

    def __init__(self, message: str, snapshot: Dict, results):
        super().__init__(message)
        self.snapshot = snapshot
        self.results = list(results)


@dataclasses.dataclass
class StreamRequest:
    """One inference over a spike stream.

    Provide either ``image`` ((K,) floats in [0,1], rate-encoded on the
    device at admission) or ``spikes`` ((T, K) pre-encoded train, e.g.
    densified DVS events; values must be integer-valued spike magnitudes
    in [-127, 127] — {0,1} rate/TTFS codes and {-1,0,1} DVS polarities
    all are — because trains are staged device-side as packed int8/int16
    AER event tables).

    ``deadline_s`` is relative to submission time; a request that finishes
    later is still served but reported (and counted) as missed.  Higher
    ``priority`` admits sooner; within a priority class admission is
    earliest-deadline-first, then FIFO (deadline-less requests last).
    """

    image: Optional[np.ndarray] = None
    spikes: Optional[np.ndarray] = None
    num_steps: Optional[int] = None  # None -> cfg.num_steps (must be >= 1)
    deadline_s: Optional[float] = None  # relative latency budget
    priority: int = 0


@dataclasses.dataclass
class StreamResult:
    request_id: int
    prediction: int
    spike_counts: np.ndarray  # (n_class,) output spike counts
    steps: int
    latency_s: float  # submit -> finish (includes queue wait)
    queue_wait_s: float  # submit -> admission into a slot
    events_per_layer: np.ndarray  # (n_layers,) measured input events
    spike_rate: float  # measured mean input rate of layer 0
    energy_pj: float  # priced from measured events
    deadline_s: Optional[float] = None  # the request's relative budget
    deadline_missed: bool = False
    # fault-tolerance dispositions: "ok" (served), "shed" (rejected by
    # the admission plane — never entered a slot), "quarantined"
    # (poisoned mid-flight; slot reset, stats discarded).  ``fault``
    # carries the shed reason or quarantine fault-code names; ``parked``
    # marks a priority request that was parked under overload and later
    # served best-effort.
    disposition: str = "ok"
    fault: Optional[str] = None
    parked: bool = False


def _doc_result(r: StreamResult) -> Dict:
    """JSON-able form of a StreamResult (snapshot manifest); the small
    per-class arrays ride in the manifest as lists."""
    return {
        "request_id": r.request_id,
        "prediction": r.prediction,
        "spike_counts": [float(x) for x in np.ravel(r.spike_counts)],
        "steps": r.steps,
        "latency_s": r.latency_s,
        "queue_wait_s": r.queue_wait_s,
        "events_per_layer": [
            float(x) for x in np.ravel(r.events_per_layer)
        ],
        "spike_rate": r.spike_rate,
        "energy_pj": r.energy_pj,
        "deadline_s": r.deadline_s,
        "deadline_missed": bool(r.deadline_missed),
        "disposition": r.disposition,
        "fault": r.fault,
        "parked": bool(r.parked),
    }


def _undoc_result(d: Dict) -> StreamResult:
    return StreamResult(
        request_id=d["request_id"],
        prediction=d["prediction"],
        spike_counts=np.asarray(d["spike_counts"], np.float64),
        steps=d["steps"],
        latency_s=d["latency_s"],
        queue_wait_s=d["queue_wait_s"],
        events_per_layer=np.asarray(d["events_per_layer"], np.float64),
        spike_rate=d["spike_rate"],
        energy_pj=d["energy_pj"],
        deadline_s=d["deadline_s"],
        deadline_missed=d["deadline_missed"],
        disposition=d["disposition"],
        fault=d["fault"],
        parked=d["parked"],
    )


class SNNStreamEngine:
    """Async-admission, deadline-aware scheduler over device-resident
    event rings and the event-driven SNN chunk runtime."""

    def __init__(
        self,
        params: Dict[str, Dict[str, Array]],
        cfg: snn.SNNConfig,
        *,
        num_slots: int = 8,
        chunk_steps: int = 5,
        seed: int = 0,
        backend: str = "auto",
        capacities: Optional[Sequence[int]] = None,
        mesh=None,
        pipeline_depth: int = 1,
        trace_capacity: int = 8192,
        timeseries_capacity: int = 4096,
        slos: Optional[Sequence] = None,
        admission: Optional[shed_mod.AdmissionPolicy] = None,
        fault_checks: bool = True,
        injector=None,
        retry: Optional[RetryPolicy] = None,
        preempt: bool = False,
    ):
        self.params = params
        self.cfg = cfg
        self.S = num_slots
        self.Tc = chunk_steps
        self._rng = jax.random.PRNGKey(seed)
        self.slos = (
            tuple(slos) if slos is not None else slo_mod.default_slos()
        )
        self._make_instruments(trace_capacity, timeseries_capacity)
        # prepare (fake-quantize) once at init — the original loop re-ran
        # the full weight-set quantization inside every chunk execution
        self._prepared = jax.device_put(runtime.prepare_params(params, cfg))
        self.backend = backend
        self.mesh = mesh
        self.pipeline_depth = max(0, int(pipeline_depth))
        self.capacities = (
            tuple(int(c) for c in capacities)
            if capacities is not None
            else None
        )
        # fault-tolerance plane: admission policy (None = historical
        # admit-everything behavior), in-graph fault checks, retry/
        # demotion supervisor, optional deterministic fault injector
        self.admission = admission
        self.fault_checks = bool(fault_checks)
        self.injector = injector
        # deadline-aware slot preemption (opt-in): a strictly tighter-
        # urgency arrival may park the loosest resident window
        self.preempt = bool(preempt)
        self._snap_index = 0  # snapshot_auto rotation counter
        self._backend_active = backend
        self._supervisor = ChunkSupervisor(
            retry or RetryPolicy(),
            on_retry=lambda n: self._m_retries.inc(n),
            on_demote=lambda: self._m_demoted.inc(),
        )
        # staged event-table geometry: layer-0 capacity bounds every
        # per-step event list; int16 addresses whenever fan-in fits
        self.C = cap_mod.input_capacity(cfg, self.capacities)
        self._addr_dtype = aer.addr_dtype_for(cfg.layer_sizes[0])
        self._ring_steps = max(int(cfg.num_steps), chunk_steps)

        self._chunk, self._chunk_nodonate = self._build_chunk(backend)
        # compile-site allowlist: one cold-start compile of the fresh
        # chunk; _grow_ring and demotion bump/reset it (known sites)
        self._chunk_compiles_expected = 1
        self._chunk_compiles_accounted = 0
        self._make_admit_fns()
        self._reset_all()

    def _build_chunk(self, backend: str):
        """Build (and jit) the tick chunk for ``backend``; returns the
        (donating, non-donating) pair.  Called at init and again by the
        supervisor's demotion path to rebuild the chunk on ``jnp`` after
        persistent fused failures."""
        cfg = self.cfg
        Tc, C = self.Tc, self.C
        K0 = cfg.layer_sizes[0]
        fault_checks = self.fault_checks
        capacities = self.capacities
        mesh, num_slots = self.mesh, self.S

        def _chunk_fn(prepared, states, ring, meta):
            # scheduling metadata lives on device: per-slot consumed-step
            # offsets, window lengths, and admit flags.  take/active are
            # derived here, and ``done`` advances in-graph, so a
            # steady-state tick uploads nothing.
            done, total, admit = meta["done"], meta["total"], meta["admit"]
            fault_in = meta["fault"]
            take = jnp.clip(total - done, 0, Tc)
            act = (take > 0).astype(jnp.float32)
            # in-jit slot turnover: slots admitted since the previous
            # chunk start from zeroed membrane/refractory state here,
            # inside the compiled function
            fresh = admit[:, None] > 0
            states = [
                neuron.NeuronState(
                    u=jnp.where(fresh, 0.0, st.u),
                    refrac=jnp.where(fresh, 0, st.refrac),
                )
                for st in states
            ]
            # each slot's next Tc steps, sliced from its resident ring
            # (slot-major (S, Tc, C) — consumed transpose-free)
            a_c = jax.vmap(
                lambda r, d: jax.lax.dynamic_slice(r, (d, 0), (Tc, C))
            )(ring["addrs"], done)
            v_c = jax.vmap(
                lambda r, d: jax.lax.dynamic_slice(r, (d, 0), (Tc, C))
            )(ring["values"], done)
            c_c = jax.vmap(
                lambda r, d: jax.lax.dynamic_slice(r, (d,), (Tc,))
            )(ring["counts"], done)
            # silence steps past the request's window: the ring beyond a
            # request's T steps holds a previous occupant's stale events,
            # and the final ragged chunk of a window slices into it
            # (shapes broadcast from ``take`` so the same body runs on a
            # shard_map-local slot block)
            in_window = (
                jnp.arange(Tc, dtype=jnp.int32)[None, :] < take[:, None]
            )
            values = jnp.where(
                in_window[:, :, None], v_c.astype(jnp.float32), 0.0
            )
            counts = jnp.where(in_window, c_c, 0)
            new_states, out_mem, out_spikes, events = (
                runtime.run_chunk_events(
                    prepared,
                    states,
                    a_c.astype(jnp.int32),
                    values,
                    counts,
                    cfg,
                    active=act,
                    capacities=capacities,
                    prepared=True,
                    backend=backend,
                    layout="slot_major",
                )
            )
            # in-graph fault detection: per-slot bitmask riding the same
            # stats pytree (so quarantine costs zero extra transfers).
            # Detection is masked to the request's own window — stale
            # ring contents past ``take`` can't false-positive — and
            # faulted slots' state is sanitized to zero in-graph
            # (jnp.where is a bit-exact no-op for clean slots), so a
            # poisoned slot self-heals while its host-side quarantine
            # is in flight and never contaminates a later occupant.
            fault = fault_in
            if fault_checks:
                bad_state = jnp.zeros(done.shape, bool)
                for st in new_states:
                    bad_state = bad_state | jnp.any(
                        ~jnp.isfinite(st.u), axis=-1
                    )
                bad_count = jnp.any(
                    (counts < 0) | (counts > C), axis=-1
                )
                ev_valid = in_window[:, :, None] & (
                    jnp.arange(C, dtype=jnp.int32)[None, None, :]
                    < jnp.clip(counts, 0, C)[:, :, None]
                )
                a32 = a_c.astype(jnp.int32)
                bad_addr = jnp.any(
                    ev_valid & ((a32 < 0) | (a32 >= K0)), axis=(1, 2)
                )
                fault = (
                    fault
                    | jnp.where(bad_state, 1, 0).astype(jnp.int32)
                    | jnp.where(bad_count | bad_addr, 2, 0).astype(
                        jnp.int32
                    )
                )
                poisoned = (fault > 0)[:, None]
                new_states = [
                    neuron.NeuronState(
                        u=jnp.where(poisoned, 0.0, st.u),
                        refrac=jnp.where(poisoned, 0, st.refrac),
                    )
                    for st in new_states
                ]
            # per-slot stats accumulate on device; only the request's own
            # steps (take per slot) count toward its result
            m = (
                jnp.arange(Tc, dtype=jnp.int32)[:, None] < take[None, :]
            ).astype(jnp.float32)
            stats = {
                "counts": jnp.sum(out_spikes * m[:, :, None], axis=0),
                "memsum": jnp.sum(out_mem * m[:, :, None], axis=0),
                "events": jnp.sum(events * m[:, None, :], axis=0).T,
                "fault": fault,
            }
            new_meta = {
                "done": done + take,
                "total": total,
                "admit": jnp.zeros_like(admit),
                # fault codes report exactly once: staged overflow bits
                # surface in this chunk's stats, then clear
                "fault": jnp.zeros_like(fault_in),
            }
            return new_states, new_meta, stats

        if mesh is None:
            body = _chunk_fn
        else:
            body = self._shard_over_slots(_chunk_fn, mesh, num_slots)
        # states + metadata are donated: the tick loop threads them
        # through the compiled chunk without ever copying them back out
        return jax.jit(body, donate_argnums=(1, 3)), jax.jit(body)

    @staticmethod
    def _shard_over_slots(chunk_fn, mesh, num_slots: int):
        """Wrap the chunk function in shard_map with the slot axis split
        over the mesh's batch axes (``distributed.partitioning`` slot and
        ring rules).

        Params are replicated; states, event rings, scheduling metadata
        and stats all shard along slots (a ``P(slot)`` pytree prefix —
        rings keep their ring_steps/event_cap dims local to the slot's
        shard).  The chunk body is elementwise over slots, so sharding is
        exact — jnp/fused parity and the single-compiled-chunk invariant
        carry over unchanged.
        """
        slot = partitioning.slot_axis(num_slots, mesh)
        return partitioning.shard_map_unchecked(
            chunk_fn,
            mesh,
            # (params, states, ring, meta) — P(slot) prefixes shard the
            # leading slot axis of every states/ring/meta leaf
            in_specs=(P(), P(slot), P(slot), P(slot)),
            out_specs=(P(slot), P(slot), P(slot)),
        )

    # ------------------------------------------------- device admission
    def _make_admit_fns(self):
        """Jitted staging: encode + compress a request's train on device
        and write it into the slot's ring, updating device metadata.

        Ring and metadata buffers are donated — each admission rewrites
        them in place (device-side), costing one small H2D upload (the
        train or the raw image) and zero host round-trips.
        """
        C = self.C
        adt = self._addr_dtype
        fault_checks = self.fault_checks

        def stage(ring, meta, train, slot):
            T = train.shape[0]
            table = runtime.encode_step_table(train, C, addr_dtype=adt)
            ring = {
                "addrs": jax.lax.dynamic_update_slice(
                    ring["addrs"], table.addrs[None], (slot, 0, 0)
                ),
                "values": jax.lax.dynamic_update_slice(
                    ring["values"], table.values[None], (slot, 0, 0)
                ),
                "counts": jax.lax.dynamic_update_slice(
                    ring["counts"], table.counts[None], (slot, 0)
                ),
            }
            if fault_checks:
                # capacity overflow: a step with more nonzero inputs
                # than the layer-0 capacity C would be *silently
                # truncated* by the packed table — flag the slot so the
                # first chunk quarantines it instead of serving a
                # wrong-by-construction result
                nnz = jnp.sum(train != 0.0, axis=-1)
                fcode = jnp.where(
                    jnp.any(nnz > C), FAULT_CAPACITY_OVERFLOW, 0
                ).astype(jnp.int32)
            else:
                fcode = jnp.int32(0)
            meta = {
                "done": meta["done"].at[slot].set(0),
                "total": meta["total"].at[slot].set(T),
                "admit": meta["admit"].at[slot].set(1),
                "fault": meta["fault"].at[slot].set(fcode),
            }
            return ring, meta

        def admit_spikes(ring, meta, train, slot):
            return stage(ring, meta, train, slot)

        def admit_image(ring, meta, image, key, slot, T):
            # rate-encode on device: the image is the only upload; the
            # dense (T, K) train never exists host-side at all
            train = coding.rate_encode(key, image, T)
            return stage(ring, meta, train, slot)

        self._admit_spikes_fn = jax.jit(
            admit_spikes, donate_argnums=(0, 1)
        )
        self._admit_image_fn = jax.jit(
            admit_image, donate_argnums=(0, 1), static_argnames=("T",)
        )

    def _alloc_ring(self, ring_steps: int) -> Dict[str, Array]:
        # Tc steps of zero padding keep the chunk's dynamic_slice
        # in-bounds (never offset-clamped) at every done offset in
        # [0, ring_steps]
        S, Tc, C = self.S, self.Tc, self.C
        R = ring_steps + Tc
        return {
            "addrs": jnp.zeros((S, R, C), self._addr_dtype),
            "values": jnp.zeros((S, R, C), jnp.int8),
            "counts": jnp.zeros((S, R), jnp.int32),
        }

    def _grow_ring(self, T: int) -> None:
        """Grow the rings to hold a T-step train (T > current capacity).

        One-time reallocation + device-side copy; other slots' staged
        trains survive.  The chunk function recompiles once for the new
        ring shape (shapes are static thereafter).
        """
        old, r_old = self._ring, self._ring_steps + self.Tc
        self._ring_steps = int(T)
        new = self._alloc_ring(self._ring_steps)
        self._ring = {
            k: new[k].at[:, :r_old].set(old[k]) for k in new
        }
        # a larger ring is a new chunk input shape: one more compile is
        # a known site, not a steady-state recompile
        self._chunk_compiles_expected += 1

    # ----------------------------------------------------- observability
    def _make_instruments(
        self, trace_capacity: int, timeseries_capacity: int
    ) -> None:
        """Create the engine's metrics registry, span recorder, and
        windowed time-series sampler.

        Episode-scoped counters live under ``engine.episode.`` and reset
        when an episode opens (first submit on an idle engine); request
        histograms and tick-phase histograms are engine-lifetime (reset
        them explicitly via ``metrics.reset(prefix=...)`` or
        ``reset_tick_stats``).  The sampler captures a registry delta
        on every tick and every admission (bounded ring; restart it via
        ``timeseries.restart()`` after warmup) — the signal ``health()``
        evaluates the engine's SLOs against.
        """
        self.metrics = MetricsRegistry()
        self.trace = TraceRecorder(capacity=trace_capacity)
        m = self.metrics
        # episode-scoped (reset at _begin_episode)
        self._m_events = m.counter("engine.episode.events")
        self._m_steps = m.counter("engine.episode.steps")
        self._m_completed = m.counter("engine.episode.completed")
        self._m_misses = m.counter("engine.episode.deadline_misses")
        self._m_wall = m.gauge("engine.episode.wall_s")
        # engine-lifetime request instruments
        self._m_submitted = m.counter("engine.requests.submitted")
        self._m_finished = m.counter("engine.requests.completed")
        self._m_missed_total = m.counter("engine.requests.deadline_missed")
        self._m_latency = m.histogram(
            "engine.request.latency_s", lo=1e-6, hi=1e3
        )
        self._m_qwait = m.histogram(
            "engine.request.queue_wait_s", lo=1e-6, hi=1e3
        )
        self._m_energy = m.histogram(
            "engine.request.energy_pj", lo=1.0, hi=1e12
        )
        # tick-phase timing (reset via reset_tick_stats)
        self._m_prep = m.histogram(
            "engine.tick.host_prep_s", lo=1e-7, hi=10.0
        )
        self._m_dispatch = m.histogram(
            "engine.tick.dispatch_s", lo=1e-7, hi=10.0
        )
        self._m_fetch = m.histogram(
            "engine.tick.stats_fetch_s", lo=1e-7, hi=10.0
        )
        self._m_qdepth = m.gauge("engine.queue.depth")
        self._m_active = m.gauge("engine.slots.active")
        # fault-tolerance instruments: admission-plane dispositions
        # (lifetime), chunk-supervisor events, injector applications,
        # and the episode-scoped exclusion counters events_per_sec()
        # subtracts so quarantined work never inflates throughput
        self._m_shed = m.counter("engine.requests.shed")
        self._m_parked_total = m.counter("engine.requests.parked")
        self._m_quarantined = m.counter("engine.requests.quarantined")
        self._m_retries = m.counter("engine.faults.chunk_retries")
        self._m_demoted = m.counter("engine.faults.backend_demoted")
        self._m_injected = m.counter("engine.faults.injected")
        # steady-state recompiles: chunk compile-cache growth beyond the
        # allowlisted sites (cold start, ring growth, demotion rebuild);
        # any increment means a shape-unstable dispatch path
        self._m_recompiles = m.counter("engine.tick.recompiles")
        self._m_q_events = m.counter("engine.episode.quarantined_events")
        self._m_q_steps = m.counter("engine.episode.quarantined_steps")
        self._m_parked_depth = m.gauge("engine.queue.parked")
        # crash-safety + preemption plane: snapshot/restore timing, the
        # corrupt-checkpoint fallback counter restore_latest_snapshot()
        # bumps, and parking-buffer traffic (park/restore cost per slot
        # in the histograms; parked_events gives the per-event divisor)
        self._m_snap_time = m.histogram(
            "engine.snapshot.save_s", lo=1e-6, hi=100.0
        )
        self._m_restore_snap_time = m.histogram(
            "engine.snapshot.restore_s", lo=1e-6, hi=100.0
        )
        self._m_ckpt_fallback = m.counter(
            "engine.faults.checkpoint_fallback"
        )
        self._m_preempt_parked = m.counter("engine.preempt.parked")
        self._m_preempt_resumed = m.counter("engine.preempt.resumed")
        self._m_preempt_events = m.counter("engine.preempt.parked_events")
        self._m_preempt_depth = m.gauge("engine.preempt.buffer_depth")
        self._m_park_time = m.histogram(
            "engine.preempt.park_s", lo=1e-7, hi=10.0
        )
        self._m_restore_time = m.histogram(
            "engine.preempt.restore_s", lo=1e-7, hi=10.0
        )
        # SLO verdict gauge (0 healthy / 1 degraded / 2 breach), written
        # by health(); readable in any snapshot without re-evaluating
        self._m_health = m.gauge("engine.slo.status")
        # windowed time series over the registry: per-tick + per-submit
        # samples; latency buckets tracked so windowed p99 (and the
        # latency SLO's fraction-over-target) reconstructs from diffs
        self.timeseries = TimeSeriesSampler(
            self.metrics,
            capacity=timeseries_capacity,
            track_buckets=("engine.request.latency_s",),
        )

    def metrics_snapshot(self) -> Dict[str, Dict]:
        """JSON-able snapshot of every engine instrument."""
        return self.metrics.snapshot()

    def export_trace(self, path) -> None:
        """Write the recorded spans as Chrome trace-event JSON
        (Perfetto-loadable)."""
        self.trace.write(path)

    def health(self) -> Dict:
        """Evaluate the engine's SLOs (multi-window burn rates over the
        time-series sampler) and publish the verdict as the
        ``engine.slo.status`` gauge.  Returns the JSON-able report:
        ``status`` is ``healthy`` / ``degraded`` / ``breach``, ``slos``
        carries per-SLO windowed error rates and per-rule burn rates."""
        report = slo_mod.evaluate(self.slos, self.timeseries)
        self._m_health.set(report["status_code"])
        report["diagnosis"] = self._diagnose(report)
        return report

    def _diagnose(self, report: Dict) -> Dict:
        """Separate *why* the SLO verdict is what it is, so an operator
        (or the serve launcher) acts on the cause, not the symptom:

        - ``faulty`` — quarantines, backend demotions, or dispatch
          retries happened: fix the fault before touching capacity.
        - ``overloaded`` — SLOs unhappy *and* the admission plane is
          actively shedding: the engine is protecting itself correctly;
          add capacity or tighten admission.
        - ``breaching`` — SLOs unhappy with no shedding and no faults:
          deadlines are simply unserveable at current throughput (or no
          admission policy is installed to shed the hopeless tail).
        - ``nominal`` — healthy.
        """
        quarantined = self._m_quarantined.value
        demoted = self._m_demoted.value
        retries = self._m_retries.value
        shed = self._m_shed.value
        recompiles = int(self._m_recompiles.value)
        window = self.timeseries.ratio(
            "engine.requests.shed", "engine.requests.submitted", 10.0
        )
        unhappy = report["status"] != "healthy"
        if quarantined > 0 or demoted > 0 or retries > 0:
            verdict = "faulty"
            hint = (
                "fault path active (quarantines/demotions/retries): "
                "inspect fault_events and engine.faults.* counters "
                "before scaling anything"
            )
        elif unhappy and shed > 0:
            verdict = "overloaded"
            hint = (
                "SLO pressure with active load shedding: the admission "
                "plane is degrading correctly — add capacity (slots/"
                "hosts) or lower the offered rate"
            )
        elif unhappy:
            verdict = "breaching"
            hint = (
                "SLO pressure with no shedding and no faults: deadlines "
                "exceed serving capacity — enable an AdmissionPolicy or "
                "relax deadline targets"
            )
        else:
            verdict = "nominal"
            hint = "no action needed"
        if recompiles > 0:
            hint += (
                "; WARNING: steady-state chunk recompiles observed "
                f"({recompiles}) — a dispatch path is shape-unstable "
                "(every compile stalls serving for the full trace+compile)"
            )
        # preemption thrash: windows are being swapped in and out faster
        # than any of them completes — the engine is busy moving state,
        # not integrating spikes
        park_rate = self.timeseries.rate("engine.preempt.parked", 10.0)
        done_rate = self.timeseries.rate("engine.requests.completed", 10.0)
        thrash = park_rate > 0.0 and park_rate > done_rate
        if thrash:
            hint += (
                "; preempt_thrash: park/restore rate exceeds the "
                "completion rate — preemption is swapping slot state "
                "faster than windows finish (add slots, damp priority "
                "spread, or loosen deadlines)"
            )
        return {
            "verdict": verdict,
            "hint": hint,
            "recompiling": recompiles > 0,
            "steady_state_recompiles": recompiles,
            "shed_total": shed,
            "windowed_shed_rate": window,
            "parked_depth": len(self._parked),
            "preempt_thrash": thrash,
            "preempt_parked_depth": len(self._preempt_parked),
            "preempt_park_rate": park_rate,
            "quarantined_total": quarantined,
            "backend_demotions": demoted,
            "chunk_retries": retries,
            "backend": self._backend_active,
        }

    def windowed_miss_rate(self, window_s: Optional[float] = 1.0) -> float:
        """Deadline-miss fraction of completions over the trailing
        window (whole series when ``window_s`` is None) — the evolving
        signal, vs ``deadline_miss_rate()``'s episode-lifetime average."""
        return self.timeseries.ratio(
            "engine.requests.deadline_missed",
            "engine.requests.completed",
            window_s,
        )

    # ------------------------------------------------------------- state
    def _reset_all(self) -> None:
        cfg, S = self.cfg, self.S
        self._states = runtime.init_states(cfg, S)
        self._ring = self._alloc_ring(self._ring_steps)
        self._meta = {
            "done": jnp.zeros((S,), jnp.int32),
            "total": jnp.zeros((S,), jnp.int32),
            "admit": jnp.zeros((S,), jnp.int32),
            "fault": jnp.zeros((S,), jnp.int32),
        }
        self._slot_req = [None] * S  # request id per slot
        self._slot_parked = [False] * S  # admitted from the parked list
        self._slot_done = np.zeros(S, np.int64)  # steps dispatched
        self._slot_retired = np.zeros(S, np.int64)  # steps stats-retired
        self._slot_total = np.zeros(S, np.int64)
        self._slot_submit_t = np.zeros(S, np.float64)
        self._slot_admit_t = np.zeros(S, np.float64)
        self._slot_deadline: List[Optional[float]] = [None] * S  # absolute
        self._slot_rel_deadline: List[Optional[float]] = [None] * S
        self._slot_priority = np.zeros(S, np.int64)
        self._slot_counts = np.zeros((S, cfg.layer_sizes[-1]), np.float64)
        self._slot_memsum = np.zeros((S, cfg.layer_sizes[-1]), np.float64)
        self._slot_events = np.zeros((S, cfg.num_layers), np.float64)
        # one-deep stats-future pipeline: (stats device pytree,
        # per-slot take snapshot, per-slot request-id snapshot)
        self._inflight: "collections.deque[Tuple]" = collections.deque()
        self._queue: List[tuple] = []  # heap: (key, rid, req, t_sub, dl)
        # fault-tolerance plane: parked priority requests (FIFO, served
        # best-effort when the heap empties), shed/quarantined results
        # awaiting delivery by poll(), the quarantine log (joined by the
        # bench's recovery-ticks metric), and the tick index the log and
        # injector schedules are expressed in
        self._parked: "collections.deque[tuple]" = collections.deque()
        # preemption parking buffer: host-side records of displaced
        # mid-window slots (state rows + ring row + accumulators),
        # resumed by _fill_slot in urgency order
        self._preempt_parked: List[Dict] = []
        self._pending_results: List[StreamResult] = []
        self.fault_events: List[Dict] = []
        self._tick_index = 0
        self._seq = 0
        self._next_rid = 0
        self._episode_open = False
        self._episode_t0 = 0.0
        self.metrics.reset(prefix="engine.episode.")
        self.metrics.reset(prefix="engine.tick.")

    def _begin_episode(self, now: float) -> None:
        # throughput + deadline counters are per-episode: an episode opens
        # at the first submit on an idle engine and closes when the last
        # queued request drains (see events_per_sec for the denominator).
        # wall_s resets here too — it used to survive from the previous
        # episode, so a mid-episode read mixed a stale denominator with
        # fresh numerators (tests/test_snn_engine.py pins the fix).
        self.metrics.reset(prefix="engine.episode.")
        self._episode_t0 = now
        self._episode_open = True

    # episode counters read straight from the registry; properties keep
    # the pre-obs attribute API (and make stray writes fail loudly)
    @property
    def total_events(self) -> float:
        return self._m_events.value

    @property
    def total_steps(self) -> int:
        return int(self._m_steps.value)

    @property
    def completed(self) -> int:
        return int(self._m_completed.value)

    @property
    def deadline_misses(self) -> int:
        return int(self._m_misses.value)

    @property
    def wall_s(self) -> float:
        return self._m_wall.value

    # --------------------------------------------------------- admission
    def _resolve_steps(self, req: StreamRequest) -> int:
        # explicit None check: ``req.num_steps or cfg.num_steps`` silently
        # treated num_steps=0 as unset
        T = (
            self.cfg.num_steps
            if req.num_steps is None
            else int(req.num_steps)
        )
        if T < 1:
            raise ValueError(f"num_steps must be >= 1, got {req.num_steps}")
        return T

    def submit(self, req: StreamRequest) -> int:
        """Enqueue one request; returns its request id.

        Admission happens at the next ``poll()``: free slots are filled in
        (priority desc, earliest deadline, FIFO) order, so a later submit
        with a tighter deadline overtakes queued work it never saw.
        """
        T = self._resolve_steps(req)
        K = self.cfg.layer_sizes[0]
        if req.spikes is not None:
            shape = tuple(np.shape(req.spikes))
            if shape != (T, K):
                raise ValueError(
                    f"request spikes shape {shape} != ({T}, {K})"
                )
            # staged device-side as int8 event values: trains must be
            # integer-valued spike magnitudes (all our encoders are)
            s = np.asarray(req.spikes)
            if not np.all(np.isfinite(s)):
                raise ValueError(
                    "request spikes contain NaN/inf — non-finite trains "
                    "are rejected at the admission boundary"
                )
            if not np.all((s == np.round(s)) & (np.abs(s) <= 127)):
                raise ValueError(
                    "request spikes must be integer-valued magnitudes in "
                    "[-127, 127] (e.g. {0,1} rate codes, {-1,0,1} DVS "
                    "polarities) — the train is staged as an int8 AER "
                    "event table"
                )
        elif req.image is not None:
            shape = tuple(np.shape(req.image))
            if shape != (K,):
                raise ValueError(f"request image shape {shape} != ({K},)")
            # contents matter, not just shape: a NaN pixel makes
            # rate_encode (uniform < NaN is always False) emit an
            # all-zero train — a silently wrong answer, not a crash —
            # so non-finite images are rejected here at the boundary
            # (tests/test_faults.py pins the silent-garbage failure)
            img = np.asarray(req.image)
            if not np.all(np.isfinite(img)):
                raise ValueError(
                    "request image contains NaN/inf — non-finite images "
                    "are rejected at the admission boundary"
                )
        else:
            raise ValueError("StreamRequest needs image or spikes")
        now = time.perf_counter()
        if not self._episode_open:
            self._begin_episode(now)
        rid = self._next_rid
        self._next_rid += 1
        dl = now + req.deadline_s if req.deadline_s is not None else None
        self._m_submitted.inc()
        if self.admission is not None:
            verdict, reason = shed_mod.backpressure(
                self.admission,
                queue_depth=len(self._queue),
                parked_depth=len(self._parked),
                priority=req.priority,
            )
            if verdict == shed_mod.SHED:
                self._shed(rid, req, now, dl, reason)
                self.timeseries.sample()
                return rid
            if verdict == shed_mod.PARK:
                self._park(rid, req, now, dl, reason)
                self.timeseries.sample()
                return rid
        key = (
            -int(req.priority),
            0 if dl is not None else 1,  # deadline-less requests last
            dl if dl is not None else 0.0,
            self._seq,  # FIFO tiebreak; also keeps heap entries orderable
        )
        self._seq += 1
        heapq.heappush(self._queue, (key, rid, req, now, dl))
        self._m_qdepth.set(len(self._queue))
        self.trace.instant(
            "submit", now, track="queue",
            args={"rid": rid, "priority": req.priority},
        )
        # admission is a state change worth a time-series point (queue
        # depth, submitted counter) even between ticks
        self.timeseries.sample()
        return rid

    def _admit(
        self,
        s: int,
        rid: int,
        req: StreamRequest,
        t_submit: float,
        abs_deadline: Optional[float],
    ) -> None:
        T = self._resolve_steps(req)
        if T > self._ring_steps:
            self._grow_ring(T)
        t_stage = time.perf_counter()
        # every admission upload is *explicit* (device_put), so the whole
        # serving loop — not just steady-state ticks — runs clean under
        # jax.transfer_guard("disallow")
        slot = jax.device_put(np.int32(s))
        if req.spikes is not None:
            # single explicit upload of the (T, K) train; compression to
            # the packed event table happens on device
            train = jax.device_put(np.asarray(req.spikes, np.float32))
            self._ring, self._meta = self._admit_spikes_fn(
                self._ring, self._meta, train, slot
            )
        else:
            self._rng, k = jax.random.split(self._rng)
            img = jax.device_put(np.asarray(req.image, np.float32))
            self._ring, self._meta = self._admit_image_fn(
                self._ring, self._meta, img, k, slot, T=T
            )
        self._slot_req[s] = rid
        self._slot_done[s] = 0
        self._slot_retired[s] = 0
        self._slot_total[s] = T
        self._slot_submit_t[s] = t_submit
        self._slot_admit_t[s] = time.perf_counter()
        # lifecycle spans: time queued (submit -> stage start) on the
        # queue track, then the staging upload on the winning slot's
        # track; queue_wait_s keeps its pre-obs meaning (submit ->
        # admission complete, staging included)
        self.trace.span(
            "queue", t_submit, t_stage, track="queue",
            args={"rid": rid, "priority": req.priority},
        )
        self.trace.span(
            "stage", t_stage, self._slot_admit_t[s], track=f"slot{s}",
            args={"rid": rid, "steps": T},
        )
        self._m_qwait.record(self._slot_admit_t[s] - t_submit)
        self._slot_deadline[s] = abs_deadline
        self._slot_rel_deadline[s] = req.deadline_s
        self._slot_priority[s] = int(req.priority)
        self._slot_counts[s] = 0.0
        self._slot_memsum[s] = 0.0
        self._slot_events[s] = 0.0

    # --------------------------------------------------- admission plane
    def _void_result(
        self,
        rid: int,
        req: StreamRequest,
        t_submit: float,
        *,
        disposition: str,
        fault: Optional[str],
    ) -> StreamResult:
        """A result that carries a disposition instead of an inference:
        no prediction, no stats, no deadline verdict (the request was
        never served, so it neither met nor missed anything)."""
        cfg = self.cfg
        now = time.perf_counter()
        return StreamResult(
            request_id=rid,
            prediction=-1,
            spike_counts=np.zeros(cfg.layer_sizes[-1]),
            steps=self._resolve_steps(req),
            latency_s=now - t_submit,
            queue_wait_s=now - t_submit,
            events_per_layer=np.zeros(cfg.num_layers),
            spike_rate=0.0,
            energy_pj=0.0,
            deadline_s=req.deadline_s,
            deadline_missed=False,
            disposition=disposition,
            fault=fault,
        )

    def _shed(
        self,
        rid: int,
        req: StreamRequest,
        t_submit: float,
        abs_deadline: Optional[float],
        reason: str,
    ) -> None:
        self._m_shed.inc()
        self.trace.instant(
            "shed", time.perf_counter(), track="queue",
            args={"rid": rid, "reason": reason},
        )
        self._pending_results.append(self._void_result(
            rid, req, t_submit, disposition="shed", fault=reason
        ))

    def _park(
        self,
        rid: int,
        req: StreamRequest,
        t_submit: float,
        abs_deadline: Optional[float],
        reason: str,
    ) -> None:
        self._m_parked_total.inc()
        self._parked.append((rid, req, t_submit, abs_deadline))
        self._m_parked_depth.set(len(self._parked))
        self.trace.instant(
            "park", time.perf_counter(), track="queue",
            args={"rid": rid, "reason": reason},
        )

    def measured_ticks_per_s(
        self, window_s: Optional[float] = None
    ) -> float:
        """Tick throughput off the time-series sampler (trailing
        ``window_s``, falling back to the whole series when the window
        saw no flow) — the evidence the feasibility shedder converts
        into a completion-time lower bound.  0.0 on a cold engine."""
        key = "engine.tick.dispatch_s.count"
        r = self.timeseries.rate(key, window_s)
        if r <= 0.0:
            r = self.timeseries.rate(key, None)
        return r

    def _admission_verdict(
        self, req: StreamRequest, abs_deadline: Optional[float]
    ) -> Tuple[str, Optional[str]]:
        """Feasibility check when a queued request wins a free slot."""
        if self.admission is None or not self.admission.shed_unmeetable:
            return shed_mod.ADMIT, None
        return shed_mod.feasibility(
            self.admission,
            steps=self._resolve_steps(req),
            chunk_steps=self.Tc,
            deadline_abs=abs_deadline,
            now=time.perf_counter(),
            ticks_per_s=self.measured_ticks_per_s(
                self.admission.rate_window_s
            ),
            priority=req.priority,
        )

    # -------------------------------------------------------------- tick
    def _tick(self) -> List[int]:
        """One pipelined engine step: dispatch the next chunk (if any slot
        has steps left) and retire completed chunks' stats; returns the
        slots whose requests finished.

        A steady mid-window tick performs no H2D transfer — the chunk
        consumes only device-resident buffers — and exactly one D2H
        transfer, the explicit ``device_get`` of the retired chunk's
        reduced stats.  A tick whose dispatch completes some request's
        window drains the stats queue eagerly (trading that tick's
        overlap for the request's completion latency and an accurate
        deadline verdict).
        """
        S, Tc = self.S, self.Tc
        tick = self._tick_index
        self._tick_index += 1
        if self.injector is not None:
            applied = self.injector.begin_tick(self, tick)
            if applied:
                self._m_injected.inc(len(applied))
            if self.injector.stalled(tick):
                # injected stall: the tick makes no progress at all —
                # exactly the wedge drain(timeout_s=...) must survive
                return []
        t0 = time.perf_counter()
        take = np.zeros(S, np.int32)
        for s in range(S):
            if self._slot_req[s] is None:
                continue
            take[s] = min(
                Tc, int(self._slot_total[s]) - int(self._slot_done[s])
            )
        dispatched = bool(take.sum() > 0)
        t1 = time.perf_counter()
        if dispatched:
            self._states, self._meta, stats_dev = self._dispatch_chunk()
            self._slot_done += take
            self._inflight.append(
                (stats_dev, take.copy(), list(self._slot_req))
            )
            self._note_chunk_compiles()
        t2 = time.perf_counter()
        finished: List[int] = []
        # keep at most pipeline_depth chunks' stats in flight; when
        # nothing was dispatched, retire one anyway so poll() always
        # makes progress.  Eagerly drain when a request's *final* chunk
        # is in flight (all its steps dispatched, not yet retired): its
        # completion — and deadline verdict — should not wait one more
        # poll round.  Steady mid-window ticks keep the full overlap;
        # only finishing ticks synchronize.
        finishing = any(
            self._slot_req[s] is not None
            and self._slot_done[s] >= self._slot_total[s]
            and self._slot_retired[s] < self._slot_total[s]
            for s in range(S)
        )
        force = 0 if dispatched else min(1, len(self._inflight))
        while self._inflight and (
            len(self._inflight) > self.pipeline_depth or force or finishing
        ):
            force = 0
            finished.extend(self._retire())
        t3 = time.perf_counter()
        # tick-phase instruments: histograms keep exact sum/count (the
        # tick_breakdown means) plus tail percentiles; spans make queue
        # stalls and pipeline bubbles visible on the trace timeline
        self._m_prep.record(t1 - t0)
        self._m_dispatch.record(t2 - t1)
        self._m_fetch.record(t3 - t2)
        self._m_active.set(sum(r is not None for r in self._slot_req))
        self.trace.span("host_prep", t0, t1, track="tick")
        if dispatched:
            self.trace.span(
                "dispatch", t1, t2, track="tick",
                args={"steps": int(take.sum())},
            )
            for s in range(S):
                if take[s] > 0:
                    self.trace.span(
                        "chunk", t1, t2, track=f"slot{s}",
                        args={
                            "rid": self._slot_req[s],
                            "steps": int(take[s]),
                        },
                    )
        self.trace.span("stats_fetch", t2, t3, track="tick")
        return finished

    def _note_chunk_compiles(self) -> None:
        """Fold chunk compile-cache growth beyond the allowlisted sites
        (cold start, ring growth, demotion rebuild) into the
        ``engine.tick.recompiles`` counter — the repro-lint recompile
        contract (``repro.analysis.contracts.RecompileDetector`` wraps
        the same signal for tests/benchmarks)."""
        get = getattr(self._chunk, "_cache_size", None)
        if get is None:
            return
        try:
            size = int(get())
        except Exception:
            return
        extra = size - self._chunk_compiles_expected
        if extra > self._chunk_compiles_accounted:
            self._m_recompiles.inc(extra - self._chunk_compiles_accounted)
            self._chunk_compiles_accounted = extra

    def steady_state_recompiles(self) -> int:
        """Chunk recompiles beyond the known compile sites (lifetime);
        nonzero means some dispatch path is shape-unstable."""
        return int(self._m_recompiles.value)

    def _dispatch_chunk(self):
        """One supervised chunk dispatch: injected faults raise before
        the jitted call (so the donated states/meta buffers are still
        valid on retry), transient failures retry with capped backoff,
        and persistent fused failures demote the engine to the jnp
        reference chunk permanently (rebuilding the compiled pair) —
        see ``repro.faults.supervisor``."""
        def attempt():
            if self.injector is not None:
                self.injector.maybe_raise(self._backend_active)
            return self._chunk(
                self._prepared, self._states, self._ring, self._meta
            )

        def demote():
            self._backend_active = "jnp"
            self.backend = "jnp"
            self._chunk, self._chunk_nodonate = self._build_chunk("jnp")
            # fresh jit object: its cold-start compile is a known site
            self._chunk_compiles_expected = 1
            self._chunk_compiles_accounted = 0
            return attempt

        return self._supervisor.call(
            attempt,
            backend=self._backend_active,
            demote=demote if self._backend_active == "fused" else None,
        )

    def _retire(self) -> List[int]:
        """Fetch the oldest in-flight chunk's stats (the tick's single
        D2H transfer) and fold them into per-slot accumulators."""
        stats_dev, take, rids = self._inflight.popleft()
        stats = jax.device_get(stats_dev)
        fault = stats.get("fault")
        finished = []
        for s in range(self.S):
            if rids[s] is None or take[s] == 0:
                continue
            if self._slot_req[s] != rids[s]:
                continue  # slot was freed and re-admitted since dispatch
            if fault is not None and int(fault[s]) != 0:
                # poisoned slot: discard this chunk's stats (they may be
                # NaN), fail the request into a quarantined result, and
                # free the slot — the other S-1 slots fold normally and
                # the in-graph sanitization already cleaned the state
                self._quarantine(s, int(fault[s]))
                continue
            self._slot_counts[s] += stats["counts"][s]
            self._slot_memsum[s] += stats["memsum"][s]
            self._slot_events[s] += stats["events"][s]
            self._slot_retired[s] += int(take[s])
            self._m_events.inc(float(stats["events"][s].sum()))
            self._m_steps.inc(int(take[s]))
            if self._slot_retired[s] >= self._slot_total[s]:
                finished.append(s)
        return finished

    def _quarantine(self, s: int, code: int) -> None:
        """Fail slot ``s``'s request into a quarantined result and free
        the slot.  The request is *not* a completion: it leaves the
        completed/deadline-miss accounting untouched (documented
        denominator policy on ``deadline_miss_rate``), and the work it
        already folded is moved to the quarantined-exclusion counters so
        ``events_per_sec()`` stays honest."""
        rid = self._slot_req[s]
        names = fault_code_names(code)
        now = time.perf_counter()
        self._m_q_events.inc(float(self._slot_events[s].sum()))
        self._m_q_steps.inc(float(self._slot_retired[s]))
        self._m_quarantined.inc()
        self.fault_events.append({
            "tick": self._tick_index,
            "slot": s,
            "rid": rid,
            "code": code,
            "fault": names,
        })
        self.trace.instant(
            "quarantine", now, track=f"slot{s}",
            args={"rid": rid, "fault": names},
        )
        self._pending_results.append(StreamResult(
            request_id=rid,
            prediction=-1,
            spike_counts=np.zeros(self.cfg.layer_sizes[-1]),
            steps=int(self._slot_total[s]),
            latency_s=now - self._slot_submit_t[s],
            queue_wait_s=self._slot_admit_t[s] - self._slot_submit_t[s],
            events_per_layer=np.zeros(self.cfg.num_layers),
            spike_rate=0.0,
            energy_pj=0.0,
            deadline_s=self._slot_rel_deadline[s],
            deadline_missed=False,
            disposition="quarantined",
            fault=names,
            parked=self._slot_parked[s],
        ))
        self._slot_req[s] = None
        self._slot_parked[s] = False

    def _finalize(self, s: int) -> StreamResult:
        cfg = self.cfg
        T = int(self._slot_total[s])
        ev = self._slot_events[s].copy()
        oc = energy.snn_ops_from_events(
            cfg.layer_sizes, T, ev, neuron_kind=cfg.neuron_kind
        )
        counts = self._slot_counts[s]
        pred = int(np.argmax(counts + 1e-6 * self._slot_memsum[s]))
        finish_t = time.perf_counter()
        dl = self._slot_deadline[s]
        missed = dl is not None and finish_t > dl
        self._m_completed.inc()
        self._m_finished.inc()
        if missed:
            self._m_misses.inc()
            self._m_missed_total.inc()
        latency_s = finish_t - self._slot_submit_t[s]
        self._m_latency.record(latency_s)
        self._m_energy.record(oc.energy_pj())
        self.trace.instant(
            "complete", finish_t, track=f"slot{s}",
            args={
                "rid": self._slot_req[s],
                "latency_ms": latency_s * 1e3,
                "energy_pj": oc.energy_pj(),
                "deadline_missed": bool(missed),
            },
        )
        res = StreamResult(
            request_id=self._slot_req[s],
            prediction=pred,
            spike_counts=counts.copy(),
            steps=T,
            latency_s=latency_s,
            queue_wait_s=self._slot_admit_t[s] - self._slot_submit_t[s],
            events_per_layer=ev,
            spike_rate=float(ev[0] / (T * cfg.layer_sizes[0])),
            energy_pj=oc.energy_pj(),
            deadline_s=self._slot_rel_deadline[s],
            deadline_missed=missed,
            parked=self._slot_parked[s],
        )
        self._slot_req[s] = None
        self._slot_parked[s] = False
        return res

    # -------------------------------------------------------- preemption
    def _drain_inflight(self) -> None:
        """Retire every pipelined chunk's stats, finalizing any
        requests they complete into the pending-results buffer — the
        consistency point snapshot() and preemption parking require:
        afterwards ``_slot_retired == _slot_done`` for every resident
        slot, so parked/persisted host accumulators match the device
        state exactly."""
        while self._inflight:
            for s in self._retire():
                self._pending_results.append(self._finalize(s))

    def _slot_key(self, s: int):
        """Urgency key of slot ``s``'s resident request — comparable
        with the admission heap's key prefix (priority desc,
        deadline-less last, EDF)."""
        dl = self._slot_deadline[s]
        return (
            -int(self._slot_priority[s]),
            0 if dl is not None else 1,
            dl if dl is not None else 0.0,
        )

    def _best_preempt_key(self) -> Optional[Tuple]:
        """(key, index) of the most urgent preempt-parked window, or
        None when the parking buffer is empty."""
        best = None
        for i, rec in enumerate(self._preempt_parked):
            dl = rec["abs_deadline"]
            k = (
                -int(rec["priority"]),
                0 if dl is not None else 1,
                dl if dl is not None else 0.0,
            )
            if best is None or k < best[0]:
                best = (k, i)
        return best

    def _victim(self, head_key) -> Optional[int]:
        """The loosest-urgency resident slot *strictly* looser than
        ``head_key``, or None — an equal-urgency arrival never
        displaces a running window (ties would swap-thrash)."""
        worst, worst_key = None, None
        for s in range(self.S):
            if self._slot_req[s] is None:
                continue
            k = self._slot_key(s)
            if worst_key is None or k > worst_key:
                worst, worst_key = s, k
        if worst is None or not (head_key < worst_key):
            return None
        return worst

    def _maybe_preempt(self) -> None:
        """Park the loosest resident window when the queue head is
        strictly more urgent and no slot is free (``preempt=True``
        only).  At most one park per poll round — the freed slot is
        filled with the urgent request in the same round."""
        if not self.preempt or not self._queue:
            return
        if any(r is None for r in self._slot_req):
            return  # a free slot serves the arrival without displacement
        head_key = self._queue[0][0][:3]
        if self._victim(head_key) is None:
            return
        # retire pipelined stats before parking: retirement may complete
        # a slot outright (cheaper than a park/restore round trip), and
        # parking requires retired == done — a parked slot with a chunk
        # still in flight would silently drop that chunk's stats at
        # _retire()'s slot-reuse guard
        self._drain_inflight()
        if any(r is None for r in self._slot_req):
            return
        v = self._victim(head_key)
        if v is not None:
            self._park_slot(v)

    def _park_slot(self, s: int) -> None:
        """Preempt slot ``s``: move its membrane/refractory rows,
        staged ring row, scheduling metadata, and host accumulators
        into the parking buffer and free the slot.  Inverse of
        ``_resume_slot``; the round trip is bit-exact (float32/int8
        rows survive device_get/device_put unchanged).  Caller must
        have drained the stats pipeline first."""
        t0 = time.perf_counter()
        rid = self._slot_req[s]
        rec = {
            "rid": rid,
            "priority": int(self._slot_priority[s]),
            "done": int(self._slot_retired[s]),
            "total": int(self._slot_total[s]),
            "parked": bool(self._slot_parked[s]),
            "ring_steps": self._ring_steps,
            "rel_deadline": self._slot_rel_deadline[s],
            "abs_deadline": self._slot_deadline[s],
            "t_submit": float(self._slot_submit_t[s]),
            "t_admit": float(self._slot_admit_t[s]),
            "u": [
                np.asarray(jax.device_get(st.u[s])) for st in self._states
            ],
            "refrac": [
                np.asarray(jax.device_get(st.refrac[s]))
                for st in self._states
            ],
            "ring_addrs": np.asarray(
                jax.device_get(self._ring["addrs"][s])
            ),
            "ring_values": np.asarray(
                jax.device_get(self._ring["values"][s])
            ),
            "ring_counts": np.asarray(
                jax.device_get(self._ring["counts"][s])
            ),
            "counts": self._slot_counts[s].copy(),
            "memsum": self._slot_memsum[s].copy(),
            "events": self._slot_events[s].copy(),
        }
        self._preempt_parked.append(rec)
        # free the slot: total=0 makes the next chunk take nothing from
        # it; the stale device state is dead weight until overwritten
        self._meta = {
            "done": self._meta["done"].at[s].set(0),
            "total": self._meta["total"].at[s].set(0),
            "admit": self._meta["admit"].at[s].set(0),
            "fault": self._meta["fault"].at[s].set(0),
        }
        self._slot_req[s] = None
        self._slot_parked[s] = False
        t1 = time.perf_counter()
        self._m_preempt_parked.inc()
        self._m_preempt_events.inc(float(rec["events"].sum()))
        self._m_park_time.record(t1 - t0)
        self._m_preempt_depth.set(len(self._preempt_parked))
        self.trace.span(
            "park", t0, t1, track=f"slot{s}",
            args={"rid": rid, "done": rec["done"], "total": rec["total"]},
        )

    def _resume_slot(self, s: int, rec: Dict) -> None:
        """Admit a preempt-parked window into free slot ``s``,
        restoring its state/ring rows device-side.  The admit flag
        stays 0 — unlike fresh admission, the chunk must NOT zero the
        restored membranes — so the window continues from exactly the
        step it was parked at."""
        t0 = time.perf_counter()
        if rec["ring_steps"] > self._ring_steps:
            # the ring shrank relative to the record only across a
            # restore onto a smaller-ring engine; grow back so the
            # stored row fits (one allowlisted recompile)
            self._grow_ring(rec["ring_steps"])
        r = rec["ring_addrs"].shape[0]
        self._states = [
            neuron.NeuronState(
                u=st.u.at[s].set(jax.device_put(rec["u"][i])),
                refrac=st.refrac.at[s].set(
                    jax.device_put(rec["refrac"][i])
                ),
            )
            for i, st in enumerate(self._states)
        ]
        self._ring = {
            "addrs": self._ring["addrs"].at[s, :r].set(
                jax.device_put(rec["ring_addrs"])
            ),
            "values": self._ring["values"].at[s, :r].set(
                jax.device_put(rec["ring_values"])
            ),
            "counts": self._ring["counts"].at[s, :r].set(
                jax.device_put(rec["ring_counts"])
            ),
        }
        self._meta = {
            "done": self._meta["done"].at[s].set(rec["done"]),
            "total": self._meta["total"].at[s].set(rec["total"]),
            "admit": self._meta["admit"].at[s].set(0),
            "fault": self._meta["fault"].at[s].set(0),
        }
        self._slot_req[s] = rec["rid"]
        self._slot_parked[s] = rec["parked"]
        self._slot_priority[s] = rec["priority"]
        self._slot_done[s] = rec["done"]
        self._slot_retired[s] = rec["done"]
        self._slot_total[s] = rec["total"]
        self._slot_submit_t[s] = rec["t_submit"]
        self._slot_admit_t[s] = rec["t_admit"]
        self._slot_deadline[s] = rec["abs_deadline"]
        self._slot_rel_deadline[s] = rec["rel_deadline"]
        self._slot_counts[s] = rec["counts"]
        self._slot_memsum[s] = rec["memsum"]
        self._slot_events[s] = rec["events"]
        t1 = time.perf_counter()
        self._m_preempt_resumed.inc()
        self._m_restore_time.record(t1 - t0)
        self._m_preempt_depth.set(len(self._preempt_parked))
        self.trace.span(
            "resume", t0, t1, track=f"slot{s}",
            args={
                "rid": rec["rid"],
                "done": rec["done"],
                "total": rec["total"],
            },
        )

    # --------------------------------------------------- crash-safe state
    def snapshot(self, path: str) -> str:
        """Serialize the engine's complete serving state into the
        directory ``path``: per-slot membrane/refractory states, packed
        AER rings, on-device scheduling metadata, host bookkeeping, the
        admission queue, parked requests, the preemption parking
        buffer, undelivered results, the PRNG key, and the fault-event
        log.  Atomic (tmp-dir + rename + per-array crc32 checksums via
        the checkpoint plane) — a crash mid-snapshot leaves the
        previous snapshot intact.

        Wall-clock state is persisted as remaining deadline budgets and
        ages: absolute ``perf_counter`` values are meaningless in
        another process, so :meth:`restore` re-anchors them.  Restoring
        on a freshly built engine (identical params/config) finishes
        every in-flight window bit-exactly."""
        t0 = time.perf_counter()
        # consistency point: retire all pipelined stats (finalizing any
        # windows they complete) so host accumulators match device state
        self._drain_inflight()
        now = time.perf_counter()
        arrays: Dict[str, np.ndarray] = {}
        for i, st in enumerate(self._states):
            arrays[f"state{i}_u"] = np.asarray(jax.device_get(st.u))
            arrays[f"state{i}_refrac"] = np.asarray(
                jax.device_get(st.refrac)
            )
        for k, v in self._ring.items():
            arrays[f"ring_{k}"] = np.asarray(jax.device_get(v))
        for k, v in self._meta.items():
            arrays[f"meta_{k}"] = np.asarray(jax.device_get(v))
        arrays["rng_key"] = np.asarray(jax.device_get(self._rng))
        for name in ("done", "retired", "total", "priority"):
            arrays[f"slot_{name}"] = getattr(self, f"_slot_{name}").copy()
        arrays["slot_counts"] = self._slot_counts.copy()
        arrays["slot_memsum"] = self._slot_memsum.copy()
        arrays["slot_events"] = self._slot_events.copy()
        slots = []
        for s in range(self.S):
            dl = self._slot_deadline[s]
            slots.append({
                "rid": self._slot_req[s],
                "parked": bool(self._slot_parked[s]),
                "rel_deadline": self._slot_rel_deadline[s],
                "deadline_remaining_s": (
                    None if dl is None else dl - now
                ),
                "submit_age_s": now - float(self._slot_submit_t[s]),
                "admit_age_s": now - float(self._slot_admit_t[s]),
            })

        def pack_req(prefix, rid, req, t_sub, dl, extra=None):
            if req.spikes is not None:
                arrays[f"{prefix}_spikes"] = np.asarray(req.spikes)
            else:
                arrays[f"{prefix}_image"] = np.asarray(req.image)
            doc = {
                "rid": rid,
                "priority": int(req.priority),
                "num_steps": req.num_steps,
                "deadline_s": req.deadline_s,
                "submit_age_s": now - t_sub,
                "deadline_remaining_s": (
                    None if dl is None else dl - now
                ),
            }
            doc.update(extra or {})
            return doc

        queue_docs = [
            pack_req(f"q{i}", rid, req, t_sub, dl, {"seq": key[3]})
            for i, (key, rid, req, t_sub, dl)
            in enumerate(sorted(self._queue))
        ]
        parked_docs = [
            pack_req(f"p{i}", rid, req, t_sub, dl)
            for i, (rid, req, t_sub, dl) in enumerate(self._parked)
        ]
        pp_docs = []
        for i, rec in enumerate(self._preempt_parked):
            for layer in range(len(rec["u"])):
                arrays[f"pp{i}_u{layer}"] = rec["u"][layer]
                arrays[f"pp{i}_refrac{layer}"] = rec["refrac"][layer]
            for k in ("ring_addrs", "ring_values", "ring_counts",
                      "counts", "memsum", "events"):
                arrays[f"pp{i}_{k}"] = rec[k]
            dl = rec["abs_deadline"]
            pp_docs.append({
                "rid": rec["rid"],
                "priority": rec["priority"],
                "done": rec["done"],
                "total": rec["total"],
                "parked": rec["parked"],
                "ring_steps": rec["ring_steps"],
                "rel_deadline": rec["rel_deadline"],
                "deadline_remaining_s": (
                    None if dl is None else dl - now
                ),
                "submit_age_s": now - rec["t_submit"],
                "admit_age_s": now - rec["t_admit"],
            })
        manifest = {
            "kind": "snn_engine_snapshot",
            "geometry": {
                "num_slots": self.S,
                "chunk_steps": self.Tc,
                "event_capacity": self.C,
                "ring_steps": self._ring_steps,
                "layer_sizes": list(self.cfg.layer_sizes),
            },
            "backend": self._backend_active,
            "tick_index": self._tick_index,
            "seq": self._seq,
            "next_rid": self._next_rid,
            "snap_index": self._snap_index,
            "episode_open": self._episode_open,
            "episode_age_s": (
                now - self._episode_t0 if self._episode_open else 0.0
            ),
            "slots": slots,
            "queue": queue_docs,
            "parked": parked_docs,
            "preempt_parked": pp_docs,
            "pending_results": [
                _doc_result(r) for r in self._pending_results
            ],
            "fault_events": list(self.fault_events),
        }
        path = os.path.normpath(path)
        out = publish_array_dir(
            os.path.dirname(path) or ".",
            os.path.basename(path),
            arrays,
            manifest,
        )
        t1 = time.perf_counter()
        self._m_snap_time.record(t1 - t0)
        self.trace.span(
            "snapshot", t0, t1, track="engine", args={"path": out}
        )
        return out

    def restore(self, path: str) -> None:
        """Load a snapshot written by :meth:`snapshot` into this engine
        (freshly constructed with the same params/config).  Raises
        :class:`~repro.checkpoint.CheckpointCorruptError` when the
        snapshot fails checksum/read verification, ValueError on a
        geometry mismatch (different slots/chunk/capacity/layers —
        snapshots are elastic across *mesh* shape, not model shape)."""
        t_start = time.perf_counter()
        path = os.path.normpath(path)
        arrays, manifest = load_array_dir(path)
        if manifest.get("kind") != "snn_engine_snapshot":
            raise ValueError(f"{path} is not an engine snapshot")
        g = manifest["geometry"]
        want = {
            "num_slots": self.S,
            "chunk_steps": self.Tc,
            "event_capacity": self.C,
            "layer_sizes": list(self.cfg.layer_sizes),
        }
        got = {k: g.get(k) for k in want}
        if got != want:
            raise ValueError(
                f"snapshot geometry mismatch: snapshot {got} != "
                f"engine {want}"
            )
        self._reset_all()
        if int(g["ring_steps"]) != self._ring_steps:
            self._ring_steps = int(g["ring_steps"])
            # a different ring shape is a fresh compile site for this
            # engine's chunk — allowlist it
            self._chunk_compiles_expected += 1
        now = time.perf_counter()
        try:
            self._states = [
                neuron.NeuronState(
                    u=jax.device_put(arrays[f"state{i}_u"]),
                    refrac=jax.device_put(arrays[f"state{i}_refrac"]),
                )
                for i in range(len(self._states))
            ]
            self._ring = {
                k: jax.device_put(arrays[f"ring_{k}"])
                for k in ("addrs", "values", "counts")
            }
            self._meta = {
                k: jax.device_put(arrays[f"meta_{k}"])
                for k in ("done", "total", "admit", "fault")
            }
            self._rng = jax.device_put(arrays["rng_key"])
            self._slot_done = arrays["slot_done"].astype(np.int64)
            self._slot_retired = arrays["slot_retired"].astype(np.int64)
            self._slot_total = arrays["slot_total"].astype(np.int64)
            self._slot_priority = arrays["slot_priority"].astype(
                np.int64
            )
            self._slot_counts = arrays["slot_counts"].astype(np.float64)
            self._slot_memsum = arrays["slot_memsum"].astype(np.float64)
            self._slot_events = arrays["slot_events"].astype(np.float64)
            for s, doc in enumerate(manifest["slots"]):
                self._slot_req[s] = doc["rid"]
                self._slot_parked[s] = bool(doc["parked"])
                self._slot_rel_deadline[s] = doc["rel_deadline"]
                rem = doc["deadline_remaining_s"]
                self._slot_deadline[s] = (
                    None if rem is None else now + rem
                )
                self._slot_submit_t[s] = now - doc["submit_age_s"]
                self._slot_admit_t[s] = now - doc["admit_age_s"]

            def unpack_req(prefix, doc):
                kw = dict(
                    num_steps=doc["num_steps"],
                    deadline_s=doc["deadline_s"],
                    priority=doc["priority"],
                )
                if f"{prefix}_spikes" in arrays:
                    req = StreamRequest(
                        spikes=arrays[f"{prefix}_spikes"], **kw
                    )
                else:
                    req = StreamRequest(
                        image=arrays[f"{prefix}_image"], **kw
                    )
                rem = doc["deadline_remaining_s"]
                dl = None if rem is None else now + rem
                return req, now - doc["submit_age_s"], dl

            self._queue = []
            for i, doc in enumerate(manifest["queue"]):
                req, t_sub, dl = unpack_req(f"q{i}", doc)
                key = (
                    -int(req.priority),
                    0 if dl is not None else 1,
                    dl if dl is not None else 0.0,
                    doc["seq"],
                )
                heapq.heappush(
                    self._queue, (key, doc["rid"], req, t_sub, dl)
                )
            self._parked = collections.deque()
            for i, doc in enumerate(manifest["parked"]):
                req, t_sub, dl = unpack_req(f"p{i}", doc)
                self._parked.append((doc["rid"], req, t_sub, dl))
            self._preempt_parked = []
            n_layers = len(self._states)
            for i, doc in enumerate(manifest["preempt_parked"]):
                rem = doc["deadline_remaining_s"]
                self._preempt_parked.append({
                    "rid": doc["rid"],
                    "priority": int(doc["priority"]),
                    "done": int(doc["done"]),
                    "total": int(doc["total"]),
                    "parked": bool(doc["parked"]),
                    "ring_steps": int(doc["ring_steps"]),
                    "rel_deadline": doc["rel_deadline"],
                    "abs_deadline": (
                        None if rem is None else now + rem
                    ),
                    "t_submit": now - doc["submit_age_s"],
                    "t_admit": now - doc["admit_age_s"],
                    "u": [
                        arrays[f"pp{i}_u{layer}"]
                        for layer in range(n_layers)
                    ],
                    "refrac": [
                        arrays[f"pp{i}_refrac{layer}"]
                        for layer in range(n_layers)
                    ],
                    "ring_addrs": arrays[f"pp{i}_ring_addrs"],
                    "ring_values": arrays[f"pp{i}_ring_values"],
                    "ring_counts": arrays[f"pp{i}_ring_counts"],
                    "counts": arrays[f"pp{i}_counts"],
                    "memsum": arrays[f"pp{i}_memsum"],
                    "events": arrays[f"pp{i}_events"],
                })
        except KeyError as e:
            raise CheckpointCorruptError(
                f"array {e} missing from snapshot {path}"
            ) from e
        self._pending_results = [
            _undoc_result(d) for d in manifest["pending_results"]
        ]
        self.fault_events = list(manifest["fault_events"])
        self._tick_index = int(manifest["tick_index"])
        self._seq = int(manifest["seq"])
        self._next_rid = int(manifest["next_rid"])
        self._snap_index = int(manifest.get("snap_index", 0))
        self._m_qdepth.set(len(self._queue))
        self._m_parked_depth.set(len(self._parked))
        self._m_preempt_depth.set(len(self._preempt_parked))
        if not self.idle():
            self._episode_open = True
            self._episode_t0 = now - float(
                manifest.get("episode_age_s", 0.0)
            )
        t_end = time.perf_counter()
        self._m_restore_snap_time.record(t_end - t_start)
        self.trace.span(
            "restore", t_start, t_end, track="engine",
            args={"path": path, "tick": self._tick_index},
        )

    def snapshot_auto(self, directory: str, keep_n: int = 3) -> str:
        """Write the next snapshot in a keep-N rotation under
        ``directory`` (``snap_NNNNNN``), pruning the oldest beyond
        ``keep_n``; orphaned ``.tmp_*`` dirs from a previously killed
        writer are garbage-collected first."""
        os.makedirs(directory, exist_ok=True)
        gc_orphan_tmpdirs(directory)
        self._snap_index += 1
        out = self.snapshot(
            os.path.join(directory, f"snap_{self._snap_index:06d}")
        )
        names = sorted(
            d for d in os.listdir(directory) if d.startswith("snap_")
        )
        for d in names[:-keep_n] if keep_n else []:
            shutil.rmtree(
                os.path.join(directory, d), ignore_errors=True
            )
        return out

    def restore_latest_snapshot(self, directory: str) -> Optional[str]:
        """Restore the newest snapshot under ``directory`` that passes
        integrity verification.  A corrupt snapshot (truncated npz,
        checksum mismatch) is skipped with a loud warning and the
        ``engine.faults.checkpoint_fallback`` counter, falling back to
        the previous one in the rotation.  Returns the restored path,
        or None when no usable snapshot exists."""
        if not os.path.isdir(directory):
            return None
        gc_orphan_tmpdirs(directory)
        names = sorted(
            (
                d for d in os.listdir(directory)
                if d.startswith("snap_")
                and os.path.exists(
                    os.path.join(directory, d, "manifest.json")
                )
            ),
            reverse=True,
        )
        for name in names:
            p = os.path.join(directory, name)
            try:
                self.restore(p)
                return p
            except CheckpointCorruptError as e:
                self._m_ckpt_fallback.inc()
                warnings.warn(
                    f"engine snapshot {p} failed integrity check "
                    f"({e}); falling back to the previous snapshot",
                    stacklevel=2,
                )
        return None

    # ----------------------------------------------------------- serving
    def idle(self) -> bool:
        """True when no request is queued, parked (admission plane or
        preemption buffer), resident in a slot, awaiting stats
        retirement, or finished-but-undelivered."""
        return (
            not self._queue
            and not self._parked
            and not self._preempt_parked
            and all(r is None for r in self._slot_req)
            and not self._inflight
            and not self._pending_results
        )

    def queue_depth(self) -> int:
        return len(self._queue)

    def parked_depth(self) -> int:
        return len(self._parked)

    def preempt_parked_depth(self) -> int:
        """Occupancy of the preemption parking buffer (displaced
        mid-window slots awaiting resume)."""
        return len(self._preempt_parked)

    def _fill_slot(self, s: int) -> None:
        """Admit into free slot ``s``: resume the most urgent
        preempt-parked window when it beats (or ties) the queue head —
        a started window wins ties, avoiding swap thrash — else pop the
        heap in priority/EDF order, shedding (or parking) candidates
        the feasibility check proves unmeetable, then fall back to the
        parked FIFO when the heap empties (best-effort service, marked
        ``parked`` on the result)."""
        while True:
            best = self._best_preempt_key()
            if best is not None and (
                not self._queue or best[0] <= self._queue[0][0][:3]
            ):
                self._resume_slot(s, self._preempt_parked.pop(best[1]))
                return
            if not self._queue:
                break
            _, rid, req, t_sub, dl = heapq.heappop(self._queue)
            verdict, reason = self._admission_verdict(req, dl)
            if verdict == shed_mod.ADMIT:
                self._admit(s, rid, req, t_sub, dl)
                return
            if verdict == shed_mod.PARK:
                self._park(rid, req, t_sub, dl, reason)
            else:
                self._shed(rid, req, t_sub, dl, reason)
        if self._parked:
            rid, req, t_sub, dl = self._parked.popleft()
            self._m_parked_depth.set(len(self._parked))
            self._admit(s, rid, req, t_sub, dl)
            self._slot_parked[s] = True

    def poll(self) -> List[StreamResult]:
        """One scheduler round: admit queued requests into free slots
        (priority/EDF order, feasibility-shedding if an admission policy
        is set), dispatch the next chunk, retire pipelined stats, and
        return the requests that finished — including shed and
        quarantined dispositions.  Non-blocking in the scheduling sense:
        returns [] when the engine is idle."""
        self._maybe_preempt()
        for s in range(self.S):
            if self._slot_req[s] is None and (
                self._queue or self._parked or self._preempt_parked
            ):
                self._fill_slot(s)
        self._m_qdepth.set(len(self._queue))
        if (
            all(r is None for r in self._slot_req)
            and not self._inflight
        ):
            results, self._pending_results = self._pending_results, []
            if results and self.idle() and self._episode_open:
                self._m_wall.set(time.perf_counter() - self._episode_t0)
                self._episode_open = False
            if results:
                self.timeseries.sample()
            return results
        results = [self._finalize(s) for s in self._tick()]
        if self._pending_results:
            results = self._pending_results + results
            self._pending_results = []
        if self.idle() and self._episode_open:
            self._m_wall.set(time.perf_counter() - self._episode_t0)
            self._episode_open = False
        # one time-series point per tick, after completions land, so the
        # sample sees this tick's counters (misses included) — windowed
        # rates then track the run as it evolves
        self.timeseries.sample()
        return results

    def drain(
        self, timeout_s: Optional[float] = None
    ) -> List[StreamResult]:
        """Poll until idle; returns results in completion order.

        ``timeout_s`` bounds the wall-clock wait: on expiry with the
        engine still not idle, raises :class:`EngineStallError` carrying
        a per-slot diagnostic snapshot (``stall_snapshot()``) and the
        results collected so far — a wedged tick loop used to spin here
        forever with no evidence of *which* slot stopped moving."""
        results: List[StreamResult] = []
        t0 = time.perf_counter()
        while not self.idle():
            results.extend(self.poll())
            if (
                timeout_s is not None
                and time.perf_counter() - t0 > timeout_s
                and not self.idle()
            ):
                snap = self.stall_snapshot()
                stuck = [
                    d["slot"] for d in snap["slots"]
                    if d["rid"] is not None
                ]
                raise EngineStallError(
                    f"drain() timed out after {timeout_s}s with the "
                    f"engine not idle: queue={snap['queue_depth']} "
                    f"parked={snap['parked_depth']} "
                    f"preempt_parked={snap['preempt_parked_depth']} "
                    f"inflight={snap['inflight']} "
                    f"stuck_slots={stuck}",
                    snap,
                    results,
                )
        return results

    def stall_snapshot(self) -> Dict:
        """Diagnostic view of everything that could be blocking
        progress: per-slot occupancy (request id, steps dispatched /
        retired / total, deadline), queue and parked depths *with*
        the parked request ids and the preemption parking-buffer
        occupancy (a drain timeout after heavy preemption is otherwise
        undiagnosable), in-flight stats chunks, and the tick index."""
        return {
            "tick": self._tick_index,
            "queue_depth": len(self._queue),
            "parked_depth": len(self._parked),
            "parked_rids": [rid for rid, _, _, _ in self._parked],
            "preempt_parked_depth": len(self._preempt_parked),
            "preempt_parked": [
                {
                    "rid": rec["rid"],
                    "priority": rec["priority"],
                    "done": rec["done"],
                    "total": rec["total"],
                    "deadline_s": rec["rel_deadline"],
                }
                for rec in self._preempt_parked
            ],
            "inflight": len(self._inflight),
            "pending_results": len(self._pending_results),
            "backend": self._backend_active,
            "slots": [
                {
                    "slot": s,
                    "rid": self._slot_req[s],
                    "done": int(self._slot_done[s]),
                    "retired": int(self._slot_retired[s]),
                    "total": int(self._slot_total[s]),
                    "deadline_s": self._slot_rel_deadline[s],
                    "parked": self._slot_parked[s],
                }
                for s in range(self.S)
            ],
        }

    def run(self, requests: List[StreamRequest]) -> List[StreamResult]:
        """Batch-compatibility wrapper over submit()/drain(): serve all
        requests and return results sorted by request id (submission
        order)."""
        for req in requests:
            self.submit(req)
        results = self.drain()
        results.sort(key=lambda r: r.request_id)
        return results

    # ------------------------------------------------------------- stats
    def events_per_sec(self) -> float:
        """Event throughput of the serving episode.

        Counters reset when an episode begins (first submit on an idle
        engine); the denominator is the *episode* clock — elapsed time
        since episode start while requests are in flight, the episode's
        final wall time once it drains — so mid-episode reads never mix a
        stale denominator with fresh numerators.  0.0 before any serving.
        """
        if self._episode_open:
            denom = time.perf_counter() - self._episode_t0
        else:
            denom = self.wall_s
        # quarantined requests' folded work is excluded: a poisoned
        # request that burned chunks before detection produced no
        # servable result, so counting its events would inflate
        # throughput exactly when the engine is misbehaving (shed
        # requests never reach a slot, so they never enter the numerator
        # in the first place)
        ev = self.total_events - self._m_q_events.value
        return max(ev, 0.0) / max(denom, 1e-9)

    def deadline_miss_rate(self) -> float:
        """Fraction of this episode's completed requests that missed
        their deadline (requests without a deadline count as met).

        Denominator policy: **ok completions only** (parked-then-served
        requests included).  Shed requests were refused service — they
        are neither misses nor completions, and surface in
        ``shed_rate()`` instead; quarantined requests failed for fault
        reasons, not scheduling reasons, and are excluded from both
        sides so a chaos run's miss rate remains comparable to a clean
        run's.
        """
        return self.deadline_misses / max(self.completed, 1)

    def shed_rate(self) -> float:
        """Lifetime fraction of submitted requests the admission plane
        shed (parked requests are not shed — they are served
        best-effort).  0.0 with no admission policy."""
        return self._m_shed.value / max(self._m_submitted.value, 1.0)

    def reset_tick_stats(self) -> None:
        """Zero the tick-phase instruments (e.g. after a warmup episode,
        so ``tick_breakdown`` reflects steady state, not first-tick
        compilation)."""
        self.metrics.reset(prefix="engine.tick.")

    def tick_breakdown(self) -> Dict[str, float]:
        """Engine-lifetime mean per-tick timing (derived from the
        ``engine.tick.*`` histograms' exact sums), the host-overhead
        evidence the serving benchmarks record next to raw chunk
        throughput.

        ``host_prep_us`` is pure host scheduling work.  ``dispatch_us``
        is the time spent in the chunk call: on backends with truly
        async dispatch (TPU) that is sub-millisecond enqueue cost and
        device compute surfaces in ``stats_fetch_us``; on backends that
        serialize dispatch behind the previous chunk's donated buffers
        (CPU here) it *includes* the device compute wait — read it as
        "tick minus host work", not as host dispatch overhead to
        attack.  ``stats_fetch_us`` is the blocking stats retirement
        (any remaining device wait + the single D2H fetch)."""
        n = max(self._m_prep.count, 1)
        return {
            "ticks": self._m_prep.count,
            "pipeline_depth": self.pipeline_depth,
            "host_prep_us": self._m_prep.sum / n * 1e6,
            "dispatch_us": self._m_dispatch.sum / n * 1e6,
            "stats_fetch_us": self._m_fetch.sum / n * 1e6,
            "dispatch_p99_us": self._m_dispatch.percentile(99) * 1e6,
        }

    # -------------------------------------------------------- benchmarks
    def staged_chunk_args(self, trains: Sequence[np.ndarray]):
        """Stage ``trains`` (one per slot, (T, K) each) into fresh ring /
        meta / state pytrees and return ``(prepared, states, ring, meta)``
        — the argument tuple of ``chunk_for_timing()``.  Benchmark
        helper: measures the resident chunk exactly as the tick loop runs
        it, without mutating the live engine."""
        if len(trains) != self.S:
            raise ValueError(f"need {self.S} trains, got {len(trains)}")
        states = runtime.init_states(self.cfg, self.S)
        ring = self._alloc_ring(
            max(self._ring_steps, max(t.shape[0] for t in trains))
        )
        meta = {
            "done": jnp.zeros((self.S,), jnp.int32),
            "total": jnp.asarray(
                [t.shape[0] for t in trains], jnp.int32
            ),
            "admit": jnp.zeros((self.S,), jnp.int32),
            "fault": jnp.zeros((self.S,), jnp.int32),
        }
        for s, t in enumerate(trains):
            train = jax.device_put(np.asarray(t, np.float32))
            # same slot dtype as _admit(): a bare python int would hit a
            # separate (weak-typed) jit cache entry and recompile
            slot = jax.device_put(np.int32(s))
            ring, meta = self._admit_spikes_fn(ring, meta, train, slot)
        meta = {**meta, "admit": jnp.zeros((self.S,), jnp.int32)}
        return self._prepared, states, ring, meta

    def chunk_for_timing(self):
        """The compiled chunk *without* buffer donation, safe to invoke
        repeatedly on the same arguments (``time_fn``-style benchmarks);
        the tick loop itself uses the donating twin."""
        return self._chunk_nodonate
