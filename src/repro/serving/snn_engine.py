"""Streaming SNN serving engine: async admission, deadline-aware scheduling.

The LM ``ServeEngine`` batches token sequences; spiking workloads stream
*time*: each request is a spike train (rate-coded image or DVS event
stream) that must be integrated over its coding window while the neuron
membranes persist between chunks.  The paper's case study — collision
avoidance — is a latency-critical, always-on workload, so the engine is an
*async* scheduler rather than a one-shot batch loop:

- **submit()/poll()/drain().** Requests arrive at any time, including
  while chunks are in flight.  ``submit`` enqueues (returning a request
  id); ``poll`` admits queued requests into free slots and advances every
  active slot by one chunk, returning whatever finished; ``drain`` polls
  until the engine is idle.  ``run(requests)`` survives as a thin
  batch-compatibility wrapper.
- **EDF admission.** Each request carries an optional relative
  ``deadline_s`` and an integer ``priority``.  The queue is ordered by
  (priority desc, earliest absolute deadline first, FIFO); every result
  reports its queue wait and whether its deadline was missed, and the
  engine tracks an episode-level miss rate.
- **Slots.** A fixed micro-batch of ``num_slots`` concurrent requests
  shares one compiled event-driven chunk step
  (``events.runtime.run_chunk``).  Per-slot membrane + refractory state
  lives across chunks; slot shapes are static so nothing recompiles.
  Slot turnover (zeroing state on admit) happens *inside* the jitted
  chunk function via an admit mask — no per-admit host-side ``.at[s].set``
  roundtrips.
- **Sharded slots.** Pass ``mesh=`` to shard the slot axis over the mesh
  (``distributed.partitioning`` rules + ``shard_map``), scaling
  ``num_slots`` past one device while keeping the single-compiled-chunk
  invariant and jnp/fused backend parity.
- **Measured energy.** Every chunk reports per-step, per-layer event
  counts.  A request's energy estimate is priced from the events it
  *actually* generated via ``core.energy.snn_ops_from_events`` — not from
  an assumed spike rate.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import coding, energy, neuron, snn
from repro.distributed import partitioning
from repro.events import runtime

Array = jax.Array


@dataclasses.dataclass
class StreamRequest:
    """One inference over a spike stream.

    Provide either ``image`` ((K,) floats in [0,1], rate-encoded on admit)
    or ``spikes`` ((T, K) pre-encoded train, e.g. densified DVS events).

    ``deadline_s`` is relative to submission time; a request that finishes
    later is still served but reported (and counted) as missed.  Higher
    ``priority`` admits sooner; within a priority class admission is
    earliest-deadline-first, then FIFO (deadline-less requests last).
    """

    image: Optional[np.ndarray] = None
    spikes: Optional[np.ndarray] = None
    num_steps: Optional[int] = None  # None -> cfg.num_steps (must be >= 1)
    deadline_s: Optional[float] = None  # relative latency budget
    priority: int = 0


@dataclasses.dataclass
class StreamResult:
    request_id: int
    prediction: int
    spike_counts: np.ndarray  # (n_class,) output spike counts
    steps: int
    latency_s: float  # submit -> finish (includes queue wait)
    queue_wait_s: float  # submit -> admission into a slot
    events_per_layer: np.ndarray  # (n_layers,) measured input events
    spike_rate: float  # measured mean input rate of layer 0
    energy_pj: float  # priced from measured events
    deadline_s: Optional[float] = None  # the request's relative budget
    deadline_missed: bool = False


class SNNStreamEngine:
    """Async-admission, deadline-aware scheduler over the event-driven
    SNN chunk runtime."""

    def __init__(
        self,
        params: Dict[str, Dict[str, Array]],
        cfg: snn.SNNConfig,
        *,
        num_slots: int = 8,
        chunk_steps: int = 5,
        seed: int = 0,
        backend: str = "auto",
        capacities: Optional[Sequence[int]] = None,
        mesh=None,
    ):
        self.params = params
        self.cfg = cfg
        self.S = num_slots
        self.Tc = chunk_steps
        self._rng = jax.random.PRNGKey(seed)
        # prepare (fake-quantize) once at init — the original loop re-ran
        # the full weight-set quantization inside every chunk execution
        self._prepared = runtime.prepare_params(params, cfg)
        self.backend = backend
        self.mesh = mesh
        self.capacities = (
            tuple(int(c) for c in capacities)
            if capacities is not None
            else None
        )
        Tc = chunk_steps

        def _chunk_fn(prepared, states, spikes, active, take_steps, admit):
            # in-jit slot turnover: slots admitted since the previous chunk
            # start from zeroed membrane/refractory state here, inside the
            # compiled function, instead of per-admit host-side
            # ``u.at[s].set(0)`` roundtrips
            fresh = admit[:, None] > 0
            states = [
                neuron.NeuronState(
                    u=jnp.where(fresh, 0.0, st.u),
                    refrac=jnp.where(fresh, 0, st.refrac),
                )
                for st in states
            ]
            new_states, out_mem, out_spikes, events = runtime.run_chunk(
                prepared,
                states,
                spikes,
                cfg,
                active=active,
                capacities=self.capacities,
                prepared=True,
                backend=backend,
            )
            # per-slot stats accumulate on device; only the request's own
            # steps (take_steps per slot) count toward its result
            m = (
                jnp.arange(Tc, dtype=jnp.int32)[:, None]
                < take_steps[None, :]
            ).astype(jnp.float32)
            stats = {
                "counts": jnp.sum(out_spikes * m[:, :, None], axis=0),
                "memsum": jnp.sum(out_mem * m[:, :, None], axis=0),
                "events": jnp.sum(events * m[:, None, :], axis=0).T,
            }
            return new_states, stats

        if mesh is None:
            self._chunk = jax.jit(_chunk_fn)
        else:
            self._chunk = jax.jit(
                self._shard_over_slots(_chunk_fn, mesh, num_slots)
            )
        self._reset_all()

    @staticmethod
    def _shard_over_slots(chunk_fn, mesh, num_slots: int):
        """Wrap the chunk function in shard_map with the slot axis split
        over the mesh's batch axes (``distributed.partitioning`` rules).

        Params are replicated; states, spike planes, masks and stats all
        shard along slots.  The chunk body is elementwise over slots, so
        sharding is exact — jnp/fused parity and the single-compiled-chunk
        invariant carry over unchanged.
        """
        slot_spec = partitioning.spec_for((num_slots,), ("batch",), mesh)
        if len(slot_spec) == 0 or slot_spec[0] is None:
            raise ValueError(
                f"num_slots={num_slots} is not shardable over mesh axes "
                f"{dict(zip(mesh.axis_names, mesh.devices.shape))}; pick a "
                f"slot count divisible by the mesh's batch axes"
            )
        slot = slot_spec[0]
        return partitioning.shard_map_unchecked(
            chunk_fn,
            mesh,
            # (params, states, spikes (Tc,S,K), active, take_steps, admit)
            in_specs=(P(), P(slot), P(None, slot), P(slot), P(slot), P(slot)),
            out_specs=(P(slot), P(slot)),
        )

    # ------------------------------------------------------------- state
    def _reset_all(self) -> None:
        cfg, S = self.cfg, self.S
        self._states = runtime.init_states(cfg, S)
        self._slot_req = [None] * S  # request id per slot
        self._slot_train: List[Optional[np.ndarray]] = [None] * S
        self._slot_done = np.zeros(S, np.int64)  # steps consumed
        self._slot_total = np.zeros(S, np.int64)
        self._slot_submit_t = np.zeros(S, np.float64)
        self._slot_admit_t = np.zeros(S, np.float64)
        self._slot_deadline: List[Optional[float]] = [None] * S  # absolute
        self._slot_rel_deadline: List[Optional[float]] = [None] * S
        self._pending_admit = np.zeros(S, bool)  # in-jit reset at next tick
        self._slot_counts = np.zeros((S, cfg.layer_sizes[-1]), np.float64)
        self._slot_memsum = np.zeros((S, cfg.layer_sizes[-1]), np.float64)
        self._slot_events = np.zeros((S, cfg.num_layers), np.float64)
        self._queue: List[tuple] = []  # heap: (key, rid, req, t_sub, dl)
        self._seq = 0
        self._next_rid = 0
        self._episode_open = False
        self._episode_t0 = 0.0
        self.total_events = 0.0
        self.total_steps = 0
        self.wall_s = 0.0
        self.completed = 0
        self.deadline_misses = 0

    def _begin_episode(self, now: float) -> None:
        # throughput + deadline counters are per-episode: an episode opens
        # at the first submit on an idle engine and closes when the last
        # queued request drains (see events_per_sec for the denominator)
        self.total_events = 0.0
        self.total_steps = 0
        self.completed = 0
        self.deadline_misses = 0
        self._episode_t0 = now
        self._episode_open = True

    # --------------------------------------------------------- admission
    def _resolve_steps(self, req: StreamRequest) -> int:
        # explicit None check: ``req.num_steps or cfg.num_steps`` silently
        # treated num_steps=0 as unset
        T = (
            self.cfg.num_steps
            if req.num_steps is None
            else int(req.num_steps)
        )
        if T < 1:
            raise ValueError(f"num_steps must be >= 1, got {req.num_steps}")
        return T

    def submit(self, req: StreamRequest) -> int:
        """Enqueue one request; returns its request id.

        Admission happens at the next ``poll()``: free slots are filled in
        (priority desc, earliest deadline, FIFO) order, so a later submit
        with a tighter deadline overtakes queued work it never saw.
        """
        T = self._resolve_steps(req)
        K = self.cfg.layer_sizes[0]
        if req.spikes is not None:
            shape = tuple(np.shape(req.spikes))
            if shape != (T, K):
                raise ValueError(
                    f"request spikes shape {shape} != ({T}, {K})"
                )
        elif req.image is not None:
            shape = tuple(np.shape(req.image))
            if shape != (K,):
                raise ValueError(f"request image shape {shape} != ({K},)")
        else:
            raise ValueError("StreamRequest needs image or spikes")
        now = time.perf_counter()
        if not self._episode_open:
            self._begin_episode(now)
        rid = self._next_rid
        self._next_rid += 1
        dl = now + req.deadline_s if req.deadline_s is not None else None
        key = (
            -int(req.priority),
            0 if dl is not None else 1,  # deadline-less requests last
            dl if dl is not None else 0.0,
            self._seq,  # FIFO tiebreak; also keeps heap entries orderable
        )
        self._seq += 1
        heapq.heappush(self._queue, (key, rid, req, now, dl))
        return rid

    def _admit(
        self,
        s: int,
        rid: int,
        req: StreamRequest,
        t_submit: float,
        abs_deadline: Optional[float],
    ) -> None:
        cfg = self.cfg
        T = self._resolve_steps(req)
        if req.spikes is not None:
            train = np.asarray(req.spikes, np.float32)
        elif req.image is not None:
            self._rng, k = jax.random.split(self._rng)
            train = np.asarray(
                coding.rate_encode(k, jnp.asarray(req.image, jnp.float32), T)
            )
        else:
            raise ValueError("StreamRequest needs image or spikes")
        if train.shape != (T, cfg.layer_sizes[0]):
            raise ValueError(
                f"request {rid}: train shape {train.shape} != "
                f"({T}, {cfg.layer_sizes[0]})"
            )
        self._pending_admit[s] = True  # state zeroed in-jit at next tick
        self._slot_req[s] = rid
        self._slot_train[s] = train
        self._slot_done[s] = 0
        self._slot_total[s] = T
        self._slot_submit_t[s] = t_submit
        self._slot_admit_t[s] = time.perf_counter()
        self._slot_deadline[s] = abs_deadline
        self._slot_rel_deadline[s] = req.deadline_s
        self._slot_counts[s] = 0.0
        self._slot_memsum[s] = 0.0
        self._slot_events[s] = 0.0

    # -------------------------------------------------------------- tick
    def _tick(self) -> List[int]:
        """Advance every active slot by one chunk; returns finished slots."""
        cfg, S, Tc = self.cfg, self.S, self.Tc
        K = cfg.layer_sizes[0]
        chunk = np.zeros((Tc, S, K), np.float32)
        active = np.zeros(S, np.float32)
        take_steps = np.zeros(S, np.int32)
        for s in range(S):
            if self._slot_req[s] is None:
                continue
            active[s] = 1.0
            d = int(self._slot_done[s])
            take = min(Tc, int(self._slot_total[s]) - d)
            take_steps[s] = take
            chunk[:take, s] = self._slot_train[s][d : d + take]

        self._states, stats = self._chunk(
            self._prepared,
            self._states,
            jnp.asarray(chunk),
            jnp.asarray(active),
            jnp.asarray(take_steps),
            jnp.asarray(self._pending_admit.astype(np.float32)),
        )
        self._pending_admit[:] = False
        # single device->host sync per chunk: the (S, C)/(S, L) stats
        # pytree, already masked and reduced on device — the (Tc, S, *)
        # traces never leave the accelerator
        stats = jax.device_get(stats)

        finished = []
        for s in range(S):
            if self._slot_req[s] is None:
                continue
            take = int(take_steps[s])
            self._slot_counts[s] += stats["counts"][s]
            self._slot_memsum[s] += stats["memsum"][s]
            self._slot_events[s] += stats["events"][s]
            self._slot_done[s] += take
            self.total_events += float(stats["events"][s].sum())
            self.total_steps += take
            if self._slot_done[s] >= self._slot_total[s]:
                finished.append(s)
        return finished

    def _finalize(self, s: int) -> StreamResult:
        cfg = self.cfg
        T = int(self._slot_total[s])
        ev = self._slot_events[s].copy()
        oc = energy.snn_ops_from_events(
            cfg.layer_sizes, T, ev, neuron_kind=cfg.neuron_kind
        )
        counts = self._slot_counts[s]
        pred = int(np.argmax(counts + 1e-6 * self._slot_memsum[s]))
        finish_t = time.perf_counter()
        dl = self._slot_deadline[s]
        missed = dl is not None and finish_t > dl
        self.completed += 1
        if missed:
            self.deadline_misses += 1
        res = StreamResult(
            request_id=self._slot_req[s],
            prediction=pred,
            spike_counts=counts.copy(),
            steps=T,
            latency_s=finish_t - self._slot_submit_t[s],
            queue_wait_s=self._slot_admit_t[s] - self._slot_submit_t[s],
            events_per_layer=ev,
            spike_rate=float(ev[0] / (T * cfg.layer_sizes[0])),
            energy_pj=oc.energy_pj(),
            deadline_s=self._slot_rel_deadline[s],
            deadline_missed=missed,
        )
        self._slot_req[s] = None
        self._slot_train[s] = None
        return res

    # ----------------------------------------------------------- serving
    def idle(self) -> bool:
        """True when no request is queued or resident in a slot."""
        return not self._queue and all(r is None for r in self._slot_req)

    def queue_depth(self) -> int:
        return len(self._queue)

    def poll(self) -> List[StreamResult]:
        """One scheduler round: admit queued requests into free slots
        (priority/EDF order), advance all active slots by one chunk, and
        return the requests that finished.  Non-blocking in the scheduling
        sense: returns [] when the engine is idle."""
        for s in range(self.S):
            if self._slot_req[s] is None and self._queue:
                _, rid, req, t_sub, dl = heapq.heappop(self._queue)
                self._admit(s, rid, req, t_sub, dl)
        if all(r is None for r in self._slot_req):
            return []
        results = [self._finalize(s) for s in self._tick()]
        if self.idle() and self._episode_open:
            self.wall_s = time.perf_counter() - self._episode_t0
            self._episode_open = False
        return results

    def drain(self) -> List[StreamResult]:
        """Poll until idle; returns results in completion order."""
        results: List[StreamResult] = []
        while not self.idle():
            results.extend(self.poll())
        return results

    def run(self, requests: List[StreamRequest]) -> List[StreamResult]:
        """Batch-compatibility wrapper over submit()/drain(): serve all
        requests and return results sorted by request id (submission
        order)."""
        for req in requests:
            self.submit(req)
        results = self.drain()
        results.sort(key=lambda r: r.request_id)
        return results

    # ------------------------------------------------------------- stats
    def events_per_sec(self) -> float:
        """Event throughput of the serving episode.

        Counters reset when an episode begins (first submit on an idle
        engine); the denominator is the *episode* clock — elapsed time
        since episode start while requests are in flight, the episode's
        final wall time once it drains — so mid-episode reads never mix a
        stale denominator with fresh numerators.  0.0 before any serving.
        """
        if self._episode_open:
            denom = time.perf_counter() - self._episode_t0
        else:
            denom = self.wall_s
        return self.total_events / max(denom, 1e-9)

    def deadline_miss_rate(self) -> float:
        """Fraction of this episode's completed requests that missed their
        deadline (requests without a deadline count as met)."""
        return self.deadline_misses / max(self.completed, 1)
