"""Streaming SNN serving engine: micro-batched, stateful, event-driven.

The LM ``ServeEngine`` batches token sequences; spiking workloads stream
*time*: each request is a spike train (rate-coded image or DVS event
stream) that must be integrated over its coding window while the neuron
membranes persist between chunks.  This engine serves many such requests
concurrently:

- **Slots.** A fixed micro-batch of ``num_slots`` concurrent requests
  shares one compiled event-driven chunk step
  (``events.runtime.run_chunk``).  Per-slot membrane + refractory state
  lives across chunks; slot shapes are static so nothing recompiles.
- **Continuous batching.** When a request completes its window, the slot's
  state is zeroed and the next queued request is admitted at that slot —
  the chunk function never stalls on stragglers.
- **Measured energy.** Every chunk reports per-step, per-layer event
  counts.  A request's energy estimate is priced from the events it
  *actually* generated via ``core.energy.snn_ops_from_events`` — not from
  an assumed spike rate.
- **Latency.** Each result carries admit->finish wall latency plus the
  step count, so tail behavior under queueing is observable.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coding, energy, neuron, snn
from repro.events import runtime

Array = jax.Array


@dataclasses.dataclass
class StreamRequest:
    """One inference over a spike stream.

    Provide either ``image`` ((K,) floats in [0,1], rate-encoded on admit)
    or ``spikes`` ((T, K) pre-encoded train, e.g. densified DVS events).
    """

    image: Optional[np.ndarray] = None
    spikes: Optional[np.ndarray] = None
    num_steps: Optional[int] = None  # defaults to cfg.num_steps


@dataclasses.dataclass
class StreamResult:
    request_id: int
    prediction: int
    spike_counts: np.ndarray  # (n_class,) output spike counts
    steps: int
    latency_s: float
    events_per_layer: np.ndarray  # (n_layers,) measured input events
    spike_rate: float  # measured mean input rate of layer 0
    energy_pj: float  # priced from measured events


class SNNStreamEngine:
    """Micro-batching scheduler over the event-driven SNN runtime."""

    def __init__(
        self,
        params: Dict[str, Dict[str, Array]],
        cfg: snn.SNNConfig,
        *,
        num_slots: int = 8,
        chunk_steps: int = 5,
        seed: int = 0,
        backend: str = "auto",
        capacities: Optional[Sequence[int]] = None,
    ):
        self.params = params
        self.cfg = cfg
        self.S = num_slots
        self.Tc = chunk_steps
        self._rng = jax.random.PRNGKey(seed)
        # prepare (fake-quantize) once at init — the original loop re-ran
        # the full weight-set quantization inside every chunk execution
        self._prepared = runtime.prepare_params(params, cfg)
        self.backend = backend
        self.capacities = (
            tuple(int(c) for c in capacities)
            if capacities is not None
            else None
        )
        Tc = chunk_steps

        def _chunk_fn(states, spikes, active, take_steps):
            new_states, out_mem, out_spikes, events = runtime.run_chunk(
                self._prepared,
                states,
                spikes,
                cfg,
                active=active,
                capacities=self.capacities,
                prepared=True,
                backend=backend,
            )
            # per-slot stats accumulate on device; only the request's own
            # steps (take_steps per slot) count toward its result
            m = (
                jnp.arange(Tc, dtype=jnp.int32)[:, None]
                < take_steps[None, :]
            ).astype(jnp.float32)
            stats = {
                "counts": jnp.sum(out_spikes * m[:, :, None], axis=0),
                "memsum": jnp.sum(out_mem * m[:, :, None], axis=0),
                "events": jnp.sum(events * m[:, None, :], axis=0).T,
            }
            return new_states, stats

        self._chunk = jax.jit(_chunk_fn)
        self._reset_all()

    # ------------------------------------------------------------- state
    def _reset_all(self) -> None:
        cfg, S = self.cfg, self.S
        self._states = runtime.init_states(cfg, S)
        self._slot_req = [None] * S  # request id per slot
        self._slot_train: List[Optional[np.ndarray]] = [None] * S
        self._slot_done = np.zeros(S, np.int64)  # steps consumed
        self._slot_total = np.zeros(S, np.int64)
        self._slot_admit_t = np.zeros(S, np.float64)
        self._slot_counts = np.zeros((S, cfg.layer_sizes[-1]), np.float64)
        self._slot_memsum = np.zeros((S, cfg.layer_sizes[-1]), np.float64)
        self._slot_events = np.zeros((S, cfg.num_layers), np.float64)
        self.total_events = 0.0
        self.total_steps = 0
        self.wall_s = 0.0

    def _zero_slot_state(self, s: int) -> None:
        self._states = [
            neuron.NeuronState(
                u=st.u.at[s].set(0.0), refrac=st.refrac.at[s].set(0)
            )
            for st in self._states
        ]

    def _admit(self, s: int, req_id: int, req: StreamRequest) -> None:
        cfg = self.cfg
        T = req.num_steps or cfg.num_steps
        if req.spikes is not None:
            train = np.asarray(req.spikes, np.float32)
        elif req.image is not None:
            self._rng, k = jax.random.split(self._rng)
            train = np.asarray(
                coding.rate_encode(k, jnp.asarray(req.image, jnp.float32), T)
            )
        else:
            raise ValueError("StreamRequest needs image or spikes")
        if train.shape != (T, cfg.layer_sizes[0]):
            raise ValueError(
                f"request {req_id}: train shape {train.shape} != "
                f"({T}, {cfg.layer_sizes[0]})"
            )
        self._zero_slot_state(s)
        self._slot_req[s] = req_id
        self._slot_train[s] = train
        self._slot_done[s] = 0
        self._slot_total[s] = T
        self._slot_admit_t[s] = time.perf_counter()
        self._slot_counts[s] = 0.0
        self._slot_memsum[s] = 0.0
        self._slot_events[s] = 0.0

    # -------------------------------------------------------------- tick
    def _tick(self) -> List[int]:
        """Advance every active slot by one chunk; returns finished slots."""
        cfg, S, Tc = self.cfg, self.S, self.Tc
        K = cfg.layer_sizes[0]
        chunk = np.zeros((Tc, S, K), np.float32)
        active = np.zeros(S, np.float32)
        take_steps = np.zeros(S, np.int32)
        for s in range(S):
            if self._slot_req[s] is None:
                continue
            active[s] = 1.0
            d = int(self._slot_done[s])
            take = min(Tc, int(self._slot_total[s]) - d)
            take_steps[s] = take
            chunk[:take, s] = self._slot_train[s][d : d + take]

        self._states, stats = self._chunk(
            self._states,
            jnp.asarray(chunk),
            jnp.asarray(active),
            jnp.asarray(take_steps),
        )
        # single device->host sync per chunk: the (S, C)/(S, L) stats
        # pytree, already masked and reduced on device — the (Tc, S, *)
        # traces never leave the accelerator
        stats = jax.device_get(stats)

        finished = []
        for s in range(S):
            if self._slot_req[s] is None:
                continue
            take = int(take_steps[s])
            self._slot_counts[s] += stats["counts"][s]
            self._slot_memsum[s] += stats["memsum"][s]
            self._slot_events[s] += stats["events"][s]
            self._slot_done[s] += take
            self.total_events += float(stats["events"][s].sum())
            self.total_steps += take
            if self._slot_done[s] >= self._slot_total[s]:
                finished.append(s)
        return finished

    def _finalize(self, s: int) -> StreamResult:
        cfg = self.cfg
        T = int(self._slot_total[s])
        ev = self._slot_events[s].copy()
        oc = energy.snn_ops_from_events(
            cfg.layer_sizes, T, ev, neuron_kind=cfg.neuron_kind
        )
        counts = self._slot_counts[s]
        pred = int(np.argmax(counts + 1e-6 * self._slot_memsum[s]))
        res = StreamResult(
            request_id=self._slot_req[s],
            prediction=pred,
            spike_counts=counts.copy(),
            steps=T,
            latency_s=time.perf_counter() - self._slot_admit_t[s],
            events_per_layer=ev,
            spike_rate=float(ev[0] / (T * cfg.layer_sizes[0])),
            energy_pj=oc.energy_pj(),
        )
        self._slot_req[s] = None
        self._slot_train[s] = None
        return res

    # --------------------------------------------------------------- run
    def run(self, requests: List[StreamRequest]) -> List[StreamResult]:
        """Serve all requests (continuous batching) and return results in
        request order."""
        queue = list(enumerate(requests))
        results: List[StreamResult] = []
        # throughput counters are per-run: events_per_sec() reports the
        # current serving episode, not the engine's lifetime
        self.total_events = 0.0
        self.total_steps = 0
        for s in range(self.S):
            if not queue:
                break
            rid, req = queue.pop(0)
            self._admit(s, rid, req)
        t0 = time.perf_counter()
        while any(r is not None for r in self._slot_req):
            for s in self._tick():
                results.append(self._finalize(s))
                if queue:
                    rid, req = queue.pop(0)
                    self._admit(s, rid, req)
        self.wall_s = time.perf_counter() - t0
        results.sort(key=lambda r: r.request_id)
        return results

    def events_per_sec(self) -> float:
        """Throughput of the last ``run()``; 0.0 before any run."""
        return self.total_events / max(self.wall_s, 1e-9)
