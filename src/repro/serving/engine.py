"""Batched serving engine: prefill + step-synchronous batched decode.

Serves B concurrent sequences with a shared compiled decode step (the
exact function the decode_* dry-run cells lower).  Requests are padded
into fixed batch slots (continuous batching: a finished slot is refilled
by the next queued prompt at its own position/cache row — position and
cache are per-row, so no recompile).  Greedy or temperature sampling.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

PyTree = Any


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (L,) or (L, K) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, model: Model, params: PyTree, batch_size: int,
                 cache_len: int, seed: int = 0):
        self.model = model
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self._rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len)
        )

    def _sample(
        self, logits: jax.Array, temps: jax.Array, any_sampling: bool
    ) -> jax.Array:
        """Per-request sampling: row i uses requests[i]'s temperature.

        ``logits`` is (B, V) or (B, K, V) (codebook heads); ``temps`` is
        (B,).  Rows with temperature <= 0 decode greedily, others sample
        from their own temperature-scaled distribution.  ``any_sampling``
        is hoisted by the caller so the all-greedy fast path costs no
        device sync per token.
        """
        greedy = jnp.argmax(logits, axis=-1)
        if not any_sampling:
            return greedy
        self._rng, k = jax.random.split(self._rng)
        t = temps.reshape((-1,) + (1,) * (logits.ndim - 1))
        sampled = jax.random.categorical(
            k, logits / jnp.maximum(t, 1e-6), axis=-1
        )
        cond = (temps > 0.0).reshape((-1,) + (1,) * (greedy.ndim - 1))
        return jnp.where(cond, sampled, greedy)

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Batched generation; requests are chunked into engine batches."""
        outs: List[np.ndarray] = []
        for s in range(0, len(requests), self.B):
            outs.extend(self._generate_batch(requests[s : s + self.B]))
        return outs

    def _generate_batch(self, reqs: List[Request]) -> List[np.ndarray]:
        B = len(reqs)
        Lmax = max(len(r.prompt) for r in reqs)
        pad_to = lambda t: np.pad(t, [(0, Lmax - len(t))] + [(0, 0)] * (t.ndim - 1))
        tokens = np.stack([pad_to(np.asarray(r.prompt)) for r in reqs])
        batch = {"tokens": jnp.asarray(tokens)}
        logits, cache = self._prefill(self.params, batch)
        # note: per-row true lengths -> the last *valid* logit is at len-1;
        # for simplicity prompts are right-padded and rows with padding
        # resample from their true last position during the first steps.
        steps = max(r.max_new_tokens for r in reqs)
        pos = jnp.asarray([Lmax for _ in reqs], jnp.int32)
        temps = jnp.asarray([r.temperature for r in reqs], jnp.float32)
        any_sampling = any(r.temperature > 0.0 for r in reqs)
        out_tokens = [[] for _ in range(B)]
        tok = self._sample(logits, temps, any_sampling)
        for r_i in range(B):
            out_tokens[r_i].append(np.asarray(tok[r_i]))
        for t in range(steps - 1):
            step_tok = tok[:, None] if tok.ndim == 1 else tok[:, None, :]
            logits, cache = self._decode(
                self.params, step_tok.astype(jnp.int32), pos, cache
            )
            tok = self._sample(logits, temps, any_sampling)
            pos = pos + 1
            for r_i in range(B):
                out_tokens[r_i].append(np.asarray(tok[r_i]))
        return [
            np.stack(out_tokens[i][: reqs[i].max_new_tokens])
            for i in range(B)
        ]
