"""Energy-aware training objective for the event-driven SNN.

The paper's deployment target is energy, not just accuracy — so the
training loss optimizes both:

    L = CE(out_mem, labels)  +  energy_lambda * E_hat[nJ]

where ``E_hat`` prices the network's *differentiable* spike activity with
the same per-event energies the measured model
(``core.energy.snn_ops_from_events``) uses: each spike a hidden layer
emits costs its downstream fan-out in accumulator adds plus the weight
fetches.  Gradients reach the spike counts through the surrogate VJPs, so
raising ``energy_lambda`` trades accuracy for sparsity along the paper's
actual energy axis (not a generic L2 on rates).

Separately, every step reports **measured** per-layer event counts and the
measured-event energy (a pure-jnp mirror of ``snn_ops_from_events`` so it
jits inside the train step) as metrics — training logs show the true
event trajectory, not the differentiable proxy.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import snn
from repro.core.energy import ENERGY_PJ
from repro.sparse_train import event_layer

Array = jax.Array


def event_cost_pj(fan_out: int, *, weight_bits: int = 16) -> float:
    """Energy (pJ) of one input event at a layer with ``fan_out`` outputs:
    one accumulator add per output + the SRAM weight fetches."""
    wpl = 64 // weight_bits
    return fan_out * (ENERGY_PJ["add_i32"] + ENERGY_PJ["sram_64b"] / wpl)


def measured_energy_pj(
    layer_sizes: Sequence[int],
    num_steps: int,
    events_per_layer: Array,  # (n_layers,) or (n_layers, B) measured counts
    *,
    weight_bits: int = 16,
    neuron_kind: str = "lif",
) -> Array:
    """jnp mirror of ``core.energy.snn_ops_from_events(...).energy_pj()``.

    ``OpCount`` calls ``float()`` on its tallies and cannot trace; this
    computes the identical pJ total from traced event counts so the
    measured energy can be logged inside a jitted train step
    (equality with the OpCount path is unit-tested).
    """
    ev = jnp.asarray(events_per_layer, jnp.float32)
    total = jnp.zeros(ev.shape[1:], jnp.float32)
    wpl = 64 // weight_bits
    for i, (fan_in, fan_out) in enumerate(
        zip(layer_sizes[:-1], layer_sizes[1:])
    ):
        total = total + ev[i] * fan_out * ENERGY_PJ["add_i32"]
        fixed = num_steps * fan_out * (
            ENERGY_PJ["add_i32"]  # bias add
            + (ENERGY_PJ["mul_i16"] if neuron_kind == "lif" else 0.0)
            + ENERGY_PJ["add_i16"]
            + ENERGY_PJ["cmp_i16"]
        )
        total = total + fixed
        total = total + ev[i] * fan_out / wpl * ENERGY_PJ["sram_64b"]
    total = total + ev[0] / 2.0 * ENERGY_PJ["sram_64b"]
    return total


def energy_regularizer_nj(
    layer_sizes: Sequence[int],
    act: Array,  # (n_layers,) differentiable mean spikes per layer output
    *,
    weight_bits: int = 16,
) -> Array:
    """Differentiable downstream-event energy (nJ per inference).

    ``act[i]`` spikes emitted by layer i each land on layer i+1 and cost
    ``event_cost_pj(fan_out_{i+1})``; the last layer's spikes leave the
    chip and are priced free.  Input-layer events are data, carry no
    gradient, and are excluded (they are still in the *measured* metric).
    """
    total = jnp.zeros((), jnp.float32)
    fan_outs = list(layer_sizes[1:])
    for i in range(len(fan_outs) - 1):
        total = total + act[i] * event_cost_pj(
            fan_outs[i + 1], weight_bits=weight_bits
        )
    return total / 1e3  # pJ -> nJ keeps the loss term O(1)


def event_loss_fn(
    params,
    spikes: Array,  # (T, B, K)
    labels: Array,  # (B,)
    cfg: snn.SNNConfig,
    *,
    energy_lambda: float = 0.0,
    train: bool = True,
    dropout_key: Optional[jax.Array] = None,
    capacity: Optional[int] = None,
    use_kernel: bool = False,
) -> Tuple[Array, Dict[str, Array]]:
    """Event-driven analog of ``core.snn.loss_fn`` + energy objective.

    With ``energy_lambda == 0`` the scalar loss (and therefore its
    gradient) matches the dense ``snn.loss_fn`` to float tolerance — the
    subsystem's gradient-parity anchor.
    """
    out_mem, out_spikes, events, act = event_layer.event_bptt_forward(
        params,
        spikes,
        cfg,
        train=train,
        dropout_key=dropout_key,
        capacity=capacity,
        use_kernel=use_kernel,
    )
    # same CE-over-all-steps and prediction rule as the dense trainer —
    # shared helpers keep the gradient-parity anchor bit-identical
    task_loss = snn.membrane_ce_loss(out_mem, labels)

    energy_nj = energy_regularizer_nj(cfg.layer_sizes, act)
    loss = task_loss + energy_lambda * energy_nj

    pred = snn.predict_from_traces(out_mem, out_spikes)
    acc = jnp.mean((pred == labels).astype(jnp.float32))

    ev_mean = jnp.mean(events, axis=-1)  # (n_layers,) per-inference
    metrics: Dict[str, Array] = {
        "task_loss": task_loss,
        "energy_reg_nj": energy_nj,
        "accuracy": acc,
        "spike_rate": jnp.mean(out_spikes),
        "hidden_rate": act[0] / (cfg.num_steps * cfg.layer_sizes[1]),
        "energy_pj": measured_energy_pj(
            cfg.layer_sizes, cfg.num_steps, ev_mean,
            neuron_kind=cfg.neuron_kind,
        ),
    }
    for i in range(events.shape[0]):
        metrics[f"events_l{i}"] = ev_mean[i]
    return loss, metrics
