"""Event-driven surrogate-gradient training subsystem.

PR 1 made *inference* event-driven (AER gather path, measured energy);
this package closes the loop for *training*, so spike sparsity cuts cost
end to end:

- ``event_layer``: ``jax.custom_vjp`` event-driven linear layer — forward
  gathers only active weight rows (batched ``aer_spike_matmul`` or its
  jnp mirror), backward scatters the weight cotangent through the same
  active-event index set; composes with the ``core/surrogate`` spike VJPs
  for BPTT over time.  Gradient parity with dense ``core/snn`` BPTT is
  the correctness anchor.
- ``loss``: energy-aware objective — task cross-entropy plus a
  differentiable spike-activity regularizer priced with the same per-event
  energies as ``core.energy.snn_ops_from_events``; measured per-layer
  event counts and energy are logged as metrics every step.
- ``trainer``: ``EventTrainer`` on the ``train/loop.py`` substrate
  (jitted step, grad accumulation, checkpoint/restart, watchdog), trained
  on the synthetic DVS collision scenario with polarity-aware inputs.
  Entry point: ``launch/train.py --snn-events``.
"""

from repro.sparse_train import event_layer, loss, trainer
from repro.sparse_train.event_layer import event_bptt_forward, event_linear
from repro.sparse_train.loss import event_loss_fn
from repro.sparse_train.trainer import (
    EventSNNModel,
    EventTrainConfig,
    EventTrainer,
    dvs_batches,
)

__all__ = [
    "event_layer",
    "loss",
    "trainer",
    "event_linear",
    "event_bptt_forward",
    "event_loss_fn",
    "EventSNNModel",
    "EventTrainConfig",
    "EventTrainer",
    "dvs_batches",
]
