"""EventTrainer: surrogate-gradient training over the event-driven path.

Reuses the production training substrate in ``train/loop.py`` — the same
step builder (``make_train_step``), gradient accumulation, checkpointing,
and straggler watchdog the LM zoo trains with — by adapting the
event-driven SNN to the ``Model``-shaped interface the substrate expects
(``init(key)`` / ``loss(params, batch)``).

The default workload is the synthetic DVS collision scenario: every batch
is freshly rendered by ``events.aer.dvs_collision_batch``, converted to
polarity-aware input planes, and trained with the energy-aware loss.

  from repro.sparse_train import trainer
  tcfg = trainer.EventTrainConfig(image_hw=32, num_steps=15)
  t = trainer.EventTrainer(tcfg, energy_lambda=0.05, ckpt_dir=...)
  state = t.init_state(jax.random.PRNGKey(0))
  state, metrics = t.run(state, trainer.dvs_batches(0, 32, tcfg), 200)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.core import snn
from repro.events import aer
from repro.optim import adam, chain_clip
from repro.sparse_train.loss import event_loss_fn
from repro.train import loop

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EventTrainConfig:
    """Static configuration of the event-driven training workload."""

    image_hw: int = 32
    num_steps: int = 15
    hidden: int = 128
    polarity_mode: str = "two_channel"  # aer.POLARITY_MODES
    dvs_capacity: Optional[int] = None  # event-list capacity per recording
    delta_threshold: float = 0.1
    dropout_rate: float = 0.0
    quant_q115: bool = False

    @property
    def num_pixels(self) -> int:
        return self.image_hw * self.image_hw

    @property
    def input_size(self) -> int:
        return aer.input_size_for(self.num_pixels, self.polarity_mode)

    @property
    def capacity(self) -> int:
        return self.dvs_capacity or 8 * self.num_pixels

    def snn_config(self) -> snn.SNNConfig:
        return snn.SNNConfig(
            layer_sizes=(self.input_size, self.hidden, 2),
            num_steps=self.num_steps,
            dropout_rate=self.dropout_rate,
            quant_q115=self.quant_q115,
        )


class EventSNNModel:
    """Adapter: event-driven SNN -> the ``train/loop`` Model interface.

    Batches are dicts with leading batch dims (so gradient accumulation's
    microbatch reshape works):
      spikes:    (B, T, K) input spike planes
      labels:    (B,) int32
      step_seed: (B,) uint32 — the data stream's step counter; folded with
                 the run ``seed`` into the dropout key (ignored when the
                 config has no dropout)
    """

    def __init__(
        self,
        cfg: snn.SNNConfig,
        *,
        energy_lambda: float = 0.0,
        use_kernel: bool = False,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.energy_lambda = energy_lambda
        self.use_kernel = use_kernel
        self.seed = seed

    def init(self, key):
        return snn.init_params(key, self.cfg), None

    def param_count(self) -> int:
        sizes = self.cfg.layer_sizes
        return sum(
            (fi + 3) * fo for fi, fo in zip(sizes[:-1], sizes[1:])
        )  # w + b + beta_raw + threshold

    def active_param_count(self) -> int:
        return self.param_count()

    def loss(self, params, batch: Dict[str, Array]):
        spikes = jnp.moveaxis(batch["spikes"], 0, 1)  # (B,T,K) -> (T,B,K)
        train = self.cfg.dropout_rate > 0.0
        dkey = (
            jax.random.fold_in(
                jax.random.PRNGKey(self.seed),
                batch["step_seed"][0].astype(jnp.uint32),
            )
            if train
            else None
        )
        loss, metrics = event_loss_fn(
            params,
            spikes,
            batch["labels"],
            self.cfg,
            energy_lambda=self.energy_lambda,
            train=train,
            dropout_key=dkey,
            use_kernel=self.use_kernel,
        )
        metrics = dict(metrics)
        metrics["loss"] = loss
        return loss, metrics


class EventTrainer(loop.Trainer):
    """``train/loop.Trainer`` over the event-driven SNN.

    Inherits the jitted step (with donation), gradient accumulation,
    checkpoint/restart, the straggler watchdog and the ``repro.obs``
    instruments unchanged; only the model (and the paper's Adam-5e-4
    default optimizer) differ.  On top of the substrate's step-time /
    loss / grad-norm instruments it registers the paper-facing energy
    telemetry: per-layer measured spike-count counters
    (``train.events.l<i>.total``) and a measured-energy counter
    (``train.energy_pj.total``), accumulated from each sync window's
    observed per-inference metrics, plus per-inference event/energy
    histograms — so a training run's spike-activity trajectory is
    inspectable the same way a serving episode's is.
    """

    def __init__(
        self,
        tcfg: EventTrainConfig,
        *,
        energy_lambda: float = 0.0,
        use_kernel: bool = False,
        lr: float = 5e-4,
        optimizer=None,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        accum_steps: int = 1,
        seed: int = 0,
    ):
        self.tcfg = tcfg
        self.snn_cfg = tcfg.snn_config()
        model = EventSNNModel(
            self.snn_cfg,
            energy_lambda=energy_lambda,
            use_kernel=use_kernel,
            seed=seed,
        )
        opt = optimizer if optimizer is not None else chain_clip(adam(lr), 1.0)
        super().__init__(
            model,
            opt,
            ckpt_dir=ckpt_dir,
            ckpt_every=ckpt_every,
            accum_steps=accum_steps,
        )
        # paper-facing energy telemetry on top of the substrate's
        # instruments: per-layer measured event counters + energy
        m = self.metrics
        self._m_layer_events = [
            m.counter(f"train.events.l{i}.total")
            for i in range(self.snn_cfg.num_layers)
        ]
        self._m_energy_total = m.counter("train.energy_pj.total")
        self._m_energy_hist = m.histogram(
            "train.energy_pj_per_inference", lo=1.0, hi=1e12
        )
        self._m_events_hist = m.histogram(
            "train.events_per_inference", lo=1.0, hi=1e9
        )

    def _checkpoint_metric_names(self):
        """Persist the energy-regularizer telemetry next to the
        substrate counters: a resumed run's spike/energy trajectory
        continues instead of restarting from zero."""
        return super()._checkpoint_metric_names() + [
            f"train.events.l{i}.total"
            for i in range(self.snn_cfg.num_layers)
        ] + ["train.energy_pj.total"]

    def _record_window_metrics(self, metrics, window_steps, dt):
        """Substrate instruments plus the event-driven workload's
        spike/energy telemetry.

        The async-dispatch loop only materializes device metrics at
        sync boundaries, so the counters accumulate each window's
        *observed* per-inference measurements (one observation per
        window — a sampled integral, documented as such), while the
        ``train.metrics.*`` gauges and the histograms track the latest
        per-inference values exactly."""
        super()._record_window_metrics(metrics, window_steps, dt)
        total_events = 0.0
        for i, c in enumerate(self._m_layer_events):
            ev = metrics.get(f"events_l{i}")
            if ev is not None and ev >= 0:
                c.inc(ev)
                total_events += ev
        if total_events > 0:
            self._m_events_hist.record(total_events)
        energy = metrics.get("energy_pj")
        if energy is not None:
            if energy >= 0:
                self._m_energy_total.inc(energy)
            self._m_energy_hist.record(energy)

    def evaluate(self, params, batch: Dict[str, Array], *, backend="auto"):
        """Inference-mode accuracy + measured events on the serving path.

        Routes through the fused-capable chunk runtime
        (``event_layer.event_eval_forward``) rather than the BPTT graph:
        params are prepared (fake-quantized) once per call, and on TPU
        the fused Pallas chunk kernel runs the whole window.
        """
        from repro.sparse_train.event_layer import event_eval_forward

        spikes = jnp.moveaxis(batch["spikes"], 0, 1)  # (B,T,K) -> (T,B,K)
        out_mem, out_spikes, events = event_eval_forward(
            params, spikes, self.snn_cfg, backend=backend
        )
        pred = snn.predict_from_traces(out_mem, out_spikes)
        acc = jnp.mean((pred == batch["labels"]).astype(jnp.float32))
        return {
            "accuracy": acc,
            "events_per_layer": jnp.mean(events, axis=1),
            "predictions": pred,
        }


def dvs_batches(
    seed: int,
    batch_size: int,
    tcfg: EventTrainConfig,
    start_step: int = 0,
) -> Iterator[Dict[str, Array]]:
    """Endless stream of freshly-rendered DVS collision batches.

    Each batch renders ``batch_size`` synthetic recordings, AER-encodes
    their brightness changes, and maps ON/OFF polarities onto the input
    layer per ``tcfg.polarity_mode``.

    The stream's PRNG state is exactly ``(seed, step)``: ``start_step``
    fast-forwards the key-split chain so a checkpoint-resumed run sees
    bit-identical batches to an uninterrupted one (pass the restored
    ``state.step`` — ``launch/train.py --resume auto`` does).
    """
    key = jax.random.PRNGKey(seed)
    step = 0
    for _ in range(int(start_step)):
        key, _k = jax.random.split(key)
        step += 1
    while True:
        key, k = jax.random.split(key)
        stream, labels = aer.dvs_collision_batch(
            k,
            batch_size,
            image_hw=tcfg.image_hw,
            num_steps=tcfg.num_steps,
            capacity=tcfg.capacity,
            delta_threshold=tcfg.delta_threshold,
        )
        planes = aer.input_planes(
            stream,
            tcfg.num_steps,
            tcfg.num_pixels,
            polarity_mode=tcfg.polarity_mode,
        )  # (T, B, K)
        yield {
            "spikes": jnp.moveaxis(planes, 0, 1),
            "labels": labels,
            "step_seed": jnp.full((batch_size,), step, jnp.uint32),
        }
        step += 1
