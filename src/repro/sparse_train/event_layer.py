"""Event-driven linear layer with a surrogate-gradient-compatible VJP.

``events.runtime`` made *inference* event-driven: each step gathers only
the weight rows of active input addresses.  Training, however, still ran
through the dense ``core/snn.forward`` graph — autodiff through the
argsort/gather event extraction would (a) recompute the dense matmul's
cost in the backward pass and (b) deliver zero cotangent to inactive
input positions, which breaks surrogate-gradient BPTT (surrogate spike
derivatives are nonzero *off*-spike; that leak is exactly what makes SNNs
trainable).

``event_linear`` solves both with one ``jax.custom_vjp``:

- **forward**: extract the step's event list (``runtime.step_events``) and
  integrate only the gathered rows — either via the batched Pallas
  ``aer_spike_matmul`` kernel or its jnp mirror (``gather_current``).
  Work scales with measured events, not fan-in.
- **backward**:
    * ``w_bar`` **scatters the output cotangent back through the same
      active-event index set**: dense BPTT's weight gradient
      ``h^T @ g`` is supported only on rows whose input actually spiked,
      so the event-set scatter is *exactly* the dense gradient at
      event-count cost (events x fan_out, vs fan_in x fan_out dense).
    * ``h_bar = g @ w^T`` keeps dense support: upstream surrogate VJPs
      need cotangents at silent positions (that is the documented,
      fundamental limit of surrogate BPTT vs. EventProp-style schemes —
      and it only matters for hidden layers; the input layer, the widest
      one, needs no input cotangent at all).
    * ``b_bar = sum_b g``.

Gradient parity with dense ``core/snn`` BPTT is the subsystem's
correctness anchor (tests/test_sparse_train.py).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import neuron, snn
from repro.events import runtime
from repro.kernels import ops

Array = jax.Array


# --------------------------------------------------------------------------
# The custom-VJP event-driven linear layer
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _event_linear(capacity: int, use_kernel: bool, needs_input_grad: bool,
                  h, w, b):
    cur, _ = _event_forward(capacity, use_kernel, h, w, b)
    return cur


def _event_forward(capacity, use_kernel, h, w, b):
    """Gathered-rows-only synaptic integration; returns (cur, (addrs, values))."""
    addrs, values, _ = runtime.step_events(h, capacity)
    if use_kernel:
        # the batched AER Pallas kernel (float32 path): one launch for the
        # whole micro-batch, work proportional to the event capacity
        cur = ops.aer_spike_matmul_batched(addrs, values, w) + b[None, :]
    else:
        # jnp mirror of the kernel's E-block loop (fast on CPU, same math)
        cur = runtime.gather_current(w, b, addrs, values)
    return cur, (addrs, values)


def _event_linear_fwd(capacity, use_kernel, needs_input_grad, h, w, b):
    cur, (addrs, values) = _event_forward(capacity, use_kernel, h, w, b)
    return cur, (addrs, values, w)


def _event_linear_bwd(capacity, use_kernel, needs_input_grad, res, g):
    addrs, values, w = res  # addrs/values: (B, C); w: (K, N); g: (B, N)
    K, N = w.shape
    # input cotangent: dense support — surrogate spike derivatives upstream
    # are nonzero at silent positions, so parity with dense BPTT requires
    # the full row.  Hidden layers only: the input layer's h feeds back to
    # data, so its (widest) g @ w.T is skipped entirely, not just dropped.
    h_bar = (
        g @ w.T
        if needs_input_grad
        else jnp.zeros((g.shape[0], K), g.dtype)
    )
    # weight cotangent: scatter through the SAME active-event index set.
    # Padding slots carry values == 0, so they contribute nothing.
    contrib = values[:, :, None] * g[:, None, :]  # (B, C, N)
    w_bar = jnp.zeros((K, N), g.dtype).at[addrs.reshape(-1)].add(
        contrib.reshape(-1, N), mode="drop"
    )
    b_bar = jnp.sum(g, axis=0)
    return h_bar, w_bar, b_bar


_event_linear.defvjp(_event_linear_fwd, _event_linear_bwd)


def event_linear(
    h: Array,  # (B, K) spike plane (float; {0,1} or signed polarity)
    w: Array,  # (K, N) float weights
    b: Array,  # (N,) float bias
    *,
    capacity: Optional[int] = None,
    use_kernel: bool = False,
    needs_input_grad: bool = True,
) -> Array:
    """Event-driven ``h @ w + b`` whose backward is event-sparse for ``w``.

    ``capacity`` bounds the per-step event list (default: full fan-in, so
    nothing is ever truncated and parity with the dense layer is exact).
    ``needs_input_grad=False`` skips the dense ``g @ w^T`` input cotangent
    (returns zeros) — set it when ``h`` is data, i.e. the input layer.
    """
    if capacity is None:
        capacity = h.shape[-1]
    return _event_linear(
        int(capacity), bool(use_kernel), bool(needs_input_grad), h, w, b
    )


# --------------------------------------------------------------------------
# BPTT over time through the event path
# --------------------------------------------------------------------------


def event_bptt_forward(
    params: Dict[str, Dict[str, Array]],
    spikes: Array,  # (T, B, K) input spike planes ({0,1} or signed)
    cfg: snn.SNNConfig,
    *,
    train: bool = False,
    dropout_key: Optional[jax.Array] = None,
    capacity: Optional[int] = None,
    use_kernel: bool = False,
    prepared: bool = False,
) -> Tuple[Array, Array, Array, Array]:
    """Differentiable event-driven analog of ``core.snn.forward``.

    Same step structure (event_linear -> neuron_step -> dropout after the
    hidden layer in train mode), scanned over time so BPTT composes the
    per-layer event VJPs with the ``core/surrogate`` spike VJPs.

    Returns:
      out_mem:    (T, B, C) output membrane trace (for the loss)
      out_spikes: (T, B, C) output spikes
      events:     (n_layers, B) **measured** input-event counts per layer
                  (non-differentiable tally; feeds the energy model)
      act:        (n_layers,) differentiable mean spike count per layer
                  *output* per inference (feeds the energy regularizer
                  through the surrogate gradients)
    """
    ncfg = cfg.neuron_cfg
    # fake-quant (STE) outside the event layer so QAT gradients chain
    # through the same clip/round path as the dense trainer.  QAT must
    # re-quantize *live* params every step; ``prepared=True`` is for
    # callers holding frozen, already-prepared params (eval/serving).
    p = params if prepared else runtime.prepare_params(params, cfg)

    T, B = spikes.shape[0], spikes.shape[1]
    n_layers = cfg.num_layers
    states = [
        neuron.init_state((B, cfg.layer_sizes[i + 1])) for i in range(n_layers)
    ]
    if train and cfg.dropout_rate > 0.0:
        if dropout_key is None:
            raise ValueError("dropout_key required when train=True")
        drop_keys = jax.random.split(dropout_key, T)
    else:
        drop_keys = jnp.zeros((T, 2), dtype=jnp.uint32)

    def step(carry, xs):
        states, ev, act = carry
        x_t, dk = xs
        new_states, new_ev, new_act = [], [], []
        h = x_t
        for i in range(n_layers):
            lp = p[f"layer{i}"]
            cap = capacity if (capacity is not None and i == 0) else None
            cur = event_linear(
                h, lp["w"], lp["b"], capacity=cap, use_kernel=use_kernel,
                needs_input_grad=(i > 0),  # layer-0 input is data
            )
            # measured events: nnz of the actual layer input this step
            new_ev.append(
                ev[i]
                + jax.lax.stop_gradient(
                    jnp.sum(h != 0, axis=-1).astype(jnp.float32)
                )
            )
            st, spk = neuron.neuron_step(
                ncfg,
                states[i],
                cur,
                beta=snn.effective_beta(lp),
                threshold=lp["threshold"],
            )
            new_states.append(st)
            # differentiable activity: surrogate grads flow through spk
            new_act.append(act[i] + jnp.sum(spk) / B)
            h = spk
            if i == 0 and train and cfg.dropout_rate > 0.0:
                keep = jax.random.bernoulli(
                    dk, 1.0 - cfg.dropout_rate, spk.shape
                ).astype(spk.dtype)
                h = spk * keep / (1.0 - cfg.dropout_rate)
        out_mem_t = new_states[-1].u
        return (tuple(new_states), tuple(new_ev), tuple(new_act)), (
            out_mem_t,
            h,
        )

    ev0 = tuple(jnp.zeros((B,), jnp.float32) for _ in range(n_layers))
    act0 = tuple(jnp.zeros((), jnp.float32) for _ in range(n_layers))
    (_, fin_ev, fin_act), (out_mem, out_spikes) = jax.lax.scan(
        step, (tuple(states), ev0, act0), (spikes, drop_keys)
    )
    return out_mem, out_spikes, jnp.stack(fin_ev), jnp.stack(fin_act)


# --------------------------------------------------------------------------
# Inference through the fused chunk path
# --------------------------------------------------------------------------


def event_eval_forward(
    params: Dict[str, Dict[str, Array]],
    spikes: Array,  # (T, B, K) input spike planes
    cfg: snn.SNNConfig,
    *,
    backend: str = "auto",
    capacities=None,
    prepared: bool = False,
) -> Tuple[Array, Array, Array]:
    """Inference-mode forward on the *serving* hot path.

    Evaluation during event-driven training previously re-ran the
    differentiable BPTT graph; this routes through
    ``events.runtime.run_chunk`` instead — fused Pallas chunk kernel on
    TPU (``backend="auto"``), jnp oracle on CPU — with one-time parameter
    preparation.  Returns (out_mem, out_spikes, events (n_layers, B)),
    matching ``event_bptt_forward``'s inference outputs.
    """
    p = params if prepared else runtime.prepare_params(params, cfg)
    return runtime.event_forward(
        p,
        spikes,
        cfg,
        capacities=capacities,
        prepared=True,
        backend=backend,
    )
