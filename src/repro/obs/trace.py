"""Per-request span tracing for the SNN stream engine.

A ``TraceRecorder`` holds a bounded ring (``collections.deque`` with
``maxlen``) of *completed* spans — recording never allocates unbounded
memory in an always-on engine; the oldest spans fall off the back.  The
engine records two families of spans:

- **Request lifecycle** — ``submit`` (instant, on the queue track),
  ``queue`` (submit -> admission), ``stage`` (the admission upload +
  on-device encode, on the winning slot's track), one ``chunk`` span per
  tick that advanced the request (slot track, tagged with the request id
  and steps taken), and ``complete`` (instant, with latency / energy /
  deadline verdict args).
- **Tick phases** — ``host_prep`` / ``dispatch`` / ``stats_fetch`` spans
  on a dedicated ``tick`` track, one triple per engine tick, so queue
  stalls and pipeline bubbles are visible as gaps on a timeline.

Timestamps are ``time.perf_counter()`` seconds; export shifts them to a
common zero.  ``chrome_trace()`` emits Chrome trace-event JSON (the
``traceEvents`` array format) loadable in Perfetto (ui.perfetto.dev) or
``chrome://tracing``: each distinct track becomes a named thread of one
``engine`` process, spans are ``ph: "X"`` complete events, instants are
``ph: "i"`` with thread scope.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Dict, List, Optional

__all__ = ["Span", "TraceRecorder"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed span (or instant, when ``t1 is None``)."""

    name: str
    t0: float  # perf_counter seconds
    t1: Optional[float]  # None -> instant event
    track: str = "engine"
    cat: str = "engine"
    args: Optional[Dict] = None

    @property
    def duration_s(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0


class TraceRecorder:
    """Bounded ring of completed spans + Chrome trace-event export."""

    def __init__(self, capacity: int = 8192, enabled: bool = True):
        if capacity < 1:
            raise ValueError("trace capacity must be >= 1")
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self._spans: "collections.deque[Span]" = collections.deque(
            maxlen=self.capacity
        )

    @staticmethod
    def now() -> float:
        return time.perf_counter()

    def span(
        self,
        name: str,
        t0: float,
        t1: float,
        *,
        track: str = "engine",
        cat: str = "engine",
        args: Optional[Dict] = None,
    ) -> None:
        """Record a completed span.  ``t1 < t0`` is rejected loudly —
        monotonic timestamps are an invariant the tests pin."""
        if not self.enabled:
            return
        if t1 < t0:
            raise ValueError(f"span {name!r}: t1 {t1} < t0 {t0}")
        self._spans.append(Span(name, t0, t1, track, cat, args))

    def instant(
        self,
        name: str,
        t: Optional[float] = None,
        *,
        track: str = "engine",
        cat: str = "engine",
        args: Optional[Dict] = None,
    ) -> None:
        if not self.enabled:
            return
        t = self.now() if t is None else t
        self._spans.append(Span(name, t, None, track, cat, args))

    def spans(self) -> List[Span]:
        """Snapshot of the ring, oldest first."""
        return list(self._spans)

    def clear(self) -> None:
        self._spans.clear()

    def __len__(self) -> int:
        return len(self._spans)

    # ------------------------------------------------------------ export
    def chrome_trace(self) -> Dict:
        """Chrome trace-event JSON object (``{"traceEvents": [...]}``),
        Perfetto-loadable.  Tracks map to threads of one process, in
        first-seen order; timestamps are microseconds from the earliest
        recorded span."""
        spans = self.spans()
        base = min((s.t0 for s in spans), default=0.0)
        tids: Dict[str, int] = {}
        events: List[Dict] = []
        for s in spans:
            tid = tids.setdefault(s.track, len(tids) + 1)
            ev = {
                "name": s.name,
                "cat": s.cat,
                "pid": 1,
                "tid": tid,
                "ts": (s.t0 - base) * 1e6,
            }
            if s.args:
                ev["args"] = dict(s.args)
            if s.t1 is None:
                ev["ph"] = "i"
                ev["s"] = "t"  # thread-scoped instant
            else:
                ev["ph"] = "X"
                ev["dur"] = (s.t1 - s.t0) * 1e6
            events.append(ev)
        meta = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": 1,
                "args": {"name": "snn_stream_engine"},
            }
        ] + [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": 1,
                "tid": tid,
                "args": {"name": track},
            }
            for track, tid in tids.items()
        ]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
            f.write("\n")
