"""Dependency-free metrics registry for the serving stack.

Three instrument kinds, one registry:

- ``Counter`` — monotonically increasing float (events served, steps
  dispatched, deadline misses).
- ``Gauge`` — last-write-wins scalar (queue depth, episode wall time).
- ``Histogram`` — fixed-bucket *log-scale* histogram with exact
  count/sum/min/max and approximate percentiles.  Bucket upper edges are
  geometric: ``lo * 10**(i / buckets_per_decade)``, so relative
  resolution is constant across the range — right for latencies and
  energies that span decades.  Percentile extraction walks the
  cumulative counts and interpolates *geometrically* inside the landing
  bucket, then clamps to the observed ``[min, max]``; the worst-case
  relative error is one bucket ratio (``10**(1/buckets_per_decade)``,
  ~15.5% at the default 16 buckets/decade), which the obs test suite
  pins against numpy on known distributions.

Everything is plain Python (stdlib ``math``/``bisect`` only): recording
is a few arithmetic ops and a bisect, cheap enough to leave on in the
serving hot loop — ``benchmarks/stream_bench.py`` measures the actual
per-tick instrumentation cost and asserts it stays under 2% of a tick.
The engine is single-threaded, so instruments are unlocked; wrap the
registry externally if you share one across threads.

Snapshots are plain JSON-able dicts (``registry.snapshot()``), the
export format carried by ``stream_bench.json`` v3 and
``launch/serve.py --metrics-json``.
"""

from __future__ import annotations

import bisect
import json
import math
from typing import Dict, List, Optional, Union

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonic float counter."""

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name}: negative increment {v}")
        self._value += v

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        self._value = 0.0

    def snapshot(self) -> Dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-write-wins scalar."""

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        self._value = 0.0

    def snapshot(self) -> Dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Fixed-bucket log-scale histogram with p50/p90/p99 extraction.

    Values ``<= lo`` land in the underflow bucket, values ``> hi`` (after
    rounding ``hi`` up to a whole bucket edge) in the overflow bucket;
    both are reported separately so a snapshot always accounts for every
    recorded value exactly (``underflow + overflow + sum(bucket counts)
    == count``).  Non-positive values count as underflow — log buckets
    cannot place them, but min/sum/count still track them exactly.

    Non-finite values (a diverged loss going NaN, an inf latency from a
    broken clock) are counted in a separate ``invalid`` field and kept
    out of count/sum/min/max/buckets entirely: one NaN must not poison
    ``sum``/``mean`` forever (``nan + x == nan``) or land silently in
    bucket 0 via ``bisect_left``'s NaN comparison semantics.
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        *,
        lo: float,
        hi: float,
        buckets_per_decade: int = 16,
    ):
        if not (0 < lo < hi):
            raise ValueError(f"histogram {name}: need 0 < lo < hi")
        if buckets_per_decade < 1:
            raise ValueError(f"histogram {name}: buckets_per_decade >= 1")
        self.name = name
        self.lo = float(lo)
        self.hi = float(hi)
        self.buckets_per_decade = int(buckets_per_decade)
        n = int(math.ceil(
            round(math.log10(hi / lo), 9) * buckets_per_decade
        ))
        n = max(n, 1)
        # upper edges; edges[-1] >= hi by construction
        self._edges: List[float] = [
            lo * 10.0 ** ((i + 1) / buckets_per_decade) for i in range(n)
        ]
        self._counts = [0] * n
        self._underflow = 0
        self._overflow = 0
        self.count = 0
        self.sum = 0.0
        self.invalid = 0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            # NaN/inf: tallied separately, kept out of every finite
            # statistic (a single NaN would otherwise poison sum/mean
            # forever and bisect into bucket 0)
            self.invalid += 1
            return
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= self.lo:
            self._underflow += 1
        elif v > self._edges[-1]:
            self._overflow += 1
        else:
            self._counts[bisect.bisect_left(self._edges, v)] += 1

    def reset(self) -> None:
        self._counts = [0] * len(self._counts)
        self._underflow = 0
        self._overflow = 0
        self.count = 0
        self.sum = 0.0
        self.invalid = 0
        self.min = math.inf
        self.max = -math.inf

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (nearest-rank over buckets,
        geometric interpolation inside the landing bucket, clamped to
        the observed [min, max]).  0.0 when empty."""
        if self.count == 0:
            return 0.0
        target = max(1, int(math.ceil(q / 100.0 * self.count)))
        cum = self._underflow
        if target <= cum:
            # everything below lo collapses to the exact observed min
            return self.min
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if target <= cum + c:
                lower = self.lo if i == 0 else self._edges[i - 1]
                upper = self._edges[i]
                frac = (target - cum) / c
                est = lower * (upper / lower) ** frac
                return min(max(est, self.min), self.max)
            cum += c
        return self.max  # overflow bucket

    def snapshot(self) -> Dict:
        empty = self.count == 0
        return {
            "type": "histogram",
            "count": self.count,
            "sum": self.sum,
            "min": 0.0 if empty else self.min,
            "max": 0.0 if empty else self.max,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
            "lo": self.lo,
            "hi": self.hi,
            "buckets_per_decade": self.buckets_per_decade,
            "underflow": self._underflow,
            "overflow": self._overflow,
            "invalid": self.invalid,
            # sparse: only non-empty buckets, as [upper_edge, count]
            "buckets": [
                [self._edges[i], c]
                for i, c in enumerate(self._counts)
                if c
            ],
        }


Instrument = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Flat name -> instrument registry with get-or-create accessors.

    Names are dot-paths (``engine.request.latency_s``); prefix-scoped
    ``reset`` gives episode-scoped counters their lifecycle without a
    second registry.
    """

    def __init__(self):
        self._instruments: Dict[str, Instrument] = {}

    def _get_or_create(self, name: str, factory, kind: str):
        inst = self._instruments.get(name)
        if inst is None:
            inst = factory()
            self._instruments[name] = inst
        elif inst.kind != kind:
            raise TypeError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"requested {kind}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, lambda: Counter(name), "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, lambda: Gauge(name), "gauge")

    def histogram(
        self,
        name: str,
        *,
        lo: float = 1e-6,
        hi: float = 1e3,
        buckets_per_decade: int = 16,
    ) -> Histogram:
        return self._get_or_create(
            name,
            lambda: Histogram(
                name, lo=lo, hi=hi, buckets_per_decade=buckets_per_decade
            ),
            "histogram",
        )

    def get(self, name: str) -> Optional[Instrument]:
        return self._instruments.get(name)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def reset(self, prefix: Optional[str] = None) -> None:
        """Reset instruments in place (all, or those whose name starts
        with ``prefix``) — registrations survive, values zero."""
        for name, inst in self._instruments.items():
            if prefix is None or name.startswith(prefix):
                inst.reset()

    def snapshot(self) -> Dict[str, Dict]:
        return {
            name: inst.snapshot()
            for name, inst in sorted(self._instruments.items())
        }

    def write_json(self, path) -> None:
        """Deterministically ordered dump: instruments sort by name (via
        ``snapshot``), nested keys sort via ``sort_keys``, and bucket
        arrays are ascending-edge lists by construction — two runs over
        identical data produce byte-identical sidecars, so metrics
        artifacts diff cleanly across CI runs."""
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")


def percentile_tolerance(buckets_per_decade: int) -> float:
    """The histogram's worst-case relative percentile error: one bucket
    ratio.  Test helper — asserts live in tests/test_obs.py."""
    return 10.0 ** (1.0 / buckets_per_decade)
