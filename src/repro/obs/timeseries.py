"""Windowed time-series sampling over a ``MetricsRegistry``.

PR 6's registry answers "what happened over the whole run"; this module
answers "what is happening *now*" — the signal an SLO burn-rate rule, a
load-shedding admission plane, or a dashboard needs.  A
``TimeSeriesSampler`` is pointed at a registry and ``sample()``d at
whatever cadence the caller owns (the stream engine samples per tick and
per admission; the trainer samples per log window).  Each sample captures:

- the **absolute value** of every tracked instrument (counter/gauge
  value, histogram count + sum),
- the **delta** of every counter-like value since the previous sample
  (with Prometheus-style reset detection: a value that went *down* is
  treated as a reset-to-zero followed by increments, so episode-scoped
  counters that reset mid-series keep their deltas non-negative and
  summable), and
- for explicitly listed histograms, the cumulative bucket counts — so a
  *windowed* histogram (and its p99) can be reconstructed as the
  difference of two cumulative snapshots.

Samples live in a bounded ring; cumulative delta totals are tracked
separately (``cum()``), so the "sum of deltas == lifetime total"
consistency check survives ring overflow.  Windowed **rates** divide
summed deltas by summed elapsed time (``rate()``), and windowed
**ratios** divide two counters' deltas (``ratio()`` — e.g. deadline
misses / completions = windowed miss-rate) instead of the lifetime
averages a snapshot gives.

``write_jsonl(path)`` exports the ring as a JSONL sidecar — one
self-describing object per line (``t``/``dt``/``values``/``deltas``) —
the format ``stream_bench.json`` v4 names under ``artifacts`` and CI
uploads.  Everything is plain Python; a sample is a few dict builds and
float reads, and ``obs.profiler.tick_instrumentation_cost_us`` measures
it as part of the per-tick instrumentation budget (< 2% of a tick).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import Gauge, Histogram, MetricsRegistry

__all__ = ["Sample", "TimeSeriesSampler"]


@dataclasses.dataclass(frozen=True)
class Sample:
    """One timestamped capture of the tracked instruments."""

    t: float  # perf_counter seconds
    dt: float  # seconds since the previous sample (0.0 for the first)
    values: Dict[str, float]  # absolute instrument values
    deltas: Dict[str, float]  # counter-like deltas since previous sample
    buckets: Dict[str, Tuple[int, ...]]  # cumulative bucket counts
    # (underflow, *bucket_counts, overflow) for tracked histograms


def _instrument_values(inst) -> Dict[str, float]:
    """Flatten one instrument into the per-sample value dict.

    Counters/gauges contribute their value under their own name;
    histograms contribute ``<name>.count`` and ``<name>.sum`` (both
    monotone while un-reset, so they delta like counters and windowed
    means fall out as dsum/dcount).
    """
    if isinstance(inst, Histogram):
        return {f"{inst.name}.count": float(inst.count),
                f"{inst.name}.sum": float(inst.sum)}
    return {inst.name: float(inst.value)}


class TimeSeriesSampler:
    """Bounded ring of registry samples with windowed rate extraction."""

    def __init__(
        self,
        registry: MetricsRegistry,
        *,
        capacity: int = 4096,
        track_buckets: Sequence[str] = (),
        clock=time.perf_counter,
    ):
        if capacity < 2:
            raise ValueError("timeseries capacity must be >= 2")
        self.registry = registry
        self.capacity = int(capacity)
        self.track_buckets = tuple(track_buckets)
        self._clock = clock
        self.restart()

    # ------------------------------------------------------------ capture
    def restart(self) -> None:
        """Clear the ring and re-baseline deltas at the instruments'
        *current* values — the post-warmup reset point benchmarks use so
        warmup activity never leaks into windowed rates or the
        sum-of-deltas consistency check."""
        self._samples: List[Sample] = []
        self._prev: Dict[str, float] = {}
        self._cum: Dict[str, float] = {}
        self._t_prev: Optional[float] = None
        for name in self.registry.names():
            inst = self.registry.get(name)
            self._prev.update(_instrument_values(inst))

    def sample(self, t: Optional[float] = None) -> Sample:
        """Capture one sample; returns it (and appends it to the ring)."""
        t = self._clock() if t is None else float(t)
        values: Dict[str, float] = {}
        deltas: Dict[str, float] = {}
        buckets: Dict[str, Tuple[int, ...]] = {}
        for name in self.registry.names():
            inst = self.registry.get(name)
            vals = _instrument_values(inst)
            values.update(vals)
            if isinstance(inst, Gauge):
                continue  # gauges carry level, not flow: no delta
            for key, cur in vals.items():
                prev = self._prev.get(key, 0.0)
                # Prometheus-style reset detection: a monotone value
                # that went down was reset to zero and re-incremented
                d = cur if cur < prev else cur - prev
                deltas[key] = d
                self._cum[key] = self._cum.get(key, 0.0) + d
        for name in self.track_buckets:
            inst = self.registry.get(name)
            if isinstance(inst, Histogram):
                buckets[name] = (
                    inst._underflow, *inst._counts, inst._overflow
                )
        dt = 0.0 if self._t_prev is None else max(t - self._t_prev, 0.0)
        self._t_prev = t
        self._prev = values
        s = Sample(t=t, dt=dt, values=values, deltas=deltas,
                   buckets=buckets)
        self._samples.append(s)
        if len(self._samples) > self.capacity:
            del self._samples[0]
        return s

    # ------------------------------------------------------------ queries
    def __len__(self) -> int:
        return len(self._samples)

    def samples(self) -> List[Sample]:
        """Snapshot of the ring, oldest first."""
        return list(self._samples)

    def cum(self, key: str) -> float:
        """Cumulative delta total for ``key`` since the last restart —
        robust to ring overflow (it accumulates outside the ring), so
        ``baseline + cum == lifetime value`` always holds for counters
        that never reset."""
        return self._cum.get(key, 0.0)

    def span_s(self) -> float:
        """Wall-clock span the ring currently covers."""
        if len(self._samples) < 2:
            return 0.0
        return self._samples[-1].t - self._samples[0].t

    def _window(self, window_s: Optional[float]) -> List[Sample]:
        """Samples whose delta interval ends within the trailing window
        (the first sample carries no interval and never contributes)."""
        if not self._samples:
            return []
        if window_s is None:
            return self._samples[1:]
        t_end = self._samples[-1].t
        return [
            s for s in self._samples[1:] if t_end - s.t < window_s
        ]

    def window_sum(self, key: str, window_s: Optional[float] = None) -> float:
        """Summed deltas of ``key`` over the trailing window (whole
        series when ``window_s`` is None)."""
        return sum(s.deltas.get(key, 0.0) for s in self._window(window_s))

    def window_elapsed(self, window_s: Optional[float] = None) -> float:
        return sum(s.dt for s in self._window(window_s))

    def rate(self, key: str, window_s: Optional[float] = None) -> float:
        """Windowed rate (deltas per second) of a counter-like key —
        e.g. ``rate("engine.episode.events")`` is events/s over the
        window, not the lifetime average."""
        el = self.window_elapsed(window_s)
        return self.window_sum(key, window_s) / el if el > 0 else 0.0

    def ratio(
        self,
        num_key: str,
        den_key: str,
        window_s: Optional[float] = None,
    ) -> float:
        """Windowed ratio of two counters' deltas (e.g. deadline misses
        over completions = the windowed miss-rate).  0.0 when the
        denominator saw no flow in the window."""
        den = self.window_sum(den_key, window_s)
        return self.window_sum(num_key, window_s) / den if den > 0 else 0.0

    def windowed_histogram(
        self, name: str, window_s: Optional[float] = None
    ) -> Optional[Histogram]:
        """Reconstruct the histogram of values recorded *within* the
        trailing window as the difference of two cumulative bucket
        snapshots.  Needs ``name`` in ``track_buckets`` and >= 2 samples;
        returns None otherwise.  min/max are unknowable from bucket
        diffs, so the result leaves them infinite and percentiles clamp
        to bucket edges only."""
        if name not in self.track_buckets or len(self._samples) < 2:
            return None
        win = self._window(window_s)
        if not win:
            return None
        # base = the sample *before* the window's first interval
        first_idx = self._samples.index(win[0])
        base = self._samples[first_idx - 1].buckets.get(name)
        end = self._samples[-1].buckets.get(name)
        live = self.registry.get(name)
        if base is None or end is None or not isinstance(live, Histogram):
            return None
        h = Histogram(
            f"{name}.window", lo=live.lo, hi=live.hi,
            buckets_per_decade=live.buckets_per_decade,
        )
        diff = [max(e - b, 0) for e, b in zip(end, base)]
        h._underflow = diff[0]
        h._overflow = diff[-1]
        h._counts = diff[1:-1]
        h.count = sum(diff)
        # sum is reconstructible from the .sum delta series
        h.sum = self.window_sum(f"{name}.sum", window_s)
        # observed min/max are not recoverable from bucket diffs: clamp
        # percentiles to bucket geometry instead of observed extremes
        h.min = h.lo
        h.max = h._edges[-1]
        return h

    # ------------------------------------------------------------- export
    def summary(self, window_s: Optional[float] = None) -> Dict:
        """JSON-able summary of the trailing window: per-key rates for
        every delta key plus sample accounting."""
        el = self.window_elapsed(window_s)
        keys = sorted(
            {k for s in self._window(window_s) for k in s.deltas}
        )
        return {
            "samples": len(self._samples),
            "span_s": self.span_s(),
            "window_s": window_s,
            "window_elapsed_s": el,
            "rates_per_s": {k: self.rate(k, window_s) for k in keys},
        }

    def write_jsonl(self, path) -> None:
        """One JSON object per line, oldest sample first.  Keys are
        sorted so sidecars diff cleanly across runs of identical data."""
        with open(path, "w") as f:
            for s in self._samples:
                f.write(json.dumps(
                    {
                        "t": s.t,
                        "dt": s.dt,
                        "values": s.values,
                        "deltas": s.deltas,
                    },
                    sort_keys=True,
                ))
                f.write("\n")
