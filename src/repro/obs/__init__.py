"""Observability layer: metrics registry, span tracing, tick profiling,
windowed time series, and SLO burn-rate evaluation.

- ``obs.metrics`` — dependency-free counters / gauges / log-bucket
  histograms behind a ``MetricsRegistry`` (JSON-able snapshots).
- ``obs.trace`` — bounded ring of completed spans, exported as Chrome
  trace-event JSON (Perfetto-loadable).
- ``obs.profiler`` — programmatic ``jax.profiler`` capture around N
  steady-state engine ticks, plus a blocking probe that splits dispatch
  time into host-enqueue vs device-compute wait.
- ``obs.timeseries`` — bounded ring of timestamped registry samples
  with counter-delta windowed rates (events/s, miss-rate over the last
  window, not lifetime averages) and JSONL sidecar export.
- ``obs.slo`` — declarative SLO specs (error budgets, p99 latency
  targets) judged by multi-window burn-rate rules over the time
  series: ``healthy`` / ``degraded`` / ``breach``.
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import Span, TraceRecorder
from repro.obs.profiler import (
    dispatch_attribution,
    profile_ticks,
    tick_instrumentation_cost_us,
)
from repro.obs.timeseries import Sample, TimeSeriesSampler
from repro.obs.slo import (
    BurnRateRule,
    ErrorBudgetSLO,
    LatencySLO,
    STATUS_CODES,
    default_slos,
    evaluate as evaluate_slos,
    shed_rate_slo,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "TraceRecorder",
    "dispatch_attribution",
    "profile_ticks",
    "tick_instrumentation_cost_us",
    "Sample",
    "TimeSeriesSampler",
    "BurnRateRule",
    "ErrorBudgetSLO",
    "LatencySLO",
    "STATUS_CODES",
    "default_slos",
    "evaluate_slos",
    "shed_rate_slo",
]
