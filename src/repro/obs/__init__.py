"""Observability layer: metrics registry, span tracing, tick profiling.

- ``obs.metrics`` — dependency-free counters / gauges / log-bucket
  histograms behind a ``MetricsRegistry`` (JSON-able snapshots).
- ``obs.trace`` — bounded ring of completed spans, exported as Chrome
  trace-event JSON (Perfetto-loadable).
- ``obs.profiler`` — programmatic ``jax.profiler`` capture around N
  steady-state engine ticks, plus a blocking probe that splits dispatch
  time into host-enqueue vs device-compute wait.
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import Span, TraceRecorder
from repro.obs.profiler import (
    dispatch_attribution,
    profile_ticks,
    tick_instrumentation_cost_us,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "TraceRecorder",
    "dispatch_attribution",
    "profile_ticks",
    "tick_instrumentation_cost_us",
]
