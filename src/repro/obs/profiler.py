"""Tick-phase profiling: ``jax.profiler`` capture + dispatch attribution.

Two instruments for ROADMAP item 2's open question — *where do the
~10.5ms of per-tick ``dispatch_us`` go?*

- ``profile_ticks(engine, ...)`` arms a programmatic
  ``jax.profiler.start_trace`` / ``stop_trace`` window around N
  steady-state engine ticks (skipping warmup polls so first-tick
  compilation never pollutes the capture).  The resulting directory
  opens in Perfetto / TensorBoard and shows device compute against the
  host tick loop.
- ``dispatch_attribution(fn, *args)`` is a dependency-free blocking
  probe: it times the chunk call *returning* (host enqueue — Python
  dispatch + graph launch) separately from ``block_until_ready``
  (device-compute wait), splitting the engine's ``dispatch_us`` bucket
  into "host overhead to attack" vs "the device was simply busy".  On
  backends that serialize dispatch behind donated buffers the enqueue
  share is the true host cost either way.

``tick_instrumentation_cost_us(...)`` microbenches the exact
metrics/trace operations one engine tick performs — including the
per-tick time-series sample the windowed-rate/SLO layer adds — against
*scratch* instruments, so ``stream_bench.py`` can assert the
observability layer costs <2% of a tick without perturbing the live
registry.
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TraceRecorder

__all__ = [
    "profile_ticks",
    "dispatch_attribution",
    "tick_instrumentation_cost_us",
]


class _TickProfileHandle:
    """Wraps ``engine.poll``: starts the jax profiler trace after
    ``skip`` polls, stops it ``num_ticks`` polls later, then restores
    the original ``poll``.  ``stop()`` is idempotent and safe to call
    early (e.g. the serve loop drained first)."""

    def __init__(self, engine, logdir: str, num_ticks: int, skip: int):
        self._engine = engine
        self.logdir = str(logdir)
        self.num_ticks = int(num_ticks)
        self._skip = int(skip)
        self._seen = 0
        self._started = False
        self.stopped = False
        self.error: Optional[str] = None
        self._orig_poll = engine.poll
        engine.poll = self._wrapped_poll  # instance attr shadows method

    def _start(self) -> None:
        try:
            jax.profiler.start_trace(self.logdir)
            self._started = True
        except Exception as e:  # profiler backend unavailable
            self.error = f"jax.profiler.start_trace failed: {e}"
            self.stopped = True
            self._engine.poll = self._orig_poll

    def _wrapped_poll(self):
        if not self._started and not self.stopped:
            if self._seen >= self._skip:
                self._start()
            else:
                self._seen += 1
        out = self._orig_poll()
        if self._started and not self.stopped:
            self._seen += 1
            if self._seen >= self._skip + self.num_ticks:
                self.stop()
        return out

    def stop(self) -> None:
        if self.stopped:
            return
        self.stopped = True
        self._engine.poll = self._orig_poll
        if self._started:
            # block so the capture includes the in-flight chunk's compute
            jax.block_until_ready(self._engine._states)
            try:
                jax.profiler.stop_trace()
            except Exception as e:
                self.error = f"jax.profiler.stop_trace failed: {e}"


def profile_ticks(
    engine, logdir: str, num_ticks: int = 20, skip: int = 2
) -> _TickProfileHandle:
    """Arm a ``jax.profiler`` capture around the engine's next
    ``num_ticks`` steady-state polls (after ``skip`` warmup polls).

    Returns a handle; call ``handle.stop()`` after serving (idempotent —
    a no-op if the tick budget already closed the capture).  Works for
    both the open-loop ``poll()`` driver and the closed-loop ``run()``
    wrapper, which funnels through ``poll`` internally.
    """
    if num_ticks < 1:
        raise ValueError("num_ticks must be >= 1")
    return _TickProfileHandle(engine, logdir, num_ticks, max(0, skip))


def dispatch_attribution(
    fn, *args, warmup: int = 1, iters: int = 5
) -> Dict:
    """Split a jitted call's wall time into host-enqueue vs
    device-compute wait.

    Times ``fn(*args)`` *returning* (enqueue: Python/jit dispatch and
    graph launch) separately from ``jax.block_until_ready`` on its
    outputs (device wait).  Medians over ``iters``; each iteration
    blocks before the next so work never queues up.  The caller should
    pass a non-donating compiled function (``engine.chunk_for_timing()``)
    so the same arguments are reusable.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    enq, tot = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        t1 = time.perf_counter()
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        enq.append(t1 - t0)
        tot.append(t2 - t0)
    enq.sort()
    tot.sort()
    enqueue_s = enq[len(enq) // 2]
    total_s = tot[len(tot) // 2]
    device_wait_s = max(total_s - enqueue_s, 0.0)
    frac = device_wait_s / total_s if total_s > 0 else 0.0
    if frac >= 0.5:
        verdict = (
            "device-compute wait dominates: dispatch_us is the chunk's "
            "actual compute, not host dispatch overhead to attack"
        )
    else:
        verdict = (
            "host enqueue dominates: dispatch_us is Python/jit graph "
            "launch overhead — attack the host path"
        )
    return {
        "host_enqueue_us": enqueue_s * 1e6,
        "device_wait_us": device_wait_s * 1e6,
        "total_us": total_s * 1e6,
        "device_wait_frac": frac,
        "iters": iters,
        "verdict": verdict,
    }


def tick_instrumentation_cost_us(
    num_slots: int, reps: int = 2000
) -> float:
    """Measured cost (µs) of the metrics/trace work one engine tick
    performs, against scratch instruments: 3 tick-phase histogram
    records + 3 tick-phase spans, one chunk span per slot, the
    counter/gauge updates ``_tick``/``_retire`` make, and one
    time-series sample (with latency-bucket tracking) as taken by the
    windowed-rate/SLO layer each ``poll()``.  This is the number
    ``stream_bench.py`` compares against the measured tick time to
    bound instrumentation overhead."""
    from repro.obs.timeseries import TimeSeriesSampler

    reg = MetricsRegistry()
    rec = TraceRecorder(capacity=1024)
    hs = [
        reg.histogram(f"probe.tick.{k}_s", lo=1e-7, hi=10.0)
        for k in ("host_prep", "dispatch", "stats_fetch")
    ]
    lat = reg.histogram("probe.request.latency_s", lo=1e-6, hi=1e3)
    lat.record(0.05)
    ticks = reg.counter("probe.ticks")
    events = reg.counter("probe.events")
    steps = reg.counter("probe.steps")
    depth = reg.gauge("probe.queue_depth")
    sampler = TimeSeriesSampler(
        reg, capacity=4096, track_buckets=("probe.request.latency_s",)
    )
    t_start = time.perf_counter()
    for i in range(reps):
        t0 = time.perf_counter()
        for h in hs:
            h.record(1.1e-3)
        rec.span("host_prep", t0, t0 + 1e-5, track="tick")
        rec.span("dispatch", t0, t0 + 1e-3, track="tick")
        rec.span("stats_fetch", t0, t0 + 1e-4, track="tick")
        for s in range(num_slots):
            rec.span(
                "chunk", t0, t0 + 1e-3,
                track=f"slot{s}", args={"rid": i, "steps": 5},
            )
        ticks.inc()
        events.inc(1234.0)
        steps.inc(20.0)
        depth.set(float(i % 7))
        lat.record(0.01 * (1 + i % 3))
        sampler.sample()
    return (time.perf_counter() - t_start) / reps * 1e6
