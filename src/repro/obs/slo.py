"""Declarative SLOs with multi-window burn-rate evaluation.

An SLO pairs an *objective* ("99% of requests meet their deadline",
"p99 latency under 500 ms") with an *error budget* (the tolerated
failure fraction) and is judged by its **burn rate**: the ratio of the
observed windowed error rate to the budgeted rate.  Burn rate 1.0 means
the budget is being consumed exactly at the sustainable pace; 10x means
it will be gone in a tenth of the period.

Evaluation follows the multi-window pattern (Google SRE workbook ch. 5):
each :class:`BurnRateRule` fires only when the burn rate exceeds its
threshold over **both** a long window (evidence the problem is real, not
a blip) and a short window (evidence it is *still* happening — the rule
un-fires quickly once the incident ends).  Rules carry a severity; the
worst severity across fired rules, across SLOs, is the overall verdict:

    ``healthy``  — no rule fired
    ``degraded`` — a warn-severity rule fired (slow burn)
    ``breach``   — a page-severity rule fired (fast burn)

Two spec kinds cover the serving engine's needs:

- :class:`ErrorBudgetSLO` — a good/total counter pair (deadline misses
  over completions).  Windowed error rate = delta(errors)/delta(total)
  from the :class:`~repro.obs.timeseries.TimeSeriesSampler`.
- :class:`LatencySLO` — a percentile target over a histogram the
  sampler tracks buckets for.  The objective "p99 <= target" is
  evaluated as its error-budget equivalent — at most (100-p)% of
  requests may exceed the target — with the windowed fraction-over-
  target read exactly (at bucket granularity) from the windowed
  histogram reconstruction.

Windows are clipped to the data the series actually holds (a 5 s window
over a 2 s bench run reads the whole run, flagged ``clipped``); a rule
with *no* flow in its window abstains rather than firing.

``evaluate()`` returns a JSON-able report; ``SNNStreamEngine.health()``
runs it over the engine's own sampler, publishes the verdict as the
``engine.slo.status`` gauge (0/1/2), and ``stream_bench.json`` v4
carries the full report as its SLO verdict block.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.obs.timeseries import TimeSeriesSampler

__all__ = [
    "BurnRateRule",
    "ErrorBudgetSLO",
    "LatencySLO",
    "STATUS_CODES",
    "default_slos",
    "evaluate",
    "shed_rate_slo",
    "status_of",
]

# gauge encoding of the verdict (engine.slo.status)
STATUS_CODES = {"healthy": 0, "degraded": 1, "breach": 2}
_SEVERITIES = ("degraded", "breach")


@dataclasses.dataclass(frozen=True)
class BurnRateRule:
    """Fire ``severity`` when burn rate > ``threshold`` over both
    windows.  Classic pairs: (long=1h, short=5m, 14.4x, page) and
    (long=6h, short=30m, 6x, warn) for a 30-day budget; serving-bench
    scale uses seconds — the semantics are window-size agnostic."""

    long_window_s: float
    short_window_s: float
    threshold: float  # x budget
    severity: str = "breach"

    def __post_init__(self):
        if self.severity not in _SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {_SEVERITIES}"
            )
        if not (0 < self.short_window_s <= self.long_window_s):
            raise ValueError(
                "need 0 < short_window_s <= long_window_s "
                f"({self.short_window_s}, {self.long_window_s})"
            )
        if self.threshold <= 0:
            raise ValueError("burn threshold must be > 0")


@dataclasses.dataclass(frozen=True)
class ErrorBudgetSLO:
    """Objective: at least ``objective`` of ``total_key`` flow is *not*
    counted by ``error_key``.  Budget = 1 - objective."""

    name: str
    error_key: str  # counter (or histogram .count) delta key
    total_key: str
    objective: float  # e.g. 0.95 -> 5% error budget
    rules: Tuple[BurnRateRule, ...]

    def __post_init__(self):
        if not (0.0 < self.objective < 1.0):
            raise ValueError(f"objective must be in (0, 1): {self.objective}")

    @property
    def budget(self) -> float:
        return 1.0 - self.objective

    def error_rate(
        self, series: TimeSeriesSampler, window_s: Optional[float]
    ) -> Tuple[Optional[float], float]:
        """(windowed error fraction or None when no flow, total flow)."""
        total = series.window_sum(self.total_key, window_s)
        if total <= 0:
            return None, 0.0
        return series.window_sum(self.error_key, window_s) / total, total


@dataclasses.dataclass(frozen=True)
class LatencySLO:
    """Objective: the ``percentile``-th percentile of ``histogram_key``
    stays <= ``target_s`` — evaluated as the equivalent error budget
    (at most (100-percentile)% of requests over target)."""

    name: str
    histogram_key: str  # must be in the sampler's track_buckets
    target_s: float
    percentile: float = 99.0
    rules: Tuple[BurnRateRule, ...] = ()

    def __post_init__(self):
        if not (0.0 < self.percentile < 100.0):
            raise ValueError("percentile must be in (0, 100)")
        if self.target_s <= 0:
            raise ValueError("target_s must be > 0")

    @property
    def budget(self) -> float:
        return (100.0 - self.percentile) / 100.0

    def error_rate(
        self, series: TimeSeriesSampler, window_s: Optional[float]
    ) -> Tuple[Optional[float], float]:
        """Windowed fraction of recorded values above ``target_s``,
        from the bucket-diff reconstruction (exact at bucket
        granularity: a bucket counts as "over" when its lower edge is
        >= target, "under" when its upper edge is <= target, and the
        straddling bucket splits geometrically)."""
        h = series.windowed_histogram(self.histogram_key, window_s)
        if h is None or h.count == 0:
            return None, 0.0
        over = float(h._overflow)
        target = self.target_s
        for i, c in enumerate(h._counts):
            if not c:
                continue
            lower = h.lo if i == 0 else h._edges[i - 1]
            upper = h._edges[i]
            if lower >= target:
                over += c
            elif upper > target:
                # geometric split of the straddling bucket
                frac_under = (
                    math.log(target / lower) / math.log(upper / lower)
                )
                over += c * (1.0 - frac_under)
        return over / h.count, float(h.count)


SLOSpec = Union[ErrorBudgetSLO, LatencySLO]


def default_slos(
    *,
    deadline_objective: float = 0.95,
    p99_target_s: float = 1.0,
    scale_s: float = 1.0,
) -> Tuple[SLOSpec, ...]:
    """The serving engine's standard SLO pair.

    ``scale_s`` stretches the rule windows (1.0 = bench scale: 2 s/0.5 s
    fast-burn page, 8 s/2 s slow-burn warn; a long-lived fleet would
    pass minutes-to-hours scale).
    """
    rules = (
        BurnRateRule(
            long_window_s=2.0 * scale_s,
            short_window_s=0.5 * scale_s,
            threshold=10.0,
            severity="breach",
        ),
        BurnRateRule(
            long_window_s=8.0 * scale_s,
            short_window_s=2.0 * scale_s,
            threshold=2.0,
            severity="degraded",
        ),
    )
    return (
        ErrorBudgetSLO(
            name="deadline_misses",
            error_key="engine.requests.deadline_missed",
            total_key="engine.requests.completed",
            objective=deadline_objective,
            rules=rules,
        ),
        LatencySLO(
            name="latency_p99",
            histogram_key="engine.request.latency_s",
            target_s=p99_target_s,
            percentile=99.0,
            rules=rules,
        ),
    )


def shed_rate_slo(
    *,
    objective: float = 0.99,
    scale_s: float = 1.0,
) -> ErrorBudgetSLO:
    """Opt-in fault-tolerance SLO: at least ``objective`` of submitted
    requests are *not* shed by the admission plane.

    Deliberately not part of :func:`default_slos` — with shedding off
    (the engine default) the counter never moves and the rule only
    abstains, and an engine that sheds under overload is *degrading
    correctly* (``engine.health()['diagnosis']`` reads it as
    ``overloaded``, not broken).  Operators running a bounded queue
    append this to the default pair to page on sustained shedding:

    ``slos=default_slos(...) + (shed_rate_slo(objective=0.95),)``
    """
    return ErrorBudgetSLO(
        name="shed_rate",
        error_key="engine.requests.shed",
        total_key="engine.requests.submitted",
        objective=objective,
        rules=(
            BurnRateRule(
                long_window_s=2.0 * scale_s,
                short_window_s=0.5 * scale_s,
                threshold=10.0,
                severity="breach",
            ),
            BurnRateRule(
                long_window_s=8.0 * scale_s,
                short_window_s=2.0 * scale_s,
                threshold=2.0,
                severity="degraded",
            ),
        ),
    )


def _eval_rule(
    slo: SLOSpec, rule: BurnRateRule, series: TimeSeriesSampler
) -> Dict:
    span = series.span_s()
    out: Dict = {
        "severity": rule.severity,
        "threshold": rule.threshold,
        "long_window_s": rule.long_window_s,
        "short_window_s": rule.short_window_s,
        "clipped": span < rule.long_window_s,
        "fired": False,
    }
    burns = {}
    for label, window_s in (
        ("long", rule.long_window_s),
        ("short", rule.short_window_s),
    ):
        err, flow = slo.error_rate(series, window_s)
        burns[label] = (
            None if err is None else err / slo.budget
        )
        out[f"{label}_error_rate"] = err
        out[f"{label}_burn_rate"] = burns[label]
        out[f"{label}_flow"] = flow
    # both windows must show the burn; a window with no flow abstains
    out["fired"] = all(
        b is not None and b > rule.threshold for b in burns.values()
    )
    return out


def evaluate(
    slos: Sequence[SLOSpec], series: TimeSeriesSampler
) -> Dict:
    """Evaluate every SLO's rules against the series; returns a
    JSON-able report with the overall ``status`` verdict."""
    report_slos: List[Dict] = []
    worst = 0
    for slo in slos:
        err_all, flow_all = slo.error_rate(series, None)
        rules = [_eval_rule(slo, r, series) for r in slo.rules]
        slo_worst = 0
        for r in rules:
            if r["fired"]:
                slo_worst = max(
                    slo_worst, STATUS_CODES[r["severity"]]
                )
        worst = max(worst, slo_worst)
        entry = {
            "name": slo.name,
            "kind": type(slo).__name__,
            "budget": slo.budget,
            "observed_error_rate": err_all,
            "observed_flow": flow_all,
            "status": status_of(slo_worst),
            "rules": rules,
        }
        if isinstance(slo, LatencySLO):
            entry["target_s"] = slo.target_s
            entry["percentile"] = slo.percentile
        report_slos.append(entry)
    return {
        "status": status_of(worst),
        "status_code": worst,
        "span_s": series.span_s(),
        "samples": len(series),
        "slos": report_slos,
    }


def status_of(code: int) -> str:
    for name, c in STATUS_CODES.items():
        if c == code:
            return name
    raise ValueError(f"unknown status code {code}")
