"""Fixed-point quantization (paper §4.3: Q1.15 weights/biases/neuron state).

The paper stores all neural quantities in Q1.15 — 16-bit signed fixed point
with 15 fractional bits, range [-1, 1-2^-15] — and accumulates synaptic sums
in a 28-bit intermediate.  Two paths are provided:

  - **true-int path** (`quantize`/`dequantize`, int16 arrays): used by the
    Pallas `q115_matmul`/`spike_matmul` kernels, which accumulate in int32
    (the 28-bit accumulator analog) and rescale once at the end.
  - **fake-quant path** (`fake_quant`): float arrays rounded to the Q-grid
    with a straight-through gradient.  This composes with pjit sharding and
    autodiff, so the *whole LM zoo* can run "Q1.15 mode" under the
    production mesh; it is bit-exact to the true-int path for values in
    range (property-tested).

A generic QM.N format is supported; Q1.15 is the paper's default.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Signed fixed-point format with ``int_bits`` integer (incl. sign) and
    ``frac_bits`` fractional bits."""

    int_bits: int = 1
    frac_bits: int = 15

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits

    @property
    def scale(self) -> float:
        return float(2**self.frac_bits)

    @property
    def max_val(self) -> float:
        return (2 ** (self.total_bits - 1) - 1) / self.scale

    @property
    def min_val(self) -> float:
        return -(2 ** (self.total_bits - 1)) / self.scale

    @property
    def storage_dtype(self):
        if self.total_bits <= 8:
            return jnp.int8
        if self.total_bits <= 16:
            return jnp.int16
        return jnp.int32


Q1_15 = QFormat(1, 15)
Q4_12 = QFormat(4, 12)
Q8_8 = QFormat(8, 8)
Q1_7 = QFormat(1, 7)  # int8 variant for the KV-cache / grad-compression path


def quantize(x: Array, fmt: QFormat = Q1_15) -> Array:
    """Float -> integer codes (round-to-nearest-even, saturating)."""
    lo = -(2 ** (fmt.total_bits - 1))
    hi = 2 ** (fmt.total_bits - 1) - 1
    codes = jnp.clip(jnp.round(x * fmt.scale), lo, hi)
    return codes.astype(fmt.storage_dtype)


def dequantize(codes: Array, fmt: QFormat = Q1_15) -> Array:
    return codes.astype(jnp.float32) / fmt.scale


@jax.custom_vjp
def _ste_round(x: Array) -> Array:
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(x: Array, fmt: QFormat = Q1_15) -> Array:
    """Round ``x`` to the Q-grid, straight-through gradient (QAT hook).

    Bit-exact match of quantize->dequantize for in-range values.
    """
    clipped = jnp.clip(x, fmt.min_val, fmt.max_val)
    return _ste_round(clipped * fmt.scale) / fmt.scale


def quant_params(params, fmt: QFormat = Q1_15):
    """Fake-quantize every float leaf of a param pytree (Q1.15 mode)."""

    def leaf(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return fake_quant(x, fmt)
        return x

    return jax.tree_util.tree_map(leaf, params)


def accumulator_bits(fan_in: int, fmt: QFormat = Q1_15) -> int:
    """Bits needed to hold a fan_in-wide sum of Q-format values without
    overflow — the paper's '28-bit intermediate result' for its adder tree.

    A sum of ``fan_in`` Q1.15 values needs 16 + ceil(log2(fan_in)) bits;
    e.g. fan_in=4096 -> 16+12 = 28 bits, exactly the paper's width.
    """
    import math

    return fmt.total_bits + max(1, math.ceil(math.log2(max(fan_in, 2))))
