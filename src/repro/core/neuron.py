"""LIF and Lapicque neuron dynamics (paper §3.1, Eqs. 1-2/4).

Faithful to the paper's formulation:

  Lapicque (Eq. 1):  U[t+1] = U[t] + (T/C) * I[t]
  LIF      (Eq. 2):  U[t+1] = beta*U[t] + I[t+1] - R*(beta*U[t] + I[t+1])

where R is the reset indicator (spike).  On spike (U >= U_thr) the membrane
is reset to zero ("reset-to-zero", the paper's mechanism); a "subtract"
mechanism (U -= thr) is also provided for completeness.

The refractory extension (paper §4.2.2) suppresses firing for
``refractory_steps`` steps after each spike via a per-neuron countdown.

All dynamics are expressed as a single-step function plus a `lax.scan`
runner so they compose with jit/pjit/grad and with the Pallas `lif_fused`
kernel (kernels/lif_fused.py) which implements the same step fused over
time in VMEM.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import surrogate

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class NeuronConfig:
    """Static neuron hyperparameters (learnables live in the param pytree)."""

    kind: str = "lif"  # "lif" | "lapicque"
    reset: str = "zero"  # "zero" | "subtract"
    surrogate: str = "atan"
    refractory_steps: int = 0  # 0 = disabled; paper uses 5 when enabled
    # Lapicque gain T/C (paper Eq. 1); ignored for LIF.
    lapicque_gain: float = 1.0

    def spike_fn(self) -> Callable[[Array], Array]:
        return surrogate.get(self.surrogate)


class NeuronState(NamedTuple):
    """Per-neuron dynamic state threaded through the time scan."""

    u: Array  # membrane potential
    refrac: Array  # int32 refractory countdown (zeros when disabled)


def init_state(shape: Tuple[int, ...], dtype=jnp.float32) -> NeuronState:
    return NeuronState(
        u=jnp.zeros(shape, dtype=dtype),
        refrac=jnp.zeros(shape, dtype=jnp.int32),
    )


def neuron_step(
    cfg: NeuronConfig,
    state: NeuronState,
    current: Array,
    *,
    beta: Array,
    threshold: Array,
) -> Tuple[NeuronState, Array]:
    """One time-step of membrane dynamics.  Returns (new_state, spikes).

    ``beta``/``threshold`` may be scalars or per-neuron vectors (learnable,
    as in the paper: "learnable parameter such as threshold and beta").
    """
    spike_fn = cfg.spike_fn()

    if cfg.kind == "lif":
        u_pre = beta * state.u + current
    elif cfg.kind == "lapicque":
        u_pre = state.u + cfg.lapicque_gain * current
    else:
        raise ValueError(f"unknown neuron kind {cfg.kind!r}")

    raw_spk = spike_fn(u_pre - threshold)

    if cfg.refractory_steps > 0:
        can_fire = (state.refrac <= 0).astype(u_pre.dtype)
        spk = raw_spk * can_fire
        refrac_next = jnp.where(
            spk > 0,
            jnp.int32(cfg.refractory_steps),
            jnp.maximum(state.refrac - 1, 0),
        )
    else:
        spk = raw_spk
        refrac_next = state.refrac

    if cfg.reset == "zero":
        # Eq. 2: U[t+1] = u_pre - R * u_pre
        u_next = u_pre - jax.lax.stop_gradient(u_pre) * spk
    elif cfg.reset == "subtract":
        u_next = u_pre - threshold * spk
    else:
        raise ValueError(f"unknown reset mechanism {cfg.reset!r}")

    return NeuronState(u=u_next, refrac=refrac_next), spk


def run_neuron(
    cfg: NeuronConfig,
    currents: Array,  # (T, ...) input current per step
    *,
    beta: Array,
    threshold: Array,
    init: Optional[NeuronState] = None,
) -> Tuple[Array, NeuronState]:
    """Scan `neuron_step` over the leading time axis.

    Returns (spikes (T, ...), final_state).
    """
    if init is None:
        init = init_state(currents.shape[1:], currents.dtype)

    def body(state, i):
        state, spk = neuron_step(cfg, state, i, beta=beta, threshold=threshold)
        return state, spk

    final, spikes = jax.lax.scan(body, init, currents)
    return spikes, final


def membrane_trace(
    cfg: NeuronConfig,
    currents: Array,
    *,
    beta: Array,
    threshold: Array,
) -> Tuple[Array, Array]:
    """Like `run_neuron` but also returns the membrane potential trace.

    Used for losses computed on output-layer membrane potentials
    (cross-entropy summed across time steps, paper §4.2.1) and for the
    Fig.-1-style membrane visualisations.
    """

    def body(state, i):
        state, spk = neuron_step(cfg, state, i, beta=beta, threshold=threshold)
        return state, (spk, state.u)

    init = init_state(currents.shape[1:], currents.dtype)
    _, (spikes, us) = jax.lax.scan(body, init, currents)
    return spikes, us
