"""Binarized CNN baseline (paper Table 2 comparator, Nakahara et al. [36]).

The paper compares its SNN against a binarized CNN on FPGA.  We implement a
small BCNN in JAX — sign-binarized weights and activations with
straight-through gradients — trained on the same collision data, so the
energy comparison (core/energy.py) and the accuracy comparison are
apples-to-apples on our synthetic dataset.

Architecture (scaled to 64x64 input, in the spirit of [36]'s conv-only
design): conv3x3(16) -> maxpool2 -> conv3x3(32) -> maxpool2 ->
conv3x3(64) -> global-avg-pool -> dense(2).  First conv keeps real-valued
inputs (standard BNN practice); internal activations are binarized.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BCNNConfig:
    input_hw: int = 64
    channels: Tuple[int, ...] = (16, 32, 64)
    n_classes: int = 2


@jax.custom_vjp
def binarize(x: Array) -> Array:
    """sign(x) in {-1,+1} with straight-through (hardtanh-clipped) grad."""
    return jnp.where(x >= 0, 1.0, -1.0)


def _bin_fwd(x):
    return binarize(x), x


def _bin_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


binarize.defvjp(_bin_fwd, _bin_bwd)


def init_params(key: jax.Array, cfg: BCNNConfig) -> Dict[str, Dict[str, Array]]:
    params: Dict[str, Dict[str, Array]] = {}
    keys = jax.random.split(key, len(cfg.channels) + 1)
    c_in = 1
    for i, c_out in enumerate(cfg.channels):
        fan_in = 3 * 3 * c_in
        params[f"conv{i}"] = {
            "w": jax.random.normal(keys[i], (3, 3, c_in, c_out))
            / jnp.sqrt(fan_in),
            "g": jnp.ones((c_out,)),  # bn-like scale
            "b": jnp.zeros((c_out,)),
        }
        c_in = c_out
    params["fc"] = {
        "w": jax.random.normal(keys[-1], (c_in, cfg.n_classes))
        / jnp.sqrt(c_in),
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params


def _conv(x: Array, w: Array) -> Array:
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool2(x: Array) -> Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(params, images: Array, cfg: BCNNConfig) -> Array:
    """images: (B, H, W) grayscale in [0,1] -> logits (B, n_classes)."""
    x = images[..., None] * 2.0 - 1.0  # center
    n_conv = len(cfg.channels)
    for i in range(n_conv):
        lp = params[f"conv{i}"]
        wb = binarize(lp["w"])
        xin = x if i == 0 else binarize(x)  # first layer real-valued input
        x = _conv(xin, wb)
        x = x * lp["g"] + lp["b"]
        if i < n_conv - 1:
            x = _maxpool2(x)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return x @ binarize(params["fc"]["w"]) + params["fc"]["b"]


def loss_fn(params, images: Array, labels: Array, cfg: BCNNConfig):
    logits = forward(params, images, cfg)
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, cfg.n_classes)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"accuracy": acc}


def conv_shapes_for_energy(cfg: BCNNConfig):
    """Layer shapes for core.energy.bcnn_inference_ops."""
    hw = cfg.input_hw
    shapes = []
    c_in = 1
    for i, c_out in enumerate(cfg.channels):
        shapes.append((hw, hw, 3, 3, c_in, c_out))
        if i < len(cfg.channels) - 1:
            hw //= 2
        c_in = c_out
    fc = [(c_in, cfg.n_classes)]
    return shapes, fc
