"""Core library: the paper's contribution as composable JAX modules.

- neuron:    LIF / Lapicque dynamics with refractory periods (Eqs. 1-2/4)
- surrogate: spike-gradient surrogates for BPTT training
- coding:    rate / TTFS / delta input spike coding (§3.2)
- snn:       the paper's SpikingMLP (4096-512-2, 25 steps) + loss
- quant:     Q1.15 fixed-point paths (§4.3)
- energy:    analytic op/energy model (Tables 2-3 analog)
- bcnn:      binarized-CNN baseline (Table 2 comparator)
"""

from repro.core import bcnn, coding, energy, neuron, quant, snn, surrogate

__all__ = ["bcnn", "coding", "energy", "neuron", "quant", "snn", "surrogate"]
