"""Analytic energy/operation model (paper Tables 2-3 analog).

The paper measures watts on an Artix-7; this container cannot.  Instead we
count operations and memory accesses per inference and price them with
published per-op energies (Horowitz, ISSCC 2014, 45nm; widely used for
accelerator napkin math).  The *structure* of the paper's claim — an
event-driven, adder-only, Q1.15 SNN performs ~7.6x more ops per joule than
a dense binarized CNN (1093 vs 143 GOPS/W, "86% more energy efficient") —
is what we reproduce; absolute numbers differ from a 28nm FPGA and are
labelled as model estimates everywhere they are reported.

Energy table (pJ), 45nm:
    int8 add 0.03 | int16 add 0.05 | int32 add 0.1
    int8 mul 0.2  | int16 mul 0.8 (interp.) | int32 mul 3.1
    fp16 add 0.4  | fp16 mul 1.1  | fp32 add 0.9 | fp32 mul 3.7
    SRAM 64b read (32KB) ~5 pJ | DRAM 64b ~640 pJ
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

# pJ per operation (Horowitz ISSCC'14, 45nm)
ENERGY_PJ: Dict[str, float] = {
    "add_i8": 0.03,
    "add_i16": 0.05,
    "add_i32": 0.10,
    "mul_i8": 0.20,
    "mul_i16": 0.80,
    "mul_i32": 3.10,
    "add_f16": 0.40,
    "mul_f16": 1.10,
    "add_f32": 0.90,
    "mul_f32": 3.70,
    "cmp_i16": 0.03,  # comparator ~ narrow add
    "xnor_popcnt": 0.02,  # 1b xnor + popcount slice, per synapse
    "sram_64b": 5.0,
    "dram_64b": 640.0,
}


@dataclasses.dataclass
class OpCount:
    """Operation & memory-access tally for one inference."""

    ops: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, kind: str, n: float) -> None:
        self.ops[kind] = self.ops.get(kind, 0.0) + float(n)

    def energy_pj(self) -> float:
        return sum(ENERGY_PJ[k] * n for k, n in self.ops.items())

    def total_ops(self) -> float:
        """Arithmetic ops only (paper counts GOPS over compute ops)."""
        return sum(
            n for k, n in self.ops.items() if not k.startswith(("sram", "dram"))
        )

    def gops_per_watt(self) -> float:
        """ops / joule == GOPS/W (unit identity)."""
        e_j = self.energy_pj() * 1e-12
        if e_j == 0:
            return float("inf")
        return self.total_ops() / e_j / 1e9


def snn_inference_ops(
    layer_sizes: Sequence[int],
    num_steps: int,
    spike_rates: Sequence[float],
    *,
    weight_bits: int = 16,
    event_driven: bool = True,
) -> OpCount:
    """Event-driven SNN cost (paper §4.3 hardware).

    ``spike_rates[i]`` = mean firing rate of the *input* to layer i (layer 0
    input = rate-coded pixels).  Synaptic integration costs one int-add per
    *active* input synapse per step (cascaded adder over binary inputs —
    no multiplies).  Neuron update costs one int16 mul (beta*U) + add +
    compare per neuron per step; Lapicque drops the mul.
    """
    c = OpCount()
    acc_add = "add_i32"  # 28-bit intermediate -> int32 accumulator class
    for i, (fan_in, fan_out) in enumerate(zip(layer_sizes[:-1], layer_sizes[1:])):
        rate = spike_rates[i] if event_driven else 1.0
        syn_adds = num_steps * rate * fan_in * fan_out
        c.add(acc_add, syn_adds)
        c.add(acc_add, num_steps * fan_out)  # bias add
        # LIF neuron hardware unit: beta*U (int16 mul), +I (add), compare
        c.add("mul_i16", num_steps * fan_out)
        c.add("add_i16", num_steps * fan_out)
        c.add("cmp_i16", num_steps * fan_out)
        # weight fetches for active synapses (SRAM, 64b lines -> weights/4)
        wpl = 64 // weight_bits
        c.add("sram_64b", num_steps * rate * fan_in * fan_out / wpl)
    # input spike fetch: 1 bit each, 64 per line
    c.add("sram_64b", num_steps * layer_sizes[0] / 64)
    return c


def snn_ops_from_events(
    layer_sizes: Sequence[int],
    num_steps: int,
    events_per_layer: Sequence[float],
    *,
    weight_bits: int = 16,
    neuron_kind: str = "lif",
) -> OpCount:
    """Event-driven SNN cost from **measured** event counts.

    ``events_per_layer[i]`` = number of input events layer i actually
    received over the whole inference window (counted by
    ``events.runtime``), replacing the assumed ``rate * fan_in * T`` of
    ``snn_inference_ops``.  Synaptic integration costs one accumulator add
    (and one weight fetch) per event per output; the neuron update still
    runs every step for every neuron (the LIF hardware unit is clocked,
    not event-gated).
    """
    c = OpCount()
    acc_add = "add_i32"
    wpl = 64 // weight_bits
    for i, (fan_in, fan_out) in enumerate(zip(layer_sizes[:-1], layer_sizes[1:])):
        ev = float(events_per_layer[i])
        c.add(acc_add, ev * fan_out)
        c.add(acc_add, num_steps * fan_out)  # bias add
        if neuron_kind == "lif":
            c.add("mul_i16", num_steps * fan_out)  # beta * U
        c.add("add_i16", num_steps * fan_out)
        c.add("cmp_i16", num_steps * fan_out)
        c.add("sram_64b", ev * fan_out / wpl)
    # AER input events arrive as ~32-bit (time, address) words, 2 per line
    c.add("sram_64b", float(events_per_layer[0]) / 2)
    return c


def snn_train_ops_from_events(
    layer_sizes: Sequence[int],
    num_steps: int,
    events_per_layer: Sequence[float],
    *,
    dense: bool = False,
) -> OpCount:
    """Surrogate-gradient BPTT cost of one training example (fwd + bwd).

    The event-driven trainer (``sparse_train``) pays per *measured* event:

      - forward gather:      1 f32 add per event per output
      - weight-grad scatter: 1 f32 MAC per event per output (the backward
        scatters cotangents through the same active-event index set —
        dense BPTT's ``h^T @ g`` is zero at silent rows, so this is exact)
      - input cotangent ``g @ W^T``: dense (surrogate derivatives are
        nonzero off-spike), but only for hidden layers — the input layer,
        the widest one, needs no input cotangent at all
      - bias grad + neuron fwd/bwd: fixed per neuron-step

    With ``dense=True`` the same graph is priced at the dense trainer's
    cost (every synapse a MAC in forward and in the weight grad,
    regardless of activity) — the flat baseline the event path is
    compared against in ``benchmarks/sparse_train_bench.py``.
    """
    c = OpCount()
    for i, (fan_in, fan_out) in enumerate(
        zip(layer_sizes[:-1], layer_sizes[1:])
    ):
        ev = (
            float(num_steps * fan_in)
            if dense
            else float(events_per_layer[i])
        )
        if dense:
            # dense forward + weight grad are MACs over every synapse
            c.add("mul_f32", ev * fan_out)
            c.add("add_f32", ev * fan_out)
            c.add("mul_f32", ev * fan_out)
            c.add("add_f32", ev * fan_out)
        else:
            # gathered forward: binary/polarity spikes, adds only
            c.add("add_f32", ev * fan_out)
            # event-set weight-grad scatter: value * cotangent MAC
            c.add("mul_f32", ev * fan_out)
            c.add("add_f32", ev * fan_out)
        # weight fetches (fwd) + grad-row touches (bwd), f32 words
        c.add("sram_64b", 2 * ev * fan_out / 2)
        if i > 0:
            # input cotangent g @ W^T — dense support either way
            c.add("mul_f32", num_steps * fan_in * fan_out)
            c.add("add_f32", num_steps * fan_in * fan_out)
            c.add("sram_64b", num_steps * fan_in * fan_out / 2)
        # bias add (fwd) + bias grad (bwd)
        c.add("add_f32", 2 * num_steps * fan_out)
        # neuron update fwd (beta*U + I, compare) and bwd (surrogate grad
        # eval + chain through beta/threshold/membrane): ~6 f32 ops/step
        c.add("mul_f32", 3 * num_steps * fan_out)
        c.add("add_f32", 3 * num_steps * fan_out)
    return c


# Paper Table 2 (Artix-7, measured): the SNN row and its BCNN baseline.
PAPER_TABLE2 = {
    "snn": {"power_mw": 495.0, "gops": 541.0, "gops_per_w": 1093.0},
    "bcnn36": {"power_mw": 2300.0, "gops": 329.0, "gops_per_w": 143.0},
}


def gopsw_deviation(model_gopsw: float, paper_gopsw: float) -> float:
    """Signed relative deviation of the model estimate from the paper's
    measured Artix-7 GOPS/W: (model - paper) / paper."""
    return (model_gopsw - paper_gopsw) / paper_gopsw


def bcnn_inference_ops(
    conv_shapes: Sequence[tuple],
    fc_shapes: Sequence[tuple],
) -> OpCount:
    """Binarized CNN cost (paper's Table 2 baseline [36]).

    conv_shapes: (out_h, out_w, k, k, c_in, c_out) per conv layer.
    fc_shapes:   (fan_in, fan_out) per dense layer.
    Binarized MAC = XNOR+popcount per synapse; batch-norm/sign per output
    as int16 ops; activations/weights fetched from SRAM.
    """
    c = OpCount()
    for (oh, ow, k1, k2, cin, cout) in conv_shapes:
        macs = oh * ow * k1 * k2 * cin * cout
        c.add("xnor_popcnt", macs)
        c.add("add_i16", oh * ow * cout)  # bn + sign
        c.add("sram_64b", macs / 64)
    for (fi, fo) in fc_shapes:
        c.add("xnor_popcnt", fi * fo)
        c.add("add_i16", fo)
        c.add("sram_64b", fi * fo / 64)
    return c


def dense_fcn_inference_ops(
    layer_sizes: Sequence[int], *, bits: int = 16
) -> OpCount:
    """16-bit dense FCN cost — the 'traditional FCN' the paper contrasts."""
    c = OpCount()
    mul = "mul_i16" if bits == 16 else "mul_f32"
    add = "add_i32" if bits == 16 else "add_f32"
    for fan_in, fan_out in zip(layer_sizes[:-1], layer_sizes[1:]):
        c.add(mul, fan_in * fan_out)
        c.add(add, fan_in * fan_out)
        c.add("sram_64b", fan_in * fan_out / (64 // bits))
    return c


def efficiency_gain(snn: OpCount, baseline: OpCount) -> float:
    """Paper's headline metric: (SNN GOPS/W - base GOPS/W)/SNN GOPS/W.

    The paper states the SNN is '86% more energy efficient' with
    1093 vs 143 GOPS/W; (1093-143)/1093 = 0.869.
    """
    s, b = snn.gops_per_watt(), baseline.gops_per_watt()
    return (s - b) / s


def energy_reduction(snn: OpCount, baseline: OpCount) -> float:
    """Energy-per-inference reduction: 1 - E_snn / E_base.

    This is the analytically-meaningful form of the paper's 86% claim:
    the SNN solves the task with far fewer (and cheaper) operations than
    the generic CNN baseline, so its energy *per classification* is ~8x
    lower.  (GOPS/W by itself rewards cheap ops, not less work — the
    paper's measured GOPS/W gap additionally folds in platform power;
    see EXPERIMENTS.md §Energy-notes.)
    """
    return 1.0 - snn.energy_pj() / baseline.energy_pj()


# Published per-frame workload of the paper's BCNN baseline [36]
# (Nakahara et al., FPL'17): 329 GOPS at 161 fps -> ~2.0e9 ops/frame.
BCNN36_OPS_PER_FRAME = 329e9 / 161.0


def bcnn36_inference_ops() -> OpCount:
    """Op-count model of the paper's Table-2 BCNN baseline at its
    *published* scale, priced with the same energy table."""
    c = OpCount()
    c.add("xnor_popcnt", BCNN36_OPS_PER_FRAME)
    c.add("sram_64b", BCNN36_OPS_PER_FRAME / 64)
    return c
