"""The paper's SNN model (§4.2, Fig. 4): 4096 -> 512 LIF -> 2 LIF.

Faithful reproduction:
  - input layer: flatten 64x64 image -> 4096 binary spike vector per step
  - hidden layer: Linear(4096,512) + LIF (learnable beta & threshold) +
    dropout (regularization, on hidden spikes, train only)
  - output layer: Linear(512,2) + LIF; loss = cross-entropy on output
    membrane potential, summed over all 25 time steps; prediction = argmax
    of output spike counts (snntorch convention the paper follows)
  - optional refractory period (5 steps) on hidden and output layers
  - optional Q1.15 weight quantization (paper's hardware number format)

The model is parametric (layer sizes, #steps) so the 32x32 / 64x64 / 128x128
sweep of paper Table 1 is one config knob.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import coding, neuron, quant

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    layer_sizes: Sequence[int] = (4096, 512, 2)  # paper Fig. 4
    num_steps: int = 25  # paper §4.2.1
    neuron_kind: str = "lif"  # "lif" | "lapicque"
    reset: str = "zero"
    surrogate: str = "atan"
    refractory_steps: int = 0  # 5 for the §4.2.2 variant
    dropout_rate: float = 0.2
    beta_init: float = 0.9
    threshold_init: float = 1.0
    quant_q115: bool = False  # fake-quant weights to Q1.15 on the fly

    @property
    def neuron_cfg(self) -> neuron.NeuronConfig:
        return neuron.NeuronConfig(
            kind=self.neuron_kind,
            reset=self.reset,
            surrogate=self.surrogate,
            refractory_steps=self.refractory_steps,
        )

    @property
    def num_layers(self) -> int:
        return len(self.layer_sizes) - 1


def init_params(key: jax.Array, cfg: SNNConfig) -> Dict[str, Dict[str, Array]]:
    """Kaiming-uniform linear layers + learnable per-layer beta/threshold."""
    params: Dict[str, Dict[str, Array]] = {}
    keys = jax.random.split(key, cfg.num_layers)
    for i, (fan_in, fan_out) in enumerate(
        zip(cfg.layer_sizes[:-1], cfg.layer_sizes[1:])
    ):
        bound = 1.0 / jnp.sqrt(fan_in)
        wk, bk = jax.random.split(keys[i])
        params[f"layer{i}"] = {
            "w": jax.random.uniform(
                wk, (fan_in, fan_out), minval=-bound, maxval=bound
            ),
            "b": jax.random.uniform(bk, (fan_out,), minval=-bound, maxval=bound),
            # learnable neuron params (paper: "learnable parameter such as
            # threshold and beta"); stored pre-sigmoid for beta so it stays
            # in (0,1) under unconstrained optimization.
            "beta_raw": jnp.full((fan_out,), _beta_raw_init(cfg.beta_init)),
            "threshold": jnp.full((fan_out,), cfg.threshold_init),
        }
    return params


def _beta_raw_init(beta: float) -> float:
    import math

    beta = min(max(beta, 1e-4), 1 - 1e-4)
    return math.log(beta / (1 - beta))


def effective_beta(layer_params: Dict[str, Array]) -> Array:
    return jax.nn.sigmoid(layer_params["beta_raw"])


def forward(
    params: Dict[str, Dict[str, Array]],
    spikes: Array,  # (T, B, input_size) in {0,1}
    cfg: SNNConfig,
    *,
    train: bool = False,
    dropout_key: Optional[jax.Array] = None,
) -> Tuple[Array, Array]:
    """Run the SNN over the coding window.

    Returns:
      out_mem:   (T, B, n_class) output-layer membrane trace (for the loss)
      out_spikes:(T, B, n_class) output spikes (for prediction by counts)
    """
    ncfg = cfg.neuron_cfg
    p = params
    if cfg.quant_q115:
        p = {
            name: {
                **lp,
                "w": quant.fake_quant(lp["w"], quant.Q1_15),
                "b": quant.fake_quant(lp["b"], quant.Q1_15),
            }
            for name, lp in params.items()
        }

    T, B = spikes.shape[0], spikes.shape[1]
    n_layers = cfg.num_layers

    states = [
        neuron.init_state((B, cfg.layer_sizes[i + 1])) for i in range(n_layers)
    ]
    if train and cfg.dropout_rate > 0.0:
        if dropout_key is None:
            raise ValueError("dropout_key required when train=True")
        # one dropout mask per time step (snntorch applies dropout per call)
        drop_keys = jax.random.split(dropout_key, T)
    else:
        drop_keys = jnp.zeros((T, 2), dtype=jnp.uint32)

    def step(carry, xs):
        states = carry
        x_t, dk = xs
        new_states = []
        h = x_t
        for i in range(n_layers):
            lp = p[f"layer{i}"]
            cur = h @ lp["w"] + lp["b"]
            st, spk = neuron.neuron_step(
                ncfg,
                states[i],
                cur,
                beta=effective_beta(lp),
                threshold=lp["threshold"],
            )
            new_states.append(st)
            h = spk
            if i == 0 and train and cfg.dropout_rate > 0.0:
                keep = jax.random.bernoulli(
                    dk, 1.0 - cfg.dropout_rate, spk.shape
                ).astype(spk.dtype)
                h = spk * keep / (1.0 - cfg.dropout_rate)
        out_mem_t = new_states[-1].u
        out_spk_t = h
        return tuple(new_states), (out_mem_t, out_spk_t)

    _, (out_mem, out_spikes) = jax.lax.scan(
        step, tuple(states), (spikes, drop_keys)
    )
    return out_mem, out_spikes


def membrane_ce_loss(out_mem: Array, labels: Array) -> Array:
    """Cross-entropy on the output membrane trace (T, B, C), summed over
    all time steps (paper: 'Cross-entropy loss is computed across all time
    steps, summing up to form the total loss')."""
    logp = jax.nn.log_softmax(out_mem, axis=-1)  # (T, B, C)
    onehot = jax.nn.one_hot(labels, out_mem.shape[-1])
    ce_per_step = -jnp.sum(onehot[None] * logp, axis=-1)  # (T, B)
    return jnp.mean(jnp.sum(ce_per_step, axis=0))


def predict_from_traces(out_mem: Array, out_spikes: Array) -> Array:
    """Spike-count argmax over the window (snntorch convention),
    tie-broken by membrane sum so all-zero-spike batches still predict."""
    counts = jnp.sum(out_spikes, axis=0)  # (B, C)
    return jnp.argmax(counts + 1e-6 * jnp.sum(out_mem, axis=0), axis=-1)


def loss_fn(
    params,
    spikes: Array,
    labels: Array,  # (B,) int class labels
    cfg: SNNConfig,
    *,
    train: bool = True,
    dropout_key: Optional[jax.Array] = None,
) -> Tuple[Array, Dict[str, Array]]:
    """Membrane cross-entropy loss (see ``membrane_ce_loss``) + metrics."""
    out_mem, out_spikes = forward(
        params, spikes, cfg, train=train, dropout_key=dropout_key
    )
    loss = membrane_ce_loss(out_mem, labels)
    pred = predict_from_traces(out_mem, out_spikes)
    acc = jnp.mean((pred == labels).astype(jnp.float32))
    return loss, {"accuracy": acc, "spike_rate": jnp.mean(out_spikes)}


def predict(params, images: Array, cfg: SNNConfig, key: jax.Array) -> Array:
    """End-to-end inference: rate-encode + forward + spike-count argmax."""
    flat = images.reshape(images.shape[0], -1)
    spikes = coding.rate_encode(key, flat, cfg.num_steps)
    out_mem, out_spikes = forward(params, spikes, cfg, train=False)
    return predict_from_traces(out_mem, out_spikes)


def hidden_spike_rates(params, spikes: Array, cfg: SNNConfig) -> Array:
    """Mean per-layer spike rates — feeds the event-driven energy model."""
    ncfg = cfg.neuron_cfg
    B = spikes.shape[1]
    n_layers = cfg.num_layers
    states = [
        neuron.init_state((B, cfg.layer_sizes[i + 1])) for i in range(n_layers)
    ]

    def step(carry, x_t):
        states = carry
        new_states, rates = [], []
        h = x_t
        for i in range(n_layers):
            lp = params[f"layer{i}"]
            cur = h @ lp["w"] + lp["b"]
            st, spk = neuron.neuron_step(
                ncfg, states[i], cur,
                beta=effective_beta(lp), threshold=lp["threshold"],
            )
            new_states.append(st)
            rates.append(jnp.mean(spk))
            h = spk
        return tuple(new_states), jnp.stack(rates)

    _, rates = jax.lax.scan(step, tuple(states), spikes)
    return jnp.mean(rates, axis=0)  # (n_layers,)
