"""Input spike coding (paper §3.2).

Static images are converted into time-varying spike trains:

  - ``rate_encode``  : Bernoulli rate coding — pixel intensity == per-step
    spike probability (the paper's choice; Fig. 2).
  - ``ttfs_encode``  : time-to-first-spike — brighter pixels fire earlier.
  - ``delta_encode`` : delta modulation over an input sequence — spikes on
    signal change.

All encoders return a (T, *x.shape) array with time leading, dtype float32
spikes in {0,1} (signed {-1,0,1} for delta), so they feed `neuron.run_*`
and the SpikingMLP directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rate_encode(key: jax.Array, x: Array, num_steps: int) -> Array:
    """Bernoulli rate coding.  ``x`` must be normalized to [0, 1]."""
    p = jnp.clip(x, 0.0, 1.0)
    u = jax.random.uniform(key, (num_steps,) + x.shape, dtype=jnp.float32)
    return (u < p).astype(jnp.float32)


def rate_encode_deterministic(x: Array, num_steps: int) -> Array:
    """Deterministic rate coding via phase accumulation (error diffusion).

    Emits round(p * T) spikes, evenly spaced — useful for reproducible tests
    and for the hardware path where a PRNG per pixel is not free.
    """
    p = jnp.clip(x, 0.0, 1.0)
    t = jnp.arange(1, num_steps + 1, dtype=jnp.float32)
    # spike at step t iff floor(t*p) > floor((t-1)*p)
    acc_t = jnp.floor(t[:, None] * p.reshape(1, -1))
    acc_prev = jnp.floor((t - 1)[:, None] * p.reshape(1, -1))
    spikes = (acc_t > acc_prev).astype(jnp.float32)
    return spikes.reshape((num_steps,) + x.shape)


def ttfs_encode(x: Array, num_steps: int) -> Array:
    """Time-to-first-spike: intensity 1.0 fires at t=0, 0 never fires."""
    p = jnp.clip(x, 0.0, 1.0)
    # fire time; p==0 -> num_steps (never)
    t_fire = jnp.where(p > 0, jnp.round((1.0 - p) * (num_steps - 1)), num_steps)
    t = jnp.arange(num_steps, dtype=t_fire.dtype)
    spikes = (t.reshape((num_steps,) + (1,) * x.ndim) == t_fire[None]).astype(
        jnp.float32
    )
    return spikes


def delta_encode(x_seq: Array, threshold: float = 0.1) -> Array:
    """Delta modulation over a (T, ...) input sequence.

    Emits +1 when the signal rises by more than ``threshold`` since the last
    emitted level, -1 when it falls; tracked with an accumulator so encoding
    error does not drift.
    """

    def body(level, x_t):
        diff = x_t - level
        up = (diff >= threshold).astype(x_seq.dtype)
        dn = (diff <= -threshold).astype(x_seq.dtype)
        spike = up - dn
        new_level = level + spike * threshold
        return new_level, spike

    level0 = jnp.zeros_like(x_seq[0])
    _, spikes = jax.lax.scan(body, level0, x_seq)
    return spikes


def spike_rate(spikes: Array) -> Array:
    """Mean firing rate over the time axis — used by the energy model."""
    return jnp.mean(spikes, axis=0)
