"""Surrogate-gradient spike functions.

The Heaviside spike nonlinearity has zero gradient almost everywhere; SNN
training (snntorch-style BPTT, as used by the paper) replaces the backward
pass with a smooth surrogate.  Forward is always the exact hard threshold —
only the VJP is surrogate.

Provided surrogates (all as `jax.custom_vjp`):
  - ``atan``        : snntorch default for Leaky.  d/du = alpha / (2*(1+(pi/2*alpha*u)^2))
  - ``fast_sigmoid``: d/du = 1 / (slope*|u| + 1)^2
  - ``boxcar``      : straight-through estimator, d/du = 1[|u| < width/2]
"""

from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def _heaviside(u: Array) -> Array:
    """Exact spike forward: 1.0 where u >= 0 (u is membrane - threshold)."""
    return (u >= 0.0).astype(u.dtype)


def _make_spike_fn(grad_fn: Callable[[Array], Array]) -> Callable[[Array], Array]:
    @jax.custom_vjp
    def spike(u: Array) -> Array:
        return _heaviside(u)

    def fwd(u: Array):
        return _heaviside(u), u

    def bwd(u: Array, g: Array):
        return (g * grad_fn(u),)

    spike.defvjp(fwd, bwd)
    return spike


def atan(alpha: float = 2.0) -> Callable[[Array], Array]:
    """ATan surrogate (snntorch default)."""

    def grad_fn(u: Array) -> Array:
        return alpha / (2.0 * (1.0 + (math.pi / 2.0 * alpha * u) ** 2))

    return _make_spike_fn(grad_fn)


def fast_sigmoid(slope: float = 25.0) -> Callable[[Array], Array]:
    """Fast-sigmoid surrogate (SuperSpike)."""

    def grad_fn(u: Array) -> Array:
        return 1.0 / (slope * jnp.abs(u) + 1.0) ** 2

    return _make_spike_fn(grad_fn)


def boxcar(width: float = 1.0) -> Callable[[Array], Array]:
    """Straight-through / boxcar surrogate."""

    def grad_fn(u: Array) -> Array:
        return (jnp.abs(u) < width / 2.0).astype(u.dtype)

    return _make_spike_fn(grad_fn)


_REGISTRY = {
    "atan": atan,
    "fast_sigmoid": fast_sigmoid,
    "boxcar": boxcar,
}


@functools.lru_cache(maxsize=None)
def get(name: str, **kwargs) -> Callable[[Array], Array]:
    """Look up a surrogate spike fn by name (kwargs must be hashable)."""
    if name not in _REGISTRY:
        raise ValueError(f"unknown surrogate {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)
