"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` implements the exact semantics the kernel must match.
Kernel tests sweep shapes/dtypes and assert allclose (bit-exact for the
integer paths) against these.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

Q115_FRAC_BITS = 15


def lif_fused_ref(
    currents: Array,  # (T, B, N) float32 input currents
    beta: Array,  # (N,) float32 decay
    threshold: Array,  # (N,) float32 firing threshold
    *,
    refractory_steps: int = 0,
    reset: str = "zero",
) -> Tuple[Array, Array]:
    """Multi-step LIF dynamics; returns (spikes (T,B,N), final_u (B,N)).

    Semantics identical to core.neuron.neuron_step (inference: hard
    threshold, no surrogate), scanned over T.
    """
    T, B, N = currents.shape

    def body(carry, cur_t):
        u, refrac = carry
        u_pre = beta[None, :] * u + cur_t
        raw = (u_pre >= threshold[None, :]).astype(jnp.float32)
        if refractory_steps > 0:
            can = (refrac <= 0).astype(jnp.float32)
            spk = raw * can
            refrac = jnp.where(
                spk > 0, jnp.int32(refractory_steps), jnp.maximum(refrac - 1, 0)
            )
        else:
            spk = raw
        if reset == "zero":
            u_next = u_pre * (1.0 - spk)
        elif reset == "subtract":
            u_next = u_pre - threshold[None, :] * spk
        else:
            raise ValueError(reset)
        return (u_next, refrac), spk

    u0 = jnp.zeros((B, N), jnp.float32)
    r0 = jnp.zeros((B, N), jnp.int32)
    (u_fin, _), spikes = jax.lax.scan(body, (u0, r0), currents)
    return spikes, u_fin


def spike_matmul_ref(
    spikes: Array,  # (M, K) int8 in {0, 1}
    weights_q: Array,  # (K, N) int16 Q1.15 codes
) -> Array:
    """Event-driven synaptic integration (cascaded-adder semantics).

    Exact integer accumulation: out[m, n] = sum_k spikes[m,k] * wq[k,n],
    in int32 (the paper's 28-bit intermediate fits: 16 + log2(K) bits).
    """
    return jax.lax.dot_general(
        spikes.astype(jnp.int32),
        weights_q.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def aer_spike_matmul_ref(
    addrs: Array,  # (E,) int32 event addresses in [0, K)
    values: Array,  # (E,) int-like signed event values (0 = padding)
    weights_q: Array,  # (K, N) int16 Q1.15 codes
) -> Array:
    """AER event-driven integration: gather only the weight rows of active
    input addresses and accumulate them, weighted by event polarity.

    Exact integer contract: out[n] = sum_e values[e] * wq[addrs[e], n],
    int32.  With ``values`` = the {0,1} validity mask of an event list
    built from a dense spike row, this equals ``spike_matmul_ref`` on that
    row — property-tested in tests/test_events.py.
    """
    rows = jnp.take(weights_q, addrs, axis=0).astype(jnp.int32)  # (E, N)
    return jnp.sum(rows * values.astype(jnp.int32)[:, None], axis=0)


def q115_matmul_ref(x_q: Array, w_q: Array) -> Array:
    """Q1.15 fixed-point matmul: int16 x int16 -> int32 accum -> round-to-
    nearest shift >>15 -> saturate int16.  Bit-exact contract."""
    # Dataflow matches the FPGA contract (paper §4.3): Q1.15 x Q1.15
    # products are rescaled back to Q1.15 (>>15, round-to-nearest) BEFORE
    # accumulation, so a fan-in-4096 sum needs 16 + log2(4096) = 28 bits —
    # exactly the paper's "28-bit intermediate result".  Accumulating raw
    # Q2.30 products instead would need 42 bits and overflow int32.
    prod = x_q.astype(jnp.int32)[:, :, None] * w_q.astype(jnp.int32)[None, :, :]
    prod = (prod + (1 << (Q115_FRAC_BITS - 1))) >> Q115_FRAC_BITS
    acc = jnp.sum(prod, axis=1)
    out = jnp.clip(acc, -(2**15), 2**15 - 1).astype(jnp.int16)
    return out


def q115_matmul_acc_ref(x_q: Array, w_q: Array) -> Array:
    """Raw int32 accumulator variant (products >>15 then summed), pre-clip.

    This is the value the kernel accumulates; exposed for tests.
    """
    prod = x_q.astype(jnp.int32)[:, :, None] * w_q.astype(jnp.int32)[None, :, :]
    prod = (prod + (1 << (Q115_FRAC_BITS - 1))) >> Q115_FRAC_BITS
    return jnp.sum(prod, axis=1).astype(jnp.int32)
