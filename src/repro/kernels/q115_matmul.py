"""Q1.15 fixed-point matmul — Pallas TPU kernel (paper §4.3 number format).

int16 Q1.15 x int16 Q1.15 with the FPGA's dataflow: each product is
rescaled back to Q1.15 (>>15, round-to-nearest) *before* accumulation so a
fan-in-4096 sum fits the paper's 28-bit intermediate (16 + log2(4096));
the int32 VMEM accumulator plays that role.  Output saturates to int16.

The product tensor (bm, bk, bn) is materialized per k-slab, so block_k is
kept small (16) to bound VMEM: 128*16*128 * 4B = 1 MiB.

Bit-exact contract vs ref.q115_matmul_ref / q115_matmul_acc_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

Array = jax.Array

FRAC_BITS = 15
_ROUND = 1 << (FRAC_BITS - 1)


def _q115_kernel(x_ref, w_ref, out_ref, acc_scr, *, nk: int, saturate: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.int32)  # (bm, bk)
    w = w_ref[...].astype(jnp.int32)  # (bk, bn)
    # Q1.15*Q1.15 -> Q2.30 products, rescale each to Q1.15 pre-accumulate
    prod = x[:, :, None] * w[None, :, :]  # (bm, bk, bn) int32, <= 2^30
    prod = (prod + _ROUND) >> FRAC_BITS
    acc_scr[...] += jnp.sum(prod, axis=1)

    @pl.when(k == nk - 1)
    def _flush():
        acc = acc_scr[...]
        if saturate:
            out_ref[...] = jnp.clip(acc, -(2**15), 2**15 - 1).astype(
                jnp.int16
            )
        else:
            out_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("saturate", "block_m", "block_n", "block_k", "interpret"),
)
def q115_matmul(
    x_q: Array,  # (M, K) int16 Q1.15
    w_q: Array,  # (K, N) int16 Q1.15
    *,
    saturate: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 16,
    interpret: bool = False,
) -> Array:
    """Q1.15 matmul.  saturate=True -> int16 Q1.15 out; else raw int32."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        x_q = jnp.pad(x_q, ((0, pm), (0, pk)))
    if pk or pn:
        w_q = jnp.pad(w_q, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn
    nk = Kp // bk
    out_dtype = jnp.int16 if saturate else jnp.int32

    out = pl.pallas_call(
        functools.partial(_q115_kernel, nk=nk, saturate=saturate),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, w_q)
    return out[:M, :N]
