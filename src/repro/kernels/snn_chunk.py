"""Fused event-driven SNN chunk — one Pallas invocation per Tc-step chunk.

This is the TPU analog of the paper's whole §4.3 pipeline, not just one
stage of it: on the FPGA the event decoder, cascaded adder and LIF neuron
unit are a single circuit and the membrane register never leaves the chip.
The pre-existing kernels each captured half of that — ``aer_spike_matmul``
fused the event gather, ``lif_fused`` fused the membrane update — but the
chunk runtime still stitched them together through HBM: per-step currents
written out by the gather, read back by the LIF pass, and membrane state
round-tripped between every step.  This kernel closes the loop:

  - **per-step event lists ride in via scalar prefetch** (SMEM): the whole
    (B, Tc, C) address/value/count table is available before the body runs,
    so event addresses can drive dynamic weight-row indexing;
  - **membrane potential and refractory counters live in VMEM scratch for
    all Tc steps** — HBM traffic for state is exactly one read of the
    incoming (B, N) slot states and one write of the outgoing ones,
    versus 2*Tc round-trips for the split pipeline;
  - **each E-block's weight-row gathers are gated on a non-silent
    predicate**: event lists are packed valid-first (``runtime.
    step_events``), so a block is silent iff its base offset is past the
    prefetched event count — silent stretches of the capacity cost one
    scalar compare each, and no weight rows are touched (the ROADMAP's
    "gate the weight DMA per E-block" item: on TPU the gather from the
    VMEM-resident slab, and the DMA it implies on spill, simply never
    issues);
  - **hidden layers run as gated in-VMEM matvecs**: the hidden spike plane
    is already resident (it was just computed), so event-extracting it
    would cost more than the (N_hid, N_out) product it feeds — a whole-
    plane non-silent predicate skips even that when the layer is quiet.
    For the paper's 4096-512-2 network >99% of synaptic work is in layer
    0, which takes the gathered path.

Semantics are anchored against ``events.runtime.run_chunk`` (the jnp
oracle): frozen continuous-batching slots, refractory counters, zero and
subtract reset, LIF and Lapicque dynamics, Q1.15 fake-quantized weights,
and measured per-layer event counts all match to float32 tolerance
(tests/test_snn_chunk.py).  On CPU the same kernel runs in interpret mode.

Grid: (B,) — one program per batch slot; weights are broadcast blocks
(index map constant in b) so each layer's slab is resident once, and slot
programs are embarrassingly parallel.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

Array = jax.Array

_LANE = 128  # TPU lane width: last-dim padding quantum
_EV_PAD = 128  # padded event-count lane (supports up to 128 layers)
# padded neurons get a huge-but-finite threshold: never fires, and unlike
# +inf it cannot make `thr * spike` produce NaN in subtract-reset mode
_PAD_THRESHOLD = 1e30


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _chunk_kernel(
    act_ref,  # (B,) int32 prefetch: 1 = slot active, 0 = frozen
    addr_ref,  # (B, Tc*C) int32 prefetch: layer-0 event addresses
    val_ref,  # (B, Tc*C) f32 prefetch: signed event values (0 = pad)
    cnt_ref,  # (B, Tc) int32 prefetch: valid events per step
    *refs,
    num_layers: int,
    num_steps: int,
    cap: int,
    block_e: int,
    refractory_steps: int,
    reset: str,
    kind: str,
    lapicque_gain: float,
):
    L = num_layers
    ws = refs[0:L]  # (K_i, NP_i) weight slabs
    biases = refs[L : 2 * L]  # (1, NP_i)
    betas = refs[2 * L : 3 * L]
    thrs = refs[3 * L : 4 * L]
    u0s = refs[4 * L : 5 * L]  # (1, NP_i) incoming slot state
    r0s = refs[5 * L : 6 * L]  # (1, NP_i) int32
    mem_ref, spk_ref, ev_ref = refs[6 * L : 6 * L + 3]
    ufins = refs[6 * L + 3 : 7 * L + 3]
    rfins = refs[7 * L + 3 : 8 * L + 3]
    u_scr = refs[8 * L + 3 : 9 * L + 3]  # VMEM-resident membranes
    r_scr = refs[9 * L + 3 : 10 * L + 3]  # VMEM-resident refractory

    b = pl.program_id(0)
    is_active = act_ref[b] > 0
    ne = cap // block_e

    @pl.when(jnp.logical_not(is_active))
    def _frozen():
        # run_chunk semantics for inactive slots: state held, no spikes, no
        # events, output membrane trace pinned at the held value
        for i in range(L):
            ufins[i][...] = u0s[i][...]
            rfins[i][...] = r0s[i][...]
        mem_ref[...] = jnp.broadcast_to(
            u0s[L - 1][...][None], mem_ref.shape
        )
        spk_ref[...] = jnp.zeros_like(spk_ref)
        ev_ref[...] = jnp.zeros_like(ev_ref)

    @pl.when(is_active)
    def _run():
        for i in range(L):
            u_scr[i][...] = u0s[i][...]
            r_scr[i][...] = r0s[i][...]

        lane = jax.lax.broadcasted_iota(jnp.int32, (1, _EV_PAD), 1)

        def step(t, _):
            # ---- layer 0: gated event-driven synaptic integration
            n0 = cnt_ref[b, t]
            base0 = t * cap

            def eblock(eb, acc):
                base = base0 + eb * block_e

                def gather(i, a):
                    addr = addr_ref[b, base + i]
                    v = val_ref[b, base + i]
                    row = ws[0][pl.ds(addr, 1), :].astype(jnp.float32)
                    return a + row * v

                # events are packed valid-first: a block past the count is
                # pure padding — one scalar compare, no row gathers
                return jax.lax.cond(
                    eb * block_e < n0,
                    lambda a: jax.lax.fori_loop(0, block_e, gather, a),
                    lambda a: a,
                    acc,
                )

            cur = jax.lax.fori_loop(
                0, ne, eblock, jnp.zeros_like(biases[0][...])
            )
            cur = cur + biases[0][...]

            ev_counts = [n0.astype(jnp.float32)]
            h = None
            for i in range(L):
                if i > 0:
                    # hidden layers: spike plane already VMEM-resident —
                    # gated dense matvec (skip the product when silent)
                    hcnt = jnp.sum(h)  # spikes are {0,1}: sum == nnz
                    ev_counts.append(hcnt)
                    w_i, b_i = ws[i], biases[i]
                    cur = jax.lax.cond(
                        hcnt > 0,
                        lambda h=h, w_i=w_i, b_i=b_i: (
                            jnp.dot(
                                h,
                                w_i[...],
                                preferred_element_type=jnp.float32,
                            )
                            + b_i[...]
                        ),
                        lambda b_i=b_i: b_i[...] + jnp.zeros_like(b_i[...]),
                    )
                # ---- LIF / Lapicque membrane update, state in scratch
                u = u_scr[i][...]
                if kind == "lif":
                    u_pre = betas[i][...] * u + cur
                else:  # lapicque
                    u_pre = u + lapicque_gain * cur
                raw = (u_pre >= thrs[i][...]).astype(jnp.float32)
                if refractory_steps > 0:
                    can = (r_scr[i][...] <= 0).astype(jnp.float32)
                    spk = raw * can
                    r_scr[i][...] = jnp.where(
                        spk > 0,
                        jnp.int32(refractory_steps),
                        jnp.maximum(r_scr[i][...] - 1, 0),
                    )
                else:
                    spk = raw
                if reset == "zero":
                    u_scr[i][...] = u_pre * (1.0 - spk)
                else:  # subtract
                    u_scr[i][...] = u_pre - thrs[i][...] * spk
                h = spk

            mem_ref[pl.ds(t, 1)] = u_scr[L - 1][...][None]
            spk_ref[pl.ds(t, 1)] = h[None]
            ev_row = jnp.zeros((1, _EV_PAD), jnp.float32)
            for i in range(L):
                ev_row = jnp.where(lane == i, ev_counts[i], ev_row)
            ev_ref[pl.ds(t, 1)] = ev_row[None]
            return 0

        jax.lax.fori_loop(0, num_steps, step, 0)
        for i in range(L):
            ufins[i][...] = u_scr[i][...]
            rfins[i][...] = r_scr[i][...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "refractory_steps",
        "reset",
        "kind",
        "lapicque_gain",
        "block_e",
        "interpret",
        "layout",
    ),
)
def snn_chunk(
    weights: Sequence[Array],  # L x (K_i, N_i) f32 (fake-quantized ok)
    biases: Sequence[Array],  # L x (N_i,) f32
    betas: Sequence[Array],  # L x (N_i,) f32 (effective, post-sigmoid)
    thresholds: Sequence[Array],  # L x (N_i,) f32
    u0: Sequence[Array],  # L x (B, N_i) f32 incoming membranes
    r0: Sequence[Array],  # L x (B, N_i) i32 incoming refractory
    addrs: Array,  # (Tc, B, C) int layer-0 event addresses
    values: Array,  # (Tc, B, C) signed event values (0 = pad)
    counts: Array,  # (Tc, B) int valid events per step
    active: Array,  # (B,) slot mask (nonzero = active)
    *,
    refractory_steps: int = 0,
    reset: str = "zero",
    kind: str = "lif",
    lapicque_gain: float = 1.0,
    block_e: int = 128,
    interpret: bool = False,
    layout: str = "time_major",
) -> Tuple[Array, Array, Array, Tuple[Array, ...], Tuple[Array, ...]]:
    """Run the whole SNN ``Tc`` steps in one kernel launch.

    Returns (out_mem (Tc, B, N_last), out_spikes (Tc, B, N_last),
    events (Tc, L, B), u_fin (L x (B, N_i)), refrac_fin (L x (B, N_i))).

    Event lists must be packed valid-first with zero values on padding —
    exactly what ``events.runtime.step_events`` produces; the E-block gate
    relies on it.  Narrow dtypes (int16 addresses, int8 values — the
    device-resident staging format) are widened here, on device, right
    before prefetch.  ``layout="slot_major"`` accepts (B, Tc, C) tables —
    the per-slot ring-buffer layout — and skips the transpose the
    time-major layout needs to build the flat per-slot prefetch stream.
    """
    L = len(weights)
    assert L <= _EV_PAD, "event-count lane supports at most 128 layers"
    if layout == "slot_major":
        B, Tc, C = addrs.shape
    elif layout == "time_major":
        Tc, B, C = addrs.shape
    else:
        raise ValueError(f"unknown event layout {layout!r}")

    be = min(block_e, C)
    pc = (-C) % be
    if pc:
        pad = (
            ((0, 0), (0, 0), (0, pc))
        )
        addrs = jnp.pad(addrs, pad)
        values = jnp.pad(values, pad)
    Cp = C + pc

    outs = [w.shape[1] for w in weights]
    np_out = [_round_up(n, _LANE) for n in outs]

    ws, bs, bet, thr, u0p, r0p = [], [], [], [], [], []
    for i in range(L):
        pn = np_out[i] - outs[i]
        w = weights[i].astype(jnp.float32)
        if i > 0:  # rows must match the padded spike plane of layer i-1
            w = jnp.pad(w, ((0, np_out[i - 1] - w.shape[0]), (0, pn)))
        elif pn:
            w = jnp.pad(w, ((0, 0), (0, pn)))
        ws.append(w)
        bs.append(jnp.pad(biases[i].astype(jnp.float32), (0, pn))[None, :])
        bet.append(jnp.pad(betas[i].astype(jnp.float32), (0, pn))[None, :])
        thr.append(
            jnp.pad(
                thresholds[i].astype(jnp.float32),
                (0, pn),
                constant_values=_PAD_THRESHOLD,
            )[None, :]
        )
        u0p.append(jnp.pad(u0[i].astype(jnp.float32), ((0, 0), (0, pn))))
        r0p.append(jnp.pad(r0[i].astype(jnp.int32), ((0, 0), (0, pn))))

    # prefetch tables: flat per-slot event streams + per-step counts
    if layout == "slot_major":
        addrs_f = addrs.reshape(B, Tc * Cp).astype(jnp.int32)
        values_f = values.reshape(B, Tc * Cp).astype(jnp.float32)
        counts_f = counts.astype(jnp.int32)
    else:
        addrs_f = (
            addrs.transpose(1, 0, 2).reshape(B, Tc * Cp).astype(jnp.int32)
        )
        values_f = (
            values.transpose(1, 0, 2).reshape(B, Tc * Cp).astype(jnp.float32)
        )
        counts_f = counts.transpose(1, 0).astype(jnp.int32)
    act = (jnp.asarray(active) != 0).astype(jnp.int32)

    in_specs = []
    for i in range(L):
        # index map constant in b: each slab is resident once, shared by
        # every slot program
        in_specs.append(
            pl.BlockSpec(ws[i].shape, lambda b, *_: (0, 0))
        )
    for group in (bs, bet, thr):
        for i in range(L):
            in_specs.append(
                pl.BlockSpec((1, np_out[i]), lambda b, *_: (0, 0))
            )
    for group in (u0p, r0p):
        for i in range(L):
            in_specs.append(
                pl.BlockSpec((1, np_out[i]), lambda b, *_: (b, 0))
            )

    npl = np_out[-1]
    out_specs = [
        pl.BlockSpec((Tc, 1, npl), lambda b, *_: (0, b, 0)),  # mem
        pl.BlockSpec((Tc, 1, npl), lambda b, *_: (0, b, 0)),  # spikes
        pl.BlockSpec((Tc, 1, _EV_PAD), lambda b, *_: (0, b, 0)),  # events
    ]
    out_shape = [
        jax.ShapeDtypeStruct((Tc, B, npl), jnp.float32),
        jax.ShapeDtypeStruct((Tc, B, npl), jnp.float32),
        jax.ShapeDtypeStruct((Tc, B, _EV_PAD), jnp.float32),
    ]
    for i in range(L):  # final membranes
        out_specs.append(pl.BlockSpec((1, np_out[i]), lambda b, *_: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, np_out[i]), jnp.float32))
    for i in range(L):  # final refractory counters
        out_specs.append(pl.BlockSpec((1, np_out[i]), lambda b, *_: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, np_out[i]), jnp.int32))

    scratch_shapes = [pltpu.VMEM((1, np_out[i]), jnp.float32) for i in range(L)]
    scratch_shapes += [pltpu.VMEM((1, np_out[i]), jnp.int32) for i in range(L)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B,),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch_shapes,
    )
    results = pl.pallas_call(
        functools.partial(
            _chunk_kernel,
            num_layers=L,
            num_steps=Tc,
            cap=Cp,
            block_e=be,
            refractory_steps=refractory_steps,
            reset=reset,
            kind=kind,
            lapicque_gain=lapicque_gain,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(act, addrs_f, values_f, counts_f, *ws, *bs, *bet, *thr, *u0p, *r0p)

    mem, spk, ev = results[0], results[1], results[2]
    u_fin = tuple(
        results[3 + i][:, : outs[i]] for i in range(L)
    )
    r_fin = tuple(
        results[3 + L + i][:, : outs[i]] for i in range(L)
    )
    n_last = outs[-1]
    events = ev[:, :, :L].transpose(0, 2, 1)  # (Tc, L, B)
    return mem[:, :, :n_last], spk[:, :, :n_last], events, u_fin, r_fin
