"""Public kernel API: jit'd wrappers with automatic interpret fallback.

On CPU (this container) every kernel runs in Pallas interpret mode — the
kernel body executes in Python with identical semantics; on a real TPU
backend the same `pl.pallas_call` lowers to Mosaic.  `on_tpu()` picks the
path; callers never pass `interpret` themselves.

Also hosts the composed op the SNN inference path uses:
`snn_layer_step` = spike_matmul -> bias -> lif (the paper's Figure 5
pipeline: cascaded adder -> LIF neuron hardware unit).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import aer_matmul as _aer
from repro.kernels import lif_fused as _lif
from repro.kernels import q115_matmul as _q115
from repro.kernels import snn_chunk as _chunk
from repro.kernels import spike_matmul as _smm

Array = jax.Array


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lif_fused(
    currents: Array,
    beta: Array,
    threshold: Array,
    *,
    refractory_steps: int = 0,
    reset: str = "zero",
) -> Tuple[Array, Array]:
    return _lif.lif_fused(
        currents,
        beta,
        threshold,
        refractory_steps=refractory_steps,
        reset=reset,
        interpret=not on_tpu(),
    )


def spike_matmul(spikes: Array, weights_q: Array) -> Array:
    return _smm.spike_matmul(spikes, weights_q, interpret=not on_tpu())


def aer_spike_matmul(addrs: Array, values: Array, weights_q: Array) -> Array:
    """Event-driven synaptic integration over an AER event list.

    out[n] = sum_e values[e] * weights_q[addrs[e], n]  (int32 accumulator,
    the 28-bit-class adder-tree intermediate).  Work scales with the event
    count, not fan-in — the hardware-faithful path for sparse spike trains.
    """
    return _aer.aer_spike_matmul(addrs, values, weights_q,
                                 interpret=not on_tpu())


def aer_spike_matmul_batched(
    addrs: Array, values: Array, weights: Array
) -> Array:
    """Batched event-driven integration, one grid axis per stream.

    out[b, n] = sum_e values[b, e] * weights[addrs[b, e], n] — the
    training-batch analog of ``aer_spike_matmul`` (int16 weights: exact
    int32 accumulation; float32 weights: the surrogate-gradient forward).
    """
    return _aer.aer_spike_matmul_batched(addrs, values, weights,
                                         interpret=not on_tpu())


def snn_chunk(
    weights,
    biases,
    betas,
    thresholds,
    u0,
    r0,
    addrs: Array,
    values: Array,
    counts: Array,
    active: Array,
    *,
    refractory_steps: int = 0,
    reset: str = "zero",
    kind: str = "lif",
    lapicque_gain: float = 1.0,
    interpret=None,
    layout: str = "time_major",
):
    """Fused multi-timestep, multi-layer event-driven SNN chunk.

    One Pallas invocation advances the whole network ``Tc`` steps: layer-0
    weight-row gathers driven by scalar-prefetched event lists (gated per
    E-block on a non-silent predicate), membranes + refractory counters
    resident in VMEM scratch across all steps, hidden layers as gated
    in-VMEM matvecs.  ``layout="slot_major"`` consumes (B, Tc, C) tables
    (the serving engine's device-resident ring layout) transpose-free.
    See ``kernels.snn_chunk`` for the full contract.
    """
    return _chunk.snn_chunk(
        weights,
        biases,
        betas,
        thresholds,
        u0,
        r0,
        addrs,
        values,
        counts,
        active,
        refractory_steps=refractory_steps,
        reset=reset,
        kind=kind,
        lapicque_gain=lapicque_gain,
        interpret=(not on_tpu()) if interpret is None else interpret,
        layout=layout,
    )


def q115_matmul(x_q: Array, w_q: Array, *, saturate: bool = True) -> Array:
    return _q115.q115_matmul(
        x_q, w_q, saturate=saturate, interpret=not on_tpu()
    )


def snn_layer_forward(
    spikes_T: Array,  # (T, B, fan_in) f32/int {0,1} input spike train
    w: Array,  # (fan_in, fan_out) float weights
    b: Array,  # (fan_out,) float bias
    beta: Array,  # (fan_out,)
    threshold: Array,  # (fan_out,)
    *,
    refractory_steps: int = 0,
) -> Array:
    """Full hardware-path layer: Q1.15 weights, integer cascaded-adder
    integration per step, fused LIF over the window.  Returns spike train
    (T, B, fan_out) f32.

    This is the inference path of paper Fig. 5; training uses the float
    graph in core/snn.py (QAT via quant.fake_quant keeps them aligned).
    """
    T, B, fan_in = spikes_T.shape
    wq = quant.quantize(w, quant.Q1_15)  # (fan_in, fan_out) int16
    bq = quant.quantize(b, quant.Q1_15)  # bias in the same Q1.15 scale

    # integrate all T steps: fold time into rows for one big integration
    spk_i8 = spikes_T.reshape(T * B, fan_in).astype(jnp.int8)
    acc = spike_matmul(spk_i8, wq)  # (T*B, fan_out) int32
    # bias added post-adder-tree in the same fixed-point scale (paper §4.3)
    acc = acc + bq.astype(jnp.int32)[None, :]
    currents = acc.astype(jnp.float32) / quant.Q1_15.scale
    currents = currents.reshape(T, B, -1)

    out_spikes, _ = lif_fused(
        currents, beta, threshold, refractory_steps=refractory_steps
    )
    return out_spikes
