"""AER event-driven synaptic integration — Pallas TPU kernel.

Where ``spike_matmul`` consumes a *dense* {0,1} spike plane and relies on
whole-tile zero predicates to skip silence, this kernel consumes the AER
event list directly: a vector of active input addresses.  Work is
proportional to the number of events, not the layer fan-in — the true
hardware analog of the paper's event-driven cascaded adder (§4.3), where
only firing synapses clock the adder tree.

Dataflow:
  - event addresses + signed event values ride in as **scalar-prefetch**
    operands (SMEM), available before the body runs so they can drive
    dynamic row indexing;
  - weights are blocked along N only; each grid step owns the full (K, bn)
    column slab in VMEM (Q1.15 int16: 4096 x 128 x 2B = 1 MiB);
  - grid is (N blocks, E blocks), E innermost ("arbitrary"), accumulating
    into an int32 VMEM scratch — the paper's 28-bit-class intermediate;
  - an event-count predicate gates each E block: blocks of pure padding
    (or silent stretches of the stream) cost a scalar test, no gathers.

Integer contract (bit-exact vs ref.aer_spike_matmul_ref):
  out[n] = sum_e values[e] * wq[addrs[e], n]   (int32)

``values`` carries polarity (+1/-1) and padding (0); for the SNN hidden
path it is simply the event-validity mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

Array = jax.Array


def _aer_kernel(
    addr_ref,  # (E,) int32 scalar-prefetch: event addresses
    val_ref,  # (E,) int32 scalar-prefetch: signed event values (0 = pad)
    w_ref,  # (K, bn) int16 weight column slab
    out_ref,  # (1, bn) int32
    acc_scr,  # (1, bn) int32 VMEM accumulator
    *,
    block_e: int,
    ne: int,
):
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    base = e * block_e

    # events in this block (abs: +1/-1 polarities must not cancel the gate)
    def _count(i, c):
        return c + jnp.abs(val_ref[base + i])

    n_events = jax.lax.fori_loop(0, block_e, _count, jnp.int32(0))

    @pl.when(n_events > 0)
    def _integrate():
        def _gather(i, acc):
            a = addr_ref[base + i]
            v = val_ref[base + i]
            row = w_ref[pl.ds(a, 1), :].astype(jnp.int32)  # (1, bn)
            return acc + row * v

        acc_scr[...] = jax.lax.fori_loop(0, block_e, _gather, acc_scr[...])

    @pl.when(e == ne - 1)
    def _flush():
        out_ref[...] = acc_scr[...]


def _aer_batched_kernel(
    addr_ref,  # (B, E) int32 scalar-prefetch: per-stream event addresses
    val_ref,  # (B, E) scalar-prefetch: signed event values (0 = pad)
    w_ref,  # (K, bn) weight column slab (int16 or float32)
    out_ref,  # (1, bn) accumulator dtype
    acc_scr,  # (1, bn) VMEM accumulator
    *,
    block_e: int,
    ne: int,
):
    b = pl.program_id(0)
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    base = e * block_e
    acc_dtype = acc_scr.dtype

    # events of stream b in this E block (nonzero test, not a sum: float
    # magnitudes < 1 must still count, and polarities must not cancel)
    def _count(i, c):
        return c + (val_ref[b, base + i] != 0).astype(jnp.int32)

    n_events = jax.lax.fori_loop(0, block_e, _count, jnp.int32(0))

    @pl.when(n_events > 0)
    def _integrate():
        def _gather(i, acc):
            a = addr_ref[b, base + i]
            v = val_ref[b, base + i].astype(acc_dtype)
            row = w_ref[pl.ds(a, 1), :].astype(acc_dtype)  # (1, bn)
            return acc + row * v

        acc_scr[...] = jax.lax.fori_loop(0, block_e, _gather, acc_scr[...])

    @pl.when(e == ne - 1)
    def _flush():
        out_ref[...] = acc_scr[...]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_e", "interpret")
)
def aer_spike_matmul_batched(
    addrs: Array,  # (B, E) int32 in [0, K); padding slots point anywhere
    values: Array,  # (B, E) int-like / float; 0 on padding
    weights: Array,  # (K, N) int16 Q1.15 codes or float32 weights
    *,
    block_n: int = 128,
    block_e: int = 128,
    interpret: bool = False,
) -> Array:
    """Batched event-driven integration: one grid axis per stream.

    out[b, n] = sum_e values[b, e] * weights[addrs[b, e], n]

    Semantically ``jax.vmap(aer_spike_matmul)`` over the stream axis, but
    as one kernel launch: grid (B, N blocks, E blocks) with the whole
    (B, E) event table scalar-prefetched to SMEM, so every stream's row
    gathers are driven by its own slice.  This is the training-batch path
    (vmap of a scalar-prefetch ``pallas_call`` is not supported on all
    backends, and a single launch amortizes the weight-slab DMA across the
    batch).

    dtype contract: int16 weights accumulate exactly in int32 (bit-exact
    vs ``ref.aer_spike_matmul_ref`` per stream); float32 weights accumulate
    in float32 (the surrogate-gradient training forward).
    """
    B, E = addrs.shape
    K, N = weights.shape
    if weights.dtype == jnp.int16:
        acc_dtype = jnp.int32
        values = values.astype(jnp.int32)
    else:
        acc_dtype = jnp.float32
        weights = weights.astype(jnp.float32)
        values = values.astype(jnp.float32)
    bn = min(block_n, N)
    be = min(block_e, E)
    pe, pn = (-E) % be, (-N) % bn
    if pe:
        addrs = jnp.pad(addrs, ((0, 0), (0, pe)))
        values = jnp.pad(values, ((0, 0), (0, pe)))
    if pn:
        weights = jnp.pad(weights, ((0, 0), (0, pn)))
    Ep, Np = E + pe, N + pn
    ne = Ep // be

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Np // bn, ne),
        in_specs=[
            pl.BlockSpec((K, bn), lambda b, j, e, addr, val: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, j, e, addr, val: (b, j)),
        scratch_shapes=[pltpu.VMEM((1, bn), acc_dtype)],
    )
    out = pl.pallas_call(
        functools.partial(_aer_batched_kernel, block_e=be, ne=ne),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Np), acc_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(addrs.astype(jnp.int32), values, weights)
    return out[:, :N]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_e", "interpret")
)
def aer_spike_matmul(
    addrs: Array,  # (E,) int32 in [0, K); padding slots point anywhere
    values: Array,  # (E,) int-like; +1/-1 polarity, 0 on padding
    weights_q: Array,  # (K, N) int16 Q1.15 codes
    *,
    block_n: int = 128,
    block_e: int = 128,
    interpret: bool = False,
) -> Array:
    """Returns int32 accumulator (N,); dequantize with /2^15."""
    (E,) = addrs.shape
    K, N = weights_q.shape
    bn = min(block_n, N)
    be = min(block_e, E)
    pe, pn = (-E) % be, (-N) % bn
    if pe:
        addrs = jnp.pad(addrs, (0, pe))
        values = jnp.pad(values, (0, pe))
    if pn:
        weights_q = jnp.pad(weights_q, ((0, 0), (0, pn)))
    Ep, Np = E + pe, N + pn
    ne = Ep // be

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Np // bn, ne),
        in_specs=[
            pl.BlockSpec((K, bn), lambda j, e, addr, val: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, e, addr, val: (0, j)),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_aer_kernel, block_e=be, ne=ne),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, Np), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(addrs.astype(jnp.int32), values.astype(jnp.int32), weights_q)
    return out[0, :N]
