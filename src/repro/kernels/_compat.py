"""Small jax-version shims shared by the Pallas kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; support
both so the kernels import under every toolchain the container ships.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

__all__ = ["CompilerParams"]
