"""Event-driven spike x weight integration — Pallas TPU kernel.

TPU-native analog of the paper's *cascaded adder* (§4.3): activations are
binary spikes, so synaptic integration is a masked add-reduction of weight
rows — no multiplies.  On TPU the energy story shifts from "remove the
multiplier" (MXU multipliers are free silicon) to:

  1. **memory traffic**: spikes travel as int8 (1 byte vs 2/4), weights as
     int16 Q1.15 codes (half of f32);
  2. **event skipping**: spiking activity is sparse (measured ~1-10% in the
     trained net).  Each (m, k) spike tile is reduced on-chip first; a
     whole-tile zero-spike predicate gates the integration arithmetic with
     `pl.when` — silent tiles cost a load + test, not a matmul.  (A deeper
     implementation would gate the weight DMA too via manual copies; noted
     in DESIGN.md.)

Grid: (M/bm, N/bn, K/bk), k innermost ("arbitrary" semantics) accumulating
into an int32 VMEM scratch — the paper's 28-bit adder-tree intermediate.

Integer contract (bit-exact vs ref.spike_matmul_ref):
  acc[m, n] = sum_k spk[m, k] * wq[k, n]   (int32)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

Array = jax.Array


def _spike_mm_kernel(spk_ref, w_ref, out_ref, acc_scr, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    spk = spk_ref[...]  # (bm, bk) int8 in {0,1}
    n_events = jnp.sum(spk.astype(jnp.int32))

    @pl.when(n_events > 0)
    def _integrate():
        # {0,1} spikes: integer dot == masked add-reduction (adder tree).
        acc_scr[...] += jax.lax.dot_general(
            spk.astype(jnp.int32),
            w_ref[...].astype(jnp.int32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[...] = acc_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def spike_matmul(
    spikes: Array,  # (M, K) int8 {0,1}
    weights_q: Array,  # (K, N) int16 Q1.15 codes
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> Array:
    """Returns int32 accumulator (M, N); dequantize with /2^15."""
    M, K = spikes.shape
    K2, N = weights_q.shape
    assert K == K2, (spikes.shape, weights_q.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        spikes = jnp.pad(spikes, ((0, pm), (0, pk)))
    if pk or pn:
        weights_q = jnp.pad(weights_q, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn
    nk = Kp // bk

    out = pl.pallas_call(
        functools.partial(_spike_mm_kernel, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(spikes, weights_q)
    return out[:M, :N]
