"""Pallas TPU kernels for the paper's compute hot-spots (+ jnp oracles).

- lif_fused:    fused multi-step LIF neuron dynamics (VMEM-resident state)
- spike_matmul: event-driven binary-spike integration (cascaded adder)
- q115_matmul:  Q1.15 fixed-point matmul, int32 (28-bit-class) accumulator
- ops:          public wrappers (interpret on CPU, Mosaic on TPU)
- ref:          pure-jnp oracles, the correctness contract for every kernel
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
