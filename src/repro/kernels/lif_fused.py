"""Fused multi-step LIF dynamics — Pallas TPU kernel.

TPU-native analog of the paper's "LIF Neuron Hardware Unit" (§4.3): on the
FPGA the membrane register lives next to the adder so U never leaves the
chip; here the whole coding window (T steps) is processed inside one kernel
invocation with the membrane potential and refractory counters pinned in
VMEM scratch.  HBM traffic is exactly: currents read once, spikes written
once — versus 2T round-trips of U for the step-at-a-time jnp version.

Grid: (B/block_b, N/block_n); each program owns a (block_b, block_n) tile
of neurons for all T steps (time is the innermost, sequential loop — the
dependence is inherently sequential in T, parallel in neurons, which maps
to the VPU's (8, 128) lanes).

VMEM budget per program (defaults block_b=8, block_n=128, T=25, f32):
  currents (25,8,128)*4 = 100 KiB, spikes同 100 KiB, U/refrac (8,128)*8 = 8 KiB
  << 16 MiB VMEM.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _lif_kernel(
    cur_ref,  # (T, bb, bn) f32 VMEM
    beta_ref,  # (1, bn) f32
    thr_ref,  # (1, bn) f32
    spk_ref,  # (T, bb, bn) f32 out
    ufin_ref,  # (bb, bn) f32 out
    u_scr,  # (bb, bn) f32 scratch
    refrac_scr,  # (bb, bn) i32 scratch
    *,
    num_steps: int,
    refractory_steps: int,
    reset: str,
):
    u_scr[...] = jnp.zeros_like(u_scr)
    refrac_scr[...] = jnp.zeros_like(refrac_scr)
    beta = beta_ref[0, :][None, :]
    thr = thr_ref[0, :][None, :]

    def step(t, _):
        cur_t = cur_ref[pl.ds(t, 1)][0]
        u_pre = beta * u_scr[...] + cur_t
        raw = (u_pre >= thr).astype(jnp.float32)
        if refractory_steps > 0:
            can = (refrac_scr[...] <= 0).astype(jnp.float32)
            spk = raw * can
            refrac_scr[...] = jnp.where(
                spk > 0,
                jnp.int32(refractory_steps),
                jnp.maximum(refrac_scr[...] - 1, 0),
            )
        else:
            spk = raw
        if reset == "zero":
            u_scr[...] = u_pre * (1.0 - spk)
        else:  # subtract
            u_scr[...] = u_pre - thr * spk
        spk_ref[pl.ds(t, 1)] = spk[None]
        return ()

    jax.lax.fori_loop(0, num_steps, step, ())
    ufin_ref[...] = u_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "refractory_steps", "reset", "block_b", "block_n", "interpret",
    ),
)
def lif_fused(
    currents: Array,  # (T, B, N) f32
    beta: Array,  # (N,) f32
    threshold: Array,  # (N,) f32
    *,
    refractory_steps: int = 0,
    reset: str = "zero",
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> Tuple[Array, Array]:
    """Returns (spikes (T,B,N) f32, final_u (B,N) f32)."""
    T, B, N = currents.shape
    bb, bn = min(block_b, B), min(block_n, N)
    pad_b, pad_n = (-B) % bb, (-N) % bn
    if pad_b or pad_n:
        currents = jnp.pad(currents, ((0, 0), (0, pad_b), (0, pad_n)))
        beta = jnp.pad(beta, (0, pad_n))
        # padded neurons get +inf threshold so they never fire
        threshold = jnp.pad(
            threshold, (0, pad_n), constant_values=jnp.float32(jnp.inf)
        )
    Bp, Np = B + pad_b, N + pad_n

    grid = (Bp // bb, Np // bn)
    spikes, u_fin = pl.pallas_call(
        functools.partial(
            _lif_kernel,
            num_steps=T,
            refractory_steps=refractory_steps,
            reset=reset,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, bb, bn), lambda i, j: (0, i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((T, bb, bn), lambda i, j: (0, i, j)),
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, Bp, Np), jnp.float32),
            jax.ShapeDtypeStruct((Bp, Np), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, bn), jnp.float32),
            pltpu.VMEM((bb, bn), jnp.int32),
        ],
        interpret=interpret,
    )(currents, beta[None, :], threshold[None, :])

    if pad_b or pad_n:
        spikes = spikes[:, :B, :N]
        u_fin = u_fin[:B, :N]
    return spikes, u_fin
