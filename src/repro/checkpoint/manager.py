"""Fault-tolerant checkpointing: atomic writes, keep-N, auto-resume,
elastic (mesh-shape-independent) restore, integrity verification.

Design for the 1000+-node target:
  - checkpoints are written *atomically* (tmp dir + rename) so a node
    failure mid-save never corrupts the latest checkpoint;
  - every array carries a crc32 checksum in the manifest — restore
    detects truncated or bit-flipped checkpoints (disk corruption, torn
    copies) and `restore_latest` falls back to the previous keep-N
    checkpoint instead of loading garbage into a training run;
  - save gathers to host-replicated numpy (npz per pytree) — restore can
    therefore reshard onto ANY mesh (elastic scaling: train on 512 chips,
    resume on 256);
  - `latest_step()` + `restore_latest()` implement checkpoint/restart: the
    launcher always calls restore_latest and starts from step 0 only when
    nothing is found (see launch/train.py); both skip and garbage-collect
    orphaned `.tmp_*` dirs left by a process killed mid-save;
  - background-thread save (`async_save=True`) overlaps serialization with
    the next step (double-buffered via a copied host tree), the standard
    straggler/throughput mitigation for frequent checkpoints; `close()`
    (or context-manager exit) joins the writer so interpreter teardown
    cannot strand a partial tmp dir;
  - keep_n bounds disk usage.

The atomic array-dir helpers (`publish_array_dir` / `load_array_dir`)
are shared with the serving plane: `SNNStreamEngine.snapshot()` uses the
same tmp-dir+rename+checksum discipline for warm-restart snapshots.

On a real multi-host pod the gather maps to `multihost_utils.
process_allgather` and only host 0 writes; in this single-host container
that path degenerates to device_get, which is what we exercise in tests.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import warnings
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

TMP_PREFIX = ".tmp_"


class CheckpointCorruptError(Exception):
    """A checkpoint failed integrity verification (truncated npz,
    checksum mismatch, missing arrays, unreadable manifest)."""


def _crc32(arr: np.ndarray) -> int:
    a = np.ascontiguousarray(arr)
    return zlib.crc32(a.tobytes()) & 0xFFFFFFFF


def publish_array_dir(
    directory: str,
    name: str,
    arrays: Dict[str, np.ndarray],
    manifest: Dict,
) -> str:
    """Atomically write `arrays` + `manifest` as `directory/name`.

    Writes arrays.npz and manifest.json (augmented with per-array crc32
    checksums) into a `.tmp_*` dir, then publishes with a single rename
    — a crash at any point leaves either the previous version or an
    orphaned tmp dir, never a half-written published dir.
    """
    final = os.path.join(directory, name)
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=TMP_PREFIX)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        doc = dict(manifest)
        doc["checksums"] = {k: _crc32(v) for k, v in arrays.items()}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(doc, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def load_array_dir(path: str) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Load and checksum-verify an array dir written by
    `publish_array_dir`. Raises CheckpointCorruptError on any integrity
    failure; manifests without checksums (pre-v10 checkpoints) load
    unverified for backward compatibility."""
    manifest_path = os.path.join(path, "manifest.json")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"unreadable manifest in {path}: {e}"
        ) from e
    try:
        with np.load(os.path.join(path, "arrays.npz")) as data:
            arrays = {k: data[k] for k in data.files}
    except (OSError, ValueError, zlib.error, EOFError,
            zipfile.BadZipFile) as e:
        raise CheckpointCorruptError(
            f"unreadable/truncated arrays.npz in {path}: {e}"
        ) from e
    checksums = manifest.get("checksums")
    if checksums is not None:
        missing = set(checksums) - set(arrays)
        if missing:
            raise CheckpointCorruptError(
                f"arrays missing from {path}: {sorted(missing)}"
            )
        for k, want in checksums.items():
            got = _crc32(arrays[k])
            if got != want:
                raise CheckpointCorruptError(
                    f"checksum mismatch for '{k}' in {path}: "
                    f"manifest {want:#010x} != data {got:#010x}"
                )
    return arrays, manifest


def gc_orphan_tmpdirs(directory: str) -> List[str]:
    """Remove orphaned `.tmp_*` dirs left by a process killed mid-save.
    Returns the paths removed. Caller must ensure no save is in flight
    in this process (CheckpointManager guards this itself)."""
    removed = []
    if not os.path.isdir(directory):
        return removed
    for d in os.listdir(directory):
        if d.startswith(TMP_PREFIX):
            p = os.path.join(directory, d)
            shutil.rmtree(p, ignore_errors=True)
            removed.append(p)
    return removed


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep_n: int = 3,
        async_save: bool = False,
    ):
        self.directory = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.fallbacks = 0  # corrupt checkpoints skipped by restore_latest
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------- lifecycle
    def close(self):
        """Join any in-flight async save. After close() the manager is
        still usable; this only drains the writer so interpreter exit
        cannot strand a partial `.tmp_*` dir."""
        self.wait()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree, metadata: Optional[Dict] = None):
        """Atomic checkpoint of an arbitrary pytree at `step`."""
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        if self.async_save:
            self.wait()  # at most one in-flight save
            self._thread = threading.Thread(
                target=self._write, args=(step, names, host_leaves, metadata)
            )
            self._thread.start()
        else:
            self._write(step, names, host_leaves, metadata)

    def _write(self, step, names, host_leaves, metadata):
        publish_array_dir(
            self.directory,
            f"step_{step:010d}",
            {f"a{i}": x for i, x in enumerate(host_leaves)},
            {"step": step, "names": names, "metadata": metadata or {}},
        )
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:010d}"),
                ignore_errors=True,
            )

    def _gc_orphans(self):
        # only safe when this process has no writer mid-save; another
        # manager instance's live tmp dir would be renamed away before
        # we could race it in the workflows this repo runs (one writer
        # per directory).
        if self._thread is not None and self._thread.is_alive():
            return
        removed = gc_orphan_tmpdirs(self.directory)
        for p in removed:
            warnings.warn(
                f"checkpoint: removed orphaned partial save {p} "
                "(process killed mid-save?)",
                stacklevel=3,
            )

    # --------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                # ignore partially-renamed/corrupt dirs without manifest
                if os.path.exists(
                    os.path.join(self.directory, d, "manifest.json")
                ):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        self._gc_orphans()
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, step: int, like: PyTree, shardings: Optional[PyTree] = None
    ) -> PyTree:
        """Restore into the structure of `like`; optionally placed onto
        `shardings` (elastic restore — any mesh shape). Raises
        CheckpointCorruptError if the checkpoint fails checksum/read
        verification, ValueError on a structure mismatch."""
        path = os.path.join(self.directory, f"step_{step:010d}")
        data, manifest = load_array_dir(path)
        names, like_leaves, treedef = _flatten_with_names(like)
        if names != manifest["names"]:
            raise ValueError(
                "checkpoint/model structure mismatch: "
                f"{set(names) ^ set(manifest['names'])}"
            )
        try:
            leaves = [data[f"a{i}"] for i in range(len(names))]
        except KeyError as e:
            raise CheckpointCorruptError(
                f"array {e} missing from {path}"
            ) from e
        leaves = [
            np.asarray(x).astype(l.dtype) if hasattr(l, "dtype") else x
            for x, l in zip(leaves, like_leaves)
        ]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        return tree

    def restore_latest(
        self, like: PyTree, shardings: Optional[PyTree] = None
    ) -> Tuple[Optional[int], Optional[PyTree]]:
        """Restore the newest checkpoint that passes integrity
        verification. A corrupt checkpoint is skipped with a loud
        warning (`self.fallbacks` counts them) and the previous keep-N
        checkpoint is tried — a byte-flipped latest save degrades the
        recovery point instead of crashing the resume."""
        self._gc_orphans()
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step, like, shardings)
            except CheckpointCorruptError as e:
                self.fallbacks += 1
                warnings.warn(
                    f"checkpoint step {step} failed integrity check "
                    f"({e}); falling back to previous checkpoint",
                    stacklevel=2,
                )
        return None, None
