"""Fault-tolerant checkpointing: atomic writes, keep-N, auto-resume,
elastic (mesh-shape-independent) restore.

Design for the 1000+-node target:
  - checkpoints are written *atomically* (tmp dir + rename) so a node
    failure mid-save never corrupts the latest checkpoint;
  - save gathers to host-replicated numpy (npz per pytree) — restore can
    therefore reshard onto ANY mesh (elastic scaling: train on 512 chips,
    resume on 256);
  - `latest_step()` + `restore_latest()` implement checkpoint/restart: the
    launcher always calls restore_latest and starts from step 0 only when
    nothing is found (see launch/train.py);
  - background-thread save (`async_save=True`) overlaps serialization with
    the next step (double-buffered via a copied host tree), the standard
    straggler/throughput mitigation for frequent checkpoints;
  - keep_n bounds disk usage.

On a real multi-host pod the gather maps to `multihost_utils.
process_allgather` and only host 0 writes; in this single-host container
that path degenerates to device_get, which is what we exercise in tests.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep_n: int = 3,
        async_save: bool = False,
    ):
        self.directory = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree, metadata: Optional[Dict] = None):
        """Atomic checkpoint of an arbitrary pytree at `step`."""
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        if self.async_save:
            self.wait()  # at most one in-flight save
            self._thread = threading.Thread(
                target=self._write, args=(step, names, host_leaves, metadata)
            )
            self._thread.start()
        else:
            self._write(step, names, host_leaves, metadata)

    def _write(self, step, names, host_leaves, metadata):
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_")
        try:
            np.savez(
                os.path.join(tmp, "arrays.npz"),
                **{f"a{i}": x for i, x in enumerate(host_leaves)},
            )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(
                    {"step": step, "names": names, "metadata": metadata or {}},
                    f,
                )
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:010d}"),
                ignore_errors=True,
            )

    # --------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                # ignore partially-renamed/corrupt dirs without manifest
                if os.path.exists(
                    os.path.join(self.directory, d, "manifest.json")
                ):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, step: int, like: PyTree, shardings: Optional[PyTree] = None
    ) -> PyTree:
        """Restore into the structure of `like`; optionally placed onto
        `shardings` (elastic restore — any mesh shape)."""
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        names, like_leaves, treedef = _flatten_with_names(like)
        if names != manifest["names"]:
            raise ValueError(
                "checkpoint/model structure mismatch: "
                f"{set(names) ^ set(manifest['names'])}"
            )
        leaves = [data[f"a{i}"] for i in range(len(names))]
        leaves = [
            np.asarray(x).astype(l.dtype) if hasattr(l, "dtype") else x
            for x, l in zip(leaves, like_leaves)
        ]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        return tree

    def restore_latest(
        self, like: PyTree, shardings: Optional[PyTree] = None
    ) -> Tuple[Optional[int], Optional[PyTree]]:
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings)
