from repro.checkpoint.manager import (
    CheckpointCorruptError,
    CheckpointManager,
    gc_orphan_tmpdirs,
    load_array_dir,
    publish_array_dir,
)

__all__ = [
    "CheckpointCorruptError",
    "CheckpointManager",
    "gc_orphan_tmpdirs",
    "load_array_dir",
    "publish_array_dir",
]
