"""Logical-axis partitioning rules (MaxText-style) -> PartitionSpecs.

Every param/activation dim carries a logical name; rules map names to mesh
axes.  `spec_for` walks a shape's logical axes in order, assigning mesh
axes when (a) the rule's axes exist in the mesh, (b) the dim is divisible
by their total size, and (c) no axis is used twice in one spec — so the
same rule table serves 1-device smoke tests, the 256-chip pod and the
512-chip multi-pod mesh, degrading gracefully (e.g. yi-34b's 56 heads are
not 16-divisible -> heads fall back to replicated; the roofline analysis
§Perf quantifies that cost and the hillclimb fixes it).

Parallelism profiles (see DESIGN.md §4):
  pod   : pure data parallel (cross-pod traffic = one grad all-reduce)
  data  : FSDP (embed-dim sharding of params/optimizer) + batch DP
  model : tensor parallel (heads / mlp / experts / vocab)
"""

from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# logical dim name -> mesh axes (applied together, in order)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),  # FSDP shard of params + optimizer
    "vocab": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "inner": ("model",),
    "lru": ("model",),
    "lru_in": (),
    "state": ("model",),
    "q_rank": (),
    "kv_rank": (),
    "clip": (),
    "codebook": (),
    "groups": (),
    "layers": (),
    "seq": ("model",),  # decode-cache seq dim: context parallel over model
    "head_dim": (),
    "conv_w": (),
    # activation-only logical dims
    "act_seq": (),  # set to ("data",) for sequence-parallel profiles
    "embed_act": (),  # activation feature dim stays replicated
    "cap": (),  # MoE expert-capacity dim
    # streaming-SNN serving dims (serving/snn_engine device-resident state)
    "slot": ("pod", "data"),  # engine micro-batch slot axis (like batch)
    "ring_steps": (),  # per-slot event-ring time axis: stays with its slot
    "event_cap": (),  # packed per-step event-list capacity: replicated
}


@dataclasses.dataclass(frozen=True)
class PartitionRules:
    table: Dict[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )

    def override(self, **kw) -> "PartitionRules":
        t = dict(self.table)
        for k, v in kw.items():
            t[k] = tuple(v) if v else ()
        return PartitionRules(t)


def spec_for(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[PartitionRules] = None,
) -> P:
    """Build a PartitionSpec for one array."""
    rules = rules or PartitionRules()
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    parts = []
    for dim, name in zip(shape, axes):
        assigned: Tuple[str, ...] = ()
        if name is not None:
            cand = tuple(
                ax
                for ax in rules.table.get(name, ())
                if ax in mesh_sizes and ax not in used
            )
            if cand:
                total = int(np.prod([mesh_sizes[ax] for ax in cand]))
                if dim % total == 0:
                    assigned = cand
                else:
                    # try progressively shorter prefixes (e.g. just "pod")
                    for k in range(len(cand) - 1, 0, -1):
                        total = int(np.prod([mesh_sizes[ax] for ax in cand[:k]]))
                        if dim % total == 0:
                            assigned = cand[:k]
                            break
        used.update(assigned)
        if len(assigned) == 0:
            parts.append(None)
        elif len(assigned) == 1:
            parts.append(assigned[0])
        else:
            parts.append(assigned)
    # trim trailing Nones (canonical form)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_specs(
    shapes: PyTree, axes: PyTree, mesh: Mesh,
    rules: Optional[PartitionRules] = None,
) -> PyTree:
    """Map spec_for over matching (shapes, logical-axes) pytrees."""

    def one(s, a):
        return spec_for(s.shape, a, mesh, rules)

    return jax.tree_util.tree_map(
        one, shapes, axes,
        is_leaf=lambda t: isinstance(t, tuple)
        and all(isinstance(x, (str, type(None))) for x in t),
    )


def tree_shardings(shapes, axes, mesh, rules=None) -> PyTree:
    specs = tree_specs(shapes, axes, mesh, rules)
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda t: isinstance(t, P),
    )


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# --------------------------------------------------------------- shard_map
# jax moved shard_map out of experimental and renamed check_rep->check_vma;
# wrap both spellings so sharded code runs on every container toolchain
# (shared by distributed/pipeline.py and serving/snn_engine.py).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = {"check_vma": False}
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = {"check_rep": False}


def shard_map_unchecked(fn, mesh: Mesh, *, in_specs, out_specs):
    """Version-portable ``shard_map`` with replication checking disabled."""
    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **_CHECK_KW
    )


def slot_axis(num_slots: int, mesh: Mesh,
              rules: Optional[PartitionRules] = None):
    """Mesh axes the serving engine's slot dimension shards over.

    Everything slot-indexed in the stream engine — neuron states, the
    per-slot event ring buffers ((S, ring_steps, event_cap), via the
    ``slot``/``ring_steps``/``event_cap`` rules), scheduling metadata and
    the per-chunk stats — shards along this one axis; a ``P(slot_axis)``
    pytree *prefix* therefore covers all of them.  Raises loudly when
    ``num_slots`` does not divide the mesh's slot axes: a silently
    replicated slot axis would run every slot on every device, which is
    exactly the misconfiguration sharded serving exists to avoid.
    """
    spec = spec_for((num_slots,), ("slot",), mesh, rules)
    if len(spec) == 0 or spec[0] is None:
        raise ValueError(
            f"num_slots={num_slots} is not shardable over mesh axes "
            f"{dict(zip(mesh.axis_names, mesh.devices.shape))}; pick a "
            f"slot count divisible by the mesh's batch axes"
        )
    return spec[0]


# ------------------------------------------------- activation constraints
# MaxText-style: model code calls `constrain(x, logical_axes)` at the key
# activation points (block inputs, attention heads, mlp hidden, MoE
# buffers, logits).  Outside an `activation_sharding` context (smoke
# tests, 1-device runs) it is a no-op; inside (dry-run / production
# launch) it pins the intermediate sharding so XLA's propagation cannot
# pick pathological layouts (measured: granite train_4k dropped from
# 831 GB temp / 12.3 s collective to per-device-sane values; see
# EXPERIMENTS.md §Perf notes).

_act_ctx = threading.local()


@contextmanager
def activation_sharding(mesh: Mesh, rules: Optional[PartitionRules] = None):
    prev = getattr(_act_ctx, "val", None)
    _act_ctx.val = (mesh, rules or PartitionRules())
    try:
        yield
    finally:
        _act_ctx.val = prev


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    ctx = getattr(_act_ctx, "val", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------- cache axes
_CACHE_LEAF_AXES: Dict[str, Tuple[str, ...]] = {
    "k": ("batch", "seq", "kv", "head_dim"),
    "v": ("batch", "seq", "kv", "head_dim"),
    "k_scale": ("batch", "seq", "kv"),
    "v_scale": ("batch", "seq", "kv"),
    "c_kv": ("batch", "seq", "kv_rank"),
    "k_rope": ("batch", "seq", "head_dim"),
    "state": ("batch", "heads", "head_dim", "state"),
    "conv_x": ("batch", "conv_w", "inner"),
    "conv_B": ("batch", "conv_w", "state"),
    "conv_C": ("batch", "conv_w", "state"),
    "h": ("batch", "lru"),
    "conv": ("batch", "conv_w", "lru"),
}


def cache_logical_axes(cache_shapes: PyTree) -> PyTree:
    """Derive logical axes for a decode-cache pytree from leaf names.

    Stacked layer dims (from scan groups) are detected by ndim mismatch
    and get a leading 'layers' axis.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in flat:
        name = None
        for k in reversed(path):
            if isinstance(k, jax.tree_util.DictKey):
                name = k.key
                break
        base = _CACHE_LEAF_AXES[name]
        extra = leaf.ndim - len(base)
        axes = ("layers",) * extra + base
        out.append(axes)
    return jax.tree_util.tree_unflatten(treedef, out)


# ----------------------------------------------------------- optimizer
def opt_state_specs(opt_state, param_specs, mesh) -> PyTree:
    """Optimizer states shard like their params (mu/nu mirror params);
    scalar counts are replicated."""

    def one(leaf):
        if hasattr(leaf, "ndim") and leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return None  # placeholder, replaced below via structure match

    # AdamState/SGDState are NamedTuples of (count?, tree, tree)
    import jax.tree_util as jtu

    def map_state(state):
        if isinstance(state, tuple) and hasattr(state, "_fields"):
            return type(state)(*[map_state(s) for s in state])
        # a pytree shaped like params
        treedef_p = jtu.tree_structure(param_specs)
        treedef_s = jtu.tree_structure(state)
        if treedef_p == treedef_s:
            return param_specs
        if hasattr(state, "ndim"):
            return NamedSharding(mesh, P())
        return jtu.tree_map(lambda _: NamedSharding(mesh, P()), state)

    return map_state(opt_state)
