"""Gradient compression with error feedback (cross-pod DP all-reduce).

At 1000+ nodes the cross-pod (DCN) gradient all-reduce dominates step
time for DP-heavy profiles.  This module provides int8 quantize ->
all-reduce -> dequantize with *error feedback* (Seide et al. 2014;
1-bit-Adam lineage): the quantization residual is carried into the next
step, so convergence matches uncompressed SGD/Adam to first order
(property-tested in tests/test_compression.py).

This is also the paper's Q-format idea applied at the *gradient* level:
gradients are Q1.7-coded per-tensor (symmetric max-scale int8), 4x fewer
bytes on the wire than f32.

Usage: wrap the optimizer —
    opt = compressed(adam(1e-3), axis="pod")     # inside shard_map
or use `compress/decompress` directly around a manual psum.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adam import Optimizer

PyTree = Any


class CompressedState(NamedTuple):
    inner: Any
    error: PyTree  # error-feedback residual, same structure as grads


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (codes, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_int8(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def compress_tree(grads: PyTree, error: PyTree) -> Tuple[PyTree, PyTree]:
    """Quantize (grads + carried error); returns (quantized_float, new_error).

    The returned tree is float32 (already dequantized) so it can feed any
    all-reduce; the wire format in a real deployment is (codes, scale).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        codes, scale = quantize_int8(g32)
        deq = dequantize_int8(codes, scale)
        return deq, g32 - deq

    flat = jax.tree_util.tree_map(one, grads, error)
    deq = jax.tree_util.tree_map(lambda t: t[0], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
    return deq, err


def compressed(opt: Optimizer, psum_axis: Optional[str] = None) -> Optimizer:
    """Error-feedback int8 compression in front of an optimizer.

    If `psum_axis` is given the compressed grads are jax.lax.pmean'd over
    that axis (for use inside shard_map over the pod axis); otherwise the
    caller is responsible for the reduction (jit + sharding path).
    """

    def init(params):
        err = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
        )
        return CompressedState(inner=opt.init(params), error=err)

    def update(grads, state: CompressedState, params=None):
        deq, err = compress_tree(grads, state.error)
        if psum_axis is not None:
            deq = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g, psum_axis), deq
            )
        updates, inner = opt.update(deq, state.inner, params)
        return updates, CompressedState(inner=inner, error=err)

    return Optimizer(init=init, update=update)
