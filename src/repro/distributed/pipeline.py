"""GPipe-style pipeline parallelism via shard_map + ppermute.

Opt-in substrate for depth-dominated models: the layer-stacked params of a
uniform group are split into S stages along the stacked dim; microbatches
stream through stages with `jax.lax.ppermute` boundary transfers inside
`shard_map` over a `pipe` mesh axis.

Schedule: standard GPipe fill-drain over M microbatches — bubble fraction
(S-1)/(M+S-1).  Each device runs `scan` over M+S-1 ticks; at tick t it
processes microbatch t - stage_idx (when valid).

This is deliberately the simple schedule: it is compile-time-fast
(one scan), correct for any stage-uniform block, and sufficient to prove
the distribution config end-to-end on placeholder devices.  1F1B /
circular schedules are noted as future work in DESIGN.md.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.partitioning import shard_map_unchecked

PyTree = Any


def pipeline_forward(
    fn: Callable[[PyTree, jax.Array], jax.Array],
    mesh: Mesh,
    axis: str = "pipe",
):
    """Build a pipelined forward for a stage function.

    fn(stage_params, x) -> x  applies ONE stage (a chunk of layers).
    Returns pipe_fn(stacked_stage_params, microbatches) -> outputs where
      stacked_stage_params : leaves (S, ...)   (S = mesh[axis])
      microbatches         : (M, mb, ...) input microbatches
    """
    S = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    @functools.partial(
        shard_map_unchecked,
        mesh=mesh,
        in_specs=(P(axis), P(None)),
        out_specs=P(None),
    )
    def pipe_fn(stage_params, microbatches):
        # stage_params leaves arrive as (1, ...) local slices
        local = jax.tree_util.tree_map(lambda t: t[0], stage_params)
        stage = jax.lax.axis_index(axis)
        M = microbatches.shape[0]
        T = M + S - 1
        mb_shape = microbatches.shape[1:]

        def tick(carry, t):
            buf, outs = carry  # buf: the activation entering this stage
            # stage 0 ingests microbatch t (if any)
            mb_idx = jnp.clip(t, 0, M - 1)
            fresh = jax.lax.dynamic_index_in_dim(
                microbatches, mb_idx, 0, keepdims=False
            )
            x_in = jnp.where(stage == 0, fresh, buf)
            active = (t - stage >= 0) & (t - stage < M)
            y = fn(local, x_in)
            y = jnp.where(active, y, buf)
            # last stage commits its output for microbatch t-(S-1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            commit = (stage == S - 1) & (t - (S - 1) >= 0)
            outs = jax.lax.cond(
                commit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0
                ),
                lambda o: o,
                outs,
            )
            # shift activations to the next stage
            y_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)]
            )
            return (y_next, outs), ()

        buf0 = jnp.zeros(mb_shape, microbatches.dtype)
        outs0 = jnp.zeros((M, *mb_shape), microbatches.dtype)
        (_, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(T)
        )
        # every device holds the last stage's outs copy only on stage S-1;
        # broadcast it: outs is nonzero only there -> psum picks it
        outs = jax.lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    return pipe_fn


def make_pipe_mesh(num_stages: int):
    """Small helper used by tests: 1-D pipe mesh over available devices."""
    devs = jax.devices()[:num_stages]
    import numpy as np

    return Mesh(np.array(devs), ("pipe",))
