from repro.distributed import compression, partitioning, pipeline

__all__ = ["compression", "partitioning", "pipeline"]
