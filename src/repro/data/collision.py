"""Synthetic collision-avoidance dataset (DroNet availability gate).

The paper trains on ~32k annotated images labelled collision / no-collision
(DroNet, Loquercio et al. 2018).  That dataset is not available offline, so
this module procedurally renders scenes whose label depends on obstacle
*proximity* — the actual visual cue a collision classifier learns:

  - collision (label 1): a large obstacle (rect/ellipse/triangle) occupying
    a large fraction of the frame near the center line (close object).
  - no-collision (label 0): empty road, or small/peripheral obstacles
    (distant objects), same textures.

Scenes include a brightness-graded ground plane, perspective "road" edges,
Gaussian noise, and random global illumination so the task is non-trivial;
preprocessing matches the paper: grayscale, HxW in {32,64,128}, values
normalized to [0,1].

This is a documented simulation gate (DESIGN.md §7): accuracy numbers are
analogs of paper Table 1, not identical values.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CollisionConfig:
    image_hw: int = 64
    num_train: int = 4096
    num_test: int = 1024
    seed: int = 0
    noise_std: float = 0.05


def _render_scene(rng: np.random.Generator, hw: int, label: int) -> np.ndarray:
    """Render one grayscale scene in [0,1]."""
    img = np.zeros((hw, hw), dtype=np.float32)

    # sky/ground gradient + illumination
    illum = rng.uniform(0.5, 1.0)
    horizon = int(hw * rng.uniform(0.35, 0.55))
    ys = np.arange(hw)[:, None]
    img += np.where(ys < horizon, 0.75, 0.35).astype(np.float32)
    img[horizon:] += np.linspace(0.0, 0.25, hw - horizon)[:, None]

    # perspective road edges (light lines converging at the horizon)
    vx = hw // 2 + rng.integers(-hw // 8, hw // 8)
    for sign in (-1, 1):
        x0 = hw // 2 + sign * int(hw * rng.uniform(0.3, 0.48))
        for y in range(horizon, hw):
            t = (y - horizon) / max(hw - horizon, 1)
            x = int(vx + (x0 - vx) * t)
            if 0 <= x < hw:
                img[y, max(x - 1, 0) : min(x + 1, hw)] += 0.15

    def draw_obstacle(cx, cy, size, dark):
        kind = rng.integers(0, 3)
        yy, xx = np.mgrid[0:hw, 0:hw]
        if kind == 0:  # rectangle
            m = (np.abs(xx - cx) < size) & (np.abs(yy - cy) < size * 1.3)
        elif kind == 1:  # ellipse
            m = ((xx - cx) / max(size, 1)) ** 2 + (
                (yy - cy) / max(size * 1.2, 1)
            ) ** 2 < 1.0
        else:  # triangle-ish wedge
            m = (np.abs(xx - cx) < (yy - (cy - size * 1.3)) * 0.6) & (
                yy > cy - size * 1.3
            ) & (yy < cy + size * 1.3)
        img[m] = dark

    if label == 1:
        # close obstacle: large, near-center, low on the frame
        size = int(hw * rng.uniform(0.18, 0.33))
        cx = hw // 2 + rng.integers(-hw // 6, hw // 6 + 1)
        cy = int(hw * rng.uniform(0.55, 0.8))
        draw_obstacle(cx, cy, size, dark=rng.uniform(0.02, 0.18))
    else:
        # 0-2 distant/peripheral obstacles: small or far to the side
        for _ in range(int(rng.integers(0, 3))):
            size = int(hw * rng.uniform(0.03, 0.08))
            side = rng.integers(0, 2)
            cx = (
                rng.integers(0, hw // 5)
                if side == 0
                else rng.integers(4 * hw // 5, hw)
            )
            cy = int(hw * rng.uniform(0.45, 0.7))
            draw_obstacle(cx, cy, size, dark=rng.uniform(0.05, 0.25))

    img *= illum
    img += rng.normal(0.0, 0.05, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def generate(cfg: CollisionConfig) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (train_x, train_y, test_x, test_y); x: (N,H,W) in [0,1]."""
    rng = np.random.default_rng(cfg.seed)
    n = cfg.num_train + cfg.num_test
    labels = rng.integers(0, 2, size=n).astype(np.int32)
    imgs = np.stack(
        [_render_scene(rng, cfg.image_hw, int(l)) for l in labels]
    ).astype(np.float32)
    tr, te = cfg.num_train, cfg.num_test
    return imgs[:tr], labels[:tr], imgs[tr : tr + te], labels[tr : tr + te]


def batches(
    x: np.ndarray,
    y: np.ndarray,
    batch_size: int,
    *,
    seed: int = 0,
    shuffle: bool = True,
) -> Iterator[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Epoch iterator yielding device arrays (flattened images)."""
    rng = np.random.default_rng(seed)
    idx = np.arange(len(x))
    if shuffle:
        rng.shuffle(idx)
    for s in range(0, len(x) - batch_size + 1, batch_size):
        sel = idx[s : s + batch_size]
        yield jnp.asarray(x[sel].reshape(len(sel), -1)), jnp.asarray(y[sel])
