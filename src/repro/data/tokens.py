"""Synthetic LM token pipeline (sharding-aware host feed).

Real corpora are not available offline; training/serving examples and
benchmarks use a deterministic synthetic stream with enough structure that
loss decreases (n-gram-ish Markov source), produced per-host so a
multi-host launch feeds disjoint shards (data-parallel contract).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    batch_size: int = 8
    seed: int = 0
    # data-parallel feed contract
    host_id: int = 0
    num_hosts: int = 1


def _markov_row(rng: np.random.Generator, vocab: int, k: int = 32) -> np.ndarray:
    """Sparse transition row: k successors with Zipf-ish mass."""
    succ = rng.integers(0, vocab, size=k)
    w = 1.0 / np.arange(1, k + 1)
    return succ, w / w.sum()


class MarkovTokenStream:
    """Deterministic pseudo-text: order-1 Markov chain over a hashed
    transition table (no O(vocab^2) storage)."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(
            cfg.seed * 1_000_003 + cfg.host_id
        )

    def _step(self, tok: np.ndarray) -> np.ndarray:
        # hash token -> per-token rng -> next token; vectorized
        h = (tok.astype(np.uint64) * np.uint64(2654435761)) % np.uint64(2**31)
        u = self._rng.random(tok.shape)
        # mix hashed successor with occasional random jump (temperature)
        succ = ((h + np.uint64(1)) * np.uint64(48271)) % np.uint64(
            self.cfg.vocab_size
        )
        jump = self._rng.integers(0, self.cfg.vocab_size, tok.shape)
        return np.where(u < 0.85, succ.astype(np.int64), jump).astype(np.int32)

    def batches(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        cfg = self.cfg
        tok = self._rng.integers(
            0, cfg.vocab_size, size=(cfg.batch_size,), dtype=np.int32
        )
        while True:
            seq = np.empty((cfg.batch_size, cfg.seq_len + 1), dtype=np.int32)
            seq[:, 0] = tok
            for t in range(1, cfg.seq_len + 1):
                seq[:, t] = self._step(seq[:, t - 1])
            tok = seq[:, -1]
            yield seq[:, :-1], seq[:, 1:]  # (inputs, targets)


def make_batch(
    vocab_size: int, batch: int, seq: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """One-shot batch for tests/benchmarks."""
    cfg = TokenStreamConfig(
        vocab_size=vocab_size, seq_len=seq, batch_size=batch, seed=seed
    )
    return next(MarkovTokenStream(cfg).batches())
