from repro.data import collision, tokens

__all__ = ["collision", "tokens"]
