"""Admission-plane load shedding for the SNN stream engine.

The paper's case study — collision avoidance — is deadline-driven: a
result that arrives after its deadline is worthless, and an engine that
*accepts* work it provably cannot finish on time spends capacity
manufacturing guaranteed misses.  This module is the admission plane's
decision logic, split into two pure, unit-testable checks the engine
calls at its two admission boundaries:

- :func:`backpressure` at ``submit()`` — a bounded admission queue.
  When the queue is at ``max_queue_depth`` the request is **shed**
  immediately (``priority > 0`` requests are **parked** instead, up to
  the same bound), so overload surfaces as an explicit ``SHED``
  disposition at the edge rather than as unbounded queue growth and a
  tail of deadline misses.

- :func:`feasibility` at admission-pop time — the EDF-aware shedder.
  When a queued request wins a free slot, its deadline is tested
  against a **provable lower bound** on its completion time derived
  from the measured trailing-window tick rate
  (``obs.timeseries.rate("engine.tick.dispatch_s.count")``): a slot
  advances at most ``Tc`` steps per tick, so a ``T``-step window takes
  at least ``T / (ticks_per_s * Tc)`` seconds from now.  If even that
  optimistic bound lands past the deadline, the request is shed (or
  parked for ``priority > 0``) — the engine refuses to convert a
  certain miss into wasted chunks.  With no measured rate (cold engine,
  empty window) the check **abstains and admits**: "provably
  unmeetable" requires evidence, and shedding on a guess would turn the
  admission plane itself into a fault.

Both checks return a :class:`Verdict` (``admit`` / ``shed`` / ``park``)
plus a reason string that flows into ``StreamResult.fault`` and the
``engine.requests.shed`` / ``engine.requests.parked`` counters, so the
SLO machinery can tell "breaching because overloaded and shedding
correctly" from "breaching because broken" (see
``SNNStreamEngine.health()``'s diagnosis block).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["AdmissionPolicy", "Verdict", "backpressure", "feasibility"]

ADMIT = "admit"
SHED = "shed"
PARK = "park"

Verdict = Tuple[str, Optional[str]]  # (ADMIT|SHED|PARK, reason)


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Knobs of the admission plane.

    ``max_queue_depth``
        Bounded admission queue; ``None`` keeps the historical
        unbounded queue (no backpressure shedding).
    ``shed_unmeetable``
        Enable the feasibility shedder at admission-pop time.
    ``rate_window_s``
        Trailing window the measured tick rate is read over; the check
        falls back to the whole-series rate when the window saw no
        flow (an engine idle for longer than the window).
    ``safety``
        Multiplier on the completion-time lower bound.  1.0 sheds only
        on the provable bound; > 1.0 sheds earlier (pessimistic), < 1.0
        is not meaningful and is clamped to 1.0.
    ``min_ticks_per_s``
        Minimum measured rate that counts as evidence; below it the
        feasibility check abstains (admits).
    """

    max_queue_depth: Optional[int] = None
    shed_unmeetable: bool = True
    rate_window_s: float = 2.0
    safety: float = 1.0
    min_ticks_per_s: float = 1e-3

    def __post_init__(self):
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1 or None, got "
                f"{self.max_queue_depth}"
            )
        if self.rate_window_s <= 0:
            raise ValueError("rate_window_s must be > 0")


def backpressure(
    policy: AdmissionPolicy,
    *,
    queue_depth: int,
    parked_depth: int,
    priority: int,
) -> Verdict:
    """Bounded-queue check at ``submit()``.

    Sheds once the queue is full; ``priority > 0`` requests park instead
    (best-effort service once the queue drains), but the parked list is
    bounded by the same depth so a priority flood cannot reopen the
    unbounded-queue failure mode.
    """
    if policy.max_queue_depth is None:
        return ADMIT, None
    if queue_depth < policy.max_queue_depth:
        return ADMIT, None
    if priority > 0 and parked_depth < policy.max_queue_depth:
        return PARK, "queue_full"
    return SHED, "queue_full"


def eta_lower_bound_s(
    *, steps: int, ticks_per_s: float, chunk_steps: int
) -> float:
    """Provable lower bound on serving ``steps`` from a standing start:
    a slot advances at most ``chunk_steps`` per tick, ticks arrive at
    the measured rate, so completion takes at least this many seconds.
    """
    ticks_needed = -(-int(steps) // int(chunk_steps))  # ceil division
    return ticks_needed / ticks_per_s


def feasibility(
    policy: AdmissionPolicy,
    *,
    steps: int,
    chunk_steps: int,
    deadline_abs: Optional[float],
    now: float,
    ticks_per_s: float,
    priority: int,
) -> Verdict:
    """EDF-aware shed check when a queued request wins a free slot.

    ``ticks_per_s`` is the measured trailing-window tick rate (the
    caller reads it off the engine's ``TimeSeriesSampler``); 0 or
    sub-threshold rates mean "no evidence" and the check admits.
    """
    if not policy.shed_unmeetable or deadline_abs is None:
        return ADMIT, None
    if ticks_per_s < policy.min_ticks_per_s:
        return ADMIT, None  # no measured evidence: cannot *prove* a miss
    eta = now + max(policy.safety, 1.0) * eta_lower_bound_s(
        steps=steps, ticks_per_s=ticks_per_s, chunk_steps=chunk_steps
    )
    if eta <= deadline_abs:
        return ADMIT, None
    if priority > 0:
        return PARK, "deadline_unmeetable"
    return SHED, "deadline_unmeetable"
