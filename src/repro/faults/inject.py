"""Deterministic fault injection for the SNN stream engine.

Chaos testing needs *reproducible* chaos: a seeded
:class:`FaultSchedule` is a plain list of :class:`Fault` records, and a
:class:`FaultInjector` applies them against a live ``SNNStreamEngine``
from inside its tick loop.  Six fault kinds cover the engine's real
failure surfaces:

``nan_membrane``
    Flips one membrane potential of a resident slot to NaN on the
    device — the canonical "poisoned state" fault.  The engine's
    in-graph fault checks must detect it in the next chunk, quarantine
    exactly that slot, and keep the other S-1 slots bit-identical to a
    fault-free run.
``corrupt_ring``
    Overwrites the slot's staged per-step event *count* at its current
    ``done`` offset with an impossible value (negative), modelling a
    corrupted AER table.  Detected by the chunk's in-window count-range
    check.
``chunk_exception``
    Arms the injector to raise :class:`InjectedChunkError` from the
    next ``times`` chunk dispatches (optionally only while the engine
    runs a given backend) — exercising the retry supervisor and, for
    persistent fused-only failures, the fused->jnp demotion path.
``stall``
    Freezes the tick loop for ``ticks`` ticks (no dispatch, no
    retirement) — the wedge ``drain(timeout_s=...)`` must survive.
``process_kill``
    Delivers SIGKILL to the *current process* at the scheduled tick —
    no atexit handlers, no flushes, exactly what a preempted node or an
    OOM-killer does.  Only meaningful inside a chaos subprocess (the
    kill-and-resume tests in ``tests/test_recovery.py``); the engine's
    snapshot/restore and the checkpoint manager's atomic-write
    discipline are what must survive it.
``corrupt_checkpoint``
    Flips bytes in the ``arrays.npz`` of the checkpoint/snapshot at
    ``path`` (the newest ``step_*``/``snap_*`` dir when ``path`` is a
    rotation directory), modelling disk corruption or a torn copy.  The
    manifest checksums must detect it and ``restore_latest`` /
    ``restore_latest_snapshot`` must fall back to the previous save.

Application is governed by *injectability*: state/ring faults need a
slot that is resident, mid-window, and past its admit tick (a freshly
admitted slot is zeroed in-graph, which would silently swallow the
fault).  A fault whose scheduled tick arrives with no injectable slot
is carried forward to the next tick that has one, so a seeded schedule
of N state/ring faults yields exactly N applications (and therefore N
quarantines) on any sufficiently long run — the invariant the chaos
acceptance test pins.  Every application is recorded in
``injector.applied`` (tick, kind, slot, rid) so tests and the bench's
``fault_tolerance`` block can join injections against the engine's
quarantine log and measure recovery ticks.
"""

from __future__ import annotations

import dataclasses
import os
import signal
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import neuron

__all__ = [
    "Fault",
    "FaultSchedule",
    "FaultInjector",
    "InjectedChunkError",
    "FAULT_KINDS",
    "corrupt_checkpoint",
]

FAULT_KINDS = (
    "nan_membrane",
    "corrupt_ring",
    "chunk_exception",
    "stall",
    "process_kill",
    "corrupt_checkpoint",
)


def corrupt_checkpoint(path: str, *, seed: int = 0, nbytes: int = 8) -> str:
    """Deterministically flip ``nbytes`` bytes in the ``arrays.npz`` of
    the checkpoint/snapshot at ``path``.

    ``path`` may be the array dir itself or a rotation directory
    containing ``step_*``/``snap_*`` subdirs (the newest is hit —
    exactly the one ``restore_latest`` would try first, forcing the
    fallback).  Returns the corrupted npz path.  The manifest is left
    intact: detection must come from the checksum verification, not
    from an unreadable manifest."""
    target = path
    if not os.path.exists(os.path.join(target, "arrays.npz")):
        subs = sorted(
            d for d in os.listdir(path)
            if d.startswith(("step_", "snap_"))
            and os.path.exists(os.path.join(path, d, "arrays.npz"))
        )
        if not subs:
            raise FileNotFoundError(
                f"no checkpoint arrays.npz under {path}"
            )
        target = os.path.join(path, subs[-1])
    npz = os.path.join(target, "arrays.npz")
    data = bytearray(open(npz, "rb").read())
    rng = np.random.default_rng(seed)
    # flip bytes in the back half: past the zip header/manifest region,
    # inside some array's payload, so the crc32 check is what trips
    lo = len(data) // 2
    for off in rng.integers(lo, len(data), size=int(nbytes)):
        data[int(off)] ^= 0xFF
    with open(npz, "wb") as f:
        f.write(bytes(data))
    return npz


class InjectedChunkError(RuntimeError):
    """Raised by the injector from inside chunk dispatch."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    ``tick`` is the earliest engine tick it may fire.  ``slot`` is a
    *preference* for state/ring faults (falls back to any injectable
    slot).  ``times`` is how many dispatches a ``chunk_exception``
    poisons; ``ticks`` how long a ``stall`` lasts; ``only_backend``
    restricts a ``chunk_exception`` to dispatches on that backend
    (``"fused"`` faults vanish after demotion — the failover scenario).
    ``path`` is the checkpoint/snapshot directory a
    ``corrupt_checkpoint`` fault flips bytes in.
    """

    tick: int
    kind: str
    slot: Optional[int] = None
    layer: int = 0
    times: int = 1
    ticks: int = 1
    only_backend: Optional[str] = None
    path: Optional[str] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}"
            )
        if self.tick < 0:
            raise ValueError("fault tick must be >= 0")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An immutable, seed-reproducible list of faults."""

    faults: Sequence[Fault] = ()
    seed: Optional[int] = None

    @staticmethod
    def generate(
        seed: int,
        n_faults: int,
        *,
        ticks: int,
        num_slots: int,
        kinds: Sequence[str] = ("nan_membrane", "corrupt_ring",
                                "chunk_exception"),
        num_layers: int = 1,
        max_exception_times: int = 1,
    ) -> "FaultSchedule":
        """Seeded uniform schedule: ``n_faults`` draws of (tick, kind,
        slot, layer) over a ``ticks``-tick horizon.  ``chunk_exception``
        draws stay transient (``times <= max_exception_times``, no
        backend restriction) so generated schedules never exhaust the
        retry budget — targeted tests construct persistent faults
        explicitly."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(int(n_faults)):
            kind = kinds[int(rng.integers(len(kinds)))]
            faults.append(Fault(
                tick=int(rng.integers(ticks)),
                kind=kind,
                slot=int(rng.integers(num_slots)),
                layer=int(rng.integers(num_layers)),
                times=int(rng.integers(1, max_exception_times + 1)),
                ticks=1,
            ))
        faults.sort(key=lambda f: f.tick)
        return FaultSchedule(faults=tuple(faults), seed=seed)

    def __len__(self) -> int:
        return len(self.faults)


class FaultInjector:
    """Applies a :class:`FaultSchedule` against a live engine.

    The engine calls :meth:`begin_tick` at the top of every tick (the
    injector mutates device state/rings for due faults and arms
    exceptions/stalls), :meth:`stalled` to honor stall windows, and
    :meth:`maybe_raise` from inside each supervised dispatch attempt.
    """

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self.reset()

    def reset(self) -> None:
        self._pending: List[Fault] = sorted(
            self.schedule.faults, key=lambda f: f.tick
        )
        self._armed: List[Dict] = []  # {"remaining", "only_backend"}
        self._stall_until = -1
        self.applied: List[Dict] = []
        self.raised = 0

    # ------------------------------------------------------------ hooks
    def _injectable(self, engine, s: int) -> bool:
        # resident, mid-window, and already past its first chunk: a slot
        # admitted this tick still has its device admit flag set, and
        # the chunk's fresh-slot zeroing would erase the injected fault
        # before detection could see it.
        return (
            engine._slot_req[s] is not None
            and 0 < engine._slot_done[s] < engine._slot_total[s]
        )

    def _pick_slot(self, engine, preferred: Optional[int]) -> Optional[int]:
        if preferred is not None and self._injectable(engine, preferred):
            return preferred
        for s in range(engine.S):
            if self._injectable(engine, s):
                return s
        return None

    def begin_tick(self, engine, tick: int) -> List[Dict]:
        """Apply every fault due at ``tick`` (or carried forward from an
        earlier tick with no injectable target); returns the records of
        faults applied *now* (state/ring mutations + armed
        exceptions/stalls)."""
        applied_now: List[Dict] = []
        still_pending: List[Fault] = []
        for f in self._pending:
            if f.tick > tick:
                still_pending.append(f)
                continue
            rec = {"tick": tick, "kind": f.kind, "slot": None, "rid": None}
            if f.kind == "chunk_exception":
                self._armed.append({
                    "remaining": int(f.times),
                    "only_backend": f.only_backend,
                })
            elif f.kind == "stall":
                self._stall_until = max(self._stall_until, tick + f.ticks)
            elif f.kind == "process_kill":
                # record first (moot for us — the process is gone — but
                # a shared applied-log file would see it), then die the
                # way a preempted node dies: no atexit, no flushes
                self.applied.append(rec)
                os.kill(os.getpid(), signal.SIGKILL)
            elif f.kind == "corrupt_checkpoint":
                if f.path is None:
                    raise ValueError(
                        "corrupt_checkpoint fault needs path="
                    )
                try:
                    rec["path"] = corrupt_checkpoint(f.path)
                except FileNotFoundError:
                    still_pending.append(f)  # no save yet: carry forward
                    continue
            else:
                s = self._pick_slot(engine, f.slot)
                if s is None:
                    still_pending.append(f)  # carry forward
                    continue
                rec["slot"] = s
                rec["rid"] = engine._slot_req[s]
                if f.kind == "nan_membrane":
                    self._apply_nan_membrane(engine, s, f.layer)
                else:
                    self._apply_corrupt_ring(engine, s)
            self.applied.append(rec)
            applied_now.append(rec)
        self._pending = still_pending
        return applied_now

    def stalled(self, tick: int) -> bool:
        return tick < self._stall_until

    def maybe_raise(self, backend: str) -> None:
        """Raise one armed :class:`InjectedChunkError`, if any matches
        the dispatching backend.  Called once per dispatch attempt —
        each call consumes at most one armed raise, so ``times=n``
        poisons n attempts."""
        for arm in self._armed:
            if arm["remaining"] <= 0:
                continue
            if arm["only_backend"] not in (None, backend):
                continue
            arm["remaining"] -= 1
            self.raised += 1
            raise InjectedChunkError(
                f"injected chunk fault (backend={backend!r}, "
                f"{arm['remaining']} raises left)"
            )

    # ----------------------------------------------------- applications
    @staticmethod
    def _apply_nan_membrane(engine, s: int, layer: int) -> None:
        layer = min(layer, len(engine._states) - 1)
        st = engine._states[layer]
        engine._states[layer] = neuron.NeuronState(
            u=st.u.at[s, 0].set(jnp.nan), refrac=st.refrac
        )

    @staticmethod
    def _apply_corrupt_ring(engine, s: int) -> None:
        # impossible per-step event count at the slot's next read
        # offset: the chunk window starting at ``done`` must see it
        off = int(engine._slot_done[s])
        ring = engine._ring
        engine._ring = {
            **ring,
            "counts": ring["counts"].at[s, off].set(-7),
        }
