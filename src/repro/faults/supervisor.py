"""Chunk-dispatch supervision: retry transient failures, demote a
broken fused backend to the jnp reference path.

The tick loop's chunk dispatch is the engine's single point of total
failure: an exception out of the jitted chunk call (a Mosaic lowering
bug, a flaky interpreter, an injected fault) previously unwound
``poll()`` and killed the episode with S requests resident.  The
supervisor wraps that call:

- **Transient failures** are retried with capped exponential backoff
  (``engine.faults.chunk_retries`` counts them).  Retries are safe
  because a chunk call that *raises* does so while tracing/lowering or
  enqueueing — before the donated ``states``/``meta`` buffers are
  consumed — so the attempt closure can simply be invoked again.
- **Persistent failures on the fused backend** demote the engine to the
  ``jnp`` reference chunk — permanently, with one loud
  ``RuntimeWarning`` and an ``engine.faults.backend_demoted`` count —
  so a kernel bug degrades throughput instead of availability.  The
  demoted chunk is rebuilt by the caller-supplied ``demote()`` callback
  (the engine re-jits with ``backend="jnp"``), then the dispatch is
  attempted once more on the fallback.
- **Persistent failures on the reference backend** have no fallback:
  :class:`ChunkDispatchError` propagates with the retry history
  attached, and ``drain(timeout_s=...)`` surfaces the stall snapshot.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, List, Optional

__all__ = ["RetryPolicy", "ChunkDispatchError", "ChunkSupervisor"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Backoff schedule for transient chunk-dispatch failures."""

    max_retries: int = 2
    backoff_s: float = 0.005
    backoff_cap_s: float = 0.1
    demote_fused: bool = True

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_s < 0 or self.backoff_cap_s < 0:
            raise ValueError("backoff must be >= 0")

    def delay_s(self, attempt: int) -> float:
        """Capped exponential backoff before retry ``attempt`` (1-based)."""
        return min(self.backoff_s * (2.0 ** (attempt - 1)),
                   self.backoff_cap_s)


class ChunkDispatchError(RuntimeError):
    """Chunk dispatch failed after exhausting retries and any fallback.

    ``errors`` holds every underlying exception in attempt order.
    """

    def __init__(self, message: str, errors: List[BaseException]):
        super().__init__(message)
        self.errors = list(errors)


class ChunkSupervisor:
    """Runs a chunk-dispatch attempt under the retry/demotion policy.

    ``on_retry``/``on_demote`` are metric hooks (called with the attempt
    count / once on demotion); ``demote`` swaps the engine's chunk to
    the jnp path and returns the *fallback* attempt callable, or
    ``None`` when no fallback exists (already on the reference path).
    ``sleep`` is injectable for tests.
    """

    def __init__(
        self,
        policy: Optional[RetryPolicy] = None,
        *,
        on_retry: Optional[Callable[[int], None]] = None,
        on_demote: Optional[Callable[[], None]] = None,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.policy = policy or RetryPolicy()
        self._on_retry = on_retry
        self._on_demote = on_demote
        self._sleep = sleep

    def call(
        self,
        attempt: Callable[[], object],
        *,
        backend: str,
        demote: Optional[Callable[[], Callable[[], object]]] = None,
    ) -> object:
        """Invoke ``attempt`` with retries; on exhaustion, demote fused
        dispatch via ``demote()`` and try the fallback once (plus its
        own retry budget).  Raises :class:`ChunkDispatchError` when no
        path succeeds."""
        errors: List[BaseException] = []
        for i in range(self.policy.max_retries + 1):
            try:
                return attempt()
            except Exception as exc:  # noqa: BLE001 — supervisor boundary
                errors.append(exc)
                if i < self.policy.max_retries:
                    if self._on_retry is not None:
                        self._on_retry(1)
                    self._sleep(self.policy.delay_s(i + 1))

        can_demote = (
            self.policy.demote_fused
            and backend == "fused"
            and demote is not None
        )
        if not can_demote:
            raise ChunkDispatchError(
                f"chunk dispatch failed after "
                f"{self.policy.max_retries + 1} attempts on "
                f"backend={backend!r}: {errors[-1]!r}",
                errors,
            )

        warnings.warn(
            "SNNStreamEngine: fused chunk dispatch failed "
            f"{len(errors)} times ({errors[-1]!r}); permanently "
            "demoting backend fused -> jnp for this engine",
            RuntimeWarning,
            stacklevel=2,
        )
        if self._on_demote is not None:
            self._on_demote()
        fallback = demote()
        for i in range(self.policy.max_retries + 1):
            try:
                return fallback()
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)
                if i < self.policy.max_retries:
                    if self._on_retry is not None:
                        self._on_retry(1)
                    self._sleep(self.policy.delay_s(i + 1))
        raise ChunkDispatchError(
            "chunk dispatch failed on fused and on the jnp fallback "
            f"({len(errors)} attempts): {errors[-1]!r}",
            errors,
        )
