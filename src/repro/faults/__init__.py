"""Fault tolerance for the SNN serving stack.

Three cooperating pieces, consumed by ``serving.snn_engine``:

- :mod:`repro.faults.shedding` — admission-plane load shedding
  (bounded-queue backpressure + EDF feasibility shedder).
- :mod:`repro.faults.supervisor` — chunk-dispatch retry with capped
  backoff and fused->jnp backend demotion.
- :mod:`repro.faults.inject` — deterministic seeded fault injection
  (NaN membranes, corrupted rings, dispatch exceptions, tick stalls)
  for the chaos test suite and ``benchmarks/stream_bench.py``'s
  ``fault_tolerance`` block.
"""

from repro.faults.inject import (  # noqa: F401
    FAULT_KINDS,
    Fault,
    FaultInjector,
    FaultSchedule,
    InjectedChunkError,
    corrupt_checkpoint,
)
from repro.faults.shedding import (  # noqa: F401
    AdmissionPolicy,
    backpressure,
    feasibility,
)
from repro.faults.supervisor import (  # noqa: F401
    ChunkDispatchError,
    ChunkSupervisor,
    RetryPolicy,
)

__all__ = [
    "AdmissionPolicy",
    "backpressure",
    "feasibility",
    "ChunkDispatchError",
    "ChunkSupervisor",
    "RetryPolicy",
    "FAULT_KINDS",
    "Fault",
    "FaultInjector",
    "FaultSchedule",
    "InjectedChunkError",
    "corrupt_checkpoint",
]
