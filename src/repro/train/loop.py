"""Training loop substrate: step builder, grad accumulation, metrics,
checkpoint/restart, straggler watchdog, and ``repro.obs`` wiring.

`make_train_step` builds the pure step function used by both the real
trainer and the multi-pod dry-run (launch/dryrun.py lowers exactly this
function for every arch x shape) — one source of truth for the compiled
graph.

Every ``Trainer`` carries the same observability kit as the serving
engine: a ``MetricsRegistry`` (``trainer.metrics`` — step-time / loss /
grad-norm histograms, step counters, latest-metrics gauges under
``train.metrics.*``), a ``TraceRecorder`` (``trainer.trace`` — one span
per sync window on the ``train`` track, straggler warnings as instant
events) and a ``TimeSeriesSampler`` (``trainer.timeseries`` — one point
per log window, so windowed steps/s and loss trajectories export as
JSONL).  Recording happens only at ``log_every`` sync boundaries — the
cadence at which the loop already blocks on the device — so the
instrumentation adds no extra host/device synchronization.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.models.model import Model
from repro.obs import MetricsRegistry, TimeSeriesSampler, TraceRecorder
from repro.optim.adam import Optimizer, apply_updates

PyTree = Any


class TrainState:  # simple pytree container
    def __init__(self, params, opt_state, step):
        self.params = params
        self.opt_state = opt_state
        self.step = step

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten
)


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    accum_steps: int = 1,
) -> Callable:
    """(state, batch) -> (state, metrics).  With accum_steps > 1 the batch
    leading dim must be (accum_steps * microbatch) and gradients are
    accumulated over a lax.scan of microbatches (memory/footprint knob)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params, opt_state = state.params, state.opt_state
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), ()

            mbs = jax.tree_util.tree_map(
                lambda t: t.reshape(accum_steps, -1, *t.shape[1:]), batch
            )
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / accum_steps, gsum
            )
            loss = lsum / accum_steps
            metrics = {"loss": loss}

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)
            )
        )
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


@dataclasses.dataclass
class StragglerWatchdog:
    """Wall-time monitor over whatever cadence the caller feeds it.
    An observation exceeding `factor` x the running median marks this
    host a straggler candidate: we log it and (configurably) trigger a
    checkpoint so the controller can evict/replace the slow node.

    ``Trainer.run`` feeds it the *mean step time of each sync window*
    (it only blocks on the device at ``log_every`` boundaries), so a
    single slow step inside an otherwise-normal window is diluted by
    the window length and a persistent slowdown is what trips it —
    shrink ``log_every`` (or ``factor``) when single-step spikes must
    be caught; `warmup` counts observations, i.e. windows there.
    Logic is host-side and runs as-is in this container."""

    factor: float = 3.0
    warmup: int = 5
    _times: list = dataclasses.field(default_factory=list)

    def observe(self, dt: float) -> Optional[str]:
        self._times.append(dt)
        if len(self._times) <= self.warmup:
            return None
        hist = sorted(self._times[:-1])
        median = hist[len(hist) // 2]
        if dt > self.factor * median:
            return (
                f"straggler: step took {dt:.3f}s vs median {median:.3f}s "
                f"(x{dt / median:.1f})"
            )
        return None


class Trainer:
    """Checkpoint/restart-capable loop driving the pure step function."""

    def __init__(
        self,
        model: Model,
        optimizer: Optimizer,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        keep_n: int = 3,
        accum_steps: int = 1,
        jit: bool = True,
        donate: bool = True,
    ):
        self.model = model
        self.optimizer = optimizer
        self.step_fn = make_train_step(model, optimizer, accum_steps)
        if jit:
            self.step_fn = jax.jit(
                self.step_fn, donate_argnums=(0,) if donate else ()
            )
        self.ckpt = (
            CheckpointManager(ckpt_dir, keep_n=keep_n, async_save=True)
            if ckpt_dir
            else None
        )
        self.ckpt_every = ckpt_every
        self.watchdog = StragglerWatchdog()
        # the trainer-level PRNG key rides in the checkpoint payload so
        # a restored run resumes the exact random state; init_state /
        # restore_or_init overwrite this placeholder
        self.rng = jax.random.PRNGKey(0)
        self._make_instruments()

    # ----------------------------------------------------- observability
    def _make_instruments(self) -> None:
        """The trainer's ``repro.obs`` instruments, mirroring the serving
        engine's: a metrics registry (step-time/loss/grad-norm
        histograms + step counters under ``train.``), a span recorder
        (one span per sync window on the ``train`` track, straggler
        warnings as instants), and a time-series sampler capturing one
        point per log window — the cadence at which the async-dispatch
        loop actually materializes device values, so observability never
        adds a device sync of its own."""
        self.metrics = MetricsRegistry()
        self.trace = TraceRecorder(capacity=4096)
        m = self.metrics
        self._m_steps = m.counter("train.steps")
        self._m_windows = m.counter("train.windows")
        self._m_stragglers = m.counter("train.straggler_warnings")
        self._m_step_time = m.histogram(
            "train.step_time_s", lo=1e-5, hi=1e4
        )
        self._m_loss = m.histogram("train.loss", lo=1e-6, hi=1e6)
        self._m_grad = m.histogram("train.grad_norm", lo=1e-9, hi=1e9)
        self.timeseries = TimeSeriesSampler(m, capacity=4096)

    def _record_window_metrics(
        self, metrics: Dict[str, float], window_steps: int, dt: float
    ) -> None:
        """Fold one sync window's observations into the registry.

        ``metrics`` is the last step's metric dict (host floats); ``dt``
        the window's mean per-step wall time.  Gauges under
        ``train.metrics.*`` always carry the latest observation — the
        exported snapshot's gauges therefore match ``run()``'s returned
        metrics exactly.  A NaN loss (divergence) lands in the
        histogram's ``invalid`` tally instead of poisoning its sum.
        Subclasses extend this to add workload-specific instruments
        (``EventTrainer`` adds per-layer spike/energy counters)."""
        self._m_steps.inc(window_steps)
        self._m_windows.inc()
        self._m_step_time.record(dt)
        if "loss" in metrics:
            self._m_loss.record(metrics["loss"])
        if "grad_norm" in metrics:
            self._m_grad.record(metrics["grad_norm"])
        for k, v in metrics.items():
            self.metrics.gauge(f"train.metrics.{k}").set(v)

    def export_obs(
        self,
        metrics_json=None,
        trace_out=None,
        timeseries_out=None,
        log_fn=print,
    ) -> None:
        """Write whichever observability sidecars were requested: the
        registry snapshot (deterministic JSON), the Chrome trace, and
        the per-window time series (JSONL)."""
        if metrics_json:
            self.metrics.write_json(metrics_json)
            log_fn(f"train metrics snapshot -> {metrics_json}")
        if trace_out:
            self.trace.write(trace_out)
            log_fn(
                f"train trace ({len(self.trace)} spans) -> {trace_out} "
                f"(load in ui.perfetto.dev)"
            )
        if timeseries_out:
            self.timeseries.write_jsonl(timeseries_out)
            log_fn(
                f"train time series ({len(self.timeseries)} samples) -> "
                f"{timeseries_out}"
            )

    def init_state(self, key) -> TrainState:
        self.rng = key
        params, _ = self.model.init(key)
        return TrainState(
            params, self.optimizer.init(params), jnp.zeros((), jnp.int32)
        )

    # ------------------------------------------------- checkpoint payload
    def _checkpoint_metric_names(self):
        """Lifetime counters persisted in the checkpoint payload so a
        restored run continues its accounting instead of restarting
        from zero (subclasses extend — ``EventTrainer`` adds the
        energy-regularizer telemetry)."""
        return ["train.steps", "train.windows", "train.straggler_warnings"]

    def _ckpt_tree(self, state: TrainState) -> Dict:
        """The full-state checkpoint payload: model params + optimizer
        state + step (``state``), the trainer PRNG key, and the
        persisted lifetime counters.  One pytree, so the checkpoint
        manager's atomic write + checksum verification covers the whole
        resume state."""
        return {
            "state": state,
            "rng": self.rng,
            "metrics": {
                name: np.float64(self.metrics.counter(name).value)
                for name in self._checkpoint_metric_names()
            },
        }

    def restore_or_init(self, key) -> TrainState:
        """Resume from the newest intact checkpoint (corrupt ones fall
        back to the previous keep-N save — see
        ``CheckpointManager.restore_latest``), restoring params, opt
        state, step, PRNG key, and lifetime counters; init fresh from
        ``key`` when no usable checkpoint exists."""
        state = self.init_state(key)
        if self.ckpt is not None:
            _, restored = self.ckpt.restore_latest(self._ckpt_tree(state))
            if restored is not None:
                self.rng = restored["rng"]
                for name, v in restored["metrics"].items():
                    c = self.metrics.counter(name)
                    c.inc(float(v) - c.value)
                return restored["state"]
        return state

    def run(
        self,
        state: TrainState,
        batches: Iterator[Dict[str, jax.Array]],
        num_steps: int,
        log_every: int = 10,
        log_fn=print,
    ) -> Tuple[TrainState, Dict[str, float]]:
        """Drive ``num_steps`` async-dispatched training steps.

        The host only synchronizes with the device at ``log_every``
        boundaries (and once at the end): a per-step
        ``block_until_ready`` — or even an implicit ``int(state.step)``
        — serializes host and device, so between syncs the loop just
        enqueues step N+1 while step N executes and the dispatch pipeline
        stays full.  The straggler watchdog accordingly observes the
        *mean* step time of each sync window (same warmup/factor
        semantics; a stuck device still trips it at the next boundary).
        """
        last_metrics: Dict[str, float] = {}
        # host-side step counter: int(state.step) forces a device sync,
        # so derive log/checkpoint boundaries without touching the device
        step0 = int(state.step)
        t_window = time.perf_counter()
        window_steps = 0
        for i in range(num_steps):
            batch = next(batches)
            state, metrics = self.step_fn(state, batch)
            window_steps += 1
            step_no = step0 + i + 1
            sync = i % log_every == 0 or i == num_steps - 1
            if sync:
                jax.block_until_ready(metrics["loss"])
                t_now = time.perf_counter()
                dt = (t_now - t_window) / window_steps
                warn = self.watchdog.observe(dt)
                if warn:
                    log_fn(f"[watchdog] {warn}")
                    self._m_stragglers.inc()
                    self.trace.instant(
                        "straggler", t_now, track="train",
                        args={"step": step_no, "mean_step_s": dt},
                    )
                last_metrics = {
                    k: float(v) for k, v in metrics.items()
                }
                # one span + registry fold + time-series point per sync
                # window: the loop's own cadence, no extra device syncs
                self._record_window_metrics(
                    last_metrics, window_steps, dt
                )
                self.trace.span(
                    "window", t_window, t_now, track="train",
                    args={
                        "step": step_no,
                        "steps": window_steps,
                        "ms_per_step": dt * 1e3,
                        "loss": last_metrics.get("loss"),
                    },
                )
                self.timeseries.sample(t_now)
                t_window = time.perf_counter()
                window_steps = 0
                log_fn(
                    f"step {step_no}: "
                    + " ".join(f"{k}={v:.4f}" for k, v in last_metrics.items())
                    + f" ({dt*1e3:.0f} ms/step)"
                )
            if self.ckpt is not None and step_no % self.ckpt_every == 0:
                self.ckpt.save(step_no, self._ckpt_tree(state))
        if self.ckpt is not None:
            self.ckpt.save(step0 + num_steps, self._ckpt_tree(state))
            self.ckpt.close()  # join the async writer before returning
        return state, last_metrics
