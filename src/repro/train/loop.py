"""Training loop substrate: step builder, grad accumulation, metrics,
checkpoint/restart, straggler watchdog.

`make_train_step` builds the pure step function used by both the real
trainer and the multi-pod dry-run (launch/dryrun.py lowers exactly this
function for every arch x shape) — one source of truth for the compiled
graph.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.models.model import Model
from repro.optim.adam import Optimizer, apply_updates

PyTree = Any


class TrainState:  # simple pytree container
    def __init__(self, params, opt_state, step):
        self.params = params
        self.opt_state = opt_state
        self.step = step

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten
)


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    accum_steps: int = 1,
) -> Callable:
    """(state, batch) -> (state, metrics).  With accum_steps > 1 the batch
    leading dim must be (accum_steps * microbatch) and gradients are
    accumulated over a lax.scan of microbatches (memory/footprint knob)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params, opt_state = state.params, state.opt_state
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), ()

            mbs = jax.tree_util.tree_map(
                lambda t: t.reshape(accum_steps, -1, *t.shape[1:]), batch
            )
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / accum_steps, gsum
            )
            loss = lsum / accum_steps
            metrics = {"loss": loss}

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)
            )
        )
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


@dataclasses.dataclass
class StragglerWatchdog:
    """Wall-time monitor over whatever cadence the caller feeds it.
    An observation exceeding `factor` x the running median marks this
    host a straggler candidate: we log it and (configurably) trigger a
    checkpoint so the controller can evict/replace the slow node.

    ``Trainer.run`` feeds it the *mean step time of each sync window*
    (it only blocks on the device at ``log_every`` boundaries), so a
    single slow step inside an otherwise-normal window is diluted by
    the window length and a persistent slowdown is what trips it —
    shrink ``log_every`` (or ``factor``) when single-step spikes must
    be caught; `warmup` counts observations, i.e. windows there.
    Logic is host-side and runs as-is in this container."""

    factor: float = 3.0
    warmup: int = 5
    _times: list = dataclasses.field(default_factory=list)

    def observe(self, dt: float) -> Optional[str]:
        self._times.append(dt)
        if len(self._times) <= self.warmup:
            return None
        hist = sorted(self._times[:-1])
        median = hist[len(hist) // 2]
        if dt > self.factor * median:
            return (
                f"straggler: step took {dt:.3f}s vs median {median:.3f}s "
                f"(x{dt / median:.1f})"
            )
        return None


class Trainer:
    """Checkpoint/restart-capable loop driving the pure step function."""

    def __init__(
        self,
        model: Model,
        optimizer: Optimizer,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        keep_n: int = 3,
        accum_steps: int = 1,
        jit: bool = True,
        donate: bool = True,
    ):
        self.model = model
        self.optimizer = optimizer
        self.step_fn = make_train_step(model, optimizer, accum_steps)
        if jit:
            self.step_fn = jax.jit(
                self.step_fn, donate_argnums=(0,) if donate else ()
            )
        self.ckpt = (
            CheckpointManager(ckpt_dir, keep_n=keep_n, async_save=True)
            if ckpt_dir
            else None
        )
        self.ckpt_every = ckpt_every
        self.watchdog = StragglerWatchdog()

    def init_state(self, key) -> TrainState:
        params, _ = self.model.init(key)
        return TrainState(
            params, self.optimizer.init(params), jnp.zeros((), jnp.int32)
        )

    def restore_or_init(self, key) -> TrainState:
        state = self.init_state(key)
        if self.ckpt is not None:
            step, restored = self.ckpt.restore_latest(state)
            if restored is not None:
                return restored
        return state

    def run(
        self,
        state: TrainState,
        batches: Iterator[Dict[str, jax.Array]],
        num_steps: int,
        log_every: int = 10,
        log_fn=print,
    ) -> Tuple[TrainState, Dict[str, float]]:
        """Drive ``num_steps`` async-dispatched training steps.

        The host only synchronizes with the device at ``log_every``
        boundaries (and once at the end): a per-step
        ``block_until_ready`` — or even an implicit ``int(state.step)``
        — serializes host and device, so between syncs the loop just
        enqueues step N+1 while step N executes and the dispatch pipeline
        stays full.  The straggler watchdog accordingly observes the
        *mean* step time of each sync window (same warmup/factor
        semantics; a stuck device still trips it at the next boundary).
        """
        last_metrics: Dict[str, float] = {}
        # host-side step counter: int(state.step) forces a device sync,
        # so derive log/checkpoint boundaries without touching the device
        step0 = int(state.step)
        t_window = time.perf_counter()
        window_steps = 0
        for i in range(num_steps):
            batch = next(batches)
            state, metrics = self.step_fn(state, batch)
            window_steps += 1
            step_no = step0 + i + 1
            sync = i % log_every == 0 or i == num_steps - 1
            if sync:
                jax.block_until_ready(metrics["loss"])
                dt = (time.perf_counter() - t_window) / window_steps
                t_window = time.perf_counter()
                window_steps = 0
                warn = self.watchdog.observe(dt)
                if warn:
                    log_fn(f"[watchdog] {warn}")
                last_metrics = {
                    k: float(v) for k, v in metrics.items()
                }
                log_fn(
                    f"step {step_no}: "
                    + " ".join(f"{k}={v:.4f}" for k, v in last_metrics.items())
                    + f" ({dt*1e3:.0f} ms/step)"
                )
            if self.ckpt is not None and step_no % self.ckpt_every == 0:
                self.ckpt.save(step_no, state)
        if self.ckpt is not None:
            self.ckpt.save(step0 + num_steps, state)
            self.ckpt.wait()
        return state, last_metrics
