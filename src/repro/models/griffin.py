"""RG-LRU recurrent block (Griffin / RecurrentGemma, De et al. 2024).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)                (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                (input gate)
    log a_t = -c * softplus(Lambda) * r_t       (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is linear in h given the gates, so prefill/training uses
`jax.lax.associative_scan` (log-depth parallel scan over L — maps well to
TPU, unlike a sequential scan); decode is the O(1) step.

The full recurrent *block* (as in RecurrentGemma): two input branches
(linear y-gate with GELU, linear x into conv1d(4) into RG-LRU),
elementwise merge, linear out.  Like the LIF membrane, h never leaves
fast memory during decode — see DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import constrain
from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array


def rglru_block_init(key, cfg: ModelConfig, dtype=jnp.float32):
    E = cfg.d_model
    R = cfg.lru_width or E
    W = 4  # temporal conv width (recurrentgemma)
    ks = jax.random.split(key, 7)
    p, a = {}, {}
    p["w_y"], a["w_y"] = layers.dense_init(ks[0], (E, R), ("embed", "lru"), dtype)
    p["w_in"], a["w_in"] = layers.dense_init(ks[1], (E, R), ("embed", "lru"), dtype)
    p["conv_w"] = jax.random.normal(ks[2], (W, R)).astype(dtype) * 0.1
    a["conv_w"] = ("conv_w", "lru")
    p["w_a"], a["w_a"] = layers.dense_init(ks[3], (R, R), ("lru", "lru_in"), dtype)
    p["b_a"], a["b_a"] = jnp.zeros((R,), dtype), ("lru",)
    p["w_gx"], a["w_gx"] = layers.dense_init(ks[4], (R, R), ("lru", "lru_in"), dtype)
    p["b_gx"], a["b_gx"] = jnp.zeros((R,), dtype), ("lru",)
    # Lambda init so that a^c in [0.9, 0.999] at r=1 (paper init)
    u = jax.random.uniform(ks[5], (R,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / cfg.rglru_c))
    p["lambda_raw"], a["lambda_raw"] = lam.astype(dtype), ("lru",)
    p["w_out"], a["w_out"] = layers.dense_init(
        ks[6], (R, E), ("lru", "embed"), dtype
    )
    return p, a


def _rglru_gates(p, x: Array, cfg: ModelConfig):
    """x: (..., R) conv output -> (log_a, beta_x) with
    beta_x = sqrt(1 - a^2) * i_t * x."""
    r = jax.nn.sigmoid(x @ p["w_a"].astype(x.dtype) + p["b_a"].astype(x.dtype))
    i = jax.nn.sigmoid(x @ p["w_gx"].astype(x.dtype) + p["b_gx"].astype(x.dtype))
    log_a = (
        -cfg.rglru_c
        * jax.nn.softplus(p["lambda_raw"].astype(jnp.float32))
        * r.astype(jnp.float32)
    )
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - a2, 1e-9, 1.0))
    bx = beta * (i.astype(jnp.float32) * x.astype(jnp.float32))
    return log_a, bx


def rglru_scan(log_a: Array, bx: Array, h0: Array = None) -> Array:
    """Associative scan of h_t = a_t h_{t-1} + bx_t over axis 1.

    log_a, bx: (B, L, R) float32.  Returns h (B, L, R).
    """
    if h0 is not None:
        # fold h0 into the first step: h_1 = a_1 h0 + bx_1
        bx = bx.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(c1, c2):
        la1, b1 = c1
        la2, b2 = c2
        return la1 + la2, b1 * jnp.exp(la2) + b2

    la, b = jax.lax.associative_scan(combine, (log_a, bx), axis=1)
    return b


def rglru_block_forward(
    p, x: Array, cfg: ModelConfig, h0=None, conv0=None,
    return_state: bool = False,
):
    """Full recurrent block.  x: (B, L, E) -> (B, L, E)."""
    y = jax.nn.gelu(x @ p["w_y"].astype(x.dtype))
    y = constrain(y, ("batch", "act_seq", "lru"))
    u = x @ p["w_in"].astype(x.dtype)  # (B, L, R)
    u = constrain(u, ("batch", "act_seq", "lru"))
    W = p["conv_w"].shape[0]
    if conv0 is None:
        up = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([conv0.astype(u.dtype), u], axis=1)
    uc = sum(
        up[:, i : i + u.shape[1], :] * p["conv_w"].astype(x.dtype)[i][None, None]
        for i in range(W)
    )
    log_a, bx = _rglru_gates(p, uc, cfg)
    h = rglru_scan(log_a, bx, h0)  # (B, L, R) float32
    out = (h.astype(x.dtype) * y) @ p["w_out"].astype(x.dtype)
    if return_state:
        return out, {"h": h[:, -1], "conv": up[:, -(W - 1):, :]}
    return out


def rglru_cache_init(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Array]:
    R = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, R), jnp.float32),
        "conv": jnp.zeros((batch, 3, R), dtype),
    }


def rglru_block_decode(
    p, x: Array, cache: Dict[str, Array], cfg: ModelConfig
) -> Tuple[Array, Dict[str, Array]]:
    """One-token step.  x: (B, 1, E)."""
    xt = x[:, 0]
    y = jax.nn.gelu(xt @ p["w_y"].astype(x.dtype))
    u = xt @ p["w_in"].astype(x.dtype)  # (B, R)
    window = jnp.concatenate(
        [cache["conv"].astype(u.dtype), u[:, None]], axis=1
    )  # (B, W, R)
    uc = jnp.einsum("bwr,wr->br", window, p["conv_w"].astype(x.dtype))
    log_a, bx = _rglru_gates(p, uc, cfg)
    h = jnp.exp(log_a) * cache["h"] + bx
    out = ((h.astype(x.dtype) * y) @ p["w_out"].astype(x.dtype))[:, None]
    return out, {"h": h, "conv": window[:, 1:]}
