"""Block assembly: unified layer plan + scan-over-layers execution.

Every architecture reduces to a *layer plan*: a repeating pattern of
blocks, scanned over the repeat dimension (weight-stacked params — keeps
HLO size and compile time O(1) in depth, MaxText-style), plus an optional
non-divisible tail group.

  dense/vlm/audio : pattern [(gqa|mla, mlp)]            x num_layers
  moe             : pattern [(gqa, moe)]                x num_layers
  ssm             : pattern [(ssm, None)]               x num_layers
  hybrid(griffin) : pattern [(rg,mlp),(rg,mlp),(gqa,mlp)] x repeats + tail

Blocks are pre-norm residual:  x += mixer(norm(x)); x += ffn(norm(x)).
Remat policy (`cfg.remat`) wraps the scan body.
"""

from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import constrain
from repro.models import attention, griffin, layers, moe, ssm
from repro.models.config import ModelConfig

Array = jax.Array
PyTree = Any

MIXERS = ("gqa", "mla", "ssm", "rg")


def dequant_block_params(p: PyTree) -> PyTree:
    """Per-layer on-the-fly dequant of int-stored weights (serving quant
    modes).  Runs INSIDE the layer scan body so only one layer's float
    weights are ever live — whole-tree upfront dequant doubles peak HBM
    (measured: yi-34b decode 24 GiB -> fits after this change)."""

    def deq(x):
        if x.dtype == jnp.int16:
            return x.astype(jnp.bfloat16) * jnp.bfloat16(2**-15)
        if x.dtype == jnp.int8:
            return x.astype(jnp.bfloat16) * jnp.bfloat16(2**-7)
        return x

    return jax.tree_util.tree_map(deq, p)


# ================================================================ plan
def layer_plan(cfg: ModelConfig) -> List[Tuple[str, List[Tuple[str, Optional[str]]], int]]:
    """Returns [(group_name, pattern, repeats)]; sum(len(pattern)*repeats)
    == num_layers."""
    if cfg.family == "ssm":
        pattern = [("ssm", None)]
    elif cfg.family == "hybrid":
        pattern = [
            ("rg", "mlp") if k == "rg" else ("gqa", "mlp")
            for k in (cfg.block_pattern or ("rg", "rg", "attn"))
        ]
    else:
        mixer = "mla" if cfg.mla else "gqa"
        ffn = "moe" if cfg.num_experts else "mlp"
        pattern = [(mixer, ffn)]
    n = len(pattern)
    repeats, rem = divmod(cfg.num_layers, n)
    plan = []
    if repeats:
        plan.append(("main", pattern, repeats))
    if rem:
        plan.append(("tail", pattern[:rem], 1))
    return plan


# ================================================================ blocks
def _mixer_init(key, cfg: ModelConfig, kind: str, dtype):
    if kind == "gqa":
        return attention.gqa_init(key, cfg, dtype)
    if kind == "mla":
        return attention.mla_init(key, cfg, dtype)
    if kind == "ssm":
        return ssm.ssm_init(key, cfg, dtype)
    if kind == "rg":
        return griffin.rglru_block_init(key, cfg, dtype)
    raise ValueError(kind)


def block_init(key, cfg: ModelConfig, spec: Tuple[str, Optional[str]], dtype):
    mixer_kind, ffn_kind = spec
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["norm1"], a["norm1"] = layers.norm_init(cfg.d_model, cfg.norm_kind, dtype)
    p["mixer"], a["mixer"] = _mixer_init(k1, cfg, mixer_kind, dtype)
    if ffn_kind is not None:
        p["norm2"], a["norm2"] = layers.norm_init(
            cfg.d_model, cfg.norm_kind, dtype
        )
        if ffn_kind == "moe":
            p["ffn"], a["ffn"] = moe.moe_init(k2, cfg, dtype)
        else:
            p["ffn"], a["ffn"] = layers.mlp_init(
                k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype
            )
    return p, a


def _apply_ffn(p, h, cfg: ModelConfig, ffn_kind):
    if ffn_kind == "moe":
        out, aux = moe.moe_forward(p["ffn"], h, cfg)
        return out, aux
    return layers.apply_mlp(p["ffn"], h, cfg.mlp_kind), {}


def block_forward(p, x, positions, cfg: ModelConfig, spec):
    """Training / no-cache forward.  Returns (x, aux)."""
    mixer_kind, ffn_kind = spec
    p = dequant_block_params(p)
    x = constrain(x, ("batch", "act_seq", "embed_act"))
    h = layers.apply_norm(p["norm1"], x, cfg.norm_kind, cfg.norm_eps)
    if mixer_kind == "gqa":
        mx = attention.gqa_forward(p["mixer"], h, positions, cfg)
    elif mixer_kind == "mla":
        mx = attention.mla_forward(p["mixer"], h, positions, cfg)
    elif mixer_kind == "ssm":
        mx = ssm.ssm_forward(p["mixer"], h, cfg)
    elif mixer_kind == "rg":
        mx = griffin.rglru_block_forward(p["mixer"], h, cfg)
    else:
        raise ValueError(mixer_kind)
    x = x + mx
    aux = {}
    if ffn_kind is not None:
        h = layers.apply_norm(p["norm2"], x, cfg.norm_kind, cfg.norm_eps)
        out, aux = _apply_ffn(p, h, cfg, ffn_kind)
        x = x + out
    return x, aux


def block_prefill(p, x, positions, cfg: ModelConfig, spec, cache_len):
    """Forward + populate this block's decode cache."""
    mixer_kind, ffn_kind = spec
    p = dequant_block_params(p)
    x = constrain(x, ("batch", "act_seq", "embed_act"))
    h = layers.apply_norm(p["norm1"], x, cfg.norm_kind, cfg.norm_eps)
    if mixer_kind == "gqa":
        mx, cache = attention.gqa_prefill(p["mixer"], h, positions, cfg, cache_len)
    elif mixer_kind == "mla":
        mx, cache = attention.mla_prefill(p["mixer"], h, positions, cfg, cache_len)
    elif mixer_kind == "ssm":
        mx, state = ssm.ssm_forward(p["mixer"], h, cfg, return_state=True)
        W = cfg.ssm_conv_width
        # conv caches hold the last W-1 *pre-activation* stream values
        cache = _ssm_prefill_cache(p["mixer"], h, state, cfg)
        del W
    elif mixer_kind == "rg":
        mx, st = griffin.rglru_block_forward(
            p["mixer"], h, cfg, return_state=True
        )
        cache = st
    else:
        raise ValueError(mixer_kind)
    x = x + mx
    if ffn_kind is not None:
        hn = layers.apply_norm(p["norm2"], x, cfg.norm_kind, cfg.norm_eps)
        out, _ = _apply_ffn(p, hn, cfg, ffn_kind)
        x = x + out
    return x, cache


def _ssm_prefill_cache(pm, h, state, cfg: ModelConfig):
    """Recompute the conv tails for the ssm decode cache."""
    B, L, _ = h.shape
    G, N, W = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv_width
    xs = h @ pm["w_x"].astype(h.dtype)
    Bs = jnp.einsum("ble,egn->blgn", h, pm["w_B"].astype(h.dtype)).reshape(
        B, L, G * N
    )
    Cs = jnp.einsum("ble,egn->blgn", h, pm["w_C"].astype(h.dtype)).reshape(
        B, L, G * N
    )

    def tail(t):
        tp = jnp.pad(t, ((0, 0), (W - 1, 0), (0, 0)))
        return tp[:, -(W - 1) :, :]

    return {
        "conv_x": tail(xs), "conv_B": tail(Bs), "conv_C": tail(Cs),
        "state": state,
    }


def block_decode(p, x, pos, cache, cfg: ModelConfig, spec):
    mixer_kind, ffn_kind = spec
    p = dequant_block_params(p)
    x = constrain(x, ("batch", "act_seq", "embed_act"))
    h = layers.apply_norm(p["norm1"], x, cfg.norm_kind, cfg.norm_eps)
    if mixer_kind == "gqa":
        mx, cache = attention.gqa_decode(p["mixer"], h, pos, cache, cfg)
    elif mixer_kind == "mla":
        mx, cache = attention.mla_decode(p["mixer"], h, pos, cache, cfg)
    elif mixer_kind == "ssm":
        mx, cache = ssm.ssm_decode(p["mixer"], h, cache, cfg)
    elif mixer_kind == "rg":
        mx, cache = griffin.rglru_block_decode(p["mixer"], h, cache, cfg)
    else:
        raise ValueError(mixer_kind)
    x = x + mx
    if ffn_kind is not None:
        hn = layers.apply_norm(p["norm2"], x, cfg.norm_kind, cfg.norm_eps)
        out, _ = _apply_ffn(p, hn, cfg, ffn_kind)
        x = x + out
    return x, cache


def block_cache_init(cfg: ModelConfig, spec, batch, cache_len, dtype):
    mixer_kind, _ = spec
    if mixer_kind in ("gqa",):
        ring = cfg.attention_kind in ("swa", "local") and cfg.window
        S = min(cfg.window, cache_len) if ring else cache_len
        shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
        if cfg.kv_cache_quant:
            return {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], dtype),
                "v_scale": jnp.zeros(shape[:3], dtype),
            }
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if mixer_kind == "mla":
        return {
            "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros(
                (batch, cache_len, cfg.qk_rope_head_dim), dtype
            ),
        }
    if mixer_kind == "ssm":
        return ssm.ssm_cache_init(cfg, batch, dtype)
    if mixer_kind == "rg":
        return griffin.rglru_cache_init(cfg, batch, dtype)
    raise ValueError(mixer_kind)


# ================================================================ stacks
def _prepend_axis(axes: PyTree, name: str) -> PyTree:
    return jax.tree_util.tree_map(
        lambda t: (name,) + t,
        axes,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(s, (str, type(None))) for s in t
        ),
    )


def stacked_init(key, n: int, init_fn):
    """vmap an init over n layer keys -> stacked params; axes get a
    leading 'layers' logical dim."""
    box = {}

    def inner(k):
        p, a = init_fn(k)
        box["axes"] = a
        return p

    params = jax.vmap(inner)(jax.random.split(key, n))
    return params, _prepend_axis(box["axes"], "layers")


def group_init(key, cfg: ModelConfig, pattern, repeats: int, dtype):
    """Init one plan group: dict b0..b{k-1}, each stacked over repeats."""
    p, a = {}, {}
    for i, spec in enumerate(pattern):
        ki = jax.random.fold_in(key, i)
        p[f"b{i}"], a[f"b{i}"] = stacked_init(
            ki, repeats, lambda k, s=spec: block_init(k, cfg, s, dtype)
        )
    return p, a


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots
        )
    return jax.checkpoint(fn)


def group_forward(gp, x, positions, cfg: ModelConfig, pattern):
    """Scan the group's repeat dim.  Returns (x, summed aux)."""

    def body(carry, layer_params):
        h = carry
        aux_sum = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(pattern):
            h, aux = block_forward(layer_params[f"b{i}"], h, positions, cfg, spec)
            if "moe_aux_loss" in aux:
                aux_sum = aux_sum + aux["moe_aux_loss"]
        return h, aux_sum

    body = _remat(body, cfg)
    if cfg.scan_layers:
        x, auxes = jax.lax.scan(body, x, gp)
        return x, jnp.sum(auxes)
    # unrolled (tiny smoke configs)
    total = jnp.zeros((), jnp.float32)
    n = jax.tree_util.tree_leaves(gp)[0].shape[0]
    for r in range(n):
        lp = jax.tree_util.tree_map(lambda t: t[r], gp)
        x, aux = body(x, lp)
        total = total + aux
    return x, total


def group_prefill(gp, x, positions, cfg: ModelConfig, pattern, cache_len):
    def body(carry, layer_params):
        h = carry
        caches = {}
        for i, spec in enumerate(pattern):
            h, c = block_prefill(
                layer_params[f"b{i}"], h, positions, cfg, spec, cache_len
            )
            caches[f"b{i}"] = c
        return h, caches

    if cfg.scan_layers:
        return jax.lax.scan(body, x, gp)
    n = jax.tree_util.tree_leaves(gp)[0].shape[0]
    caches = []
    for r in range(n):
        lp = jax.tree_util.tree_map(lambda t: t[r], gp)
        x, c = body(x, lp)
        caches.append(c)
    stacked = jax.tree_util.tree_map(
        lambda *ts: jnp.stack(ts), *caches
    )
    return x, stacked


def group_decode(gp, x, pos, caches, cfg: ModelConfig, pattern):
    def body(carry, xs):
        layer_params, cache = xs
        h = carry
        new_caches = {}
        for i, spec in enumerate(pattern):
            h, c = block_decode(
                layer_params[f"b{i}"], h, pos, cache[f"b{i}"], cfg, spec
            )
            new_caches[f"b{i}"] = c
        return h, new_caches

    if cfg.scan_layers:
        return jax.lax.scan(body, x, (gp, caches))
    n = jax.tree_util.tree_leaves(gp)[0].shape[0]
    outs = []
    for r in range(n):
        lp = jax.tree_util.tree_map(lambda t: t[r], gp)
        cr = jax.tree_util.tree_map(lambda t: t[r], caches)
        x, c = body(x, (lp, cr))
        outs.append(c)
    stacked = jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *outs)
    return x, stacked


def group_cache_init(cfg: ModelConfig, pattern, repeats, batch, cache_len, dtype):
    caches = {}
    for i, spec in enumerate(pattern):
        one = block_cache_init(cfg, spec, batch, cache_len, dtype)
        caches[f"b{i}"] = jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t[None], (repeats, *t.shape)), one
        )
    return caches
