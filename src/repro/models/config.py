"""Unified model configuration for the architecture zoo.

One dataclass covers dense / MoE / SSM / hybrid / VLM / audio backbones;
per-arch files in repro/configs instantiate it with published numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio

    # transformer backbone
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: Optional[int] = None  # default d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1000
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    qkv_bias: bool = False  # qwen1.5-style attention biases
    tie_embeddings: bool = False
    emb_scale: Optional[float] = None  # e.g. sqrt(d_model) for gemma-family
    logit_softcap: Optional[float] = None  # e.g. 30.0 recurrentgemma
    rope_theta: float = 10000.0
    rope_pct: float = 1.0  # partial rotary (stablelm2: 0.25)
    pos_kind: str = "rope"  # rope | sinusoidal (musicgen)

    # attention variants
    attention_kind: str = "full"  # full | swa (sliding window)
    window: Optional[int] = None  # SWA/local window length
    attn_impl: str = "auto"  # auto | full | chunked
    attn_chunk: int = 1024  # kv block for chunked attention
    # unroll the chunked-attention kv loop (dry-run cost extraction only:
    # XLA's cost_analysis counts while-loop bodies once, not x trip count)
    attn_chunk_unroll: bool = False
    attn_logit_softcap: Optional[float] = None

    # MLA (minicpm3 / deepseek-style) — set mla=True to replace GQA
    mla: bool = False
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64

    # MoE
    num_experts: int = 0  # 0 = dense MLP
    num_experts_per_tok: int = 2
    capacity_factor: float = 1.25
    router_softmax_order: str = "topk_then_softmax"  # mixtral convention
    # tokens per dispatch group (Gshard): capacity C = Gs*k/E*cf, and the
    # dispatch einsum costs E*C*d per token — small groups keep it a few %
    # of expert FLOPs while preserving fixed shapes.
    moe_group_size: int = 512

    # SSM (mamba2)
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (recurrentgemma/griffin)
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rg", "rg", "local") per group
    lru_width: Optional[int] = None
    rglru_c: float = 8.0

    # modality frontend stubs
    num_image_tokens: int = 0  # vlm: patch-embedding positions per sample
    num_codebooks: int = 0  # audio: EnCodec codebooks (0 = plain LM)

    # training / numerics
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"
    # vocab padding: embedding/lm_head vocab dims are padded up to a
    # multiple of this so they shard cleanly over `model` (any multiple of
    # 128 divides the 16-way TP axis); padded logits are masked to -inf.
    vocab_pad_multiple: int = 128
    remat: str = "full"  # none | dots | full
    scan_layers: bool = True

    # quantization (the paper's energy-aware mode)
    # q115 / q1_7      : fake-quant (QAT; float storage, grid-snapped)
    # q115_int / q1_7_int : TRUE int16/int8 weight storage, dequantized on
    #   the fly — halves/quarters weight HBM traffic (serving §Perf mode)
    quant: Optional[str] = None
    # int8 KV cache with per-(token, head) max-abs scales (the paper's
    # Q-format idea applied to attention state; serving memory-term win)
    kv_cache_quant: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def sub_quadratic(self) -> bool:
        """Whether long_500k decode is runnable (bounded attention state)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.attention_kind == "swa"
        )

    @property
    def padded_vocab(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        n_red = min(self.num_layers, 2)
        if self.family == "hybrid" and self.block_pattern:
            n_red = len(self.block_pattern)  # exercise the full pattern
        base = dict(
            num_layers=n_red,
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            q_lora_rank=64,
            kv_lora_rank=32,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
            ssm_state=16,
            ssm_headdim=32,
            ssm_chunk=32,
            window=min(self.window, 64) if self.window else None,
            lru_width=128 if self.lru_width else None,
            num_image_tokens=16 if self.num_image_tokens else 0,
            block_pattern=self.block_pattern[:] if self.block_pattern else (),
            scan_layers=False,
            remat="none",
            dtype="float32",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)
