"""Shared neural layers: norms, RoPE, MLPs, embeddings.

Every init function returns (params, logical_axes): params is a dict of
arrays, logical_axes a matching dict of tuples naming each dim (used by
distributed/partitioning.py to derive PartitionSpecs).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import constrain

Array = jax.Array


# ---------------------------------------------------------------- helpers
def _uniform(key, shape, scale, dtype):
    return jax.random.uniform(
        key, shape, minval=-scale, maxval=scale, dtype=jnp.float32
    ).astype(dtype)


def dense_init(key, shape, axes, dtype=jnp.float32, fan_in_dims=1):
    """fan-in-scaled init; axes = logical names, one per dim."""
    fan_in = math.prod(shape[:fan_in_dims])
    scale = 1.0 / math.sqrt(fan_in)
    return _uniform(key, shape, scale, dtype), tuple(axes)


# ---------------------------------------------------------------- norms
def norm_init(d: int, kind: str, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    a = {"scale": ("embed",)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
        a["bias"] = ("embed",)
    return p, a


def apply_norm(p, x: Array, kind: str, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float, rope_pct: float = 1.0):
    rot_dim = int(head_dim * rope_pct) // 2 * 2
    inv = 1.0 / (
        theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    )
    return inv, rot_dim


def apply_rope(
    x: Array,  # (..., L, H, D)
    positions: Array,  # (..., L) int32
    theta: float,
    rope_pct: float = 1.0,
) -> Array:
    D = x.shape[-1]
    inv, rot_dim = rope_freqs(D, theta, rope_pct)
    if rot_dim == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., L, rot/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., L, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., : rot_dim // 2], xr[..., rot_dim // 2 :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate(
        [y1.astype(x.dtype), y2.astype(x.dtype), xp], axis=-1
    )


# ---------------------------------------------------------------- mlp
def mlp_init(key, d_model: int, d_ff: int, kind: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p, a = {}, {}
    if kind in ("swiglu", "geglu"):
        p["w_gate"], a["w_gate"] = dense_init(
            k1, (d_model, d_ff), ("embed", "mlp"), dtype
        )
    p["w_up"], a["w_up"] = dense_init(
        k2, (d_model, d_ff), ("embed", "mlp"), dtype
    )
    p["w_down"], a["w_down"] = dense_init(
        k3, (d_ff, d_model), ("mlp", "embed"), dtype
    )
    return p, a


def apply_mlp(p, x: Array, kind: str) -> Array:
    up = x @ p["w_up"].astype(x.dtype)
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * up
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype)) * up
    elif kind == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(kind)
    h = constrain(h, ("batch", "act_seq", "mlp"))
    return h @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------- embed
def embedding_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    p = {"table": jax.random.normal(key, (vocab, d_model)).astype(dtype) * 0.02}
    a = {"table": ("vocab", "embed")}
    return p, a


def embed(p, tokens: Array, scale: Optional[float]) -> Array:
    x = p["table"][tokens]
    if scale is not None:
        x = x * scale
    return x


def unembed(p_head: Array, x: Array, softcap: Optional[float]) -> Array:
    logits = x @ p_head.astype(x.dtype)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits.astype(jnp.float32) / softcap)
    return logits
