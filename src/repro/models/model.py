"""Unified Model API: init / loss / prefill / decode for every arch.

Handles the modality frontends (stubs per assignment):
  - vlm   : precomputed CLIP patch embeddings (B, n_img, 1024) are projected
            by a trainable linear into d_model and prepended to the token
            embeddings; labels cover only the text positions.
  - audio : EnCodec token streams (B, L, K codebooks); embeddings are the
            sum over K codebook tables (MusicGen), logits are per-codebook.

`Model.abstract()` returns (param ShapeDtypeStructs, logical axes) without
allocating — the dry-run path for 34B-param configs on a CPU host.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.distributed.partitioning import constrain
from repro.models import layers, transformer
from repro.models.config import ModelConfig

Array = jax.Array
PyTree = Any

CLIP_EMBED_DIM = 1024  # frozen CLIP-L/14 output width (stub frontend)


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def _sinusoidal_pe(positions: Array, d_model: int) -> Array:
    """(B, L) -> (B, L, d_model) classic transformer PE (musicgen)."""
    half = d_model // 2
    freq = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------ init
    def init(self, key) -> Tuple[PyTree, PyTree]:
        cfg = self.cfg
        pdt = _dtype(cfg.param_dtype)
        keys = jax.random.split(key, 8)
        p, a = {}, {}
        Vp = cfg.padded_vocab
        if cfg.num_codebooks:
            p["embed"] = {
                "table": jax.random.normal(
                    keys[0], (cfg.num_codebooks, Vp, cfg.d_model)
                ).astype(pdt) * 0.02
            }
            a["embed"] = {"table": ("codebook", "vocab", "embed")}
        else:
            p["embed"], a["embed"] = layers.embedding_init(
                keys[0], Vp, cfg.d_model, pdt
            )
        if cfg.num_image_tokens:
            p["img_proj"], a["img_proj"] = layers.dense_init(
                keys[1], (CLIP_EMBED_DIM, cfg.d_model), ("clip", "embed"), pdt
            )
        for gi, (gname, pattern, repeats) in enumerate(transformer.layer_plan(cfg)):
            p[gname], a[gname] = transformer.group_init(
                jax.random.fold_in(keys[2], gi), cfg, pattern, repeats, pdt
            )
        p["final_norm"], a["final_norm"] = layers.norm_init(
            cfg.d_model, cfg.norm_kind, pdt
        )
        if not cfg.tie_embeddings:
            if cfg.num_codebooks:
                p["lm_head"], a["lm_head"] = layers.dense_init(
                    keys[3], (cfg.d_model, cfg.num_codebooks, Vp),
                    ("embed", "codebook", "vocab"), pdt,
                )
            else:
                p["lm_head"], a["lm_head"] = layers.dense_init(
                    keys[3], (cfg.d_model, Vp), ("embed", "vocab"), pdt
                )
        if cfg.quant in ("q115_int", "q1_7_int"):
            p = self._quantize_storage(p)
        return p, a

    def abstract(self) -> Tuple[PyTree, PyTree]:
        """(param shapes, logical axes) without allocation."""
        box = {}

        def f(key):
            params, axes = self.init(key)
            box["axes"] = axes
            return params

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, box["axes"]

    # ------------------------------------------------------------ embed
    def _embed_tokens(self, p, tokens: Array) -> Array:
        cfg = self.cfg
        cdt = _dtype(cfg.dtype)
        if cfg.num_codebooks:
            # tokens (B, L, K) -> sum of per-codebook embeddings
            x = jnp.zeros((*tokens.shape[:2], cfg.d_model), cdt)
            for k in range(cfg.num_codebooks):
                x = x + p["embed"]["table"][k][tokens[..., k]].astype(cdt)
        else:
            x = p["embed"]["table"][tokens].astype(cdt)
        if cfg.emb_scale is not None:
            x = x * jnp.asarray(cfg.emb_scale, cdt)
        return x

    def _inputs(self, p, batch: Dict[str, Array]) -> Array:
        """Token (+ frontend) embeddings -> (B, L_total, E)."""
        x = self._embed_tokens(p, batch["tokens"])
        if self.cfg.num_image_tokens:
            img = batch["img_embeds"].astype(x.dtype) @ p["img_proj"].astype(
                x.dtype
            )
            x = jnp.concatenate([img, x], axis=1)
        return x

    # ------------------------------------------------------------ body
    def _quantize_storage(self, p):
        """True-int storage (serving mode): matmul weights (ndim>=2) are
        kept as Q-format integer codes; norms/biases stay float."""
        fmt = quant.Q1_15 if self.cfg.quant == "q115_int" else quant.Q1_7

        def leaf(x):
            if (
                hasattr(x, "ndim") and x.ndim >= 2
                and jnp.issubdtype(x.dtype, jnp.floating)
            ):
                return quant.quantize(x, fmt)
            return x

        return jax.tree_util.tree_map(leaf, p)

    def _maybe_quant(self, p):
        cfg = self.cfg
        if cfg.quant == "q115":
            return quant.quant_params(p, quant.Q1_15)
        if cfg.quant == "q1_7":
            return quant.quant_params(p, quant.Q1_7)
        if cfg.quant in ("q115_int", "q1_7_int"):
            # dequantize ONLY the top-level (non-group) params here; the
            # layer-stacked groups are dequantized per layer inside the
            # scan body (transformer.dequant_block_params) so one layer's
            # float weights are live at a time.
            group_names = {g for g, _, _ in transformer.layer_plan(cfg)}
            return {
                k: (v if k in group_names
                    else transformer.dequant_block_params(v))
                for k, v in p.items()
            }
        return p

    def _add_pe(self, x: Array, positions: Array) -> Array:
        if self.cfg.pos_kind == "sinusoidal":
            x = x + _sinusoidal_pe(positions, self.cfg.d_model).astype(x.dtype)
        return x

    def backbone(self, p, x: Array, positions: Array) -> Tuple[Array, Array]:
        cfg = self.cfg
        x = self._add_pe(x, positions)
        aux_total = jnp.zeros((), jnp.float32)
        for gname, pattern, _ in transformer.layer_plan(cfg):
            x, aux = transformer.group_forward(
                p[gname], x, positions, cfg, pattern
            )
            aux_total = aux_total + aux
        x = layers.apply_norm(
            p["final_norm"], x, cfg.norm_kind, cfg.norm_eps
        )
        return x, aux_total

    def _head(self, p, h: Array) -> Array:
        """Logits over the padded vocab; padded entries masked to -inf."""
        cfg = self.cfg
        if cfg.num_codebooks:
            w = (
                p["embed"]["table"].transpose(2, 0, 1)
                if cfg.tie_embeddings
                else p["lm_head"]
            )  # (E, K, Vp)
            logits = jnp.einsum("...e,ekv->...kv", h, w.astype(h.dtype))
            if cfg.logit_softcap is not None:
                logits = cfg.logit_softcap * jnp.tanh(
                    logits.astype(jnp.float32) / cfg.logit_softcap
                )
        else:
            w = p["embed"]["table"].T if cfg.tie_embeddings else p["lm_head"]
            logits = layers.unembed(w, h, cfg.logit_softcap)
        if cfg.padded_vocab != cfg.vocab_size:
            valid = (
                jax.lax.iota(jnp.int32, cfg.padded_vocab) < cfg.vocab_size
            )
            logits = jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))
        return logits

    # ------------------------------------------------------------ train
    def loss(self, p, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
        """batch: tokens (B,L[,K]) int32, targets same shape (-1 = masked),
        optional img_embeds."""
        cfg = self.cfg
        p = self._maybe_quant(p)
        x = self._inputs(p, batch)
        B, L = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
        h, aux = self.backbone(p, x, positions)
        if cfg.num_image_tokens:  # only text positions produce logits
            h = h[:, cfg.num_image_tokens :]
        logits = self._head(p, h).astype(jnp.float32)
        cb = ("codebook",) if cfg.num_codebooks else ()
        logits = constrain(logits, ("batch", "act_seq") + cb + ("vocab",))
        targets = batch["targets"]
        mask = (targets >= 0).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = jnp.maximum(targets, 0)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(nll * mask) / denom
        loss = ce + 0.01 * aux / max(cfg.num_layers, 1)
        metrics = {
            "loss": loss, "ce": ce,
            "moe_aux": aux,
            "tokens": jnp.sum(mask),
        }
        return loss, metrics

    # ---------------------------------------------------------- serving
    def prefill(
        self, p, batch: Dict[str, Array], cache_len: int
    ) -> Tuple[Array, PyTree]:
        """Run the prompt; returns (last-position logits (B, ...), cache)."""
        cfg = self.cfg
        p = self._maybe_quant(p)
        x = self._inputs(p, batch)
        B, L = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
        x = self._add_pe(x, positions)
        cache = {}
        for gname, pattern, _ in transformer.layer_plan(cfg):
            x, c = transformer.group_prefill(
                p[gname], x, positions, cfg, pattern, cache_len
            )
            cache[gname] = c
        x = layers.apply_norm(p["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        logits = self._head(p, x[:, -1:])[:, 0]
        return logits.astype(jnp.float32), cache

    def decode_step(
        self, p, token: Array, pos: Array, cache: PyTree
    ) -> Tuple[Array, PyTree]:
        """token: (B, 1[,K]) int32; pos: (B,) absolute position of token."""
        cfg = self.cfg
        p = self._maybe_quant(p)
        x = self._embed_tokens(p, token)
        x = self._add_pe(x, pos[:, None])
        new_cache = {}
        for gname, pattern, _ in transformer.layer_plan(cfg):
            x, c = transformer.group_decode(
                p[gname], x, pos, cache[gname], cfg, pattern
            )
            new_cache[gname] = c
        x = layers.apply_norm(p["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        logits = self._head(p, x)[:, 0]
        return logits.astype(jnp.float32), new_cache

    def init_cache(self, batch: int, cache_len: int) -> PyTree:
        cfg = self.cfg
        cdt = _dtype(cfg.dtype)
        cache = {}
        for gname, pattern, repeats in transformer.layer_plan(cfg):
            cache[gname] = transformer.group_cache_init(
                cfg, pattern, repeats, batch, cache_len, cdt
            )
        return cache

    def abstract_cache(self, batch: int, cache_len: int) -> PyTree:
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, cache_len)
        )

    def param_count(self) -> int:
        shapes, _ = self.abstract()
        import math
        return sum(
            math.prod(s.shape)
            for s in jax.tree_util.tree_leaves(shapes)
        )

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.num_experts:
            return total
        shapes, _ = self.abstract()
        expert_leaves = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            keys = [getattr(k, 'key', None) for k in path]
            if "ffn" in keys and any(
                k in ("w_gate", "w_up", "w_down") for k in keys
            ):
                import math
                expert_leaves += math.prod(leaf.shape)
        inactive = expert_leaves * (
            1 - cfg.num_experts_per_tok / cfg.num_experts
        )
        return int(total - inactive)
