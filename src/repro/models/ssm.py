"""Mamba2 — SSD (state-space duality) layer, chunked matmul formulation.

Training/prefill uses the chunked SSD algorithm (Dao & Gu 2024): the
sequence is split into chunks of Q tokens; intra-chunk work is a masked
quadratic matmul (MXU-friendly), inter-chunk work is a length-L/Q linear
recurrence over per-chunk states (lax.scan).  Decode uses the O(1)
recurrent form with (conv_state, ssm_state) carried in the cache — the
same "state never leaves fast memory" pattern as the paper's LIF membrane
register (DESIGN.md §Arch-applicability).

Projections are kept as separate params (w_z/w_x/w_B/w_C/w_dt and per-part
convs) instead of one fused in_proj so each can carry its own logical
sharding axes (the fused layout has a mixed output dim that defeats clean
TP; see partitioning rules).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import constrain
from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array


def ssm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    E = cfg.d_model
    DI = cfg.d_inner
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    W = cfg.ssm_conv_width
    ks = jax.random.split(key, 11)
    p, a = {}, {}
    p["w_z"], a["w_z"] = layers.dense_init(ks[10], (E, DI), ("embed", "inner"), dtype)
    p["w_x"], a["w_x"] = layers.dense_init(ks[1], (E, DI), ("embed", "inner"), dtype)
    p["w_B"], a["w_B"] = layers.dense_init(
        ks[2], (E, G, N), ("embed", "groups", "state"), dtype
    )
    p["w_C"], a["w_C"] = layers.dense_init(
        ks[3], (E, G, N), ("embed", "groups", "state"), dtype
    )
    p["w_dt"], a["w_dt"] = layers.dense_init(
        ks[4], (E, H), ("embed", "heads"), dtype
    )
    # depthwise causal convs (width W) on x, B, C streams
    p["conv_x"] = jax.random.normal(ks[5], (W, DI)).astype(dtype) * 0.1
    a["conv_x"] = ("conv_w", "inner")
    p["conv_B"] = jax.random.normal(ks[6], (W, G * N)).astype(dtype) * 0.1
    a["conv_B"] = ("conv_w", "state")
    p["conv_C"] = jax.random.normal(ks[7], (W, G * N)).astype(dtype) * 0.1
    a["conv_C"] = ("conv_w", "state")
    # per-head decay / skip / dt bias
    p["A_log"] = jnp.log(
        jax.random.uniform(ks[8], (H,), minval=1.0, maxval=16.0)
    ).astype(dtype)
    a["A_log"] = ("heads",)
    p["D"] = jnp.ones((H,), dtype)
    a["D"] = ("heads",)
    p["dt_bias"] = jnp.log(
        jnp.expm1(
            jax.random.uniform(ks[9], (H,), minval=1e-3, maxval=1e-1)
        )
    ).astype(dtype)
    a["dt_bias"] = ("heads",)
    p["norm_scale"] = jnp.ones((DI,), dtype)
    a["norm_scale"] = ("inner",)
    p["out_proj"], a["out_proj"] = layers.dense_init(
        ks[0], (DI, E), ("inner", "embed"), dtype
    )
    return p, a


def _causal_conv(x: Array, w: Array) -> Array:
    """Depthwise causal conv along axis 1.  x: (B, L, D), w: (W, D)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return out


def _segsum(dA: Array) -> Array:
    """(..., Q) -> (..., Q, Q) lower-triangular segment sums."""
    c = jnp.cumsum(dA, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    Q = dA.shape[-1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    xdt: Array,  # (B, L, H, P) inputs pre-multiplied by dt
    dA: Array,  # (B, L, H) = dt * A (negative)
    Bm: Array,  # (B, L, G, N)
    Cm: Array,  # (B, L, G, N)
    chunk: int,
    h0: Array = None,  # optional initial state (B, H, P, N)
) -> Tuple[Array, Array]:
    """Chunked SSD scan.  Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    B, L, H, P = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // Q
    # reshape to chunks
    xc = xdt.reshape(B, nc, Q, H, P)
    dAc = dA.reshape(B, nc, Q, H).transpose(0, 3, 1, 2)  # (B, H, nc, Q)
    Bc = Bm.reshape(B, nc, Q, G, N)
    Cc = Cm.reshape(B, nc, Q, G, N)
    rep = H // G  # heads per group

    # head -> group map for einsums: expand B/C to heads lazily via take
    def hgrp(t):  # (B, nc, Q, G, N) -> (B, nc, Q, H, N)
        return jnp.repeat(t, rep, axis=3)

    Bh, Ch = hgrp(Bc), hgrp(Cc)

    # --- intra-chunk (diag) ---
    Lmat = jnp.exp(_segsum(dAc))  # (B, H, nc, Q, Q)
    scores = jnp.einsum(
        "bclhn,bcshn->bhcls", Ch, Bh, preferred_element_type=jnp.float32
    )
    y_diag = jnp.einsum(
        "bhcls,bhcls,bcshp->bclhp",
        scores,
        Lmat,
        xc,
        preferred_element_type=jnp.float32,
    )

    # --- chunk states ---
    csum = jnp.cumsum(dAc, axis=-1)  # (B, H, nc, Q)
    decay_states = jnp.exp(csum[..., -1:] - csum)  # (B, H, nc, Q)
    states = jnp.einsum(
        "bcshn,bhcs,bcshp->bchpn",
        Bh,
        decay_states,
        xc,
        preferred_element_type=jnp.float32,
    )  # (B, nc, H, P, N)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(csum[..., -1])  # (B, H, nc)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def body(carry, xs):
        s_c, d_c = xs  # (B, H, P, N), (B, H)
        prev = carry
        new = prev * d_c[..., None, None] + s_c
        return new, prev

    s_seq = states.transpose(1, 0, 2, 3, 4)  # (nc, B, H, P, N)
    d_seq = chunk_decay.transpose(2, 0, 1)  # (nc, B, H)
    final, prevs = jax.lax.scan(body, h0.astype(jnp.float32), (s_seq, d_seq))
    prev_states = prevs.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    # --- inter-chunk (off-diag) outputs ---
    state_decay = jnp.exp(csum)  # (B, H, nc, Q) decay from chunk start incl l
    y_off = jnp.einsum(
        "bclhn,bchpn,bhcl->bclhp",
        Ch,
        prev_states,
        state_decay,
        preferred_element_type=jnp.float32,
    )

    y = (y_diag + y_off).reshape(B, Lp, H, P)[:, :L]
    return y, final


def _split_heads(t: Array, H: int, P: int) -> Array:
    return t.reshape(*t.shape[:-1], H, P)


def ssm_forward(
    p, x: Array, cfg: ModelConfig, h0=None, return_state: bool = False
):
    """x: (B, L, E) -> (B, L, E).  Training / prefill path."""
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    B, L, E = x.shape
    z = x @ p["w_z"].astype(x.dtype)  # (B, L, DI)
    xs = x @ p["w_x"].astype(x.dtype)
    Bs = jnp.einsum("ble,egn->blgn", x, p["w_B"].astype(x.dtype)).reshape(
        B, L, G * N
    )
    Cs = jnp.einsum("ble,egn->blgn", x, p["w_C"].astype(x.dtype)).reshape(
        B, L, G * N
    )
    dt_raw = x @ p["w_dt"].astype(x.dtype)  # (B, L, H)

    xs = jax.nn.silu(_causal_conv(xs, p["conv_x"].astype(x.dtype)))
    xs = constrain(xs, ("batch", "act_seq", "inner"))
    Bs = jax.nn.silu(_causal_conv(Bs, p["conv_B"].astype(x.dtype))).reshape(
        B, L, G, N
    )
    Cs = jax.nn.silu(_causal_conv(Cs, p["conv_C"].astype(x.dtype))).reshape(
        B, L, G, N
    )
    Bs = constrain(Bs, ("batch", "act_seq", "groups", "state"))
    Cs = constrain(Cs, ("batch", "act_seq", "groups", "state"))

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B, L, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    dA = dt * A  # (B, L, H)

    xh = _split_heads(xs, H, P)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    y, state = ssd_chunked(xdt, dA, Bs, Cs, cfg.ssm_chunk, h0)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, L, H * P).astype(x.dtype)

    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (
        yf
        * jax.lax.rsqrt(jnp.mean(yf**2, -1, keepdims=True) + 1e-6)
        * p["norm_scale"].astype(jnp.float32)
    ).astype(x.dtype)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, state
    return out


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Array]:
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    W = cfg.ssm_conv_width
    return {
        "conv_x": jnp.zeros((batch, W - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((batch, W - 1, G * N), dtype),
        "conv_C": jnp.zeros((batch, W - 1, G * N), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def _conv_step(cache_part: Array, new: Array, w: Array):
    """One causal-conv step.  cache: (B, W-1, D) previous inputs."""
    window = jnp.concatenate([cache_part, new[:, None, :]], axis=1)  # (B,W,D)
    out = jnp.einsum("bwd,wd->bd", window, w)
    return out, window[:, 1:, :]


def ssm_decode(
    p, x: Array, cache: Dict[str, Array], cfg: ModelConfig
) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode.  x: (B, 1, E)."""
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    B = x.shape[0]
    xt = x[:, 0]
    z = xt @ p["w_z"].astype(x.dtype)
    xs = xt @ p["w_x"].astype(x.dtype)
    Bs = jnp.einsum("be,egn->bgn", xt, p["w_B"].astype(x.dtype)).reshape(B, G * N)
    Cs = jnp.einsum("be,egn->bgn", xt, p["w_C"].astype(x.dtype)).reshape(B, G * N)
    dt_raw = xt @ p["w_dt"].astype(x.dtype)

    xs, conv_x = _conv_step(cache["conv_x"], xs, p["conv_x"].astype(x.dtype))
    Bs, conv_B = _conv_step(cache["conv_B"], Bs, p["conv_B"].astype(x.dtype))
    Cs, conv_C = _conv_step(cache["conv_C"], Cs, p["conv_C"].astype(x.dtype))
    xs, Bs, Cs = jax.nn.silu(xs), jax.nn.silu(Bs), jax.nn.silu(Cs)
    Bs = Bs.reshape(B, G, N)
    Cs = Cs.reshape(B, G, N)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)  # (B, H)

    xh = xs.reshape(B, H, P).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bs, rep, axis=1).astype(jnp.float32)  # (B, H, N)
    Ch = jnp.repeat(Cs, rep, axis=1).astype(jnp.float32)
    state = cache["state"] * dA[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xh, Bh, dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, H * P).astype(x.dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (
        yf
        * jax.lax.rsqrt(jnp.mean(yf**2, -1, keepdims=True) + 1e-6)
        * p["norm_scale"].astype(jnp.float32)
    ).astype(x.dtype)
    out = (y @ p["out_proj"].astype(x.dtype))[:, None, :]
    new_cache = {
        "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "state": state,
    }
    return out, new_cache
