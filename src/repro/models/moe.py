"""Mixture-of-Experts: top-k router + grouped-capacity einsum dispatch.

Gshard-style dispatch/combine einsums over *token groups*: tokens are
flattened (batch-major, so batch sharding propagates through the reshape)
into groups of `moe_group_size`; capacity is per group,
C = ceil(Gs * k / E * cf).  The dispatch einsum costs E*C*d FLOPs per
token — with Gs=512 that is ~0.7% (mixtral) to ~20% (granite's tiny
experts) of the expert MLP FLOPs, while everything stays a dense einsum
that shards cleanly under SPMD (expert dim -> `model` mesh axis = expert
parallelism; group dim -> (`pod`,`data`) = data parallelism).

Two rejected alternatives, measured in the dry-run (EXPERIMENTS.md §Perf
notes): whole-row capacity einsum dispatch (C grows with S -> dispatch
FLOPs rival expert FLOPs) and scatter-add dispatch (data-dependent
scatter into the expert dim defeats SPMD -> XLA replicates the buffers
and emits ~390 GB/layer of all-reduce).

Overflow tokens are dropped (zero combine weight; the residual passes
them through) — standard fixed-shape TPU MoE.  Conceptually this is the
paper's event-driven insight at the token level: routing is a spike —
only selected experts integrate a token (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import constrain
from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    E, F, N = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["router"], a["router"] = layers.dense_init(
        ks[0], (E, N), ("embed", "expert"), dtype
    )
    scale = 1.0 / math.sqrt(E)
    fscale = 1.0 / math.sqrt(F)
    p["w_gate"] = jax.random.uniform(
        ks[1], (N, E, F), minval=-scale, maxval=scale
    ).astype(dtype)
    a["w_gate"] = ("expert", "embed", "mlp")
    p["w_up"] = jax.random.uniform(
        ks[2], (N, E, F), minval=-scale, maxval=scale
    ).astype(dtype)
    a["w_up"] = ("expert", "embed", "mlp")
    p["w_down"] = jax.random.uniform(
        ks[3], (N, F, E), minval=-fscale, maxval=fscale
    ).astype(dtype)
    a["w_down"] = ("expert", "mlp", "embed")
    return p, a


def group_size(cfg: ModelConfig, tokens: int) -> int:
    gs = min(cfg.moe_group_size, tokens)
    while tokens % gs:
        gs -= 1
    return gs


def capacity(gs: int, cfg: ModelConfig) -> int:
    c = math.ceil(
        gs * cfg.num_experts_per_tok / cfg.num_experts * cfg.capacity_factor
    )
    return max(int(c), 1)


def router_weights(logits: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """Top-k routing -> (weights (..., k), indices (..., k))."""
    k = cfg.num_experts_per_tok
    if cfg.router_softmax_order == "topk_then_softmax":
        vals, idx = jax.lax.top_k(logits, k)
        w = jax.nn.softmax(vals.astype(jnp.float32), axis=-1)
    else:  # softmax_then_topk (granite)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        w, idx = jax.lax.top_k(probs, k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    return w, idx


def moe_forward(p, x: Array, cfg: ModelConfig) -> Tuple[Array, Dict]:
    """x: (B, S, E) -> (out (B, S, E), aux metrics)."""
    B, S, D = x.shape
    N, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    Gs = group_size(cfg, T)
    G = T // Gs
    C = capacity(Gs, cfg)
    xg = x.reshape(G, Gs, D)  # batch-major flatten: sharding propagates
    xg = constrain(xg, ("batch", "act_seq", "embed_act"))

    logits = xg @ p["router"].astype(x.dtype)  # (G, Gs, N)
    w, idx = router_weights(logits, cfg)  # (G, Gs, K) f32 / i32

    # queue position of each (token, slot) within its expert, per group
    onehot = jax.nn.one_hot(idx, N, dtype=jnp.int32)  # (G, Gs, K, N)
    flat = onehot.reshape(G, Gs * K, N)
    pos_flat = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.sum(pos_flat.reshape(G, Gs, K, N) * onehot, axis=-1)
    keep = pos < C  # (G, Gs, K)
    slot_oh = jax.nn.one_hot(jnp.minimum(pos, C - 1), C, dtype=x.dtype)
    slot_oh = slot_oh * keep[..., None].astype(x.dtype)  # (G, Gs, K, C)

    dispatch = jnp.einsum(
        "gske,gskc->gsec", onehot.astype(x.dtype), slot_oh
    )  # (G, Gs, E, C)
    combine = jnp.einsum(
        "gske,gskc,gsk->gsec",
        onehot.astype(jnp.float32),
        slot_oh.astype(jnp.float32),
        w,
    ).astype(x.dtype)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)  # (G, E, C, D)
    xe = constrain(xe, ("batch", "expert", "cap", "embed_act"))

    h_up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    if cfg.mlp_kind in ("swiglu", "geglu"):
        h_gate = jnp.einsum(
            "gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype)
        )
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        h = act(h_gate) * h_up
    else:
        h = jax.nn.gelu(h_up)
    h = constrain(h, ("batch", "expert", "cap", "mlp"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    ye = constrain(ye, ("batch", "expert", "cap", "embed_act"))

    out = jnp.einsum("gecd,gsec->gsd", ye, combine)  # (G, Gs, D)
    out = out.reshape(B, S, D)

    # load-balancing auxiliaries (Switch aux loss)
    me = jnp.mean(
        onehot.astype(jnp.float32).sum(2).reshape(T, N), axis=0
    )
    pe = jnp.mean(
        jax.nn.softmax(logits.astype(jnp.float32), -1).reshape(T, N), axis=0
    )
    aux = {
        "moe_aux_loss": N * jnp.sum(me * pe),
        "moe_dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out, aux
