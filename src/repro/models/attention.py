"""Attention: GQA/MHA/MQA with full, sliding-window and chunked (online-
softmax, flash-style) implementations, plus MLA (multi-head latent
attention, MiniCPM3/DeepSeek-style) — with KV caches for serving.

Cache formats
  full cache : k/v (B, S_max, Kv, D) — dense archs; entries written at
               their absolute position.
  ring cache : k/v (B, W, Kv, D) for SWA/local-attention archs — slot =
               pos % W, so a 500k-token decode holds only W entries.
  mla cache  : c_kv (B, S, r) + k_rope (B, S, dr) — compressed latents.

Keys are stored rope-applied (absolute positions), the standard serving
layout.  All softmax math in float32.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import constrain
from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array
NEG_INF = -1e30


# ================================================================ params
def gqa_init(key, cfg: ModelConfig, dtype=jnp.float32):
    H, Kv, D, E = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = layers.dense_init(
        ks[0], (E, H, D), ("embed", "heads", "head_dim"), dtype
    )
    p["wk"], a["wk"] = layers.dense_init(
        ks[1], (E, Kv, D), ("embed", "kv", "head_dim"), dtype
    )
    p["wv"], a["wv"] = layers.dense_init(
        ks[2], (E, Kv, D), ("embed", "kv", "head_dim"), dtype
    )
    p["wo"], a["wo"] = layers.dense_init(
        ks[3], (H, D, E), ("heads", "head_dim", "embed"), dtype, fan_in_dims=2
    )
    if cfg.qkv_bias:
        p["bq"], a["bq"] = jnp.zeros((H, D), dtype), ("heads", "head_dim")
        p["bk"], a["bk"] = jnp.zeros((Kv, D), dtype), ("kv", "head_dim")
        p["bv"], a["bv"] = jnp.zeros((Kv, D), dtype), ("kv", "head_dim")
    return p, a


def mla_init(key, cfg: ModelConfig, dtype=jnp.float32):
    E, H = cfg.d_model, cfg.num_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["q_a"], a["q_a"] = layers.dense_init(ks[0], (E, r_q), ("embed", "q_rank"), dtype)
    p["q_norm"], a["q_norm"] = jnp.ones((r_q,), dtype), ("q_rank",)
    p["q_b"], a["q_b"] = layers.dense_init(
        ks[1], (r_q, H, dn + dr), ("q_rank", "heads", "head_dim"), dtype
    )
    p["kv_a"], a["kv_a"] = layers.dense_init(
        ks[2], (E, r_kv + dr), ("embed", "kv_rank"), dtype
    )
    p["kv_norm"], a["kv_norm"] = jnp.ones((r_kv,), dtype), ("kv_rank",)
    p["kv_b"], a["kv_b"] = layers.dense_init(
        ks[3], (r_kv, H, dn + dv), ("kv_rank", "heads", "head_dim"), dtype
    )
    p["wo"], a["wo"] = layers.dense_init(
        ks[4], (H, dv, E), ("heads", "head_dim", "embed"), dtype, fan_in_dims=2
    )
    return p, a


# ================================================================ masking
def _mask(q_pos: Array, kv_pos: Array, window: Optional[int]) -> Array:
    """(..., Lq, Lk) boolean validity: causal + optional sliding window +
    kv_pos >= 0 (ring-buffer slots not yet written have kv_pos < 0)."""
    m = kv_pos[..., None, :] <= q_pos[..., :, None]
    m &= kv_pos[..., None, :] >= 0
    if window is not None:
        m &= kv_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf**2, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------- int8 KV cache (paper's
# Q-format applied to attention state: per-(token, head) max-abs scales)
def kv_quantize(x: Array) -> Tuple[Array, Array]:
    """(B, S, Kv, D) -> (int8 codes, (B, S, Kv) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = amax / 127.0 + 1e-12
    codes = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return codes, scale.astype(x.dtype)


def kv_dequantize(codes: Array, scale: Array) -> Array:
    return codes.astype(scale.dtype) * scale[..., None]


# ================================================================ attend
def attend_full(
    q: Array,  # (B, Lq, Kv, G, D)  (G = H // Kv query groups)
    k: Array,  # (B, Lk, Kv, D)
    v: Array,  # (B, Lk, Kv, D)
    q_pos: Array,  # (B, Lq)
    kv_pos: Array,  # (B, Lk)
    *,
    window: Optional[int],
    scale: float,
    softcap: Optional[float] = None,
) -> Array:
    scores = jnp.einsum(
        "blkgd,bskd->bkgls", q, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = _mask(q_pos, kv_pos, window)[:, None, None]  # (B,1,1,Lq,Lk)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgls,bskd->blkgd", w, v)


def attend_chunked(
    q: Array,  # (B, Lq, Kv, G, D)
    k: Array,
    v: Array,
    q_pos: Array,
    kv_pos: Array,
    *,
    window: Optional[int],
    scale: float,
    chunk: int,
    softcap: Optional[float] = None,
    unroll: bool = False,
) -> Array:
    """Online-softmax streaming over KV chunks — O(Lq*chunk) live scores.

    Equivalent to attend_full (property-tested); used for long prefill.
    `unroll=True` replaces the lax.scan with a Python loop (identical
    math) so dry-run cost_analysis sees every chunk iteration.
    """
    B, Lk = k.shape[0], k.shape[1]
    pad = (-Lk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = (Lk + pad) // chunk
    kc = k.reshape(B, n_chunks, chunk, *k.shape[2:]).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, *v.shape[2:]).swapaxes(0, 1)
    pc = kv_pos.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    Bq, Lq, Kv, G, D = q.shape
    m0 = jnp.full((B, Kv, G, Lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, Lq), jnp.float32)
    acc0 = jnp.zeros((B, Lq, Kv, G, v.shape[-1]), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i = xs
        s = jnp.einsum(
            "blkgd,bskd->bkgls", q, k_i, preferred_element_type=jnp.float32
        ) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        msk = _mask(q_pos, p_i, window)[:, None, None]
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == NEG_INF)
        m_safe = jnp.maximum(m_new, -0.9e30)
        corr = jnp.exp(m - m_safe)
        p = jnp.exp(s - m_safe[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgls,bskd->blkgd", p.astype(v_i.dtype), v_i)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), ()

    if unroll:
        carry = (m0, l0, acc0)
        for i in range(n_chunks):
            carry, _ = body(carry, (kc[i], vc[i], pc[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc))
    l = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return (acc / l).astype(v.dtype)


def _attend(q, k, v, q_pos, kv_pos, cfg: ModelConfig, scale: float):
    window = cfg.window if cfg.attention_kind in ("swa", "local") else None
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if k.shape[1] >= 8192 else "full"
    fn = attend_chunked if impl == "chunked" else attend_full
    kw = dict(window=window, scale=scale, softcap=cfg.attn_logit_softcap)
    if impl == "chunked":
        kw["chunk"] = cfg.attn_chunk
        kw["unroll"] = cfg.attn_chunk_unroll
    return fn(q, k, v, q_pos, kv_pos, **kw)


# ================================================================ GQA fwd
def _project_qkv(p, x, cfg: ModelConfig, positions):
    H, Kv = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("ble,ehd->blhd", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("ble,ekd->blkd", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("ble,ekd->blkd", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain(q, ("batch", "act_seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "act_seq", "kv", "head_dim"))
    v = constrain(v, ("batch", "act_seq", "kv", "head_dim"))
    q = layers.apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
    k = layers.apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    return q.reshape(*q.shape[:2], Kv, H // Kv, cfg.head_dim), k, v


def gqa_forward(
    p,
    x: Array,  # (B, L, E)
    positions: Array,  # (B, L)
    cfg: ModelConfig,
) -> Array:
    """Training / prefill self-attention (causal, optional SWA)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    scale = cfg.head_dim ** -0.5
    o = _attend(q, k, v, positions, positions, cfg, scale)
    o = o.reshape(*x.shape[:2], cfg.num_heads, cfg.head_dim)
    o = constrain(o, ("batch", "act_seq", "heads", "head_dim"))
    return jnp.einsum("blhd,hde->ble", o, p["wo"].astype(x.dtype))


def gqa_prefill(p, x, positions, cfg: ModelConfig, cache_len: int):
    """Like gqa_forward but also returns the populated KV cache."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    scale = cfg.head_dim ** -0.5
    o = _attend(q, k, v, positions, positions, cfg, scale)
    o = o.reshape(*x.shape[:2], cfg.num_heads, cfg.head_dim)
    o = constrain(o, ("batch", "act_seq", "heads", "head_dim"))
    out = jnp.einsum("blhd,hde->ble", o, p["wo"].astype(x.dtype))

    L = x.shape[1]
    B = x.shape[0]
    quantized = cfg.kv_cache_quant
    if quantized:
        kq, ks = kv_quantize(k)
        vq, vs = kv_quantize(v)
    ring = cfg.attention_kind in ("swa", "local") and cfg.window
    if ring:
        W = min(cfg.window, cache_len)
        # keep the last W entries, placed at slot = pos % W
        take = min(L, W)
        slots = positions[:, -take:] % W
        bidx = jnp.arange(B)[:, None]

        def place(t, width=None):
            c = jnp.zeros((B, W, *t.shape[2:]), t.dtype)
            return c.at[bidx, slots].set(t[:, -take:])

        if quantized:
            cache = {"k": place(kq), "v": place(vq),
                     "k_scale": place(ks), "v_scale": place(vs)}
        else:
            cache = {"k": place(k), "v": place(v)}
    else:
        def place(t):
            c = jnp.zeros((B, cache_len, *t.shape[2:]), t.dtype)
            return jax.lax.dynamic_update_slice(
                c, t, (0,) * t.ndim
            )

        if quantized:
            cache = {"k": place(kq), "v": place(vq),
                     "k_scale": place(ks), "v_scale": place(vs)}
        else:
            cache = {"k": place(k), "v": place(v)}
    return out, cache


def gqa_decode(
    p,
    x: Array,  # (B, 1, E)
    pos: Array,  # (B,) int32 current absolute position
    cache: Dict[str, Array],
    cfg: ModelConfig,
) -> Tuple[Array, Dict[str, Array]]:
    """One decode step against a full or ring KV cache."""
    positions = pos[:, None]
    q, k, v = _project_qkv(p, x, cfg, positions)
    ring = cfg.attention_kind in ("swa", "local") and cfg.window
    S = cache["k"].shape[1]
    bidx = jnp.arange(x.shape[0])[:, None]
    if ring:
        slot = (pos % S)[:, None]
    else:
        slot = pos[:, None]
    quantized = "k_scale" in cache
    if quantized:
        kq, ks = kv_quantize(k)
        vq, vs = kv_quantize(v)
        new_cache = {
            "k": cache["k"].at[bidx, slot].set(kq),
            "v": cache["v"].at[bidx, slot].set(vq),
            "k_scale": cache["k_scale"].at[bidx, slot].set(ks),
            "v_scale": cache["v_scale"].at[bidx, slot].set(vs),
        }
        ck = kv_dequantize(new_cache["k"], new_cache["k_scale"])
        cv = kv_dequantize(new_cache["v"], new_cache["v_scale"])
    else:
        ck = cache["k"].at[bidx, slot].set(k)
        cv = cache["v"].at[bidx, slot].set(v)
        new_cache = {"k": ck, "v": cv}

    if ring:
        # reconstruct absolute positions of ring slots
        j = jnp.arange(S)[None, :]
        s = slot  # (B,1)
        kv_pos = pos[:, None] - ((s - j) % S)
    else:
        j = jnp.arange(S)[None, :]
        kv_pos = jnp.where(j <= pos[:, None], j, -1)
    kv_pos = jnp.where(kv_pos >= 0, kv_pos, -1)

    scale = cfg.head_dim ** -0.5
    window = cfg.window if ring else None
    o = attend_full(
        q, ck, cv, positions, kv_pos, window=window, scale=scale,
        softcap=cfg.attn_logit_softcap,
    )
    o = o.reshape(x.shape[0], 1, cfg.num_heads, cfg.head_dim)
    out = jnp.einsum("blhd,hde->ble", o, p["wo"].astype(x.dtype))
    return out, new_cache


# ================================================================ MLA fwd
def _mla_qkv(p, x, cfg: ModelConfig, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = _rms(x @ p["q_a"].astype(x.dtype), p["q_norm"])
    q = jnp.einsum("blr,rhd->blhd", cq, p["q_b"].astype(x.dtype))
    q = constrain(q, ("batch", "act_seq", "heads", "head_dim"))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_full = x @ p["kv_a"].astype(x.dtype)
    c_kv = _rms(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., cfg.kv_lora_rank :][:, :, None, :]  # (B,L,1,dr)
    k_rope = layers.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(p, c_kv, cfg: ModelConfig):
    dn = cfg.qk_nope_head_dim
    kv = jnp.einsum("bsr,rhd->bshd", c_kv, p["kv_b"].astype(c_kv.dtype))
    kv = constrain(kv, ("batch", "act_seq", "heads", "head_dim"))
    return kv[..., :dn], kv[..., dn:]  # k_nope (B,S,H,dn), v (B,S,H,dv)


def _mla_attend(p, q_nope, q_rope, c_kv, k_rope, q_pos, kv_pos, cfg, absorb):
    """Shared MLA attention core; absorb=True uses the latent-space trick
    (score/context computed against c_kv directly — decode optimization)."""
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    scale = (dn + dr) ** -0.5
    if absorb:
        kv_b_k = p["kv_b"][..., :dn]  # (r, H, dn)
        kv_b_v = p["kv_b"][..., dn:]  # (r, H, dv)
        q_eff = jnp.einsum(
            "blhd,rhd->blhr", q_nope, kv_b_k.astype(q_nope.dtype)
        )
        s = jnp.einsum(
            "blhr,bsr->bhls", q_eff, c_kv, preferred_element_type=jnp.float32
        )
        s = s + jnp.einsum(
            "blhd,bsd->bhls", q_rope, k_rope, preferred_element_type=jnp.float32
        )
        s = s * scale
        mask = _mask(q_pos, kv_pos, None)[:, None]
        s = jnp.where(mask, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(c_kv.dtype)
        ctx = jnp.einsum("bhls,bsr->blhr", w, c_kv)
        o = jnp.einsum("blhr,rhd->blhd", ctx, kv_b_v.astype(ctx.dtype))
    else:
        k_nope, v = _mla_expand_kv(p, c_kv, cfg)
        B, S = k_rope.shape[0], k_rope.shape[1]
        k_rope_h = jnp.broadcast_to(
            k_rope[:, :, None, :], (B, S, cfg.num_heads, dr)
        )
        k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # MLA has no KV grouping: Kv = H, G = 1
        o = attend_full(
            q[:, :, :, None, :], k, v, q_pos, kv_pos,
            window=None, scale=scale,
        )[:, :, :, 0, :]
    return jnp.einsum("blhd,hde->ble", o, p["wo"].astype(o.dtype))


def mla_forward(p, x, positions, cfg: ModelConfig, absorb: bool = False):
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    return _mla_attend(
        p, q_nope, q_rope, c_kv, k_rope, positions, positions, cfg, absorb
    )


def mla_prefill(p, x, positions, cfg: ModelConfig, cache_len: int,
                absorb: bool = False):
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    out = _mla_attend(
        p, q_nope, q_rope, c_kv, k_rope, positions, positions, cfg, absorb
    )
    B = x.shape[0]
    ckv_c = jnp.zeros((B, cache_len, cfg.kv_lora_rank), c_kv.dtype)
    krope_c = jnp.zeros((B, cache_len, cfg.qk_rope_head_dim), k_rope.dtype)
    ckv_c = jax.lax.dynamic_update_slice(ckv_c, c_kv, (0, 0, 0))
    krope_c = jax.lax.dynamic_update_slice(krope_c, k_rope, (0, 0, 0))
    return out, {"c_kv": ckv_c, "k_rope": krope_c}


def mla_decode(p, x, pos, cache, cfg: ModelConfig, absorb: bool = True):
    positions = pos[:, None]
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, x, cfg, positions)
    bidx = jnp.arange(x.shape[0])[:, None]
    c_kv = cache["c_kv"].at[bidx, pos[:, None]].set(c_kv_new)
    k_rope = cache["k_rope"].at[bidx, pos[:, None]].set(k_rope_new)
    S = c_kv.shape[1]
    j = jnp.arange(S)[None, :]
    kv_pos = jnp.where(j <= pos[:, None], j, -1)
    out = _mla_attend(
        p, q_nope, q_rope, c_kv, k_rope, positions, kv_pos, cfg, absorb
    )
    return out, {"c_kv": c_kv, "k_rope": k_rope}
