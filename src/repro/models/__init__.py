from repro.models import attention, config, griffin, layers, model, moe, ssm, transformer

__all__ = [
    "attention", "config", "griffin", "layers", "model", "moe", "ssm",
    "transformer",
]
