"""RecurrentGemma-2B — Griffin hybrid: RG-LRU recurrent blocks with local
attention, 1 attention : 2 recurrent.

[arXiv:2402.19427; hf:google/recurrentgemma-2b]
26L d_model=2560 10H MQA(kv=1, head_dim=256) d_ff=7680 vocab=256000,
lru_width=2560, local window=2048, GeGLU, tied embeds, sqrt(d) emb scale.
"""

import math

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp_kind="geglu",
    norm_kind="rmsnorm",
    rope_theta=10000.0,
    rope_pct=0.5,
    emb_scale=math.sqrt(2560.0),
    tie_embeddings=True,
    logit_softcap=30.0,
    attention_kind="local",
    window=2048,
    block_pattern=("rg", "rg", "attn"),
    lru_width=2560,
    rglru_c=8.0,
)
