"""Mamba2-130m — attention-free SSD (state-space duality) stack.

[arXiv:2405.21060; hf:state-spaces/mamba2-130m]
24L d_model=768 vocab=50280 ssm_state=128 headdim=64 expand=2, tied embeds.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=1,  # attention-free; SSM heads derived from d_inner/headdim
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    norm_kind="rmsnorm",
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv_width=4,
    ssm_chunk=256,
)
