"""Phi-3-Vision 4.2B — phi-3-mini backbone + CLIP patch-embedding frontend
(frontend is a STUB per assignment: input_specs provides precomputed
(B, 576, 1024) CLIP-L/14 patch embeddings; a trainable projection maps
them into d_model and they are prepended to the token stream).

[hf:microsoft/Phi-3-vision-128k-instruct]
32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10000.0,
    num_image_tokens=576,
)
