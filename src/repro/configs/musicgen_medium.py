"""MusicGen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf:facebook/musicgen-medium]
48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048 per codebook, 4 EnCodec
codebooks (embeddings summed, per-codebook logit heads), sinusoidal PE,
GELU FFN, LayerNorm.  EnCodec itself is a stub: inputs are token ids.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    mlp_kind="gelu",
    norm_kind="layernorm",
    pos_kind="sinusoidal",
    rope_pct=0.0,
    num_codebooks=4,
)
