"""The paper's own architecture: 4096-512-2 spiking MLP, 25 time steps
(Fig. 4), LIF neurons with learnable beta/threshold, dropout, optional
5-step refractory period and Q1.15 weights."""

from repro.core.snn import SNNConfig

CONFIG = SNNConfig(
    layer_sizes=(4096, 512, 2),
    num_steps=25,
    neuron_kind="lif",
    reset="zero",
    surrogate="atan",
    refractory_steps=0,
    dropout_rate=0.2,
)

CONFIG_REFRACTORY = SNNConfig(
    layer_sizes=(4096, 512, 2),
    num_steps=25,
    refractory_steps=5,
    dropout_rate=0.2,
)

CONFIG_LAPICQUE = SNNConfig(
    layer_sizes=(4096, 512, 2),
    num_steps=25,
    neuron_kind="lapicque",
    dropout_rate=0.2,
)
