"""IBM Granite 3.0 1B-A400M — fine-grained 32-expert top-8 MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base]
24L d_model=1024 16H GQA(kv=8) d_ff=512/expert vocab=49155, tied embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    num_experts=32,
    num_experts_per_tok=8,
    router_softmax_order="softmax_then_topk",
)
