"""MiniCPM3-4B — dense transformer with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B]
62L d_model=2560 40H d_ff=6400 vocab=73448; MLA: q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v=64; scale_emb=12.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=96,  # qk_nope + qk_rope
    d_ff=6400,
    vocab_size=73448,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10000.0,
    emb_scale=12.0,
    mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
)
