"""Architecture registry: the 10 assigned archs + the paper's own SNN.

Each module exposes CONFIG (a models.config.ModelConfig) with the exact
published numbers; `get(name)` resolves by arch id (dashes ok).
"""

from __future__ import annotations

import importlib
from typing import List

ARCH_IDS: List[str] = [
    "mixtral-8x7b",
    "granite-moe-1b-a400m",
    "mamba2-130m",
    "stablelm-1.6b",
    "codeqwen1.5-7b",
    "yi-34b",
    "minicpm3-4b",
    "recurrentgemma-2b",
    "phi-3-vision-4.2b",
    "musicgen-medium",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get(arch_id: str):
    """Return the ModelConfig for an architecture id."""
    if arch_id in ("collision-snn", "collision_snn"):
        raise ValueError(
            "collision-snn is an SNNConfig; use repro.configs.collision_snn"
        )
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def all_configs():
    return {a: get(a) for a in ARCH_IDS}
