"""Mixtral 8x7B — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf mistralai/Mixtral-8x7B-v0.1]
32L d_model=4096 32H GQA(kv=8) d_ff=14336 vocab=32000, SWA window 4096.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1e6,
    attention_kind="swa",
    window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    router_softmax_order="topk_then_softmax",
)
