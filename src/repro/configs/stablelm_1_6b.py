"""StableLM-2 1.6B — dense MHA with partial rotary and LayerNorm.

[hf:stabilityai/stablelm-2-1_6b]
24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352, rope_pct=0.25,
qkv biases, untied embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    mlp_kind="swiglu",
    norm_kind="layernorm",
    rope_theta=10000.0,
    rope_pct=0.25,
    qkv_bias=True,
)
