"""Event-driven SNN forward pass over AER events.

``core.snn.forward`` computes every layer densely: each step multiplies the
full (fan_in, fan_out) weight matrix regardless of how few inputs spiked.
This runtime implements the paper's actual dataflow: each step extracts the
*events* (active input addresses) and gathers only those weight rows into
the accumulation — work scales with measured spiking activity.

Float semantics match ``core.snn.forward`` (inference mode) up to
accumulation-order rounding: a gathered sum adds the same weight rows a
dense matmul does, in a different order, so outputs agree to float32
tolerance (property-tested on the paper's 4096-512-2 collision config).
The neuron update reuses ``core.neuron.neuron_step`` verbatim.

Every entry point also *measures* per-layer event counts, which feed
``core.energy.snn_ops_from_events`` — replacing the repo's assumed
spike-rate energy model with counted events (the ISSUE's "measured, not
assumed" energy accounting).

State is explicit (``init_states`` / ``run_chunk``) so the streaming
serving engine can carry membrane potentials across request chunks.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import neuron, quant, snn
from repro.events import aer

Array = jax.Array


# --------------------------------------------------------------------------
# Per-step event extraction + gathered synaptic integration
# --------------------------------------------------------------------------


def step_events(x: Array, capacity: int) -> Tuple[Array, Array, Array]:
    """Extract the event list of one spike plane ``x`` (..., K).

    Returns (addrs (..., C) int32, values (..., C) float32, count (...,)
    int32); ``values`` carries the (signed) spike magnitude, 0 on padding.

    O(K + C log K) cumsum-based stable compaction (vs the original
    O(K log K) argsort, kept as ``step_events_argsort`` for oracle and
    baseline-benchmark use): a running count over the plane assigns each
    active position its output slot (its cumsum rank), and because that
    rank sequence is monotone the *inverse* map — which source position
    feeds output slot c — is a vectorized binary search, i.e. a gather.
    Expressing the compaction as a gather instead of the literal
    rank-scatter matters: XLA lowers generic scatters poorly on CPU (and
    serializes them on TPU), while searchsorted + take_along_axis stay
    vectorized on both; measured ~15-25x faster than either the scatter
    or the argsort at the collision config (benchmarks/snn_bench.py).
    At ``capacity`` the list truncates to the *first* ``capacity`` active
    positions — identical truncation semantics to the argsort path
    (property-tested).
    """
    K = x.shape[-1]
    lead = x.shape[:-1]
    active = x != 0
    pos = jnp.cumsum(active.astype(jnp.int32), axis=-1)  # 1-indexed rank
    count = jnp.minimum(pos[..., -1], capacity).astype(jnp.int32)
    R = int(np.prod(lead)) if lead else 1
    # src[c] = first position whose rank reaches c+1 (stable: ascending
    # address order), found by binary search over the monotone ranks
    targets = jnp.arange(1, capacity + 1, dtype=jnp.int32)
    src = jax.vmap(
        lambda p: jnp.searchsorted(p, targets, side="left")
    )(pos.reshape(R, K))
    src = jnp.minimum(src, K - 1).astype(jnp.int32).reshape(*lead, capacity)
    valid = jnp.arange(capacity, dtype=jnp.int32) < count[..., None]
    addrs = jnp.where(valid, src, 0)
    values = jnp.where(valid, jnp.take_along_axis(x, src, axis=-1), 0.0)
    return addrs, values.astype(jnp.float32), count


def step_events_argsort(x: Array, capacity: int) -> Tuple[Array, Array, Array]:
    """Original argsort-compaction event extraction (O(K log K)).

    Kept as the oracle for ``step_events`` and as the PR-2 baseline in
    ``benchmarks/snn_bench.py``; the O(K) scatter above is the hot path.
    """
    active = x != 0
    order = jnp.argsort(~active, axis=-1, stable=True)[..., :capacity]
    count = jnp.minimum(jnp.sum(active, axis=-1), capacity).astype(jnp.int32)
    valid = jnp.arange(capacity, dtype=jnp.int32) < count[..., None]
    addrs = jnp.where(valid, order, 0).astype(jnp.int32)
    values = jnp.where(valid, jnp.take_along_axis(x, order, axis=-1), 0.0)
    return addrs, values.astype(jnp.float32), count


def gather_current(
    w: Array,  # (K, N) float weights
    b: Array,  # (N,) float bias
    addrs: Array,  # (B, C) int32 event addresses
    values: Array,  # (B, C) float event values (0 = padding)
    *,
    chunk: int = 256,
) -> Array:
    """Event-driven synaptic integration: sum of gathered weight rows.

    Processes events in fixed chunks so peak memory is (B, chunk, N)
    regardless of capacity — the jnp mirror of the Pallas
    ``aer_spike_matmul`` E-block loop.
    """
    B, C = addrs.shape
    pad = (-C) % chunk
    if pad:
        addrs = jnp.pad(addrs, ((0, 0), (0, pad)))
        values = jnp.pad(values, ((0, 0), (0, pad)))
    nc = (C + pad) // chunk
    a_chunks = addrs.reshape(B, nc, chunk).transpose(1, 0, 2)
    v_chunks = values.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        a_c, v_c = xs  # (B, chunk)
        rows = jnp.take(w, a_c, axis=0)  # (B, chunk, N)
        return acc + jnp.einsum("bc,bcn->bn", v_c, rows), None

    acc0 = jnp.zeros((B, w.shape[1]), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (a_chunks, v_chunks))
    return acc + b[None, :]


def encode_step_table(
    spikes: Array,  # (..., T, K) dense spike train, integer-valued
    capacity: int,
    *,
    addr_dtype=None,
) -> aer.StepEventTable:
    """Compress a dense spike train into a packed per-step event table.

    One ``step_events`` pass over every step at once (the extraction is
    per-step independent, so slicing the table at step ``d`` is bitwise
    identical to extracting ``spikes[d]`` on the fly — the property the
    serving engine's ring-buffer residency rests on).  Values are stored
    as int8 signed magnitudes: spike trains are integer-valued by
    construction, and the engine validates that at submit.
    """
    addrs, values, counts = step_events(spikes, capacity)
    if addr_dtype is None:
        addr_dtype = aer.addr_dtype_for(spikes.shape[-1])
    # a layer wider than the dtype's range would silently wrap addresses
    # negative at astype(); fail at trace time instead
    aer.check_addr_dtype(spikes.shape[-1], addr_dtype)
    return aer.StepEventTable(
        addrs=addrs.astype(addr_dtype),
        values=values.astype(jnp.int8),
        counts=counts.astype(jnp.int32),
    )


# --------------------------------------------------------------------------
# Stateful chunk runner (shared by event_forward and the serving engine)
# --------------------------------------------------------------------------


def init_states(cfg: snn.SNNConfig, batch: int) -> List[neuron.NeuronState]:
    return [
        neuron.init_state((batch, cfg.layer_sizes[i + 1]))
        for i in range(cfg.num_layers)
    ]


def prepare_params(params, cfg: snn.SNNConfig):
    """One-time parameter preparation for the chunk runtime.

    Applies the config's Q1.15 fake-quantization (a no-op otherwise).  Do
    this once at engine/trainer init and pass ``prepared=True`` to
    ``run_chunk`` — the original hot loop re-quantized the full weight set
    on every chunk execution.
    """
    if not cfg.quant_q115:
        return params
    return {
        name: {
            **lp,
            "w": quant.fake_quant(lp["w"], quant.Q1_15),
            "b": quant.fake_quant(lp["b"], quant.Q1_15),
        }
        for name, lp in params.items()
    }


# backward-compatible alias (pre-overhaul name)
_maybe_quant = prepare_params


def run_chunk(
    params: Dict[str, Dict[str, Array]],
    states: List[neuron.NeuronState],
    spikes: Array,  # (Tc, B, K) input spike planes for this chunk
    cfg: snn.SNNConfig,
    *,
    active: Optional[Array] = None,  # (B,) mask; inactive rows are frozen
    capacities: Optional[Sequence[int]] = None,  # per-layer event caps
    prepared: bool = False,  # params already through prepare_params
    backend: str = "jnp",  # "jnp" | "fused" | "auto"
    interpret: Optional[bool] = None,  # fused path: force interpret mode
) -> Tuple[List[neuron.NeuronState], Array, Array, Array]:
    """Advance the network ``Tc`` steps event-drivenly.

    Returns (new_states, out_mem (Tc, B, C), out_spikes (Tc, B, C),
    events (Tc, n_layers, B) — measured input-event count per layer and
    step, so callers can attribute events to requests that finish
    mid-chunk).

    ``active`` freezes finished batch slots: their inputs are silenced and
    their membrane state is held, so one compiled chunk serves a partially
    filled micro-batch (continuous batching).

    ``capacities`` bounds each layer's per-step event list (default: full
    fan-in, no truncation).  Tuned capacities (``events.capacity``) shrink
    the gather loop to the measured activity envelope.

    ``backend`` selects the hot path: ``"jnp"`` is the scan-of-gathers
    oracle, ``"fused"`` the single-invocation Pallas chunk kernel
    (``kernels.snn_chunk``), and ``"auto"`` picks fused on TPU and jnp on
    CPU (where the fused kernel would run interpreted).  The fused path
    applies ``capacities[0]`` to the input event list; hidden layers run
    as gated in-VMEM matvecs and never truncate.

    Layer-0 events are extracted *once* for the whole chunk (vectorized
    over steps — ``step_events`` is per-step independent) and handed to
    ``run_chunk_events``; callers that already hold packed event tables
    (the device-resident serving engine) skip this entry point entirely.
    """
    B = spikes.shape[1]
    p = params if prepared else prepare_params(params, cfg)
    act = (
        jnp.ones((B,), jnp.float32)
        if active is None
        else active.astype(jnp.float32)
    )
    caps = _resolve_capacities(cfg, capacities)
    # silence frozen slots before extraction so their (ignored) event
    # tables cost nothing downstream and counts match across backends
    addrs, values, counts = step_events(
        spikes * act[None, :, None], caps[0]
    )
    return run_chunk_events(
        p,
        states,
        addrs,
        values,
        counts,
        cfg,
        active=act,
        capacities=caps,
        prepared=True,
        backend=backend,
        interpret=interpret,
    )


def run_chunk_events(
    params: Dict[str, Dict[str, Array]],
    states: List[neuron.NeuronState],
    addrs: Array,  # (Tc, B, C) int — layer-0 event addresses, valid-first
    values: Array,  # (Tc, B, C) — signed event values (0 = padding)
    counts: Array,  # (Tc, B) int — valid events per step
    cfg: snn.SNNConfig,
    *,
    active: Optional[Array] = None,  # (B,) mask; inactive rows are frozen
    capacities: Optional[Sequence[int]] = None,
    prepared: bool = False,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    layout: str = "time_major",  # "time_major" (Tc,B,C) | "slot_major" (B,Tc,C)
) -> Tuple[List[neuron.NeuronState], Array, Array, Array]:
    """``run_chunk`` over a *pre-extracted* layer-0 event table.

    The serving hot path: the engine stages each request's events in a
    device-resident ring at admission and slices the next ``Tc`` steps per
    chunk — this entry consumes those slices directly instead of
    re-running ``step_events`` on a dense layer-0 plane every chunk.
    Event lists must be packed valid-first with zero values on padding
    (what ``step_events``/``encode_step_table`` produce), already
    truncated to ``capacities[0]``, and silenced (zero values/counts) on
    frozen or out-of-window steps.  ``layout="slot_major"`` accepts the
    ring's native (B, Tc, C) layout without a host-side transpose.

    Returns the ``run_chunk`` tuple: (new_states, out_mem, out_spikes,
    events (Tc, n_layers, B)).
    """
    ncfg = cfg.neuron_cfg
    p = params if prepared else prepare_params(params, cfg)
    n_layers = cfg.num_layers
    if layout == "slot_major":
        B = addrs.shape[0]
    elif layout == "time_major":
        B = addrs.shape[1]
    else:
        raise ValueError(f"unknown event layout {layout!r}")
    act = (
        jnp.ones((B,), jnp.float32)
        if active is None
        else active.astype(jnp.float32)
    )
    caps = _resolve_capacities(cfg, capacities)

    if backend == "auto":
        from repro.kernels import ops as _ops

        backend = "fused" if _ops.on_tpu() else "jnp"
    if backend == "fused":
        return _run_chunk_fused(
            p, states, addrs, values, counts, cfg, act, caps, interpret,
            layout=layout,
        )
    if backend != "jnp":
        raise ValueError(f"unknown run_chunk backend {backend!r}")

    if layout == "slot_major":
        addrs = jnp.swapaxes(addrs, 0, 1)
        values = jnp.swapaxes(values, 0, 1)
        counts = jnp.swapaxes(counts, 0, 1)

    def step(states, xs):
        a_t, v_t, c_t = xs
        new_states, ev_t = [], []
        h = None
        for i in range(n_layers):
            lp = p[f"layer{i}"]
            if i == 0:
                cur = gather_current(
                    lp["w"], lp["b"], a_t.astype(jnp.int32),
                    v_t.astype(jnp.float32),
                )
                count = c_t.astype(jnp.float32)
            else:
                a_i, v_i, c_i = step_events(h, caps[i])
                cur = gather_current(lp["w"], lp["b"], a_i, v_i)
                count = c_i.astype(jnp.float32)
            st, spk = neuron.neuron_step(
                ncfg,
                states[i],
                cur,
                beta=snn.effective_beta(lp),
                threshold=lp["threshold"],
            )
            # frozen slots keep their previous membrane/refractory state
            st = neuron.NeuronState(
                u=jnp.where(act[:, None] > 0, st.u, states[i].u),
                refrac=jnp.where(
                    act[:, None] > 0, st.refrac, states[i].refrac
                ),
            )
            spk = spk * act[:, None]
            new_states.append(st)
            ev_t.append(count)
            h = spk
        out_mem_t = new_states[-1].u
        return tuple(new_states), (out_mem_t, h, jnp.stack(ev_t))

    fin_states, (out_mem, out_spikes, events) = jax.lax.scan(
        step, tuple(states), (addrs, values, counts)
    )
    return list(fin_states), out_mem, out_spikes, events


def _resolve_capacities(
    cfg: snn.SNNConfig, capacities: Optional[Sequence[int]]
) -> List[int]:
    if capacities is None:
        return [int(cfg.layer_sizes[i]) for i in range(cfg.num_layers)]
    caps = [int(c) for c in capacities]
    if len(caps) != cfg.num_layers:
        raise ValueError(
            f"capacities has {len(caps)} entries for {cfg.num_layers} layers"
        )
    if any(c < 1 for c in caps):
        raise ValueError(f"capacities must be >= 1, got {caps}")
    return caps


def _run_chunk_fused(
    p, states, addrs, values, counts, cfg: snn.SNNConfig, act, caps,
    interpret, *, layout: str = "time_major",
):
    """Dispatch one chunk to the fused Pallas kernel.

    The kernel consumes packed valid-first event tables via scalar
    prefetch — exactly the staged format, so no extraction happens here.
    """
    from repro.kernels import ops

    ncfg = cfg.neuron_cfg
    L = cfg.num_layers
    # the fused kernel truncates only the input event list (capacities[0]);
    # hidden layers run as dense in-VMEM matvecs.  A truncating hidden
    # capacity would make fused and jnp return different outputs for the
    # same arguments — and backend="auto" platform-dependent — so reject
    # it loudly instead of diverging silently.
    for i in range(1, L):
        if caps[i] < cfg.layer_sizes[i]:
            raise ValueError(
                f"backend='fused' cannot truncate hidden layers: "
                f"capacities[{i}]={caps[i]} < fan-in {cfg.layer_sizes[i]}. "
                f"Use full fan-in hidden capacities (autotune(..., "
                f"tune_hidden=False)) or backend='jnp'."
            )
    layers = [p[f"layer{i}"] for i in range(L)]
    mem, spk, events, u_fin, r_fin = ops.snn_chunk(
        tuple(lp["w"] for lp in layers),
        tuple(lp["b"] for lp in layers),
        tuple(snn.effective_beta(lp) for lp in layers),
        tuple(lp["threshold"] for lp in layers),
        tuple(st.u for st in states),
        tuple(st.refrac for st in states),
        addrs,
        values,
        counts,
        act,
        refractory_steps=ncfg.refractory_steps,
        reset=ncfg.reset,
        kind=ncfg.kind,
        lapicque_gain=ncfg.lapicque_gain,
        interpret=interpret,
        layout=layout,
    )
    new_states = [
        neuron.NeuronState(u=u, refrac=r) for u, r in zip(u_fin, r_fin)
    ]
    return new_states, mem, spk, events


# --------------------------------------------------------------------------
# Whole-window forward passes
# --------------------------------------------------------------------------


def event_forward(
    params: Dict[str, Dict[str, Array]],
    spikes: Array,  # (T, B, K) in {0,1}
    cfg: snn.SNNConfig,
    *,
    capacities: Optional[Sequence[int]] = None,
    prepared: bool = False,
    backend: str = "jnp",
) -> Tuple[Array, Array, Array]:
    """Event-driven analog of ``core.snn.forward`` (inference mode).

    Returns (out_mem (T,B,C), out_spikes (T,B,C), events (n_layers, B)).
    Outputs match the dense forward to float32 tolerance; ``events`` are
    the *measured* per-layer input-event counts of this window.
    """
    states = init_states(cfg, spikes.shape[1])
    _, out_mem, out_spikes, events = run_chunk(
        params,
        states,
        spikes,
        cfg,
        capacities=capacities,
        prepared=prepared,
        backend=backend,
    )
    return out_mem, out_spikes, jnp.sum(events, axis=0)


def event_forward_aer(
    params: Dict[str, Dict[str, Array]],
    stream: aer.EventStream,  # batch dims (B,), addresses over layer_sizes[0]
    cfg: snn.SNNConfig,
    *,
    num_steps: Optional[int] = None,
) -> Tuple[Array, Array, Array]:
    """Run the SNN directly on an AER input stream (e.g. DVS events).

    The input layer never materializes a dense plane: each step's events
    are sliced out of the time-sorted stream and gathered straight into
    the synaptic integration (polarity-signed).  Hidden layers proceed as
    in ``event_forward``.
    """
    T = num_steps if num_steps is not None else cfg.num_steps
    ncfg = cfg.neuron_cfg
    p = prepare_params(params, cfg)
    n_layers = cfg.num_layers
    B, E = stream.times.shape

    # per-row event ranges of every step: boundaries (B, T+1)
    steps = jnp.arange(T + 1, dtype=jnp.int32)
    boundaries = jax.vmap(
        lambda tr: jnp.searchsorted(tr, steps, side="left")
    )(stream.times).astype(jnp.int32)

    states = init_states(cfg, B)
    offs = jnp.arange(E, dtype=jnp.int32)

    def step(carry, t):
        states, ev = carry
        start, end = boundaries[:, t], boundaries[:, t + 1]
        # mask by polarity != 0 on top of the window: padding slots carry
        # polarity 0, and while canonical pads sit at time
        # num_steps_at_encode (outside every window), merge() without
        # num_steps stamps pads at max(times)+1 — which for a stream
        # shorter than T lands *inside* [0, T).  An end-start count would
        # then bill padding as events, inflating measured events/energy.
        valid = (
            (offs[None, :] >= start[:, None])
            & (offs[None, :] < end[:, None])
            & (stream.polarity != 0)
        )
        addrs = jnp.where(valid, stream.addrs, 0)
        values = jnp.where(valid, stream.polarity.astype(jnp.float32), 0.0)
        new_states, new_ev = [], []
        lp = p["layer0"]
        cur = gather_current(lp["w"], lp["b"], addrs, values)
        count = jnp.sum(valid, axis=-1).astype(jnp.float32)
        h = None
        for i in range(n_layers):
            lp = p[f"layer{i}"]
            if i > 0:
                addrs, vals, cnt = step_events(h, cfg.layer_sizes[i])
                cur = gather_current(lp["w"], lp["b"], addrs, vals)
                count = cnt.astype(jnp.float32)
            st, spk = neuron.neuron_step(
                ncfg,
                states[i],
                cur,
                beta=snn.effective_beta(lp),
                threshold=lp["threshold"],
            )
            new_states.append(st)
            new_ev.append(ev[i] + count)
            h = spk
        return (tuple(new_states), tuple(new_ev)), (new_states[-1].u, h)

    ev0 = tuple(jnp.zeros((B,), jnp.float32) for _ in range(n_layers))
    (_, fin_ev), (out_mem, out_spikes) = jax.lax.scan(
        step, (tuple(states), ev0), jnp.arange(T)
    )
    return out_mem, out_spikes, jnp.stack(fin_ev)


def predict_events(
    params, spikes: Array, cfg: snn.SNNConfig
) -> Tuple[Array, Array]:
    """Spike-count argmax prediction + measured events, event-driven path."""
    out_mem, out_spikes, events = event_forward(params, spikes, cfg)
    return snn.predict_from_traces(out_mem, out_spikes), events
