"""Event-driven Address-Event Representation (AER) subsystem.

The paper's energy win comes from touching only *active* synapses; this
package makes that dataflow real instead of analytic:

- ``aer``:     fixed-capacity AER event tensors, dense<->AER converters,
               stream merging, and a synthetic DVS event-camera generator
               for the collision-avoidance scenario.
- ``runtime``: event-driven SNN forward (gathers only active weight rows)
               that matches ``core.snn.forward`` to float tolerance and
               reports *measured* per-layer event counts for the energy
               model; dispatches to the fused Pallas chunk kernel
               (``kernels.snn_chunk``) via ``backend=``.
- ``capacity``: event-list capacity autotuning from measured spike-count
               percentiles, with a truncation/accuracy trade-off report.
"""

from repro.events import aer, capacity, runtime

__all__ = ["aer", "capacity", "runtime"]
