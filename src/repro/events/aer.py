"""Address-Event Representation (AER) spike tensors.

Neuromorphic hardware (and the paper's event-driven FPGA datapath) does not
move dense activation planes around — it moves *events*: (time, address)
pairs emitted only when a neuron/pixel actually fires.  This module gives
the repo a jit-able AER format:

- ``EventStream``: fixed-capacity event tensors ``(times, addrs, polarity,
  count)``.  Fixed capacity keeps every shape static so streams compose
  with jit/vmap/scan; ``count`` marks how many leading events are valid.
- ``dense_to_aer`` / ``aer_to_dense``: lossless round-trip whenever the
  capacity covers the number of active entries; on overflow the *earliest*
  events (time-major order) are kept and the tail is truncated.
- ``merge``: time-ordered merge of two streams over one address space.
- ``dvs_collision_stream``: a synthetic DVS event camera for the paper's
  collision-avoidance scenario — an obstacle approaching (collision) or
  passing laterally (no collision) rendered as brightness-change events.

Padding convention (canonical, relied on by ``events.runtime``):
invalid slots have ``times == num_steps_used_at_encode`` (i.e. strictly
after every valid event), ``addrs == 0`` and ``polarity == 0``, and valid
events are sorted by (time, address-scan order) ascending.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import coding

Array = jax.Array


class EventStream(NamedTuple):
    """Fixed-capacity AER event tensor with optional leading batch dims.

    times:    (..., E) int32 — time step of each event
    addrs:    (..., E) int32 — flattened neuron / pixel address
    polarity: (..., E) int8  — +1 / -1 event sign (0 on padding)
    count:    (...,)   int32 — number of valid leading events (<= E)
    """

    times: Array
    addrs: Array
    polarity: Array
    count: Array

    @property
    def capacity(self) -> int:
        return self.times.shape[-1]

    @property
    def batch_shape(self) -> Tuple[int, ...]:
        return self.times.shape[:-1]


def dense_to_aer(spikes: Array, capacity: int) -> EventStream:
    """Convert a dense spike train (T, ..., N) into an AER stream.

    Events are ordered time-major (all step-0 events before step-1, in
    address order within a step).  If more than ``capacity`` entries are
    active, the earliest ``capacity`` events are kept — a real AER bus
    back-pressures exactly this way (later events are the ones dropped).
    """
    T, N = spikes.shape[0], spikes.shape[-1]
    batch_shape = spikes.shape[1:-1]
    # (batch..., T*N), time-major flattening
    x = jnp.moveaxis(spikes, 0, -2).reshape(batch_shape + (T * N,))
    active = x != 0
    # stable sort: active entries first, original (time-major) order kept
    order = jnp.argsort(~active, axis=-1, stable=True)
    # capacity may exceed T*N (headroom): gather what exists, pad the rest
    take = min(capacity, T * N)
    flat_idx = order[..., :take]
    n_active = jnp.sum(active, axis=-1).astype(jnp.int32)
    count = jnp.minimum(n_active, capacity)
    valid = jnp.arange(take, dtype=jnp.int32) < count[..., None]
    times = jnp.where(valid, flat_idx // N, T).astype(jnp.int32)
    addrs = jnp.where(valid, flat_idx % N, 0).astype(jnp.int32)
    pol = jnp.take_along_axis(x, flat_idx, axis=-1)
    polarity = jnp.where(valid, jnp.sign(pol), 0).astype(jnp.int8)
    if capacity > take:
        pad = ((0, 0),) * (times.ndim - 1) + ((0, capacity - take),)
        times = jnp.pad(times, pad, constant_values=T)
        addrs = jnp.pad(addrs, pad)
        polarity = jnp.pad(polarity, pad)
    return EventStream(times=times, addrs=addrs, polarity=polarity, count=count)


def aer_to_dense(stream: EventStream, num_steps: int, num_addrs: int) -> Array:
    """Scatter an AER stream back to a dense (T, ..., N) float32 train."""
    E = stream.capacity
    batch_shape = stream.batch_shape
    nb = 1
    for d in batch_shape:
        nb *= d
    times = stream.times.reshape(nb, E)
    addrs = stream.addrs.reshape(nb, E)
    pol = stream.polarity.reshape(nb, E)
    count = stream.count.reshape(nb)

    def row(t, a, p, c):
        valid = jnp.arange(E, dtype=jnp.int32) < c
        # out-of-range index on padding -> dropped by the scatter
        idx = jnp.where(valid, t * num_addrs + a, num_steps * num_addrs)
        flat = jnp.zeros((num_steps * num_addrs,), jnp.float32)
        return flat.at[idx].add(p.astype(jnp.float32), mode="drop")

    dense = jax.vmap(row)(times, addrs, pol, count)
    dense = dense.reshape(batch_shape + (num_steps, num_addrs))
    return jnp.moveaxis(dense, -2, 0)


def merge(
    a: EventStream,
    b: EventStream,
    *,
    num_addrs: int,
    capacity: int,
    num_steps: Optional[int] = None,
) -> EventStream:
    """Time-ordered merge of two streams over the same address space.

    Keeps the earliest ``capacity`` events of the union (AER bus arbiter
    semantics); ``capacity`` may exceed the combined input capacity to
    leave headroom for later merges.  Both inputs must follow the
    canonical padding convention.  Pass ``num_steps`` (the T both streams
    were encoded with) to stamp padding slots canonically; without it the
    pad time falls back to one past the latest observed time, which still
    sorts strictly after every valid event.
    """
    times = jnp.concatenate([a.times, b.times], axis=-1)
    addrs = jnp.concatenate([a.addrs, b.addrs], axis=-1)
    pol = jnp.concatenate([a.polarity, b.polarity], axis=-1)
    # padding (times == T_pad, addrs == 0) sorts after every valid event
    key = times * num_addrs + addrs
    take = min(capacity, times.shape[-1])
    order = jnp.argsort(key, axis=-1, stable=True)[..., :take]
    count = jnp.minimum(a.count + b.count, capacity)
    valid = jnp.arange(capacity, dtype=jnp.int32) < count[..., None]
    out_t = jnp.take_along_axis(times, order, axis=-1)
    out_a = jnp.take_along_axis(addrs, order, axis=-1)
    out_p = jnp.take_along_axis(pol, order, axis=-1)
    if capacity > take:
        pad = ((0, 0),) * (out_t.ndim - 1) + ((0, capacity - take),)
        out_t, out_a, out_p = (jnp.pad(x, pad) for x in (out_t, out_a, out_p))
    if num_steps is not None:
        pad_t = jnp.full(times.shape[:-1] + (1,), num_steps, jnp.int32)
    else:
        pad_t = jnp.max(times, axis=-1, keepdims=True) + 1
    return EventStream(
        times=jnp.where(valid, out_t, pad_t).astype(jnp.int32),
        addrs=jnp.where(valid, out_a, 0).astype(jnp.int32),
        polarity=jnp.where(valid, out_p, 0).astype(jnp.int8),
        count=count.astype(jnp.int32),
    )


# --------------------------------------------------------------------------
# Packed per-step event tables (device-resident staging format)
# --------------------------------------------------------------------------


class StepEventTable(NamedTuple):
    """Packed per-step AER event lists of a dense spike train.

    Where ``EventStream`` is one flat time-sorted list (the bus/wire
    format), this is the *compute-staged* layout the chunk runtime
    consumes: one fixed-capacity, valid-first event list per time step, so
    a ``dynamic_slice`` over the step axis yields a chunk's worth of
    ready-to-gather events with no re-extraction and no densification.
    The serving engine stages every admitted request in this format, in
    device memory, for the request's whole lifetime.

    addrs:  (..., T, C) int16/int32 — event addresses, packed valid-first
            (int16 when the address space fits: ~5x smaller than the
            dense float32 plane at the collision config's autotuned
            capacity, tighter still under lower-percentile plans)
    values: (..., T, C) int8 — signed spike magnitudes (0 on padding).
            Spike trains are integer-valued by construction ({0,1} rate /
            TTFS codes, {-1,0,1} delta/DVS polarities).
    counts: (..., T) int32 — valid events per step
    """

    addrs: Array
    values: Array
    counts: Array

    @property
    def capacity(self) -> int:
        return self.addrs.shape[-1]

    @property
    def num_steps(self) -> int:
        return self.addrs.shape[-2]


def addr_dtype_for(num_addrs: int):
    """Narrowest integer dtype that can index ``num_addrs`` addresses."""
    return jnp.int16 if num_addrs <= jnp.iinfo(jnp.int16).max else jnp.int32


def check_addr_dtype(num_addrs: int, addr_dtype) -> None:
    """Raise (loudly, at trace/build time) if ``addr_dtype`` cannot index
    ``num_addrs`` addresses.

    ``astype(int16)`` on out-of-range addresses silently wraps to
    negative — a table built that way scatters events to the wrong
    neurons with no error anywhere downstream.  Every packing path must
    call this before narrowing.
    """
    info = jnp.iinfo(addr_dtype)
    if num_addrs - 1 > int(info.max):
        raise ValueError(
            f"address dtype {jnp.dtype(addr_dtype).name} cannot index "
            f"{num_addrs} addresses (max {int(info.max) + 1}): int16 AER "
            "tables silently wrap — use addr_dtype_for(num_addrs) or int32"
        )


def step_table_to_dense(table: StepEventTable, num_addrs: int) -> Array:
    """Scatter a per-step event table back to a dense (..., T, N) train.

    Test/debug inverse of ``events.runtime.encode_step_table``; lossless
    whenever the capacity covered each step's events at encode time.
    """
    C = table.capacity
    valid = (
        jnp.arange(C, dtype=jnp.int32) < table.counts[..., None]
    )
    idx = jnp.where(valid, table.addrs.astype(jnp.int32), num_addrs)
    vals = jnp.where(valid, table.values.astype(jnp.float32), 0.0)
    lead = table.addrs.shape[:-1]
    flat_idx = idx.reshape(-1, C)
    flat_val = vals.reshape(-1, C)

    def row(i, v):
        return jnp.zeros((num_addrs,), jnp.float32).at[i].add(
            v, mode="drop"
        )

    dense = jax.vmap(row)(flat_idx, flat_val)
    return dense.reshape(lead + (num_addrs,))


# --------------------------------------------------------------------------
# Polarity-aware input planes (ON/OFF channels of a DVS stream)
# --------------------------------------------------------------------------

POLARITY_MODES = ("two_channel", "signed", "on_only")


def input_size_for(num_addrs: int, polarity_mode: str) -> int:
    """Input-layer fan-in required for a stream over ``num_addrs`` pixels."""
    if polarity_mode not in POLARITY_MODES:
        raise ValueError(
            f"unknown polarity mode {polarity_mode!r}; have {POLARITY_MODES}"
        )
    return 2 * num_addrs if polarity_mode == "two_channel" else num_addrs


def input_planes(
    stream: EventStream,
    num_steps: int,
    num_addrs: int,
    *,
    polarity_mode: str = "two_channel",
) -> Array:
    """Densify an AER stream into SNN input spike planes, polarity-aware.

    DVS events carry a sign (brightness up / down).  The paper's input
    layer consumes unsigned {0,1} spikes, which throws OFF events away;
    this maps both polarities onto the input weights instead:

    - ``"two_channel"``: (T, ..., 2*num_addrs) — ON events spike channel
      block [0, K), OFF events spike [K, 2K).  Each channel gets its own
      weight rows (the snntorch/DvsGesture convention), so the first layer
      learns separate responses to brightening and darkening edges.
    - ``"signed"``: (T, ..., num_addrs) spikes in {-1, 0, +1} — polarity
      rides on the event value through the shared weight row (signed
      synaptic current, the AER-bus-faithful single-wire form; coincident
      ON+OFF at one pixel/step sum to net-zero current, as the shared
      wire physically would).
    - ``"on_only"``: (T, ..., num_addrs) in {0,1} — ON events only, the
      PR-1 serving behavior (kept for comparison).

    Channel modes densify each polarity *separately* (coincident ON+OFF
    events at one pixel/step — e.g. after ``merge`` of two recordings —
    land in both channels instead of cancelling), and clip duplicate
    events to unit magnitude so the planes stay valid spike trains.
    """
    if polarity_mode not in POLARITY_MODES:
        raise ValueError(
            f"unknown polarity mode {polarity_mode!r}; have {POLARITY_MODES}"
        )
    if polarity_mode == "signed":
        dense = aer_to_dense(stream, num_steps, num_addrs)  # signed counts
        return jnp.clip(dense, -1.0, 1.0)
    on_dense = aer_to_dense(
        stream._replace(polarity=jnp.maximum(stream.polarity, 0)),
        num_steps, num_addrs,
    )
    on = jnp.clip(on_dense, 0.0, 1.0)
    if polarity_mode == "on_only":
        return on
    off_dense = aer_to_dense(
        stream._replace(polarity=jnp.minimum(stream.polarity, 0)),
        num_steps, num_addrs,
    )
    off = jnp.clip(-off_dense, 0.0, 1.0)
    return jnp.concatenate([on, off], axis=-1)


# --------------------------------------------------------------------------
# Synthetic DVS event camera for the collision-avoidance scenario
# --------------------------------------------------------------------------


def _render_frames(
    key: jax.Array, image_hw: int, num_steps: int, label: Array
) -> Array:
    """(T, hw, hw) grayscale frames: obstacle approaching (label 1) or
    passing laterally far from center (label 0)."""
    hw, T = image_hw, num_steps
    k1, k2, k3 = jax.random.split(key, 3)
    yy, xx = jnp.mgrid[0:hw, 0:hw]
    t = jnp.arange(T, dtype=jnp.float32)[:, None, None]
    bg = 0.35 + 0.4 * (yy / hw)  # graded ground plane

    cy = hw * jax.random.uniform(k1, minval=0.5, maxval=0.7)
    # collision: centered obstacle growing as it approaches
    cx_c = hw * (0.5 + 0.2 * (jax.random.uniform(k2) - 0.5))
    size_c = hw * (0.06 + 0.30 * t / T)
    # no collision: small obstacle translating across the periphery
    x0 = hw * jax.random.uniform(k3, minval=0.05, maxval=0.25)
    cx_n = x0 + (hw * 0.6) * t / T
    size_n = jnp.full_like(t, hw * 0.05)

    cx = jnp.where(label == 1, cx_c, cx_n)
    size = jnp.where(label == 1, size_c, size_n)
    obstacle = (jnp.abs(xx[None] - cx) < size) & (
        jnp.abs(yy[None] - cy) < size * 1.2
    )
    return jnp.where(obstacle, 0.08, bg[None]).astype(jnp.float32)


def dvs_collision_stream(
    key: jax.Array,
    *,
    image_hw: int = 64,
    num_steps: int = 25,
    capacity: int = 2048,
    delta_threshold: float = 0.1,
) -> Tuple[EventStream, Array]:
    """One synthetic DVS recording: brightness-change events of a moving
    obstacle, plus its collision / no-collision label.

    Returns (stream over ``image_hw**2`` pixel addresses, scalar label).
    Frame 0 is emitted in full (every DVS dump starts with the reference
    frame's delta against black), then only changes spike — the event count
    therefore *measures* scene motion, which is what makes the
    event-driven path cheap on mostly-static scenes.
    """
    k_label, k_scene = jax.random.split(key)
    label = jax.random.bernoulli(k_label, 0.5).astype(jnp.int32)
    frames = _render_frames(k_scene, image_hw, num_steps, label)
    flat = frames.reshape(num_steps, image_hw * image_hw)
    spikes = coding.delta_encode(flat, threshold=delta_threshold)
    return dense_to_aer(spikes, capacity), label


def dvs_collision_batch(
    key: jax.Array,
    batch: int,
    *,
    image_hw: int = 64,
    num_steps: int = 25,
    capacity: int = 2048,
    delta_threshold: float = 0.1,
) -> Tuple[EventStream, Array]:
    """vmap'd batch of DVS recordings: stream with (B,) batch dim, (B,) labels."""
    keys = jax.random.split(key, batch)
    fn = lambda k: dvs_collision_stream(
        k,
        image_hw=image_hw,
        num_steps=num_steps,
        capacity=capacity,
        delta_threshold=delta_threshold,
    )
    return jax.vmap(fn)(keys)
