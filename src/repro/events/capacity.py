"""Event-capacity autotuning from measured spike-rate percentiles.

The event hot path allocates a fixed per-step event-list capacity per
layer.  The safe default — full fan-in — makes the gather loop pay for
silence: at the paper's ~10-15% input rates, >85% of a 4096-slot list is
padding that every chunk still walks (jnp path) or prefetches (fused
path).  The ROADMAP's open item: *truncate the per-step event list below
fan-in and measure the accuracy/energy trade-off*.

This module picks capacities from **measured** per-step event counts:

  1. ``measure_step_counts`` runs the event-driven chunk path over a
     representative sample and collects every (step, batch-row) event
     count per layer — the actual activity distribution, not an assumed
     rate.
  2. ``autotune`` sets each layer's capacity to a percentile of that
     distribution times a safety factor, aligned up to the kernel's
     E-block size (so gating granularity is never wasted) and clipped to
     fan-in.  The returned ``CapacityPlan`` carries the observed
     distribution tails and the implied truncation exposure.
  3. ``truncation_report`` quantifies the trade: it replays the sample at
     the tuned capacities vs. untruncated and reports prediction
     agreement, output drift, and the fraction of events dropped.

At ``percentile=100`` with ``safety > 1`` the plan is lossless on the
sample (zero truncation) and still typically 5-8x below fan-in — pure
speedup.  Lower percentiles trade accuracy for energy explicitly, with
the report as evidence.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import snn
from repro.events import runtime

__all__ = [
    "CapacityPlan",
    "input_capacity",
    "measure_step_counts",
    "autotune",
    "truncation_report",
]


def input_capacity(
    cfg: snn.SNNConfig, capacities: Optional[Sequence[int]] = None
) -> int:
    """Layer-0 per-step event-list capacity for staging resident inputs.

    The serving engine's device ring buffers are sized by this: the tuned
    layer-0 capacity when a plan is in force (``CapacityPlan.capacities``
    or an explicit tuple), full fan-in otherwise.  Validated the same way
    ``runtime.run_chunk`` validates its ``capacities`` argument, so a
    plan that would be rejected at chunk time fails at engine init
    instead.
    """
    return runtime._resolve_capacities(cfg, capacities)[0]


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """Per-layer event-list capacities + the evidence they rest on."""

    capacities: Tuple[int, ...]  # chosen per-layer capacity
    fan_in: Tuple[int, ...]  # layer fan-in (the untuned default)
    percentile: float
    safety: float
    align: int
    max_count: Tuple[int, ...]  # observed max per-step count
    pct_count: Tuple[float, ...]  # observed count at `percentile`
    # fraction of (step, row) event lists that would exceed capacity
    truncated_lists_frac: Tuple[float, ...]
    # fraction of total events that would be dropped
    dropped_events_frac: Tuple[float, ...]

    @property
    def shrink(self) -> Tuple[float, ...]:
        """Capacity reduction vs fan-in, per layer (e.g. 6.4 = 6.4x)."""
        return tuple(
            f / c if c else float("nan")
            for f, c in zip(self.fan_in, self.capacities)
        )

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["shrink"] = list(self.shrink)
        return d


def measure_step_counts(
    params,
    cfg: snn.SNNConfig,
    spikes,  # (T, B, K) representative sample
    *,
    prepared: bool = False,
) -> np.ndarray:
    """Measured per-step, per-row event counts: (n_layers, T*B) int."""
    states = runtime.init_states(cfg, spikes.shape[1])
    _, _, _, events = runtime.run_chunk(
        params, states, jnp.asarray(spikes), cfg, prepared=prepared
    )
    ev = np.asarray(events)  # (T, L, B)
    return ev.transpose(1, 0, 2).reshape(ev.shape[1], -1)


def autotune(
    params,
    cfg: snn.SNNConfig,
    spikes,  # (T, B, K) representative sample
    *,
    percentile: float = 100.0,
    safety: float = 1.25,
    align: int = 128,
    prepared: bool = False,
    tune_hidden: bool = False,
    counts: Optional[np.ndarray] = None,  # reuse a prior measurement
) -> CapacityPlan:
    """Pick per-layer capacities from measured spike-count percentiles.

    ``tune_hidden=False`` (default) pins hidden-layer capacities at full
    fan-in so the plan is valid for every ``run_chunk`` backend: the
    fused kernel computes hidden layers as dense in-VMEM matvecs and
    rejects truncating hidden capacities rather than silently diverging
    from the jnp path.  Layer 0 — the widest layer, ~99% of the gather
    work on the paper's 4096-512-2 config — is always tuned.  Set
    ``tune_hidden=True`` for jnp-only deployments that want hidden
    truncation too.
    """
    if counts is None:
        counts = measure_step_counts(params, cfg, spikes, prepared=prepared)
    caps, maxes, pcts, trunc, dropped = [], [], [], [], []
    for i in range(cfg.num_layers):
        fan_in = int(cfg.layer_sizes[i])
        c_i = counts[i]
        p = float(np.percentile(c_i, percentile)) if c_i.size else 0.0
        if i > 0 and not tune_hidden:
            cap = fan_in
        else:
            cap = int(math.ceil(p * safety))
            cap = max(
                align, int(math.ceil(cap / max(align, 1)) * max(align, 1))
            )
            cap = min(cap, fan_in)
        caps.append(cap)
        maxes.append(int(c_i.max()) if c_i.size else 0)
        pcts.append(p)
        trunc.append(float(np.mean(c_i > cap)) if c_i.size else 0.0)
        total = float(c_i.sum())
        dropped.append(
            float(np.maximum(c_i - cap, 0).sum()) / total if total else 0.0
        )
    return CapacityPlan(
        capacities=tuple(caps),
        fan_in=tuple(int(s) for s in cfg.layer_sizes[:-1]),
        percentile=float(percentile),
        safety=float(safety),
        align=int(align),
        max_count=tuple(maxes),
        pct_count=tuple(pcts),
        truncated_lists_frac=tuple(trunc),
        dropped_events_frac=tuple(dropped),
    )


def truncation_report(
    params,
    cfg: snn.SNNConfig,
    spikes,  # (T, B, K) evaluation sample
    plan: CapacityPlan,
    *,
    prepared: bool = False,
    backend: str = "jnp",
) -> Dict:
    """Measure what the tuned capacities actually cost on a sample.

    Replays the window untruncated and at ``plan.capacities`` and compares
    predictions, output membrane drift, and measured event totals.
    """
    full_m, full_s, full_ev = runtime.event_forward(
        params, spikes, cfg, prepared=prepared, backend=backend
    )
    trunc_m, trunc_s, trunc_ev = runtime.event_forward(
        params,
        spikes,
        cfg,
        capacities=plan.capacities,
        prepared=prepared,
        backend=backend,
    )
    pred_full = np.asarray(snn.predict_from_traces(full_m, full_s))
    pred_trunc = np.asarray(snn.predict_from_traces(trunc_m, trunc_s))
    ev_full = float(np.asarray(full_ev).sum())
    ev_trunc = float(np.asarray(trunc_ev).sum())
    return {
        "capacities": list(plan.capacities),
        "pred_agreement": float(np.mean(pred_full == pred_trunc)),
        "out_mem_max_abs_diff": float(
            np.max(np.abs(np.asarray(trunc_m) - np.asarray(full_m)))
        ),
        "out_spike_count_max_abs_diff": float(
            np.max(
                np.abs(
                    np.asarray(trunc_s).sum(0) - np.asarray(full_s).sum(0)
                )
            )
        ),
        "events_full": ev_full,
        "events_truncated": ev_trunc,
        "events_dropped_frac": (
            (ev_full - ev_trunc) / ev_full if ev_full else 0.0
        ),
    }
