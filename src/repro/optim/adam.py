"""Optimizers built from scratch (no optax dependency).

The paper trains with Adam (lr 5e-4) — implemented here exactly
(Kingma & Ba, bias-corrected), plus AdamW and SGD-momentum for the
substrate.  All optimizers share a functional `Optimizer` interface:

    opt = adam(5e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

States are plain pytrees (shardable under pjit with the same partitioning
rules as params, see distributed/partitioning.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., Tuple[PyTree, PyTree]]


class AdamState(NamedTuple):
    count: Array
    mu: PyTree
    nu: PyTree


def _zeros_like_tree(params: PyTree, dtype=None) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params
    )


def adam(
    lr: float | Callable[[Array], Array] = 5e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    """Adam (paper §4.2.1: 'trained using the Adam optimizer, lr 5e-4')."""

    def init(params):
        return AdamState(
            count=jnp.zeros([], jnp.int32),
            mu=_zeros_like_tree(params, jnp.float32),
            nu=_zeros_like_tree(params, jnp.float32),
        )

    def update(grads, state: AdamState, params=None):
        count = state.count + 1
        lr_t = lr(count) if callable(lr) else lr
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads,
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        updates = jax.tree_util.tree_map(
            lambda m, v: (-lr_t * (m / c1) / (jnp.sqrt(v / c2) + eps)),
            mu, nu,
        )
        return updates, AdamState(count=count, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def adamw(
    lr: float | Callable[[Array], Array] = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    base = adam(lr, b1, b2, eps)

    def update(grads, state: AdamState, params=None):
        updates, state = base.update(grads, state, params)
        count = state.count
        lr_t = lr(count) if callable(lr) else lr
        if params is not None and weight_decay:
            updates = jax.tree_util.tree_map(
                lambda u, p: u - lr_t * weight_decay * p.astype(jnp.float32),
                updates, params,
            )
        return updates, state

    return Optimizer(init=base.init, update=update)


class SGDState(NamedTuple):
    momentum: PyTree


def sgd(lr: float = 1e-2, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return SGDState(momentum=_zeros_like_tree(params, jnp.float32))

    def update(grads, state: SGDState, params=None):
        mom = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state.momentum, grads,
        )
        updates = jax.tree_util.tree_map(lambda m: -lr * m, mom)
        return updates, SGDState(momentum=mom)

    return Optimizer(init=init, update=update)


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def chain_clip(opt: Optimizer, max_norm: Optional[float] = 1.0) -> Optimizer:
    """Global-norm gradient clipping wrapper."""
    if max_norm is None:
        return opt

    def update(grads, state, params=None):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        return opt.update(grads, state, params)

    return Optimizer(init=opt.init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates
    )
