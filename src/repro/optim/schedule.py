"""Learning-rate schedules (callables step -> lr, usable as Adam's lr)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, decay_steps: int, alpha: float = 0.0):
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32), decay_steps) / decay_steps
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * ((1 - alpha) * cos + alpha)

    return f


def warmup_cosine(lr: float, warmup_steps: int, decay_steps: int,
                  alpha: float = 0.1):
    cos = cosine_decay(lr, decay_steps, alpha)

    def f(step):
        s = step.astype(jnp.float32)
        warm = lr * s / jnp.maximum(warmup_steps, 1)
        return jnp.where(s < warmup_steps, warm, cos(step - warmup_steps))

    return f
