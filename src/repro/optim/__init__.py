from repro.optim.adam import (
    Optimizer,
    adam,
    adamw,
    chain_clip,
    global_norm,
    sgd,
)
from repro.optim.schedule import constant, cosine_decay, warmup_cosine

__all__ = [
    "Optimizer",
    "adam",
    "adamw",
    "sgd",
    "chain_clip",
    "global_norm",
    "constant",
    "cosine_decay",
    "warmup_cosine",
]
