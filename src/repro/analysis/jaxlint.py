"""repro-lint: a dependency-free, JAX/Pallas-aware AST lint.

Walks python sources and flags the footguns that have bitten this repo
(or would, the moment a PR stops being careful):

=======  ===========================================================
code     meaning
=======  ===========================================================
RL101    host-module call (``np.``/``time.``/``random.``/``os.``/
         ``print``) inside a ``jax.jit`` / ``pl.pallas_call`` traced
         body — executes at trace time, bakes values into the graph
         or silently does nothing per step.
RL102    tracer leak: ``.item()`` / ``float()`` / ``int()`` /
         ``bool()`` applied to a traced value inside a jit body —
         forces a sync or raises ``TracerConversionError``.
RL103    python ``if``/``while`` branching on a traced value inside a
         jit body — trace-time specialization; use ``lax.cond`` /
         ``jnp.where``.  ``x.shape``/``x.dtype``-style static
         attributes are exempt.
RL104    ``.at[...].set/add`` on a buffer that was donated to a
         jitted call earlier in the same block — the buffer may
         already be aliased/deleted.
RL105    any other reuse of a donated buffer after the donating call
         in the same block, without rebinding — including host reads
         (``jax.device_get`` / ``jax.block_until_ready``) of donated
         state, which a snapshot path must issue *before* the
         donating dispatch.
RL106    float64 in JAX code (``jnp.float64``, ``dtype="float64"``,
         ``jax_enable_x64``) — this repo is strictly f32/int; host
         ``np.float64`` bookkeeping is exempt.
RL107    ``pl.BlockSpec(...)`` with neither an explicit block shape
         nor an explicit ``memory_space`` — unchecked whole-array
         staging.
RL201    unused import (``__init__.py`` re-exports exempt).
RL202    unreachable code after ``return``/``raise``/``break``/
         ``continue``.
RL000    file failed to parse (syntax error).
=======  ===========================================================

Suppression: put ``# repro-lint: disable=RL101,RL105 -- reason`` on
(any line of) the flagged statement.  A file-level
``# repro-lint: disable-file=RL106 -- reason`` in the first ten lines
suppresses a code for the whole file.  Suppressed findings are counted
and reported separately; they never fail the run.

The lint is intentionally conservative: it only treats a function as a
jit context when it can *see* the wrapping (`@jax.jit` decorator,
``jax.jit(name, ...)``, ``pl.pallas_call(name, ...)`` or
``pl.pallas_call(partial(name, ...))``, one level of ``alias = name``
indirection).  Keyword-only parameters of traced functions are treated
as static (the ``functools.partial``-bound config idiom used by every
kernel in ``repro.kernels``).
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Iterable, Sequence

RULES: dict[str, str] = {
    "RL000": "file failed to parse",
    "RL101": "host-module call inside a traced (jit/pallas) body",
    "RL102": "tracer leak: item()/float()/int()/bool() on a traced value",
    "RL103": "python if/while on a traced value inside a jit body",
    "RL104": ".at[].set on a buffer already donated to a jitted call",
    "RL105": "donated buffer reused after the donating call",
    "RL106": "float64 in JAX code (repo is strictly f32/int)",
    "RL107": "pl.BlockSpec without an explicit block shape",
    "RL201": "unused import",
    "RL202": "unreachable code",
}

#: modules whose *calls* are host-side effects under trace.
_HOST_MODULES = frozenset({"np", "numpy", "time", "os", "random", "io"})
#: attribute accesses on tracers that are static at trace time.
_STATIC_ATTRS = frozenset({"shape", "dtype", "ndim", "size", "weak_type", "sharding"})
#: builtins that return static values even on tracers.
_STATIC_CALLS = frozenset({"len", "isinstance", "type", "getattr", "hasattr", "range"})

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable(?:-file)?)\s*=\s*([A-Za-z0-9_,\s]+?)(?:\s*--.*)?$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str

    @property
    def key(self) -> str:
        """Line-number-free identity used for baseline matching."""
        return f"{self.path}::{self.code}::{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class LintResult:
    findings: list[Finding] = dataclasses.field(default_factory=list)
    suppressed: list[Finding] = dataclasses.field(default_factory=list)

    def merge(self, other: "LintResult") -> None:
        self.findings.extend(other.findings)
        self.suppressed.extend(other.suppressed)


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------


def _parse_suppressions(src: str) -> tuple[dict[int, set[str]], set[str]]:
    """Return (line -> suppressed codes, file-level suppressed codes)."""
    per_line: dict[int, set[str]] = {}
    file_level: set[str] = set()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(src).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            codes = {c.strip().upper() for c in m.group(2).split(",") if c.strip()}
            if m.group(1) == "disable-file":
                if tok.start[0] <= 10:
                    file_level |= codes
            else:
                per_line.setdefault(tok.start[0], set()).update(codes)
    except tokenize.TokenError:
        pass
    return per_line, file_level


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------


def _dotted(node: ast.AST) -> str | None:
    """``self._ring`` -> "self._ring"; ``jax.jit`` -> "jax.jit"; else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_expr(node: ast.AST) -> bool:
    """True for ``jax.jit`` / ``jit`` references."""
    d = _dotted(node)
    return d in ("jax.jit", "jit")


def _is_partial_expr(node: ast.AST) -> bool:
    d = _dotted(node)
    return d in ("functools.partial", "partial")


def _jit_call_static(call: ast.Call) -> tuple[set[str], set[int]]:
    """Extract static_argnames/static_argnums literals from a jit call."""
    names: set[str] = set()
    nums: set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.add(n.value)
    return names, nums


def _jit_call_donated(call: ast.Call) -> tuple[int, ...]:
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            return tuple(
                n.value
                for n in ast.walk(kw.value)
                if isinstance(n, ast.Constant) and isinstance(n.value, int)
            )
    return ()


@dataclasses.dataclass
class _JitSpec:
    kind: str  # "jit" | "pallas"
    static_names: set[str] = dataclasses.field(default_factory=set)
    static_nums: set[int] = dataclasses.field(default_factory=set)


# ---------------------------------------------------------------------------
# the linter
# ---------------------------------------------------------------------------


class _Linter:
    def __init__(self, tree: ast.Module, src: str, path: str):
        self.tree = tree
        self.src = src
        self.path = path
        self.result = LintResult()
        self.per_line, self.file_level = _parse_suppressions(src)
        # module-wide knowledge collected in one pass
        self.functions: dict[str, list[ast.FunctionDef]] = {}
        self.jit_specs: dict[str, _JitSpec] = {}
        self.jit_fn_nodes: dict[int, _JitSpec] = {}  # id(node) -> spec
        self.aliases: dict[str, str] = {}
        self.donating: dict[str, tuple[int, ...]] = {}

    # -- emission ----------------------------------------------------------

    def emit(self, node: ast.AST, code: str, message: str) -> None:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        end = getattr(node, "end_lineno", None) or line
        f = Finding(self.path, line, col, code, message)
        if code in self.file_level:
            self.result.suppressed.append(f)
            return
        for ln in range(line, end + 1):
            if code in self.per_line.get(ln, ()):  # suppression on any line of node
                self.result.suppressed.append(f)
                return
        self.result.findings.append(f)

    # -- pass 1: collect ---------------------------------------------------

    def collect(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, []).append(node)
                spec = self._decorator_jit_spec(node)
                if spec is not None:
                    self.jit_fn_nodes[id(node)] = spec
                don = self._decorator_donated(node)
                if don:
                    self.donating[node.name] = don
            elif isinstance(node, ast.Assign):
                self._collect_assign(node)
            elif isinstance(node, ast.Call):
                self._collect_call(node)
        # resolve one/few levels of aliasing: jit target name -> real def name
        for name, spec in list(self.jit_specs.items()):
            seen = {name}
            cur = name
            for _ in range(5):
                nxt = self.aliases.get(cur)
                if nxt is None or nxt in seen:
                    break
                seen.add(nxt)
                cur = nxt
                if cur not in self.jit_specs:
                    self.jit_specs[cur] = spec
        for name, spec in self.jit_specs.items():
            for fn in self.functions.get(name, ()):
                self.jit_fn_nodes.setdefault(id(fn), spec)

    def _decorator_jit_spec(self, node: ast.FunctionDef) -> _JitSpec | None:
        for dec in node.decorator_list:
            if _is_jit_expr(dec):
                return _JitSpec("jit")
            if isinstance(dec, ast.Call):
                if _is_jit_expr(dec.func):
                    names, nums = _jit_call_static(dec)
                    return _JitSpec("jit", names, nums)
                if _is_partial_expr(dec.func) and dec.args and _is_jit_expr(dec.args[0]):
                    names, nums = _jit_call_static(dec)
                    return _JitSpec("jit", names, nums)
        return None

    def _decorator_donated(self, node: ast.FunctionDef) -> tuple[int, ...]:
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and (
                _is_jit_expr(dec.func)
                or (_is_partial_expr(dec.func) and dec.args and _is_jit_expr(dec.args[0]))
            ):
                don = _jit_call_donated(dec)
                if don:
                    return don
        return ()

    def _collect_assign(self, node: ast.Assign) -> None:
        # name aliasing: a = b
        if isinstance(node.value, ast.Name):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.aliases[tgt.id] = node.value.id
        # donating callables: X = jax.jit(fn, donate_argnums=(...))
        if isinstance(node.value, ast.Call) and _is_jit_expr(node.value.func):
            don = _jit_call_donated(node.value)
            if don:
                for tgt in node.targets:
                    nm = _dotted(tgt)
                    if nm:
                        self.donating[nm] = don

    def _collect_call(self, node: ast.Call) -> None:
        d = _dotted(node.func)
        if d in ("jax.jit", "jit") and node.args:
            target = node.args[0]
            names, nums = _jit_call_static(node)
            if isinstance(target, ast.Name):
                self.jit_specs[target.id] = _JitSpec("jit", names, nums)
            elif isinstance(target, ast.Lambda):
                self.jit_fn_nodes[id(target)] = _JitSpec("jit", names, nums)
        elif d in ("pl.pallas_call", "pallas_call", "pltpu.pallas_call") and node.args:
            target = node.args[0]
            if isinstance(target, ast.Call) and _is_partial_expr(target.func) and target.args:
                # partial(kernel, **static_config): bound kwargs are static
                inner = target.args[0]
                if isinstance(inner, ast.Name):
                    self.jit_specs[inner.id] = _JitSpec(
                        "pallas", {kw.arg for kw in target.keywords if kw.arg}
                    )
            elif isinstance(target, ast.Name):
                self.jit_specs[target.id] = _JitSpec("pallas")

    # -- pass 2: rules -----------------------------------------------------

    def run(self) -> LintResult:
        self.collect()
        for node in ast.walk(self.tree):
            if id(node) in self.jit_fn_nodes and isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
            ):
                self._check_traced_body(node, self.jit_fn_nodes[id(node)])
            if isinstance(node, ast.Call):
                self._check_blockspec(node)
        self._check_f64()
        self._check_donation_flow()
        self._check_unused_imports()
        self._check_unreachable()
        return self.result

    # RL101/RL102/RL103 ----------------------------------------------------

    def _traced_params(self, fn, spec: _JitSpec) -> set[str]:
        if isinstance(fn, ast.Lambda):
            args = fn.args
        else:
            args = fn.args
        pos = [a.arg for a in (*args.posonlyargs, *args.args)]
        traced = {
            nm
            for i, nm in enumerate(pos)
            if i not in spec.static_nums and nm not in spec.static_names
        }
        if args.vararg is not None:
            traced.add(args.vararg.arg)
        # kw-only params are the partial-bound static-config idiom
        traced.discard("self")
        return traced

    def _refs_traced(self, node: ast.AST, traced: set[str]) -> bool:
        """Does `node` reference a traced name, ignoring static attrs?"""
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return False
        if isinstance(node, ast.Call):
            fname = _dotted(node.func)
            if fname in _STATIC_CALLS:
                return False
        if isinstance(node, ast.Name):
            return node.id in traced
        return any(self._refs_traced(c, traced) for c in ast.iter_child_nodes(node))

    def _check_traced_body(self, fn, spec: _JitSpec) -> None:
        traced = self._traced_params(fn, spec)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call):
                    self._check_host_call(node)
                    self._check_tracer_leak(node, traced)
                elif isinstance(node, (ast.If, ast.While)):
                    if self._refs_traced(node.test, traced):
                        kw = "while" if isinstance(node, ast.While) else "if"
                        self.emit(
                            node,
                            "RL103",
                            f"python `{kw}` on traced value inside traced body of "
                            f"`{getattr(fn, 'name', '<lambda>')}` — use lax.cond/jnp.where",
                        )

    def _check_host_call(self, call: ast.Call) -> None:
        d = _dotted(call.func)
        if d is None:
            return
        root = d.split(".", 1)[0]
        if root in _HOST_MODULES:
            self.emit(
                call,
                "RL101",
                f"host call `{d}(...)` inside traced body — runs at trace "
                "time only (use jnp/lax, or hoist out of the jit)",
            )
        elif d == "print":
            self.emit(
                call,
                "RL101",
                "`print(...)` inside traced body — prints at trace time only "
                "(use jax.debug.print)",
            )

    def _check_tracer_leak(self, call: ast.Call, traced: set[str]) -> None:
        d = _dotted(call.func)
        if (
            isinstance(call.func, ast.Attribute)
            and call.func.attr == "item"
            and not call.args
        ):
            self.emit(
                call,
                "RL102",
                ".item() inside traced body — host sync / tracer error",
            )
            return
        if d in ("float", "int", "bool") and len(call.args) == 1:
            arg = call.args[0]
            if isinstance(arg, ast.Constant):
                return
            if self._refs_traced(arg, traced):
                self.emit(
                    call,
                    "RL102",
                    f"`{d}()` on a traced value inside traced body — "
                    "tracer leak (use astype / lax primitives)",
                )

    # RL107 ----------------------------------------------------------------

    def _check_blockspec(self, call: ast.Call) -> None:
        d = _dotted(call.func)
        if d not in ("pl.BlockSpec", "pallas.BlockSpec", "BlockSpec"):
            return
        kwargs = {kw.arg for kw in call.keywords if kw.arg}
        if not call.args and not ({"block_shape", "memory_space"} & kwargs):
            self.emit(
                call,
                "RL107",
                "pl.BlockSpec without an explicit block shape or memory_space "
                "— whole-array staging with no budget accounting",
            )

    # RL106 ----------------------------------------------------------------

    def _check_f64(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Attribute) and node.attr == "float64":
                root = _dotted(node)
                if root in ("jnp.float64", "jax.numpy.float64"):
                    self.emit(node, "RL106", "jnp.float64 — repo is strictly f32/int")
            elif isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d in ("jax.config.update", "config.update") and node.args:
                    a0 = node.args[0]
                    if isinstance(a0, ast.Constant) and a0.value == "jax_enable_x64":
                        self.emit(node, "RL106", "jax_enable_x64 — repo is strictly f32/int")
            elif isinstance(node, ast.keyword) and node.arg == "dtype":
                v = node.value
                if isinstance(v, ast.Constant) and v.value == "float64":
                    self.emit(v, "RL106", 'dtype="float64" — repo is strictly f32/int')

    # RL104/RL105 ----------------------------------------------------------

    def _check_donation_flow(self) -> None:
        if not self.donating:
            return
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_block(node.body, set())

    def _node_donating_calls(self, node: ast.AST) -> list[ast.Call]:
        out = []
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                d = _dotted(n.func)
                if d in self.donating:
                    out.append(n)
        return out

    _COMPOUND = (ast.For, ast.AsyncFor, ast.While, ast.If, ast.With, ast.AsyncWith, ast.Try)

    def _scan_block(self, stmts: list[ast.stmt], dead: set[str]) -> set[str]:
        """Flow the donated-and-dead set through a statement list.

        Compound statements are scanned per sub-block (a loop that
        rebinds its donated buffers from the call outputs — the engine's
        admit loop — resurrects them for the code after the loop);
        the exit set is the union of every branch's exit set (a donation
        on *any* path kills the buffer conservatively).  Returns the
        dead set at block exit.
        """
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue  # nested defs get their own fresh scan
            if isinstance(stmt, self._COMPOUND):
                headers: list[ast.AST] = []
                if isinstance(stmt, (ast.For, ast.AsyncFor)):
                    headers = [stmt.iter]
                elif isinstance(stmt, (ast.While, ast.If)):
                    headers = [stmt.test]
                elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                    headers = [item.context_expr for item in stmt.items]
                for h in headers:
                    self._apply_simple(h, dead, rebind_targets=[])
                exits = [set(dead)]
                for blk in self._sub_blocks(stmt):
                    exits.append(self._scan_block(list(blk), set(dead)))
                dead = set().union(*exits)
                continue
            targets: list[ast.AST] = []
            if isinstance(stmt, ast.Assign):
                targets = list(stmt.targets)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                targets = [stmt.target]
            elif isinstance(stmt, ast.Delete):
                targets = list(stmt.targets)
            self._apply_simple(stmt, dead, rebind_targets=targets)
        return dead

    @staticmethod
    def _sub_blocks(stmt: ast.stmt):
        for field in ("body", "orelse", "finalbody"):
            blk = getattr(stmt, field, None)
            if isinstance(blk, list) and blk:
                yield blk
        for h in getattr(stmt, "handlers", ()) or ():
            yield h.body

    def _apply_simple(
        self, node: ast.AST, dead: set[str], rebind_targets: list[ast.AST]
    ) -> None:
        """One straight-line step: flag dead uses, apply donations,
        then resurrect rebound names."""
        calls = self._node_donating_calls(node)
        donated_here: set[int] = set()
        for call in calls:
            for a in call.args:
                donated_here.add(id(a))
        if dead:
            self._flag_dead_uses(node, dead, donated_here)
        for call in calls:
            for pos in self.donating[_dotted(call.func)]:
                if pos < len(call.args):
                    nm = _dotted(call.args[pos])
                    if nm:
                        dead.add(nm)
        for tgt in rebind_targets:
            for t in ast.walk(tgt):
                nm = _dotted(t)
                if nm is not None:
                    dead.discard(nm)

    def _flag_dead_uses(
        self, stmt: ast.AST, dead: set[str], donated_here: set[int]
    ) -> None:
        for node in ast.walk(stmt):
            if id(node) in donated_here:
                continue  # passing the buffer into the next donating call is the point
            if isinstance(node, ast.Attribute) and node.attr == "at":
                nm = _dotted(node.value)
                if nm in dead:
                    self.emit(
                        node,
                        "RL104",
                        f"`.at[]` update on `{nm}` after it was donated — "
                        "buffer is aliased/deleted",
                    )
                    return
        for node in ast.walk(stmt):
            # snapshot path: a host read (device_get / block_until_ready)
            # of a donated buffer reads freed storage — the snapshot must
            # fetch state *before* the next tick's donating dispatch
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d in ("jax.device_get", "jax.block_until_ready"):
                    for a in node.args:
                        nm = _dotted(a)
                        if nm in dead:
                            self.emit(
                                node,
                                "RL105",
                                f"host read `{d}({nm})` after `{nm}` was "
                                "donated — snapshot/host fetches of donated "
                                "state must happen before the donating "
                                "dispatch, or rebind from the call's "
                                "outputs",
                            )
                            dead.discard(nm)
                            return
        for node in ast.walk(stmt):
            if id(node) in donated_here:
                continue
            if isinstance(node, (ast.Name, ast.Attribute)) and isinstance(
                getattr(node, "ctx", None), ast.Load
            ):
                nm = _dotted(node)
                if nm in dead:
                    self.emit(
                        node,
                        "RL105",
                        f"`{nm}` reused after being donated to a jitted call "
                        "— rebind it from the call's outputs first",
                    )
                    dead.discard(nm)  # one finding per buffer per block
                    return

    # RL201 ----------------------------------------------------------------

    def _check_unused_imports(self) -> None:
        if Path(self.path).name == "__init__.py":
            return
        imported: dict[str, ast.stmt] = {}
        for node in self.tree.body:
            if isinstance(node, ast.Import):
                for alias in node.names:
                    name = alias.asname or alias.name.split(".")[0]
                    imported[name] = node
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    imported[alias.asname or alias.name] = node
        if not imported:
            return
        used: set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Name):
                used.add(node.id)
            elif isinstance(node, ast.Constant) and isinstance(node.value, str):
                # __all__ entries, string annotations, doctest-ish refs
                if node.value.isidentifier():
                    used.add(node.value)
        for name, node in imported.items():
            if name not in used:
                self.emit(node, "RL201", f"unused import `{name}`")

    # RL202 ----------------------------------------------------------------

    def _check_unreachable(self) -> None:
        terminal = (ast.Return, ast.Raise, ast.Break, ast.Continue)
        for node in ast.walk(self.tree):
            for field in ("body", "orelse", "finalbody"):
                blk = getattr(node, field, None)
                if not isinstance(blk, list):
                    continue
                for i, stmt in enumerate(blk[:-1]):
                    if isinstance(stmt, terminal):
                        self.emit(
                            blk[i + 1],
                            "RL202",
                            f"unreachable code after `{type(stmt).__name__.lower()}`",
                        )
                        break


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def lint_source(src: str, path: str = "<string>") -> LintResult:
    """Lint one python source string; returns findings + suppressed."""
    result = LintResult()
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        result.findings.append(
            Finding(path, e.lineno or 1, e.offset or 0, "RL000", f"syntax error: {e.msg}")
        )
        return result
    return _Linter(tree, src, path).run()


def iter_py_files(paths: Sequence[Path | str]) -> Iterable[Path]:
    for p in paths:
        p = Path(p)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def lint_paths(paths: Sequence[Path | str], rel_to: Path | str | None = None) -> LintResult:
    """Lint every ``*.py`` under ``paths``; paths in findings are relative
    to ``rel_to`` when given (so baselines are location-independent)."""
    agg = LintResult()
    root = Path(rel_to) if rel_to is not None else None
    for f in iter_py_files(paths):
        try:
            src = f.read_text()
        except OSError as e:  # unreadable file is itself a finding
            agg.findings.append(Finding(str(f), 1, 0, "RL000", f"unreadable: {e}"))
            continue
        shown = str(f)
        if root is not None:
            try:
                shown = str(f.resolve().relative_to(root.resolve()))
            except ValueError:
                pass
        agg.merge(lint_source(src, shown))
    return agg
