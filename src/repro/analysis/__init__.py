"""repro-lint: JAX/Pallas-aware static analysis + contract checking.

Two halves:

- :mod:`repro.analysis.jaxlint` — dependency-free AST lint over
  ``src/repro/**`` (host calls in traced bodies, tracer leaks, traced
  branching, donation misuse, f64, unshaped BlockSpecs, unused
  imports, unreachable code) with ``# repro-lint: disable=CODE``
  suppressions.
- :mod:`repro.analysis.contracts` / :mod:`repro.analysis.kernel_budget`
  — runtime/lowering contract checkers: recompilation detection,
  donation verification, AER address-width bounds, and a captured
  VMEM/SMEM budget estimate for every Pallas kernel.

CLI: ``python -m repro.analysis [--json report.json]`` — exits nonzero
on any finding not in the checked-in baseline.
"""

from .contracts import (
    ContractViolation,
    RecompileDetector,
    aer_bounds_report,
    check_aer_bounds,
    donation_report,
    runtime_donation_check,
    verify_donation,
)
from .jaxlint import RULES, Finding, LintResult, lint_paths, lint_source
from .kernel_budget import (
    DEFAULT_SMEM_BUDGET,
    DEFAULT_VMEM_BUDGET,
    KernelPlan,
    check_kernel_budgets,
)

__all__ = [
    "ContractViolation",
    "RecompileDetector",
    "aer_bounds_report",
    "check_aer_bounds",
    "donation_report",
    "runtime_donation_check",
    "verify_donation",
    "RULES",
    "Finding",
    "LintResult",
    "lint_paths",
    "lint_source",
    "DEFAULT_SMEM_BUDGET",
    "DEFAULT_VMEM_BUDGET",
    "KernelPlan",
    "check_kernel_budgets",
]
