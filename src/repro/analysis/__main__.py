"""``python -m repro.analysis`` — run the full repro-lint pass.

Runs, in order:

1. the AST lint over ``src/repro`` (or the paths given),
2. the Pallas kernel VMEM/SMEM budget + index-map bounds checks,
3. the AER address-width bounds check for the collision config.

Emits a text report (and ``--json`` report), then exits 1 if any
finding is not covered by the checked-in baseline
(``analysis_baseline.json`` at the repo root — shipped empty: every
known finding is fixed or carries an inline suppression with a reason).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import contracts, jaxlint, kernel_budget

REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_BASELINE = REPO_ROOT / "analysis_baseline.json"
BASELINE_SCHEMA = "repro-lint-baseline/v1"
REPORT_SCHEMA = "repro-analysis/v1"


def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    doc = json.loads(path.read_text())
    if doc.get("schema") != BASELINE_SCHEMA:
        raise SystemExit(f"unrecognised baseline schema in {path}: {doc.get('schema')!r}")
    return set(doc.get("findings", []))


def run(
    paths: list[str] | None = None,
    *,
    with_kernels: bool = True,
    with_aer: bool = True,
    vmem_budget: int = kernel_budget.DEFAULT_VMEM_BUDGET,
    smem_budget: int = kernel_budget.DEFAULT_SMEM_BUDGET,
) -> dict:
    """Run the full pass; returns the report dict (no exit/printing).

    Used by the CLI, ``tests/test_analysis.py``, and
    ``benchmarks/stream_bench.py`` (the v6 ``static_analysis`` block).
    """
    lint_paths = [Path(p) for p in (paths or [REPO_ROOT / "src" / "repro"])]
    result = jaxlint.lint_paths(lint_paths, rel_to=REPO_ROOT)

    plans: list[kernel_budget.KernelPlan] = []
    if with_kernels:
        plans, kfindings = kernel_budget.check_kernel_budgets(
            vmem_budget=vmem_budget, smem_budget=smem_budget
        )
        result.findings.extend(kfindings)

    aer_report: dict | None = None
    if with_aer:
        from repro.configs.collision_snn import CONFIG

        sizes = list(CONFIG.layer_sizes)
        aer_report = contracts.aer_bounds_report(sizes)
        for msg in contracts.check_aer_bounds(sizes):
            result.findings.append(
                jaxlint.Finding("src/repro/events/aer.py", 1, 0, "RA401", msg)
            )

    return {
        "schema": REPORT_SCHEMA,
        "paths": [str(p) for p in lint_paths],
        "findings": [f.to_json() for f in result.findings],
        "finding_keys": [f.key for f in result.findings],
        "suppressed": [f.to_json() for f in result.suppressed],
        "counts": {
            "findings": len(result.findings),
            "suppressed": len(result.suppressed),
        },
        "kernels": [p.to_json() for p in plans],
        "aer_bounds": aer_report,
        "budgets": {"vmem_bytes": vmem_budget, "smem_bytes": smem_budget},
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis", description=__doc__)
    ap.add_argument("paths", nargs="*", help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--json", dest="json_out", help="write the full JSON report here")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline to accept all current findings",
    )
    ap.add_argument("--no-kernels", action="store_true", help="skip kernel budget checks")
    ap.add_argument("--no-aer", action="store_true", help="skip AER bounds checks")
    ap.add_argument("--vmem-budget", type=int, default=kernel_budget.DEFAULT_VMEM_BUDGET)
    ap.add_argument("--smem-budget", type=int, default=kernel_budget.DEFAULT_SMEM_BUDGET)
    args = ap.parse_args(argv)

    report = run(
        args.paths or None,
        with_kernels=not args.no_kernels,
        with_aer=not args.no_aer,
        vmem_budget=args.vmem_budget,
        smem_budget=args.smem_budget,
    )

    baseline_path = Path(args.baseline)
    baseline = load_baseline(baseline_path)
    new = [
        f for f, k in zip(report["findings"], report["finding_keys"])
        if k not in baseline
    ]
    report["baseline"] = {
        "path": str(baseline_path),
        "entries": len(baseline),
        "new_findings": len(new),
    }
    report["counts"]["new"] = len(new)

    if args.update_baseline:
        baseline_path.write_text(
            json.dumps(
                {"schema": BASELINE_SCHEMA, "findings": sorted(set(report["finding_keys"]))},
                indent=2,
            )
            + "\n"
        )
        print(f"baseline updated: {len(report['finding_keys'])} entries -> {baseline_path}")

    if args.json_out:
        Path(args.json_out).write_text(json.dumps(report, indent=2) + "\n")

    for f in new:
        print(f"{f['path']}:{f['line']}:{f['col']}: {f['code']} {f['message']}")
    for p in report["kernels"]:
        print(
            f"kernel {p['kernel']}: grid {tuple(p['grid'])}, "
            f"VMEM {p['vmem_bytes'] / 2**20:.2f} MiB, "
            f"SMEM {p['smem_bytes'] / 2**10:.0f} KiB"
        )
    n_sup = report["counts"]["suppressed"]
    print(
        f"repro-lint: {len(new)} new finding(s), "
        f"{report['counts']['findings'] - len(new)} baselined, {n_sup} suppressed"
    )
    if new and not args.update_baseline:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
