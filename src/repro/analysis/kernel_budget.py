"""Static VMEM/SMEM budget estimation for the repo's Pallas kernels.

Rather than re-deriving BlockSpecs by hand (which would rot the moment a
kernel changes), this module *captures* the real ``pl.pallas_call``
arguments: it temporarily replaces ``pallas_call`` with a recording
stub, invokes each kernel's unjitted wrapper (``fn.__wrapped__``) at a
representative geometry, and analyses exactly the grid / BlockSpecs /
scratch the wrapper would hand to Mosaic.

Per kernel it reports:

- estimated VMEM working set: one copy of every *resident* block (index
  map constant over the grid — e.g. the scalar-prefetched weight slabs
  in ``snn_chunk``), two copies of every *pipelined* block (Pallas
  double-buffers blocks whose index map varies), plus scratch;
- estimated SMEM bytes (the scalar-prefetch operands);
- an index-map bounds check: every index map is evaluated at every grid
  corner and the produced block must lie inside the (padded) operand;
- a divisibility check: padded operand dims must be multiples of the
  block dims (the Mosaic blocked-indexing contract).

Findings use codes RB301 (VMEM over budget), RB302 (index map out of
bounds), RB303 (block does not divide operand), RB304 (SMEM over
budget).  Budgets are configurable; defaults are the v4/v5 TPU figures
from the Pallas guide (16 MiB VMEM/core) with a deliberately tight
1 MiB line for scalar-prefetch SMEM.  The estimate covers *declared*
buffers only — compiler-managed temporaries (e.g. the (bm, bk, bn)
int32 product in ``q115_matmul``) are the compiler's to spill.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Sequence

import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .jaxlint import Finding

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024  # per-core VMEM (TPU v4/v5 class)
DEFAULT_SMEM_BUDGET = 1024 * 1024  # scalar-prefetch tables

# the (4096, 512, 2) collision config at serving geometry — the paper's
# headline workload and what stream_bench drives
_COLLISION_LAYERS = ((4096, 512), (512, 2))
_SLOTS = 4
_CHUNK_STEPS = 5
_CAPACITY = 13 * 128  # layer-0 event capacity (autotuned ballpark)


@dataclasses.dataclass
class BufferPlan:
    name: str
    role: str  # "in" | "out" | "scratch" | "prefetch"
    block_shape: tuple[int, ...]
    dtype: str
    bytes_per_copy: int
    copies: int  # 1 resident, 2 pipelined
    resident: bool

    @property
    def bytes(self) -> int:
        return self.bytes_per_copy * self.copies

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["bytes"] = self.bytes
        return d


@dataclasses.dataclass
class KernelPlan:
    kernel: str
    grid: tuple[int, ...]
    num_scalar_prefetch: int
    buffers: list[BufferPlan]
    smem_bytes: int
    errors: list[str]

    @property
    def vmem_bytes(self) -> int:
        return sum(b.bytes for b in self.buffers)

    def to_json(self) -> dict:
        return {
            "kernel": self.kernel,
            "grid": list(self.grid),
            "num_scalar_prefetch": self.num_scalar_prefetch,
            "vmem_bytes": self.vmem_bytes,
            "smem_bytes": self.smem_bytes,
            "buffers": [b.to_json() for b in self.buffers],
            "errors": self.errors,
        }


# ---------------------------------------------------------------------------
# pallas_call capture
# ---------------------------------------------------------------------------


class _Capture:
    """Swap ``pallas_call`` for a recorder that returns zeros."""

    def __init__(self) -> None:
        self.records: list[dict] = []
        self._real: Any = None

    def __enter__(self) -> "_Capture":
        self._real = pl.pallas_call

        records = self.records

        def fake_pallas_call(kernel, **kw):
            def runner(*operands):
                records.append({"kw": kw, "operands": operands})
                out_shape = kw.get("out_shape")
                if isinstance(out_shape, (list, tuple)):
                    return [jnp.zeros(s.shape, s.dtype) for s in out_shape]
                return jnp.zeros(out_shape.shape, out_shape.dtype)

            return runner

        pl.pallas_call = fake_pallas_call
        return self

    def __exit__(self, *exc: Any) -> None:
        pl.pallas_call = self._real


def _itemsize(dtype: Any) -> int:
    return int(np.dtype(jnp.dtype(dtype)).itemsize)


def _as_list(x: Any) -> list:
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


def _grid_corners(grid: Sequence[int]) -> list[tuple[int, ...]]:
    axes = [sorted({0, max(0, g - 1)}) for g in grid]
    return [tuple(c) for c in itertools.product(*axes)]


def _eval_index_map(
    spec: Any, corners: Sequence[tuple[int, ...]], num_prefetch: int
) -> tuple[list[tuple[int, ...]] | None, str | None]:
    """Evaluate a BlockSpec's index map at the grid corners.

    Prefetch refs are passed as ``None`` placeholders (the repo's index
    maps never dereference them).  Returns (indices, error).
    """
    imap = getattr(spec, "index_map", None)
    if imap is None:
        return None, None
    out = []
    for c in corners:
        try:
            idx = imap(*c, *([None] * num_prefetch))
        except TypeError:
            try:
                idx = imap(*c)
            except Exception as e:
                return None, f"index map raised {type(e).__name__}: {e}"
        except Exception as e:
            return None, f"index map raised {type(e).__name__}: {e}"
        if not isinstance(idx, tuple):
            idx = (idx,)
        out.append(tuple(int(i) for i in idx))
    return out, None


def _analyze_record(name: str, rec: dict) -> KernelPlan:
    kw = rec["kw"]
    operands = rec["operands"]
    grid_spec = kw.get("grid_spec")
    if grid_spec is not None:
        grid = tuple(grid_spec.grid)
        in_specs = _as_list(grid_spec.in_specs)
        out_specs = _as_list(grid_spec.out_specs)
        scratch = _as_list(grid_spec.scratch_shapes)
        npf = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)
    else:
        grid = tuple(kw.get("grid") or ())
        in_specs = _as_list(kw.get("in_specs"))
        out_specs = _as_list(kw.get("out_specs"))
        scratch = _as_list(kw.get("scratch_shapes"))
        npf = 0
    out_shapes = _as_list(kw.get("out_shape"))
    corners = _grid_corners(grid)

    buffers: list[BufferPlan] = []
    errors: list[str] = []
    smem = 0

    # scalar-prefetch operands live whole in SMEM
    for i in range(npf):
        op = operands[i]
        smem += int(np.prod(op.shape)) * _itemsize(op.dtype) if op.shape else _itemsize(op.dtype)

    def add(spec, operand_shape, dtype, role, label):
        nonlocal errors
        bshape = tuple(int(b) for b in (spec.block_shape or ()))
        if not bshape:
            bshape = tuple(int(s) for s in operand_shape)
        per_copy = int(np.prod(bshape)) * _itemsize(dtype)
        idxs, err = _eval_index_map(spec, corners, npf)
        resident = False
        if err:
            errors.append(f"{label}: {err}")
        elif idxs is not None:
            resident = len(set(idxs)) == 1
            for c, idx in zip(corners, idxs):
                if len(idx) != len(bshape):
                    errors.append(
                        f"{label}: index map rank {len(idx)} != block rank {len(bshape)}"
                    )
                    break
                for d, (bi, bs, os) in enumerate(zip(idx, bshape, operand_shape)):
                    if bi < 0 or (bi + 1) * bs > os:
                        errors.append(
                            f"{label}: grid point {c} maps block {idx} outside "
                            f"operand dim {d} (block {bs} x idx {bi} vs size {os})"
                        )
            for d, (bs, os) in enumerate(zip(bshape, operand_shape)):
                if bs and os % bs:
                    errors.append(
                        f"{label}: block dim {d} ({bs}) does not divide "
                        f"operand dim ({os})"
                    )
        buffers.append(
            BufferPlan(
                name=label,
                role=role,
                block_shape=bshape,
                dtype=np.dtype(jnp.dtype(dtype)).name,
                bytes_per_copy=per_copy,
                copies=1 if resident else 2,
                resident=resident,
            )
        )

    data_ops = operands[npf:]
    for i, spec in enumerate(in_specs):
        if i < len(data_ops):
            op = data_ops[i]
            add(spec, tuple(op.shape), op.dtype, "in", f"in[{i}]")
        else:
            errors.append(f"in[{i}]: no matching operand captured")
    for i, (spec, s) in enumerate(zip(out_specs, out_shapes)):
        add(spec, tuple(s.shape), s.dtype, "out", f"out[{i}]")
    for i, sc in enumerate(scratch):
        shape = tuple(int(x) for x in getattr(sc, "shape", ()) or ())
        dtype = getattr(sc, "dtype", jnp.float32)
        nbytes = int(np.prod(shape)) * _itemsize(dtype) if shape else _itemsize(dtype)
        space = str(getattr(sc, "memory_space", "vmem")).lower()
        if "smem" in space:
            smem += nbytes
        else:
            buffers.append(
                BufferPlan(f"scratch[{i}]", "scratch", shape,
                           np.dtype(jnp.dtype(dtype)).name, nbytes, 1, True)
            )

    return KernelPlan(name, grid, npf, buffers, smem, errors)


# ---------------------------------------------------------------------------
# per-kernel drivers (representative geometry: the collision config)
# ---------------------------------------------------------------------------


def _plan_snn_chunk() -> KernelPlan:
    from repro.kernels import snn_chunk as mod

    L = len(_COLLISION_LAYERS)
    B, Tc, C = _SLOTS, _CHUNK_STEPS, _CAPACITY
    weights = [np.zeros(s, np.float32) for s in _COLLISION_LAYERS]
    biases = [np.zeros(s[1], np.float32) for s in _COLLISION_LAYERS]
    betas = [np.full(s[1], 0.9, np.float32) for s in _COLLISION_LAYERS]
    thresholds = [np.ones(s[1], np.float32) for s in _COLLISION_LAYERS]
    u0 = [np.zeros((B, s[1]), np.float32) for s in _COLLISION_LAYERS]
    r0 = [np.zeros((B, s[1]), np.int32) for s in _COLLISION_LAYERS]
    addrs = np.zeros((Tc, B, C), np.int16)
    values = np.zeros((Tc, B, C), np.int8)
    counts = np.zeros((Tc, B), np.int32)
    active = np.ones((B,), np.int32)
    with _Capture() as cap:
        mod.snn_chunk.__wrapped__(
            weights, biases, betas, thresholds, u0, r0,
            addrs, values, counts, active, interpret=True,
        )
    del L
    return _analyze_record("snn_chunk", cap.records[-1])


def _plan_aer_matmul() -> KernelPlan:
    from repro.kernels import aer_matmul as mod

    K, N, E = _COLLISION_LAYERS[0][0], _COLLISION_LAYERS[0][1], _CAPACITY
    addrs = np.zeros((E,), np.int32)
    values = np.zeros((E,), np.int32)
    weights_q = np.zeros((K, N), np.int16)
    with _Capture() as cap:
        mod.aer_spike_matmul.__wrapped__(addrs, values, weights_q, interpret=True)
    return _analyze_record("aer_spike_matmul", cap.records[-1])


def _plan_aer_matmul_batched() -> KernelPlan:
    from repro.kernels import aer_matmul as mod

    K, N, E, B = _COLLISION_LAYERS[0][0], _COLLISION_LAYERS[0][1], _CAPACITY, 8
    addrs = np.zeros((B, E), np.int32)
    values = np.zeros((B, E), np.int32)
    weights_q = np.zeros((K, N), np.int16)
    with _Capture() as cap:
        mod.aer_spike_matmul_batched.__wrapped__(addrs, values, weights_q, interpret=True)
    return _analyze_record("aer_spike_matmul_batched", cap.records[-1])


def _plan_lif_fused() -> KernelPlan:
    from repro.kernels import lif_fused as mod

    T, B, N = 25, 8, _COLLISION_LAYERS[0][1]
    currents = np.zeros((T, B, N), np.float32)
    beta = np.full((N,), 0.9, np.float32)
    threshold = np.ones((N,), np.float32)
    with _Capture() as cap:
        mod.lif_fused.__wrapped__(currents, beta, threshold, interpret=True)
    return _analyze_record("lif_fused", cap.records[-1])


def _plan_q115_matmul() -> KernelPlan:
    from repro.kernels import q115_matmul as mod

    M, K, N = 8, _COLLISION_LAYERS[0][0], _COLLISION_LAYERS[0][1]
    x_q = np.zeros((M, K), np.int16)
    w_q = np.zeros((K, N), np.int16)
    with _Capture() as cap:
        mod.q115_matmul.__wrapped__(x_q, w_q, interpret=True)
    return _analyze_record("q115_matmul", cap.records[-1])


KERNEL_PLANNERS: dict[str, Callable[[], KernelPlan]] = {
    "snn_chunk": _plan_snn_chunk,
    "aer_spike_matmul": _plan_aer_matmul,
    "aer_spike_matmul_batched": _plan_aer_matmul_batched,
    "lif_fused": _plan_lif_fused,
    "q115_matmul": _plan_q115_matmul,
}

_KERNEL_PATHS = {
    "snn_chunk": "src/repro/kernels/snn_chunk.py",
    "aer_spike_matmul": "src/repro/kernels/aer_matmul.py",
    "aer_spike_matmul_batched": "src/repro/kernels/aer_matmul.py",
    "lif_fused": "src/repro/kernels/lif_fused.py",
    "q115_matmul": "src/repro/kernels/q115_matmul.py",
}


def check_kernel_budgets(
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    smem_budget: int = DEFAULT_SMEM_BUDGET,
    kernels: Sequence[str] | None = None,
) -> tuple[list[KernelPlan], list[Finding]]:
    """Capture + analyse every kernel; returns (plans, findings)."""
    plans: list[KernelPlan] = []
    findings: list[Finding] = []
    for name in kernels or KERNEL_PLANNERS:
        path = _KERNEL_PATHS.get(name, f"<kernel:{name}>")
        try:
            plan = KERNEL_PLANNERS[name]()
        except Exception as e:
            findings.append(
                Finding(path, 1, 0, "RB302", f"{name}: capture failed: {type(e).__name__}: {e}")
            )
            continue
        plans.append(plan)
        if plan.vmem_bytes > vmem_budget:
            findings.append(
                Finding(
                    path, 1, 0, "RB301",
                    f"{name}: estimated VMEM working set "
                    f"{plan.vmem_bytes / 2**20:.2f} MiB exceeds budget "
                    f"{vmem_budget / 2**20:.2f} MiB",
                )
            )
        if plan.smem_bytes > smem_budget:
            findings.append(
                Finding(
                    path, 1, 0, "RB304",
                    f"{name}: scalar-prefetch SMEM {plan.smem_bytes / 2**10:.0f} KiB "
                    f"exceeds budget {smem_budget / 2**10:.0f} KiB",
                )
            )
        for err in plan.errors:
            code = "RB303" if "does not divide" in err else "RB302"
            findings.append(Finding(path, 1, 0, code, f"{name}: {err}"))
    return plans, findings
