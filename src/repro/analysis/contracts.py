"""Runtime contract checkers for the invariants the lint can't see.

Three checkers, all dependency-free (jax + numpy only):

- :class:`RecompileDetector` — counts XLA backend compiles inside a
  region (via ``jax.monitoring``) and per-function cache growth (via
  the jit cache size), against an allowlist of known compile sites.
  Catches shape-polymorphic submit paths recompiling per request.
- :func:`donation_report` / :func:`verify_donation` /
  :func:`runtime_donation_check` — static (lowered-HLO aliasing
  attrs) and runtime (donated input actually deleted) verification of
  ``donate_argnums`` discipline.
- :func:`aer_bounds_report` / :func:`check_aer_bounds` — ties the
  ``StepEventTable`` address dtype chosen by
  :func:`repro.events.aer.addr_dtype_for` to the layer widths /
  capacities it must index, so an int16 table can never silently wrap.
"""

from __future__ import annotations

import dataclasses
import re
import threading
from typing import Any, Callable, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class ContractViolation(AssertionError):
    """A machine-checked invariant does not hold."""


# ---------------------------------------------------------------------------
# recompilation detection
# ---------------------------------------------------------------------------

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_active_detectors: "set[RecompileDetector]" = set()
_listener_lock = threading.Lock()
_listener_installed = False


def _install_listener() -> None:
    global _listener_installed
    with _listener_lock:
        if _listener_installed:
            return
        try:
            jax.monitoring.register_event_duration_secs_listener(_dispatch)
            _listener_installed = True
        except Exception:  # monitoring API unavailable: cache-size tracking only
            _listener_installed = True


def _dispatch(name: str, duration: float, **kwargs: Any) -> None:
    if name != _COMPILE_EVENT:
        return
    for det in list(_active_detectors):
        det._backend_compiles += 1


def _cache_size(fn: Any) -> int | None:
    get = getattr(fn, "_cache_size", None)
    if get is None:
        return None
    try:
        return int(get())
    except Exception:
        return None


@dataclasses.dataclass
class _Tracked:
    fn: Any
    start: int | None
    allowed: int
    end: int | None = None  # frozen at region exit


class RecompileDetector:
    """Count compilations inside a region.

    >>> with RecompileDetector() as det:
    ...     det.track("step", step_fn, allowed=1)   # cold start is expected
    ...     serve_lots_of_traffic()
    >>> det.raise_on_unexpected()

    ``track()`` registers a jitted function whose compile-cache growth
    is measured; ``allowed`` is that site's compile budget for the
    region (the allowlist of known compile sites).  ``backend_compiles``
    additionally counts *every* XLA compile observed process-wide while
    the detector is active — it catches recompiles of functions nobody
    thought to track.
    """

    def __init__(self, max_backend_compiles: int | None = None):
        self._tracked: dict[str, _Tracked] = {}
        self._backend_compiles = 0
        self._max_backend = max_backend_compiles
        self._entered = False

    # -- region management -------------------------------------------------

    def __enter__(self) -> "RecompileDetector":
        _install_listener()
        _active_detectors.add(self)
        self._entered = True
        return self

    def __exit__(self, *exc: Any) -> None:
        _active_detectors.discard(self)
        # freeze per-fn growth at region exit: report()/unexpected()
        # called later must describe the guarded region, not compiles
        # that legitimately happen after it
        for t in self._tracked.values():
            if t.start is not None and t.end is None:
                t.end = _cache_size(t.fn)

    # -- tracking ----------------------------------------------------------

    def track(self, name: str, fn: Any, allowed: int = 0) -> None:
        """Register a jitted callable; compile-cache growth beyond
        ``allowed`` entries is reported as unexpected."""
        self._tracked[name] = _Tracked(fn, _cache_size(fn), allowed)

    @property
    def backend_compiles(self) -> int:
        return self._backend_compiles

    def cache_growth(self, name: str) -> int | None:
        t = self._tracked[name]
        if t.start is None:
            return None
        now = t.end if t.end is not None else _cache_size(t.fn)
        return None if now is None else now - t.start

    def report(self) -> dict:
        per_fn = {}
        for name in self._tracked:
            growth = self.cache_growth(name)
            per_fn[name] = {
                "cache_growth": growth,
                "allowed": self._tracked[name].allowed,
                "unexpected": (growth or 0) - self._tracked[name].allowed
                if growth is not None
                else None,
            }
        return {
            "backend_compiles": self._backend_compiles,
            "max_backend_compiles": self._max_backend,
            "tracked": per_fn,
        }

    def unexpected(self) -> list[str]:
        """Human-readable list of allowlist violations (empty == clean)."""
        out = []
        for name, t in self._tracked.items():
            growth = self.cache_growth(name)
            if growth is not None and growth > t.allowed:
                out.append(
                    f"`{name}` compiled {growth} time(s), allowlist permits "
                    f"{t.allowed} — shape-unstable inputs?"
                )
        if self._max_backend is not None and self._backend_compiles > self._max_backend:
            out.append(
                f"{self._backend_compiles} backend compiles observed in region "
                f"(budget {self._max_backend}) — untracked function recompiling"
            )
        return out

    def raise_on_unexpected(self) -> None:
        bad = self.unexpected()
        if bad:
            raise ContractViolation("; ".join(bad))


# ---------------------------------------------------------------------------
# donation / aliasing verification
# ---------------------------------------------------------------------------

_ARG_ATTR_RE = re.compile(r"%arg(\d+):\s*tensor<[^>]*>\s*(?:\{([^}]*)\})?")


def donation_report(fn: Any, *args: Any, **kwargs: Any) -> dict:
    """Lower ``fn(*args)`` and report which *user argnums* are donated.

    Donation shows up in the lowered module as ``tf.aliasing_output`` /
    ``jax.buffer_donor`` attributes on flattened ``%argN`` parameters;
    flat indices are mapped back to user-level positional argnums via
    each argument's pytree leaf count (best-effort: args that lower to
    zero leaves shift the mapping).
    """
    txt = fn.lower(*args, **kwargs).as_text()
    main = txt.split("func.func public @main", 1)
    sig = main[1] if len(main) == 2 else txt
    # cut at the end of the signature to avoid matching body ops
    body_at = sig.find("{\n")
    if body_at > 0:
        sig = sig[:body_at]
    donated_flat = set()
    total_flat = 0
    for m in _ARG_ATTR_RE.finditer(sig):
        total_flat = max(total_flat, int(m.group(1)) + 1)
        attrs = m.group(2) or ""
        if "tf.aliasing_output" in attrs or "jax.buffer_donor" in attrs:
            donated_flat.add(int(m.group(1)))
    # flat index -> user argnum
    leaf_counts = [len(jax.tree_util.tree_leaves(a)) for a in args]
    donated_argnums = set()
    lo = 0
    for argnum, n in enumerate(leaf_counts):
        rng = range(lo, lo + n)
        if n and all(i in donated_flat for i in rng):
            donated_argnums.add(argnum)
        lo += n
    return {
        "flat_args": total_flat,
        "donated_flat": sorted(donated_flat),
        "donated_argnums": sorted(donated_argnums),
        "leaf_counts": leaf_counts,
    }


def verify_donation(fn: Any, args: Sequence[Any], expect_donated: Iterable[int]) -> dict:
    """Raise :class:`ContractViolation` unless every argnum in
    ``expect_donated`` is fully donated in the lowered module."""
    rep = donation_report(fn, *args)
    missing = sorted(set(expect_donated) - set(rep["donated_argnums"]))
    if missing:
        raise ContractViolation(
            f"argnums {missing} are not donated in the lowered module "
            f"(donated: {rep['donated_argnums']})"
        )
    return rep


def runtime_donation_check(
    fn: Callable[..., Any], args: Sequence[Any], donated: Iterable[int]
) -> Any:
    """Call ``fn(*args)`` and verify the donated inputs were actually
    consumed (every leaf buffer deleted).  Returns the call's result."""
    out = fn(*args)
    jax.block_until_ready(out)
    not_deleted = []
    for argnum in donated:
        for leaf in jax.tree_util.tree_leaves(args[argnum]):
            if hasattr(leaf, "is_deleted") and not leaf.is_deleted():
                not_deleted.append(argnum)
                break
    if not_deleted:
        raise ContractViolation(
            f"donated argnums {sorted(set(not_deleted))} still alive after the "
            "call — donation silently dropped (aliasing mismatch or a second "
            "reference pinned the buffer)"
        )
    return out


# ---------------------------------------------------------------------------
# AER address-width bounds
# ---------------------------------------------------------------------------


def aer_bounds_report(
    layer_sizes: Sequence[int],
    capacities: Mapping[int, int] | Sequence[int] | None = None,
    num_steps: int | None = None,
) -> dict:
    """Check every ``StepEventTable`` address dtype against the width it
    must index, and the int8 value / int32 count lanes against their
    ranges.  Layer 0 is the input plane; layer ``i`` feeds addresses in
    ``[0, layer_sizes[i])``.
    """
    from repro.events import aer

    layers = []
    ok = True
    for i, width in enumerate(layer_sizes):
        dtype = aer.addr_dtype_for(width)
        max_addr = int(jnp.iinfo(dtype).max)
        fits = width - 1 <= max_addr
        ok &= fits
        cap = None
        if capacities is not None:
            try:
                cap = capacities[i]  # works for both dict and sequence
            except (KeyError, IndexError):
                cap = None
        cap_fits = cap is None or cap <= np.iinfo(np.int32).max
        ok &= cap_fits
        layers.append(
            {
                "layer": i,
                "width": int(width),
                "addr_dtype": np.dtype(dtype).name,
                "max_addr": max_addr,
                "addr_fits": bool(fits),
                "capacity": None if cap is None else int(cap),
                "count_fits_int32": bool(cap_fits),
            }
        )
    # value lane: spike values are 0/1 (optionally small counts when
    # merged); int8 holds them as long as per-step multiplicity < 128
    value_headroom = int(np.iinfo(np.int8).max)
    if num_steps is not None:
        ok &= num_steps < 2**31
    return {"ok": bool(ok), "layers": layers, "value_max": value_headroom}


def check_aer_bounds(
    layer_sizes: Sequence[int],
    capacities: Mapping[int, int] | Sequence[int] | None = None,
) -> list[str]:
    """Return violation strings (empty == clean)."""
    rep = aer_bounds_report(layer_sizes, capacities)
    out = []
    for lay in rep["layers"]:
        if not lay["addr_fits"]:
            out.append(
                f"layer {lay['layer']}: width {lay['width']} overflows "
                f"{lay['addr_dtype']} addresses (max {lay['max_addr']})"
            )
        if not lay["count_fits_int32"]:
            out.append(
                f"layer {lay['layer']}: capacity {lay['capacity']} overflows int32 counts"
            )
    return out
