"""Demo: streaming event-driven SNN serving with async admission.

Builds a small collision-avoidance SNN, then serves a mixed workload
through the streaming engine's ``submit()/poll()`` scheduler:

  1. rate-coded camera frames (procedural collision scenes), and
  2. synthetic DVS event-camera recordings (AER brightness-change events),
     submitted *mid-flight* — while the rate-coded requests' chunks are
     still integrating — with a latency deadline and elevated priority,
     so they overtake the queued tail of the first batch.

More requests than slots, so continuous batching, the persistent per-slot
membrane state, and deadline/queue-wait accounting are all exercised.
The end-of-run report comes straight from the engine's observability
layer (``repro.obs``) rather than ad-hoc per-request prints: the
metrics-registry snapshot (latency / queue-wait / energy histogram
percentiles, request counters), windowed rates from the time-series
sampler, and the multi-window burn-rate SLO verdict
(``engine.health()``).  One ad-hoc line survives — the per-traffic-class
mean energy — because it is the paper's claim in miniature: the sparse
DVS inputs are far cheaper than dense-ish rate coding at identical
network shape.

Run:  PYTHONPATH=src python examples/event_stream_serving.py \
          [--steps 25] [--seed 0] [--requests 12]

``--steps``/``--seed`` pin the coding window and every random draw (data,
weights, encodings), so CI smoke runs are deterministic.
"""

import argparse

import jax
import numpy as np

from repro.core import snn
from repro.data import collision
from repro.events import aer
from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

HW = 32


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=25,
                    help="SNN coding window (time steps)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for weights, data and encodings")
    ap.add_argument("--requests", type=int, default=12,
                    help="total requests (half rate-coded, half DVS)")
    args = ap.parse_args()
    n_rate = args.requests // 2
    n_dvs = args.requests - n_rate

    cfg = snn.SNNConfig(layer_sizes=(HW * HW, 128, 2), num_steps=args.steps)
    params = snn.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = SNNStreamEngine(params, cfg, num_slots=4, chunk_steps=5,
                             seed=args.seed)

    rate_reqs = []
    if n_rate:
        # rate-coded procedural camera frames
        data_cfg = collision.CollisionConfig(image_hw=HW, num_train=0,
                                             num_test=n_rate, seed=args.seed)
        _, _, frames, labels = collision.generate(data_cfg)
        rate_reqs = [StreamRequest(image=f.reshape(-1)) for f in frames]

    dvs_reqs = []
    if n_dvs:
        # synthetic DVS event streams, densified to the engine's input plane
        # (ON events only — the engine's input layer is HW*HW wide; see
        # launch/serve.py --dvs --polarity for the polarity-aware input layer)
        stream, dvs_labels = aer.dvs_collision_batch(
            jax.random.PRNGKey(args.seed + 1), n_dvs, image_hw=HW,
            num_steps=cfg.num_steps, capacity=8 * HW * HW,
        )
        planes = aer.input_planes(stream, cfg.num_steps, HW * HW,
                                  polarity_mode="on_only")
        # the "collision sensor" traffic class: tight deadline, priority —
        # admitted ahead of the queued rate-coded tail
        dvs_reqs = [
            StreamRequest(spikes=np.asarray(planes[:, i]),
                          deadline_s=2.0, priority=1)
            for i in range(n_dvs)
        ]

    # async admission: rate-coded requests first, then the DVS burst lands
    # mid-flight after a couple of scheduler rounds
    for r in rate_reqs:
        engine.submit(r)
    results = engine.poll() + engine.poll()
    for r in dvs_reqs:
        engine.submit(r)
    results += engine.drain()
    results.sort(key=lambda r: r.request_id)
    kinds = ["rate"] * n_rate + ["dvs"] * n_dvs

    # ------- end-of-run report, straight from the observability layer
    snap = engine.metrics_snapshot()
    print(f"served {len(results)} requests "
          f"({n_rate} rate-coded, {n_dvs} DVS) on 4 slots")
    print("metrics snapshot (registry histograms, per request):")
    for key, unit, scale in (
        ("engine.request.latency_s", "ms", 1e3),
        ("engine.request.queue_wait_s", "ms", 1e3),
        ("engine.request.energy_pj", "nJ", 1e-3),
    ):
        h = snap[key]
        print(f"  {key}: p50={h['p50']*scale:.1f}{unit} "
              f"p90={h['p90']*scale:.1f}{unit} "
              f"p99={h['p99']*scale:.1f}{unit} (n={h['count']})")
    print(f"  deadline misses: "
          f"{snap['engine.requests.deadline_missed']['value']:.0f}"
          f"/{snap['engine.requests.completed']['value']:.0f} | "
          f"throughput {engine.events_per_sec():.0f} events/s over "
          f"{engine.total_steps} slot-steps")
    ts = engine.timeseries
    print(f"time series ({len(ts)} samples over {ts.span_s():.2f}s): "
          f"windowed miss-rate {engine.windowed_miss_rate(1.0):.1%}, "
          f"{ts.rate('engine.episode.events', 1.0):.0f} events/s (1s)")

    # the paper's claim in miniature: sparse DVS inputs cost far less
    # than dense-ish rate coding at identical network shape
    for kind in ("rate", "dvs"):
        sel = [r for r in results if kinds[r.request_id] == kind]
        if not sel:
            continue
        e = np.mean([r.energy_pj for r in sel])
        rt = np.mean([r.spike_rate for r in sel])
        print(f"  {kind:4s}: mean input rate {rt:.3f}, "
              f"mean measured energy {e/1e3:.1f} nJ/inference")

    # SLO verdict: multi-window burn-rate evaluation over the series
    health = engine.health()
    fired = [
        f"{s['name']}:{s['status']}"
        for s in health["slos"] if s["status"] != "healthy"
    ]
    print(f"SLO verdict: {health['status'].upper()}"
          + (f" ({', '.join(fired)})" if fired else "")
          + f" — {len(health['slos'])} SLOs evaluated over "
            f"{health['span_s']:.2f}s of samples")


if __name__ == "__main__":
    main()
