"""End-to-end driver: the paper's full experiment at full scale.

Trains the paper's 4096-512-2 LIF SNN (25 time steps, Adam lr 5e-4,
dropout, CE summed over steps — §4.2) on 64x64 collision scenes for a few
hundred steps with checkpointing/auto-resume, evaluates train/test
accuracy (Table 1 row), and compares the LIF vs Lapicque neuron models.

  PYTHONPATH=src python examples/collision_avoidance.py \
      [--neuron lif|lapicque] [--image-hw 64] [--steps 300] [--seed 0] \
      [--refractory 0] [--q115] [--ckpt /tmp/snn_ckpt]

``--steps``/``--seed`` make runs deterministic (data, init, encoding and
dropout all derive from --seed), so CI smoke can pin exact behavior.

(--steps 300 with batch 64 ~= 5 epochs over the default 4096 images;
pass --num-train 32768 to match the paper's dataset size if you have the
CPU budget.)
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import coding, snn
from repro.data import collision
from repro.optim import adam, chain_clip
from repro.optim.adam import apply_updates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--neuron", default="lif", choices=["lif", "lapicque"])
    ap.add_argument("--image-hw", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=512)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--num-train", type=int, default=4096)
    ap.add_argument("--num-test", type=int, default=1024)
    ap.add_argument("--refractory", type=int, default=0)
    ap.add_argument("--q115", action="store_true",
                    help="QAT: train with Q1.15 fake-quant weights")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for data, init, encoding and dropout")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = snn.SNNConfig(
        layer_sizes=(args.image_hw**2, args.hidden, 2),
        num_steps=25,
        neuron_kind=args.neuron,
        refractory_steps=args.refractory,
        dropout_rate=0.2,
        quant_q115=args.q115,
    )
    print(f"config: {cfg}")
    trx, trY, tex, teY = collision.generate(
        collision.CollisionConfig(
            image_hw=args.image_hw, num_train=args.num_train,
            num_test=args.num_test, seed=args.seed,
        )
    )

    key = jax.random.PRNGKey(args.seed)
    params = snn.init_params(key, cfg)
    opt = chain_clip(adam(5e-4), 1.0)
    opt_state = opt.init(params)
    start_step = 0
    ckpt = CheckpointManager(args.ckpt, keep_n=2) if args.ckpt else None
    if ckpt:
        st, restored = ckpt.restore_latest(
            {"params": params, "opt": opt_state}
        )
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = st
            print(f"resumed from step {st}")

    @jax.jit
    def train_step(params, opt_state, x, y, k):
        ek, dk = jax.random.split(k)
        spikes = coding.rate_encode(ek, x, cfg.num_steps)
        (l, aux), g = jax.value_and_grad(snn.loss_fn, has_aux=True)(
            params, spikes, y, cfg, train=True, dropout_key=dk
        )
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, l, aux

    def epoch_batches(seed):
        yield from collision.batches(trx, trY, args.batch, seed=seed)

    it = None
    epoch = 0
    t0 = time.time()
    for step_no in range(start_step, args.steps):
        if it is None:
            it = epoch_batches(epoch)
        try:
            x, y = next(it)
        except StopIteration:
            epoch += 1
            it = epoch_batches(epoch)
            x, y = next(it)
        key, sk = jax.random.split(key)
        params, opt_state, loss, aux = train_step(params, opt_state, x, y, sk)
        if step_no % 25 == 0 or step_no == args.steps - 1:
            dt = (time.time() - t0) / max(step_no - start_step + 1, 1)
            print(
                f"step {step_no:5d} loss={float(loss):7.3f} "
                f"acc={float(aux['accuracy']):.3f} "
                f"spike_rate={float(aux['spike_rate']):.4f} "
                f"({dt*1e3:.0f} ms/step)", flush=True,
            )
        if ckpt and step_no and step_no % 100 == 0:
            ckpt.save(step_no, {"params": params, "opt": opt_state})

    # ---- evaluation (Table 1 row) ----------------------------------------
    def accuracy(x, y, k, bs=128):
        correct = 0
        for s in range(0, len(x), bs):
            k, ek = jax.random.split(k)
            spikes = coding.rate_encode(
                ek, jnp.asarray(x[s:s+bs].reshape(-1, cfg.layer_sizes[0])),
                cfg.num_steps,
            )
            _, aux = snn.loss_fn(
                params, spikes, jnp.asarray(y[s:s+bs]), cfg, train=False
            )
            correct += float(aux["accuracy"]) * len(y[s:s+bs])
        return correct / len(x)

    tr_acc = accuracy(trx[:2048], trY[:2048], jax.random.PRNGKey(args.seed + 1))
    te_acc = accuracy(tex, teY, jax.random.PRNGKey(args.seed + 2))
    print(
        f"\nRESULT neuron={args.neuron} image={args.image_hw}px "
        f"refractory={args.refractory} q115={args.q115}: "
        f"train_acc={tr_acc:.3f} test_acc={te_acc:.3f}"
    )
    print("paper Table 1 (DroNet, for reference): "
          "LIF 64px: 92%/85%; Lapicque 64px: 95%/81%")
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        ckpt.wait()


if __name__ == "__main__":
    main()
