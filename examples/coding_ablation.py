"""Input-coding ablation (paper §3.2): rate vs TTFS vs deterministic rate.

The paper chooses Bernoulli rate coding "for its simplicity and
robustness"; this ablation quantifies the choice on the collision task:
accuracy, total input spike count (the event-driven energy driver), and
energy per inference.

  PYTHONPATH=src python examples/coding_ablation.py
"""

import jax
import jax.numpy as jnp

from repro.core import coding, energy, snn
from repro.data import collision
from repro.optim import adam, chain_clip
from repro.optim.adam import apply_updates

CFG = snn.SNNConfig(layer_sizes=(1024, 128, 2), num_steps=20,
                    dropout_rate=0.2)
DATA = collision.CollisionConfig(image_hw=32, num_train=1024, num_test=256)

ENCODERS = {
    "rate (paper)": lambda key, x, T: coding.rate_encode(key, x, T),
    "rate_deterministic": lambda key, x, T: coding.rate_encode_deterministic(x, T),
    "ttfs": lambda key, x, T: coding.ttfs_encode(x, T),
}


def train_eval(encode, data, seed=0):
    trx, trY, tex, teY = data
    key = jax.random.PRNGKey(seed)
    params = snn.init_params(key, CFG)
    opt = chain_clip(adam(5e-4), 1.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y, k):
        ek, dk = jax.random.split(k)
        spikes = encode(ek, x, CFG.num_steps)
        (_, aux), g = jax.value_and_grad(snn.loss_fn, has_aux=True)(
            params, spikes, y, CFG, train=True, dropout_key=dk
        )
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, aux

    for epoch in range(4):
        for x, y in collision.batches(trx, trY, 64, seed=epoch):
            key, sk = jax.random.split(key)
            params, state, _ = step(params, state, x, y, sk)

    key, ek = jax.random.split(key)
    spikes = encode(ek, jnp.asarray(tex.reshape(len(tex), -1)), CFG.num_steps)
    _, aux = snn.loss_fn(params, spikes, jnp.asarray(teY), CFG, train=False)
    in_rate = float(jnp.mean(spikes))
    rates = snn.hidden_spike_rates(params, spikes, CFG)
    layer_rates = [in_rate] + [float(r) for r in rates][:-1]
    e_pj = energy.snn_inference_ops(
        CFG.layer_sizes, CFG.num_steps, layer_rates
    ).energy_pj()
    return float(aux["accuracy"]), in_rate, e_pj


def main():
    data = collision.generate(DATA)
    print(f"{'encoder':20s} | test_acc | input_rate | energy/inf (nJ)")
    for name, enc in ENCODERS.items():
        acc, rate, e_pj = train_eval(enc, data)
        print(f"{name:20s} | {acc:8.3f} | {rate:10.4f} | {e_pj/1e3:10.2f}")
    print("\nTTFS emits at most one spike per pixel (T-fold fewer input "
          "events) — the energy-optimal code when accuracy holds; the "
          "paper's rate coding is the robust default.")


if __name__ == "__main__":
    main()
