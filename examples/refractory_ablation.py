"""Refractory-period ablation (paper §4.2.2).

Trains the same reduced SNN with refractory periods {0, 2, 5, 8} and
reports accuracy AND spike rate — the energy angle: the refractory period
caps each neuron's firing rate, which in the event-driven hardware
(cascaded adder only integrates active synapses) translates directly into
energy per inference (core/energy.py).

  PYTHONPATH=src python examples/refractory_ablation.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import coding, energy, snn
from repro.data import collision
from repro.optim import adam, chain_clip
from repro.optim.adam import apply_updates

BASE = snn.SNNConfig(layer_sizes=(1024, 128, 2), num_steps=20,
                     dropout_rate=0.2)
DATA = collision.CollisionConfig(image_hw=32, num_train=1024, num_test=256)


def train_eval(cfg, data, seed=0):
    trx, trY, tex, teY = data
    key = jax.random.PRNGKey(seed)
    params = snn.init_params(key, cfg)
    opt = chain_clip(adam(5e-4), 1.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y, k):
        ek, dk = jax.random.split(k)
        spikes = coding.rate_encode(ek, x, cfg.num_steps)
        (_, aux), g = jax.value_and_grad(snn.loss_fn, has_aux=True)(
            params, spikes, y, cfg, train=True, dropout_key=dk
        )
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, aux

    for epoch in range(4):
        for x, y in collision.batches(trx, trY, 64, seed=epoch):
            key, sk = jax.random.split(key)
            params, state, _ = step(params, state, x, y, sk)

    key, ek = jax.random.split(key)
    spikes = coding.rate_encode(
        ek, jnp.asarray(tex.reshape(len(tex), -1)), cfg.num_steps
    )
    _, aux = snn.loss_fn(params, spikes, jnp.asarray(teY), cfg, train=False)
    rates = snn.hidden_spike_rates(params, spikes, cfg)
    in_rate = float(jnp.mean(spikes))
    layer_rates = [in_rate] + [float(r) for r in rates][:-1]
    ops = energy.snn_inference_ops(
        cfg.layer_sizes, cfg.num_steps, layer_rates
    )
    return float(aux["accuracy"]), layer_rates, ops.energy_pj()


def main():
    data = collision.generate(DATA)
    print("refractory | test_acc | hidden_rate | energy/inf (nJ)")
    base_energy = None
    for r in (0, 2, 5, 8):
        cfg = dataclasses.replace(BASE, refractory_steps=r)
        acc, rates, e_pj = train_eval(cfg, data)
        if base_energy is None:
            base_energy = e_pj
        print(
            f"{r:10d} | {acc:8.3f} | {rates[1]:11.4f} | "
            f"{e_pj/1e3:9.2f}  ({e_pj/base_energy:.2f}x)"
        )
    print("\npaper §4.2.2 uses refractory=5; the table quantifies the "
          "accuracy/energy trade the hardware design exploits.")


if __name__ == "__main__":
    main()
