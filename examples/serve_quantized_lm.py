"""Serve a small LM with batched requests — optionally in the paper's
energy-aware Q1.15 quantized mode.

Demonstrates the serving substrate the decode_* dry-run cells lower:
prefill + step-synchronous batched decode with a KV cache, greedy or
temperature sampling, through the same Model API used at 512-chip scale.

  PYTHONPATH=src python examples/serve_quantized_lm.py [--q115] \
      [--arch stablelm-1.6b] [--requests 8] [--new-tokens 24]
"""

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--q115", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    import dataclasses

    cfg = configs.get(args.arch).reduced(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=2048,
    )
    if args.q115:
        cfg = dataclasses.replace(cfg, quant="q115")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    n_params = model.param_count()
    print(f"arch={args.arch} (reduced) params={n_params/1e6:.1f}M "
          f"quant={cfg.quant}")

    engine = ServeEngine(model, params, batch_size=args.batch, cache_len=256)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, rng.integers(8, 32))
            .astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outs = engine.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    print(f"served {len(reqs)} requests, {total_new} new tokens "
          f"in {dt:.2f}s -> {total_new/dt:.1f} tok/s (CPU)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: prompt_len={len(reqs[i].prompt)} -> {o[:10]}...")
    if cfg.quant == "q115":
        print("\nQ1.15 mode: weights snapped to the paper's fixed-point "
              "grid; int16 wire format halves weight bytes (the "
              "decode-cell §Perf hillclimb quantifies the roofline win).")


if __name__ == "__main__":
    main()
