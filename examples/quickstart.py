"""Quickstart: the paper's pipeline in 60 seconds on CPU.

  PYTHONPATH=src python examples/quickstart.py

1. renders a synthetic collision-avoidance scene (DroNet analog),
2. rate-codes it into Bernoulli spike trains (paper Fig. 2),
3. runs the LIF SNN (paper Fig. 4, reduced) forward,
4. trains it for a couple of epochs and reports accuracy,
5. runs the same weights through the hardware path
   (Q1.15 spike_matmul + fused LIF Pallas kernels, interpret mode).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coding, snn
from repro.data import collision
from repro.kernels import ops
from repro.optim import adam, chain_clip
from repro.optim.adam import apply_updates


def main():
    # --- 1. data ---------------------------------------------------------
    cfg_data = collision.CollisionConfig(
        image_hw=32, num_train=1024, num_test=256, seed=0
    )
    trx, trY, tex, teY = collision.generate(cfg_data)
    print(f"dataset: {trx.shape} train, {tex.shape} test, "
          f"P(collision)={trY.mean():.2f}")

    # --- 2. rate coding (paper §3.2) --------------------------------------
    cfg = snn.SNNConfig(layer_sizes=(1024, 128, 2), num_steps=15,
                        dropout_rate=0.2)
    key = jax.random.PRNGKey(0)
    demo = coding.rate_encode(key, jnp.asarray(trx[0].ravel()), cfg.num_steps)
    print(f"rate coding: pixel intensity {trx[0].mean():.2f} -> "
          f"mean spike rate {float(demo.mean()):.2f} over {cfg.num_steps} steps")

    # --- 3/4. train the SNN (Adam lr 5e-4, CE summed over steps) ----------
    params = snn.init_params(key, cfg)
    opt = chain_clip(adam(5e-4), 1.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y, k):
        ek, dk = jax.random.split(k)
        spikes = coding.rate_encode(ek, x, cfg.num_steps)
        (l, aux), g = jax.value_and_grad(snn.loss_fn, has_aux=True)(
            params, spikes, y, cfg, train=True, dropout_key=dk
        )
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, l, aux

    for epoch in range(4):
        for x, y in collision.batches(trx, trY, 64, seed=epoch):
            key, sk = jax.random.split(key)
            params, state, loss, aux = step(params, state, x, y, sk)
        print(f"epoch {epoch}: loss={float(loss):.3f} "
              f"acc={float(aux['accuracy']):.3f}")

    key, ek = jax.random.split(key)
    spikes = coding.rate_encode(
        ek, jnp.asarray(tex.reshape(len(tex), -1)), cfg.num_steps
    )
    _, aux = snn.loss_fn(params, spikes, jnp.asarray(teY), cfg, train=False)
    print(f"test accuracy (float model): {float(aux['accuracy']):.3f}")

    # --- 5. hardware path (paper §4.3) -------------------------------------
    h = spikes[:, :64]
    for i in range(cfg.num_layers):
        lp = params[f"layer{i}"]
        h = ops.snn_layer_forward(
            h, lp["w"], lp["b"], snn.effective_beta(lp), lp["threshold"]
        )
    pred_hw = np.asarray(jnp.sum(h, axis=0).argmax(-1))
    acc_hw = (pred_hw == np.asarray(teY[:64])).mean()
    print(f"test accuracy (Q1.15 + Pallas kernels): {acc_hw:.3f}")


if __name__ == "__main__":
    main()
