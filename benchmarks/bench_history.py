"""Bench-smoke trend tracking: append headline numbers, check for drift.

Each CI bench-smoke run appends one JSONL line of headline numbers to
``BENCH_history.jsonl`` (an uploaded artifact, so the series accumulates
across runs when the previous artifact is restored):

- ``chunk_steps_per_s`` — the engine's device-resident chunk throughput
  (``stream_bench.json`` ``chunk.steps_per_s``; higher is better),
- ``vs_bench`` — the serving cross-check ratio against BENCH_snn.json's
  ``overhauled_jnp`` path (higher is better),
- ``p99_latency_ms`` — open-loop serving p99 (lower is better),
- ``obs_overhead_frac`` — measured per-tick instrumentation cost as a
  fraction of a tick (lower is better),
- ``bench_steps_per_s`` — BENCH_snn.json's own ``overhauled_jnp``
  figure, so engine drift and kernel drift separate,
- ``shed_rate`` — the v5 chaos probe's admission shed rate (lower is
  better: a rising trend at fixed load means serving got slower and
  the feasibility shedder is rejecting more),
- ``chaos_miss_rate`` — deadline miss rate among the chaos probe's
  served requests (lower is better; with shedding on, hopeless
  deadlines shed instead of missing, so this should sit near zero),
- ``recovery_restore_us`` — the v7 warm-restart cost: wall time to
  restore a full engine snapshot (lower is better; a rising trend
  means crash recovery is getting slower).

Fault-tolerance metrics are absent from pre-v5 artifacts and the
recovery metric from pre-v7 ones; the trend check skips metrics a run
did not record.

``check`` compares the newest entry against the **rolling median** of
the preceding window (default 8 runs) per metric, direction-aware, and
warns on a >15% regression.  It is deliberately **soft-fail** (exit 0)
until the series is long enough to trust on shared CI runners — pass
``--hard`` to turn warnings into a nonzero exit.  Fewer than 3 prior
entries: the check reports "insufficient history" and passes.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_history append \
      --stream stream_bench.json [--bench BENCH_snn.json] \
      [--history BENCH_history.jsonl] [--run-id $GITHUB_SHA]
  PYTHONPATH=src python -m benchmarks.bench_history check \
      [--history BENCH_history.jsonl] [--threshold 0.15] [--window 8] \
      [--hard]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional

SCHEMA = "bench_history/v1"
REPO_ROOT = Path(__file__).resolve().parents[1]
DEFAULT_HISTORY = REPO_ROOT / "BENCH_history.jsonl"

# metric -> direction ("up" = higher is better, regression is a drop;
# "down" = lower is better, regression is a rise)
METRICS = {
    "chunk_steps_per_s": "up",
    "vs_bench": "up",
    "p99_latency_ms": "down",
    "obs_overhead_frac": "down",
    "bench_steps_per_s": "up",
    "shed_rate": "down",
    "chaos_miss_rate": "down",
    "recovery_restore_us": "down",
}


def headline(
    stream_path: Path, bench_path: Optional[Path] = None
) -> Dict:
    """Extract one history entry's headline numbers from the bench
    JSONs (raises on unreadable/missing stream_bench.json — there is
    nothing to record without it)."""
    doc = json.loads(Path(stream_path).read_text())
    entry = {
        "schema": SCHEMA,
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "chunk_steps_per_s": doc["chunk"]["steps_per_s"],
        "vs_bench": doc["chunk"]["vs_bench_overhauled_jnp"],
        "p99_latency_ms": doc["open_loop"]["p99_latency_ms"],
        "obs_overhead_frac": doc["obs_overhead"]["overhead_frac"],
        "slo_status": doc.get("slo", {}).get("status"),
    }
    # v5 fault-tolerance headlines (absent on pre-v5 artifacts; check()
    # already skips metrics an entry does not carry)
    chaos = doc.get("fault_tolerance", {}).get("chaos", {})
    if isinstance(chaos.get("shed_rate"), (int, float)):
        entry["shed_rate"] = chaos["shed_rate"]
    if isinstance(chaos.get("deadline_miss_rate"), (int, float)):
        entry["chaos_miss_rate"] = chaos["deadline_miss_rate"]
    if isinstance(chaos.get("quarantined"), int):
        entry["chaos_quarantined"] = chaos["quarantined"]
    # v7 crash-safety headline: warm-restart cost (absent pre-v7)
    rec = doc.get("recovery", {})
    if isinstance(rec.get("restore_us"), (int, float)):
        entry["recovery_restore_us"] = rec["restore_us"]
    if isinstance(rec.get("preemptions"), int):
        entry["recovery_preemptions"] = rec["preemptions"]
    if bench_path and Path(bench_path).exists():
        ref = json.loads(Path(bench_path).read_text())
        entry["bench_steps_per_s"] = (
            ref["paths"]["overhauled_jnp"]["steps_per_s"]
        )
    return entry


def append(
    history_path: Path,
    stream_path: Path,
    bench_path: Optional[Path] = None,
    run_id: Optional[str] = None,
) -> Dict:
    entry = headline(stream_path, bench_path)
    if run_id:
        entry["run_id"] = run_id
    with open(history_path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def load(history_path: Path) -> List[Dict]:
    """Parse the history, skipping malformed lines (a truncated artifact
    restore must not kill the trend check)."""
    entries: List[Dict] = []
    p = Path(history_path)
    if not p.exists():
        return entries
    for line in p.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(obj, dict) and obj.get("schema") == SCHEMA:
            entries.append(obj)
    return entries


def check(
    history_path: Path,
    threshold: float = 0.15,
    window: int = 8,
    min_history: int = 3,
) -> List[str]:
    """Direction-aware trend check of the newest entry vs the rolling
    median of up to ``window`` preceding entries; returns warning
    strings (empty = no regression detected)."""
    entries = load(history_path)
    if len(entries) < min_history + 1:
        print(
            f"bench-history: {len(entries)} entries — need "
            f">{min_history} for a trend check, passing"
        )
        return []
    latest, prior = entries[-1], entries[-1 - window:-1]
    warnings: List[str] = []
    for metric, direction in METRICS.items():
        cur = latest.get(metric)
        hist = sorted(
            e[metric] for e in prior
            if isinstance(e.get(metric), (int, float))
        )
        if not isinstance(cur, (int, float)) or len(hist) < min_history:
            continue
        med = hist[len(hist) // 2]
        if med == 0:
            continue
        change = (cur - med) / abs(med)
        regressed = (
            change < -threshold if direction == "up"
            else change > threshold
        )
        if regressed:
            warnings.append(
                f"{metric}: {cur:.6g} vs rolling median {med:.6g} "
                f"({change:+.1%}, {'higher' if direction == 'down' else 'lower'}"
                f" is worse) exceeds the {threshold:.0%} budget"
            )
    return warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    ap_a = sub.add_parser("append", help="record one run's headlines")
    ap_a.add_argument("--stream", type=Path,
                      default=REPO_ROOT / "stream_bench.json")
    ap_a.add_argument("--bench", type=Path,
                      default=REPO_ROOT / "BENCH_snn.json")
    ap_a.add_argument("--history", type=Path, default=DEFAULT_HISTORY)
    ap_a.add_argument("--run-id", default=None)
    ap_c = sub.add_parser("check", help="warn on >threshold regression")
    ap_c.add_argument("--history", type=Path, default=DEFAULT_HISTORY)
    ap_c.add_argument("--threshold", type=float, default=0.15)
    ap_c.add_argument("--window", type=int, default=8)
    ap_c.add_argument("--hard", action="store_true",
                      help="exit nonzero on regression warnings "
                           "(default: soft-fail, warnings only)")
    args = ap.parse_args(argv)

    if args.cmd == "append":
        entry = append(
            args.history, args.stream, args.bench, run_id=args.run_id
        )
        shown = {
            k: v for k, v in entry.items()
            if k in METRICS or k == "slo_status"
        }
        print(f"bench-history: appended to {args.history}: "
              + json.dumps(shown, sort_keys=True))
        return 0

    warnings = check(
        args.history, threshold=args.threshold, window=args.window
    )
    for w in warnings:
        print(f"bench-history REGRESSION WARNING: {w}", file=sys.stderr)
    if not warnings:
        print("bench-history: no regression vs rolling median")
    return 1 if (warnings and args.hard) else 0


if __name__ == "__main__":
    raise SystemExit(main())
