"""Paper Table 4 analog: network-level comparison.

The paper reports slice/LUT/frequency for full SNNs (theirs: 4096-512-2
on Artix-7 at 67 MHz).  TPU/CPU analog: end-to-end inference micro-
benchmarks of the full network at the paper's three image sizes, on both
the float path and the hardware (Q1.15 + Pallas) path, with op counts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import coding, energy, snn
from repro.kernels import ops

STEPS = 25
HIDDEN = 512


def run(image_sizes=(32, 64)) -> None:
    rng = np.random.default_rng(0)
    for hw in image_sizes:
        layers = (hw * hw, HIDDEN, 2)
        cfg = snn.SNNConfig(layer_sizes=layers, num_steps=STEPS)
        params = snn.init_params(jax.random.PRNGKey(0), cfg)
        B = 8
        x = jnp.asarray(rng.random((B, hw * hw)).astype(np.float32))
        spikes = coding.rate_encode_deterministic(x, STEPS)

        fwd = jax.jit(
            lambda s: snn.forward(params, s, cfg, train=False)[1]
        )
        us_float = time_fn(fwd, spikes)

        def hw_path(s):
            h = s
            for i in range(cfg.num_layers):
                lp = params[f"layer{i}"]
                h = ops.snn_layer_forward(
                    h, lp["w"], lp["b"],
                    snn.effective_beta(lp), lp["threshold"],
                )
            return h

        us_hw = time_fn(hw_path, spikes, warmup=1, iters=3)

        rates = snn.hidden_spike_rates(params, spikes, cfg)
        opcount = energy.snn_inference_ops(
            layers, STEPS, [float(jnp.mean(spikes))] + [float(r) for r in rates][:-1]
        )
        emit(
            f"table4/snn_{hw}px_float",
            us_float / B,
            f"arch={layers[0]}-{layers[1]}-{layers[2]};steps={STEPS};"
            f"ops_per_inf={opcount.total_ops():.2e};"
            "paper_arch=4096-512-2;paper_freq_mhz=67",
        )
        emit(
            f"table4/snn_{hw}px_q115_kernels",
            us_hw / B,
            "path=spike_matmul+lif_fused(interpret);"
            "note=us_per_call dominated by interpret mode on CPU",
        )


if __name__ == "__main__":
    run()
