"""Paper Table 2 analog: SNN vs BCNN energy efficiency.

The paper measures 495 mW / 541 GOPS / 1093 GOPS/W for its SNN on Artix-7
vs 2300 mW / 329 GOPS / 143 GOPS/W for the BCNN baseline [36] — an 86%
energy-efficiency gain.  No watt-meter exists in this container, so we
price the *measured operation mix* of both trained models with the
Horowitz 45nm per-op energy table (core/energy.py):

  - a small SNN is trained on the collision data; its measured per-layer
    spike rates drive the event-driven op count;
  - the BCNN baseline (core/bcnn.py) is trained on the same data; its
    dense binarized op count is priced the same way.

Reported: GOPS/W analog for both + the efficiency gain, next to the
paper's 0.86.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import bcnn, coding, energy, snn
from repro.data import collision
from repro.optim import adam, chain_clip
from repro.optim.adam import apply_updates

HW = 32
LAYERS = (HW * HW, 128, 2)
STEPS = 15


def _train_snn(trx, trY, epochs=4):
    cfg = snn.SNNConfig(layer_sizes=LAYERS, num_steps=STEPS, dropout_rate=0.2)
    key = jax.random.PRNGKey(0)
    params = snn.init_params(key, cfg)
    opt = chain_clip(adam(5e-4), 1.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y, key):
        ekey, dkey = jax.random.split(key)
        spikes = coding.rate_encode(ekey, x, cfg.num_steps)
        (_, aux), g = jax.value_and_grad(snn.loss_fn, has_aux=True)(
            params, spikes, y, cfg, train=True, dropout_key=dkey
        )
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, aux

    for e in range(epochs):
        for x, y in collision.batches(trx, trY, 64, seed=e):
            key, sk = jax.random.split(key)
            params, state, aux = step(params, state, x, y, sk)
    return cfg, params


def _train_bcnn(trx, trY, epochs=4):
    cfg = bcnn.BCNNConfig(input_hw=HW, channels=(8, 16, 32))
    params = bcnn.init_params(jax.random.PRNGKey(0), cfg)
    opt = chain_clip(adam(1e-3), 1.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        (_, aux), g = jax.value_and_grad(bcnn.loss_fn, has_aux=True)(
            params, x.reshape(-1, HW, HW), y, cfg
        )
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, aux

    for e in range(epochs):
        for x, y in collision.batches(trx, trY, 64, seed=e):
            params, state, aux = step(params, state, x, y)
    return cfg, params


def run() -> None:
    t0 = time.time()
    trx, trY, tex, teY = collision.generate(
        collision.CollisionConfig(image_hw=HW, num_train=1024, num_test=256)
    )
    scfg, sparams = _train_snn(trx, trY)
    bcfg, bparams = _train_bcnn(trx, trY)

    # measured spike rates on test data drive the event-driven op count
    key = jax.random.PRNGKey(7)
    x = jnp.asarray(tex[:128].reshape(128, -1))
    spikes_in = coding.rate_encode(key, x, scfg.num_steps)
    layer_rates = snn.hidden_spike_rates(sparams, spikes_in, scfg)
    in_rate = float(jnp.mean(spikes_in))
    rates = [in_rate] + [float(r) for r in layer_rates][:-1]

    # price the PAPER-scale network (4096-512-2, T=25) at the measured
    # trained spike rates — the paper's Table-2 row is its full SNN
    snn_ops = energy.snn_inference_ops((4096, 512, 2), 25, rates)
    conv, fc = bcnn.conv_shapes_for_energy(bcfg)
    bcnn_small_ops = energy.bcnn_inference_ops(conv, fc)
    # the paper's Table-2 baseline at its PUBLISHED per-frame scale [36]
    bcnn36_ops = energy.bcnn36_inference_ops()
    reduction = energy.energy_reduction(snn_ops, bcnn36_ops)

    # accuracy context (both on same data)
    _, aux_s = snn.loss_fn(
        sparams, spikes_in, jnp.asarray(teY[:128]), scfg, train=False
    )
    _, aux_b = bcnn.loss_fn(
        bparams, jnp.asarray(tex[:128]), jnp.asarray(teY[:128]), bcfg
    )

    emit(
        "table2/snn_paper_scale_4096_512_2",
        (time.time() - t0) * 1e6,
        f"energy_uj_per_inf={snn_ops.energy_pj()/1e6:.3f};"
        f"ops_per_inf={snn_ops.total_ops():.2e};"
        f"in_rate={in_rate:.3f};hidden_rate={rates[1]:.3f};"
        f"acc={float(aux_s['accuracy']):.3f};paper=495mW,541GOPS,1093GOPS/W",
    )
    emit(
        "table2/bcnn36_published_scale",
        0.0,
        f"energy_uj_per_inf={bcnn36_ops.energy_pj()/1e6:.3f};"
        f"ops_per_inf={bcnn36_ops.total_ops():.2e};"
        "paper=2300mW,329GOPS,143GOPS/W",
    )
    emit(
        "table2/bcnn_small_same_task",
        0.0,
        f"energy_uj_per_inf={bcnn_small_ops.energy_pj()/1e6:.3f};"
        f"ops_per_inf={bcnn_small_ops.total_ops():.2e};"
        f"acc={float(aux_b['accuracy']):.3f};note=iso-task-small-baseline",
    )
    emit(
        "table2/energy_reduction_vs_bcnn36",
        0.0,
        f"reduction={reduction:.3f};paper_claim=0.86;"
        "metric=1-E_snn/E_bcnn_per_inference",
    )
    emit(
        "table2/paper_arithmetic_check",
        0.0,
        f"published_ratio={(1093-143)/1093:.3f};matches_86pct_claim=True",
    )

    # ---- GOPS/W cross-check vs the paper's measured Artix-7 table -------
    # Price the paper-scale SNN from *event counts at the matched (trained,
    # measured) spike rates* via snn_ops_from_events, and report how far the
    # 45nm-op-model GOPS/W lands from the paper's watt-meter numbers.  The
    # deviation is expected and documented: Horowitz per-op pJ excludes the
    # FPGA's static/platform power, which dominates the Artix-7 measurement.
    paper_sizes, paper_T = (4096, 512, 2), 25
    matched_events = [
        r * fi * paper_T for r, fi in zip(rates, paper_sizes[:-1])
    ]
    snn_meas = energy.snn_ops_from_events(paper_sizes, paper_T, matched_events)
    for name, oc in (("snn", snn_meas), ("bcnn36", bcnn36_ops)):
        paper_row = energy.PAPER_TABLE2[name]
        model_gopsw = oc.gops_per_watt()
        dev = energy.gopsw_deviation(model_gopsw, paper_row["gops_per_w"])
        emit(
            f"table2/gopsw_crosscheck_{name}",
            0.0,
            f"model_gopsw={model_gopsw:.0f};"
            f"paper_gopsw={paper_row['gops_per_w']:.0f};"
            f"deviation={dev:+.2f};"
            f"matched_rates={','.join(f'{r:.3f}' for r in rates)};"
            "note=op-model-excludes-platform-power",
        )
    # The SNN GOPS/W lands within ~1/3 of the Artix-7 measurement; the
    # BCNN's deviates wildly because GOPS/W *rewards cheap ops* (a 0.02 pJ
    # XNOR counts the same as a 0.1 pJ add) while the paper's number folds
    # in the whole FPGA's power draw.  The portable cross-check is energy
    # per classification — emitted above as table2/energy_reduction
    # (model 0.856 vs the paper's 0.86 claim).
    model_ratio = snn_meas.gops_per_watt() / bcnn36_ops.gops_per_watt()
    paper_ratio = (
        energy.PAPER_TABLE2["snn"]["gops_per_w"]
        / energy.PAPER_TABLE2["bcnn36"]["gops_per_w"]
    )
    emit(
        "table2/gopsw_ratio_crosscheck",
        0.0,
        f"model_snn_over_bcnn={model_ratio:.2f};"
        f"paper_snn_over_bcnn={paper_ratio:.2f};"
        f"ratio_deviation={energy.gopsw_deviation(model_ratio, paper_ratio):+.2f};"
        "note=gopsw-rewards-cheap-xnor-ops,see-energy_reduction-row-for-the-"
        "portable-per-inference-comparison",
    )


if __name__ == "__main__":
    run()
