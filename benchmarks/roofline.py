"""Roofline table: read experiments/dryrun/*.json and print §Roofline.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
                                               [--mesh single] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.launch.shapes import SHAPES
import repro.configs as configs


def load_cells(directory: str, baseline_only: bool = True) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        stem = os.path.splitext(os.path.basename(path))[0]
        if baseline_only and len(stem.split("__")) != 3:
            continue  # skip §Perf variant cells (tagged filenames)
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_row(c: Dict) -> str:
    if c["status"] == "skipped":
        return (
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | — | "
            f"{c['reason'].split(':')[0]} | — |"
        )
    if c["status"] == "error":
        return (
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | — | "
            f"ERROR | — |"
        )
    r = c["roofline"]
    dom = r["dominant"].replace("_s", "")
    return (
        f"| {c['arch']} | {c['shape']} | {c['mesh']} "
        f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
        f"| {r['collective_s']*1e3:.2f} | {dom} "
        f"| {r['useful_flops_ratio']:.2f} "
        f"| {c['memory_analysis']['peak_live_bytes']/2**30:.1f} |"
    )


def run(directory="experiments/dryrun", mesh=None, tag=None) -> None:
    cells = load_cells(directory)
    if mesh:
        cells = [c for c in cells if c.get("mesh") == mesh]
    if tag is not None:
        cells = [c for c in cells if c.get("variant") == tag]
    print(
        "| arch | shape | mesh | compute(ms) | memory(ms) | collective(ms) "
        "| dominant | useful | peak GiB/dev |"
    )
    print("|---|---|---|---|---|---|---|---|---|")
    order = {a: i for i, a in enumerate(configs.ARCH_IDS)}
    sorder = {s: i for i, s in enumerate(SHAPES)}
    cells.sort(
        key=lambda c: (order.get(c["arch"], 99), sorder.get(c["shape"], 9),
                       c.get("mesh", ""))
    )
    for c in cells:
        print(fmt_row(c))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    run(args.dir, args.mesh)


if __name__ == "__main__":
    main()
