"""Kernel-level benchmarks: Pallas kernels vs jnp oracles (interpret mode
on CPU — wall times here validate plumbing; real perf numbers come from
the dry-run roofline, since Mosaic doesn't run on CPU).

Derived column reports the structural perf model per kernel: HBM bytes
moved and arithmetic ops, the quantities the kernel is designed around.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref


def run() -> None:
    rng = np.random.default_rng(0)

    # lif_fused: (T,B,N) = paper network hidden layer
    T, B, N = 25, 8, 512
    cur = jnp.asarray(rng.normal(0, 0.7, (T, B, N)).astype(np.float32))
    beta = jnp.asarray(rng.uniform(0.6, 0.95, N).astype(np.float32))
    thr = jnp.ones((N,), jnp.float32)
    us_k = time_fn(
        lambda c: ops.lif_fused(c, beta, thr)[0], cur, warmup=1, iters=3
    )
    us_r = time_fn(lambda c: ref.lif_fused_ref(c, beta, thr)[0], cur)
    hbm = T * B * N * 4 * 2  # in once + out once (fused)
    hbm_unfused = T * B * N * 4 * 2 + T * B * N * 4 * 2  # + U roundtrips
    emit(
        "kernels/lif_fused_25x8x512", us_k,
        f"ref_us={us_r:.0f};hbm_bytes_fused={hbm};"
        f"hbm_bytes_stepwise={hbm_unfused};mode=interpret",
    )

    # spike_matmul: hidden layer integration at 10% spike rate
    M, K, Nn = 200, 4096, 512
    spk = jnp.asarray((rng.random((M, K)) < 0.1).astype(np.int8))
    wq = jnp.asarray(rng.integers(-(2**15), 2**15, (K, Nn)).astype(np.int16))
    us_k = time_fn(lambda s: ops.spike_matmul(s, wq), spk, warmup=1, iters=3)
    us_r = time_fn(lambda s: ref.spike_matmul_ref(s, wq), spk)
    bytes_q115 = M * K * 1 + K * Nn * 2 + M * Nn * 4
    bytes_f32 = M * K * 4 + K * Nn * 4 + M * Nn * 4
    emit(
        "kernels/spike_matmul_200x4096x512", us_k,
        f"ref_us={us_r:.0f};bytes_int_path={bytes_q115};"
        f"bytes_f32_path={bytes_f32};traffic_saving="
        f"{bytes_f32/bytes_q115:.2f}x;mode=interpret",
    )

    # q115_matmul
    xq = jnp.asarray(rng.integers(-(2**15), 2**15, (128, 512)).astype(np.int16))
    wq2 = jnp.asarray(rng.integers(-(2**15), 2**15, (512, 128)).astype(np.int16))
    us_k = time_fn(lambda a: ops.q115_matmul(a, wq2), xq, warmup=1, iters=3)
    us_r = time_fn(lambda a: ref.q115_matmul_ref(a, wq2), xq)
    emit(
        "kernels/q115_matmul_128x512x128", us_k,
        f"ref_us={us_r:.0f};accumulator=int32(28bit-class);mode=interpret",
    )


if __name__ == "__main__":
    run()
