"""Event-driven vs dense SNN execution across spike rates.

For each input spike rate r in [0, 1]:
  - ops: accumulator adds the AER path *measured* (events x fan_out) vs the
    dense path's fixed fan_in x fan_out x T — the paper's event-driven
    claim, verified with counted events instead of an assumed rate;
  - energy: both op counts priced with core.energy's Horowitz table;
  - time: wall time of the dense ``core.snn.forward`` vs the event-driven
    ``events.runtime.event_forward`` and the AER gather kernel vs the
    dense spike_matmul kernel (interpret mode on CPU — the op/energy
    scaling is the portable signal, kernel wall times are indicative only);
  - throughput: events/sec of the event-driven forward.

Usage:  PYTHONPATH=src python -m benchmarks.stream_bench [--full]
   or:  PYTHONPATH=src python -m benchmarks.run stream
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import energy, quant, snn
from repro.events import runtime
from repro.kernels import ops

RATES = (0.0, 0.05, 0.1, 0.25, 0.5, 0.75, 1.0)


def run() -> None:
    main([])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 4096-512-2 (slow on CPU)")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args(argv)

    sizes = (4096, 512, 2) if args.full else (1024, 256, 2)
    cfg = snn.SNNConfig(layer_sizes=sizes, num_steps=25)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    wq = quant.quantize(params["layer0"]["w"])  # for the kernel comparison
    B, T, K = args.batch, cfg.num_steps, sizes[0]
    rng = np.random.default_rng(0)

    dense_fwd = jax.jit(lambda s: snn.forward(params, s, cfg, train=False))
    event_fwd = jax.jit(lambda s: runtime.event_forward(params, s, cfg))
    dense_ops = energy.snn_inference_ops(
        sizes, T, [1.0] * cfg.num_layers, event_driven=False
    )

    print(f"# layer_sizes={sizes} T={T} B={B} (per-inference numbers)")
    print("rate,meas_events_l0,aer_adds,dense_adds,add_ratio,"
          "aer_energy_pj,dense_energy_pj,energy_ratio,"
          "dense_fwd_us,event_fwd_us,events_per_sec,"
          "spike_mm_us,aer_mm_us")
    for rate in RATES:
        spikes = (rng.random((T, B, K)) < rate).astype(np.float32)
        spikes_j = jnp.asarray(spikes)

        _, _, ev = event_fwd(spikes_j)
        ev_mean = np.asarray(ev).mean(axis=1)  # per-inference events/layer
        oc = energy.snn_ops_from_events(sizes, T, ev_mean)
        aer_adds = oc.ops.get("add_i32", 0.0)
        dense_adds = dense_ops.ops["add_i32"]

        t_dense = time_fn(dense_fwd, spikes_j, warmup=1, iters=3)
        t_event = time_fn(event_fwd, spikes_j, warmup=1, iters=3)
        ev_total = float(np.asarray(ev).sum())
        evps = ev_total / args.batch / (t_event * 1e-6) if t_event else 0.0

        # kernel-level: one step's integration, dense vs AER event list
        row = jnp.asarray(spikes[0, 0][None, :].astype(np.int8))
        t_mm = time_fn(ops.spike_matmul, row, wq, warmup=1, iters=3)
        idx = np.nonzero(spikes[0, 0])[0]
        cap = max(int(K * max(rate, 0.01)) + 8, 8)
        a = np.zeros(cap, np.int32)
        v = np.zeros(cap, np.int32)
        a[: len(idx[:cap])] = idx[:cap]
        v[: len(idx[:cap])] = 1
        t_aer = time_fn(
            ops.aer_spike_matmul, jnp.asarray(a), jnp.asarray(v), wq,
            warmup=1, iters=3,
        )

        print(
            f"{rate:.2f},{ev_mean[0]:.0f},{aer_adds:.3g},{dense_adds:.3g},"
            f"{aer_adds/dense_adds:.3f},"
            f"{oc.energy_pj():.3g},{dense_ops.energy_pj():.3g},"
            f"{oc.energy_pj()/dense_ops.energy_pj():.3f},"
            f"{t_dense:.0f},{t_event:.0f},{evps:.0f},"
            f"{t_mm:.0f},{t_aer:.0f}",
            flush=True,
        )


if __name__ == "__main__":
    main()
