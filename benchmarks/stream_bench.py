"""Streaming-SNN serving benchmarks: rate sweep + open-loop async serving.

Default mode — event-driven vs dense execution across spike rates.
For each input spike rate r in [0, 1]:
  - ops: accumulator adds the AER path *measured* (events x fan_out) vs the
    dense path's fixed fan_in x fan_out x T — the paper's event-driven
    claim, verified with counted events instead of an assumed rate;
  - energy: both op counts priced with core.energy's Horowitz table;
  - time: wall time of the dense ``core.snn.forward`` vs the event-driven
    ``events.runtime.event_forward`` and the AER gather kernel vs the
    dense spike_matmul kernel (interpret mode on CPU — the op/energy
    scaling is the portable signal, kernel wall times are indicative only);
  - throughput: events/sec of the event-driven forward.

``--quick`` mode — open-loop async serving on the paper's 4096-512-2
collision config: Poisson arrivals submitted to the engine's
``submit()/poll()`` scheduler while chunks are in flight, per-request
deadlines (two deliberately already-due requests make the miss accounting
deterministic), p50/p99 latency, queue wait, a per-tick host-overhead
breakdown (host scheduling prep vs time in the chunk call vs the single
D2H stats fetch; with synchronous CPU dispatch the chunk-call bucket
includes device compute — see ``SNNStreamEngine.tick_breakdown``), and
a chunk-throughput cross-check against ``BENCH_snn.json`` (same config,
batch, chunk length).  The cross-check times the engine's *device-resident* chunk —
ring-sliced pre-staged event tables, the tick loop's real hot path —
against the BENCH ``overhauled_jnp`` figure, which still includes
per-chunk layer-0 extraction; a healthy resident engine therefore sits
*above* 1.0x, and the validation floor is 0.6x (raised from the
host-assembly era's 0.35x).

Since schema v3 the quick mode also exports the engine's observability
layer (``repro.obs``):

- per-request **latency / queue-wait / energy histograms** (log-bucket
  snapshots with p50/p90/p99) straight from the engine's metrics
  registry, next to the scalar percentiles they replace as evidence;
- a **dispatch attribution** that splits the tick's dominant
  ``dispatch_us`` bucket into host-enqueue vs device-compute wait (the
  blocking probe from ``repro.obs.profiler`` — ROADMAP item 2's open
  question, answered in-artifact);
- a measured **instrumentation overhead** bound (per-tick metrics+span
  recording cost vs the measured tick, asserted < 2% by ``--validate``);
- sidecar artifacts: the Chrome trace (``*_trace.json``,
  Perfetto-loadable per-request + tick-phase spans) and the full
  metrics snapshot (``*_metrics.json``), recorded under ``artifacts``.

Schema v4 adds the engine's *windowed* observability:

- a **timeseries** block from the engine's per-tick/per-submit
  ``TimeSeriesSampler``: sample accounting, trailing-window rates
  (events/s, ticks/s, windowed miss-rate) and a consistency table
  proving the sum of sampled counter deltas equals the lifetime counter
  values (the series was restarted at the post-warmup reset point, so
  the two must agree exactly);
- an **slo** verdict block — ``engine.health()``'s full multi-window
  burn-rate report over ``default_slos`` with the p99 target set to the
  run's deadline (the planted already-due requests guarantee a nonzero
  observed error rate on the deadline SLO);
- a third sidecar: the time series itself as JSONL
  (``*_timeseries.jsonl``), one object per sample.

Schema v5 adds the **fault_tolerance** block (``repro.faults``):

- the clean run's fault/shed counters, which must all be identically
  zero (no injector, no admission policy — the detection layer is a
  bit-exact no-op on healthy traffic);
- a seeded chaos probe on the same config: load shedding enabled
  (bounded admission queue + EDF feasibility shedder), two already-due
  requests (shed at admission instead of served-and-missed), two
  priority-1 requests (parked through queue overflow, then served),
  and a seeded ``FaultSchedule`` injected mid-run.  The block reports
  the shed rate, quarantine count vs the injector's own application
  log, the worst-case injection->quarantine recovery lag in ticks,
  retry/demotion counters and the chaos-vs-clean deadline miss rate.

Schema v6 adds the **static_analysis** block (``repro.analysis``):

- the full repro-lint pass (AST lint over ``src/repro``, Pallas kernel
  VMEM/SMEM budget + index-map bounds checks, AER address-width
  bounds) re-run in-process — the findings count must be zero;
- the recompile contract: the open-loop serving region runs inside a
  ``RecompileDetector`` tracking the chunk and admit functions
  (allowlist: zero — warmup owns the cold-start compile), and the
  engine's own ``steady_state_recompiles()`` counter must be zero.

Schema v7 adds the **recovery** block (crash safety):

- snapshot/warm-restart: the engine is snapshotted mid-flight
  (``snapshot_auto`` rotation, twice), the newest snapshot is
  byte-corrupted, and a fresh engine ``restore_latest_snapshot``s —
  the checksum must catch the corruption (fallback counter == injected
  corruptions) and the survivor's drained results must be bit-exact
  against an uninterrupted oracle (``resume_parity``); save/restore
  costs are reported in µs from the engine's own histograms;
- preemption: an urgent tight-deadline arrival on a full ``preempt=
  True`` engine must park a resident slot and later restore it
  bit-exactly; park/restore round-trip µs per slot are reported.

Emits ``stream_bench.json``; ``--validate`` structurally checks it (and
its sidecars) and fails on a chunk-throughput collapse vs the BENCH
baseline, missing/inconsistent histograms, instrumentation overhead
above 2% of a tick, a thin/inconsistent time series (< 20 samples, or
deltas that disagree with lifetime totals), a malformed SLO verdict,
nonzero clean-run fault counters, a chaos probe whose quarantine count
disagrees with its injection log (or that crashed, or recovered too
slowly).

Usage:  PYTHONPATH=src python -m benchmarks.stream_bench [--full]
        PYTHONPATH=src python -m benchmarks.stream_bench --quick [--json P]
        PYTHONPATH=src python -m benchmarks.stream_bench --validate P
   or:  PYTHONPATH=src python -m benchmarks.run stream
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.analysis import RecompileDetector
from repro.analysis.__main__ import run as analysis_run
from repro.core import energy, quant, snn
from repro.events import capacity as cap_mod
from repro.events import runtime
from repro.kernels import ops
from repro.obs import (
    default_slos,
    dispatch_attribution,
    tick_instrumentation_cost_us,
)

RATES = (0.0, 0.05, 0.1, 0.25, 0.5, 0.75, 1.0)

REPO_ROOT = Path(__file__).resolve().parents[1]
DEFAULT_JSON = REPO_ROOT / "stream_bench.json"
SCHEMA = "stream_bench/v7"
# per-request histograms carried since the v3 schema
HIST_KEYS = (
    "engine.request.latency_s",
    "engine.request.queue_wait_s",
    "engine.request.energy_pj",
)
# counters whose summed sampled deltas must equal their lifetime values
# (series restarted at the post-warmup reset, which zeroes them too)
CONSISTENCY_KEYS = (
    "engine.requests.submitted",
    "engine.requests.completed",
    "engine.requests.deadline_missed",
)
# the time series must actually have resolution: the quick run takes
# ~12 submits + >= 1 tick sample per poll, so 20 is a loose floor that
# still catches a sampler that silently stopped firing
MIN_TS_SAMPLES = 20
# per-tick observability recording must stay a rounding error next to
# the measured tick (acceptance: resident throughput regresses < 2%
# with instrumentation on)
MAX_OBS_OVERHEAD_FRAC = 0.02
# the engine's device-resident chunk skips the per-chunk layer-0
# extraction BENCH_snn's overhauled_jnp still pays, so a healthy engine
# sits above 1.0x; the floor catches collapse (a resident path that
# quietly fell back to host assembly lands well below it)
MIN_VS_BENCH = 0.6
# v5 chaos probe geometry: seeded fault schedule + bounded-queue
# shedding on the same collision config as the open-loop run
FT_SEED = 7
FT_FAULTS = 6
FT_REQUESTS = 12
FT_QUEUE_DEPTH = 4
# a quarantine must land within this many ticks of its injection
# (detection is one chunk behind the mutation, plus the one-deep stats
# pipeline and the drain loop's eager finishing) — the chaos test
# suite pins <= 6 at the same geometry, the artifact floor is looser
MAX_RECOVERY_TICKS = 8
# fault/shed counters that must be identically zero on the clean run
FT_CLEAN_ZERO_KEYS = (
    "engine.requests.shed",
    "engine.requests.parked",
    "engine.requests.quarantined",
    "engine.faults.chunk_retries",
    "engine.faults.backend_demoted",
    "engine.faults.injected",
)
# v7 recovery probe geometry: snapshot/warm-restart + preemption costs
# on the same collision config, with one seeded checkpoint corruption
RC_REQUESTS = 6
RC_CORRUPTIONS = 1


def _recovery_run(cfg, params, capacities) -> Dict:
    """Crash-safety probe for the v7 ``recovery`` block.

    Measures the engine's recovery-plane costs on the same collision
    config as the open-loop run: rotating snapshot writes on a loaded
    engine, warm-restart restore into a fresh engine (after a seeded
    byte-corruption of the newest snapshot — the restore must fall back
    to the previous one), and deadline-aware preemption park/restore
    round-trips.  Both the warm-restarted and the preempting engine are
    held to bit-exact parity with an uninterrupted oracle run
    (``resume_parity`` / ``preempt_parity``).
    """
    import shutil
    import tempfile
    import warnings as _warnings

    from repro.faults import corrupt_checkpoint
    from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

    slots, Tc = 4, 5
    K = cfg.layer_sizes[0]
    rng = np.random.default_rng(2)
    trains = [
        (rng.random((cfg.num_steps, K)) < 0.2).astype(np.float32)
        for _ in range(RC_REQUESTS)
    ]

    def mk(preempt=False):
        return SNNStreamEngine(
            params, cfg, num_slots=slots, chunk_steps=Tc, backend="jnp",
            capacities=capacities, preempt=preempt,
        )

    def parity(results, oracle) -> bool:
        got = {r.request_id: r for r in results}
        if sorted(got) != sorted(oracle):
            return False
        return all(
            np.array_equal(got[i].spike_counts, oracle[i].spike_counts)
            and np.array_equal(
                got[i].events_per_layer, oracle[i].events_per_layer
            )
            and got[i].prediction == oracle[i].prediction
            and got[i].energy_pj == oracle[i].energy_pj
            for i in oracle
        )

    # oracle: the same engine instance serves the reference pass, then
    # (counters reset by run()) the snapshot pass — one chunk compile
    eng = mk()
    ref = eng.run([StreamRequest(spikes=t) for t in trains])
    # request ids keep counting up across passes on the same engine;
    # rebase every pass onto 0..n-1 window indices before comparing
    base = min(r.request_id for r in ref)
    oracle = {r.request_id - base: r for r in ref}

    snap_dir = tempfile.mkdtemp(prefix="stream_bench_recovery_")
    try:
        first_rid = eng._next_rid
        for t in trains:
            eng.submit(StreamRequest(spikes=t))
        eng.poll()
        eng.poll()
        # two rotation snapshots mid-flight (nothing has completed yet:
        # each window needs cfg.num_steps/Tc chunks plus the pipeline)
        eng.snapshot_auto(snap_dir)
        eng.poll()
        eng.snapshot_auto(snap_dir)
        src_snap = eng.metrics_snapshot()
        save_h = src_snap["engine.snapshot.save_s"]
        snapshot_us = 1e6 * save_h["sum"] / max(save_h["count"], 1)

        # corrupt the newest snapshot: restore_latest_snapshot must fall
        # back to the previous one in the rotation, loudly but cleanly
        corrupt_checkpoint(snap_dir, seed=FT_SEED)
        surv = mk()
        with _warnings.catch_warnings():
            _warnings.simplefilter("ignore")
            restored_path = surv.restore_latest_snapshot(snap_dir)
        surv_snap = surv.metrics_snapshot()
        fallbacks = int(
            surv_snap["engine.faults.checkpoint_fallback"]["value"]
        )
        rest_h = surv_snap["engine.snapshot.restore_s"]
        restore_us = 1e6 * rest_h["sum"] / max(rest_h["count"], 1)
        resumed = [
            dataclasses_replace_rid(r, r.request_id - first_rid)
            for r in surv.drain()
        ]
        resume_parity = restored_path is not None and parity(
            resumed, oracle
        )
    finally:
        shutil.rmtree(snap_dir, ignore_errors=True)

    # preemption probe: fill every slot with loose windows, then land a
    # strictly tighter one — the loosest resident window parks, the
    # urgent one runs, the parked one resumes; all bit-exact
    ep = mk(preempt=True)
    pre_rid = ep._next_rid
    for t in trains[:slots]:
        ep.submit(StreamRequest(spikes=t))
    ep.poll()
    ep.submit(
        StreamRequest(spikes=trains[slots], priority=5, deadline_s=2.0)
    )
    for t in trains[slots + 1:]:
        ep.submit(StreamRequest(spikes=t))
    pre_results = [
        dataclasses_replace_rid(r, r.request_id - pre_rid)
        for r in ep.drain(timeout_s=120.0)
    ]
    ep_snap = ep.metrics_snapshot()
    park_h = ep_snap["engine.preempt.park_s"]
    unpark_h = ep_snap["engine.preempt.restore_s"]
    park_us = 1e6 * park_h["sum"] / max(park_h["count"], 1)
    unpark_us = 1e6 * unpark_h["sum"] / max(unpark_h["count"], 1)
    return {
        "requests": RC_REQUESTS,
        "snapshot_us": float(snapshot_us),
        "restore_us": float(restore_us),
        "snapshots_written": int(save_h["count"]),
        "injected_corruptions": RC_CORRUPTIONS,
        "checkpoint_fallbacks": fallbacks,
        "resume_parity": bool(resume_parity),
        "preemptions": int(ep_snap["engine.preempt.parked"]["value"]),
        "preempt_resumes": int(
            ep_snap["engine.preempt.resumed"]["value"]
        ),
        "preempt_park_us": float(park_us),
        "preempt_restore_us": float(unpark_us),
        "preempt_round_trip_us": float(park_us + unpark_us),
        "preempt_parity": parity(pre_results, oracle),
    }


def dataclasses_replace_rid(r, rid: int):
    """Rebase a StreamResult's request id onto a run-local index (the
    recovery probe reuses one engine across passes, so raw ids keep
    counting up)."""
    import dataclasses as _dc

    return _dc.replace(r, request_id=rid)


def _fault_tolerance_run(cfg, params, capacities) -> Dict:
    """Seeded chaos probe for the v5 ``fault_tolerance`` block.

    Same collision config as the open-loop run, but with the
    fault-tolerance plane switched on: a bounded admission queue (depth
    ``FT_QUEUE_DEPTH``) with EDF feasibility shedding, and a seeded
    :class:`~repro.faults.FaultSchedule` injected mid-run.  The
    submission pattern is deterministic by construction: two
    already-due requests (the feasibility shedder rejects them at
    admission instead of serving-and-missing), two bursts that overflow
    the bounded queue (the priority-1 request in each parks, the last
    priority-0 one sheds), the rest served.  Returns the measured chaos
    sub-block; ``crashes`` counts loop-level exceptions (must be 0).
    """
    import dataclasses as _dc

    from repro.faults import (
        AdmissionPolicy,
        FaultInjector,
        FaultSchedule,
        RetryPolicy,
    )
    from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

    slots, Tc = 4, 5
    n_req = FT_REQUESTS
    K = cfg.layer_sizes[0]
    rng = np.random.default_rng(1)
    trains = [
        (rng.random((cfg.num_steps, K)) < 0.2).astype(np.float32)
        for _ in range(n_req)
    ]
    eng = SNNStreamEngine(
        params, cfg, num_slots=slots, chunk_steps=Tc, backend="jnp",
        capacities=capacities,
        admission=AdmissionPolicy(max_queue_depth=FT_QUEUE_DEPTH),
        # budget above the worst-case pile-up of same-tick injected
        # exceptions: generated schedules stay transient (see
        # FaultSchedule.generate), so the supervisor always recovers
        retry=RetryPolicy(max_retries=8, backoff_s=0.0),
    )
    # warm without the injector: pays the chunk compile and gives the
    # feasibility shedder its measured tick-rate evidence
    eng.run([StreamRequest(spikes=trains[0])])
    eng.reset_tick_stats()
    eng.metrics.reset(prefix="engine.request")
    eng.metrics.reset(prefix="engine.faults")
    eng.metrics.reset(prefix="engine.episode")
    # schedule the faults over the post-warm tick horizon
    t0 = eng._tick_index
    base = FaultSchedule.generate(
        FT_SEED, FT_FAULTS, ticks=12, num_slots=slots,
        num_layers=cfg.num_layers,
        kinds=("nan_membrane", "corrupt_ring", "chunk_exception"),
    )
    schedule = FaultSchedule(
        faults=tuple(
            _dc.replace(f, tick=f.tick + t0) for f in base.faults
        ),
        seed=FT_SEED,
    )
    inj = FaultInjector(schedule)
    eng.injector = inj

    # generous budget so the feasibility shedder only fires on the two
    # planted already-due requests (a tight budget is *legitimately*
    # sheddable when the trailing-window rate evidence straddles it,
    # which would make the artifact's shed split timing-dependent)
    deadline_s = 10.0

    def req(i, *, deadline=deadline_s, priority=0):
        return StreamRequest(
            spikes=trains[i], deadline_s=deadline, priority=priority
        )

    bursts = [
        # burst 1: 2 already-due (feasibility sheds at pop) + 2 normal
        # fill the queue; the priority-1 request parks, the last sheds
        [req(0, deadline=0.0), req(1, deadline=0.0), req(2), req(3),
         req(4, priority=1), req(5)],
        # burst 2: queue refills; same park/shed tail
        [req(6), req(7), req(8), req(9), req(10, priority=1), req(11)],
    ]
    results, crashes = [], 0
    try:
        for burst in bursts:
            for r in burst:
                eng.submit(r)
            results.extend(eng.poll())
        results.extend(eng.drain(timeout_s=120.0))
    except Exception:  # chaos must never crash the serving loop
        crashes = 1

    snap = eng.metrics_snapshot()
    ok = [r for r in results if r.disposition == "ok"]
    applied_state = [
        rec for rec in inj.applied
        if rec["kind"] in ("nan_membrane", "corrupt_ring")
    ]
    applied_tick = {rec["rid"]: rec["tick"] for rec in applied_state}
    recovery = [
        ev["tick"] - applied_tick[ev["rid"]]
        for ev in eng.fault_events if ev["rid"] in applied_tick
    ]
    chaos_miss = (
        sum(r.deadline_missed for r in ok) / len(ok) if ok else 0.0
    )
    return {
        "requests": n_req,
        "schedule_seed": FT_SEED,
        "schedule_len": len(schedule),
        "injected_faults": len(inj.applied),
        "served_ok": len(ok),
        "shed": sum(r.disposition == "shed" for r in results),
        "parked_served": int(sum(r.parked for r in ok)),
        "quarantined": sum(
            r.disposition == "quarantined" for r in results
        ),
        "quarantine_expected": len(
            {rec["rid"] for rec in applied_state}
        ),
        "shed_rate": float(eng.shed_rate()),
        "deadline_miss_rate": float(chaos_miss),
        "recovery_ticks_max": max(recovery) if recovery else None,
        "chunk_retries": float(
            snap["engine.faults.chunk_retries"]["value"]
        ),
        "backend_demotions": float(
            snap["engine.faults.backend_demoted"]["value"]
        ),
        "crashes": crashes,
        "diagnosis": eng.health()["diagnosis"]["verdict"],
    }


def open_loop_run(
    quick: bool = True, json_path: Optional[Path] = None
) -> Dict:
    """Open-loop async serving on the collision config -> stream_bench.json.

    Matches BENCH_snn.json's quick geometry (4096-512-2, 4 slots, Tc=5,
    jnp backend) so the chunk-throughput cross-check compares like with
    like.
    """
    from repro.configs.collision_snn import CONFIG as cfg
    from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

    json_path = Path(json_path) if json_path else DEFAULT_JSON
    slots, Tc = 4, 5
    n_req = 12 if quick else 32
    arrival_rate = 40.0 if quick else 60.0
    deadline_s = 2.0
    n_hopeless = 2  # already-due deadlines: deterministic misses

    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    K = cfg.layer_sizes[0]
    rng = np.random.default_rng(0)
    trains = [
        (rng.random((cfg.num_steps, K)) < 0.2).astype(np.float32)
        for _ in range(n_req)
    ]
    # autotuned capacities, like BENCH_snn.json's overhauled_jnp path —
    # the chunk cross-check below must compare like with like
    plan = cap_mod.autotune(
        params, cfg, jnp.asarray(np.stack(trains, axis=1)),
        percentile=100.0, safety=1.2, align=128,
    )
    # SLOs at the run's own scale: p99 target = the per-request deadline
    engine = SNNStreamEngine(
        params, cfg, num_slots=slots, chunk_steps=Tc, backend="jnp",
        capacities=plan.capacities,
        slos=default_slos(p99_target_s=deadline_s),
    )
    reqs = [
        StreamRequest(
            spikes=t,
            deadline_s=0.0 if i < n_hopeless else deadline_s,
        )
        for i, t in enumerate(trains)
    ]

    # warm the compiled chunk so open-loop latencies measure steady
    # state; drop the warmup's tick timings (first tick pays compile),
    # request histograms, lifetime counters and spans alike
    engine.run([StreamRequest(spikes=trains[0])])
    engine.reset_tick_stats()
    engine.metrics.reset(prefix="engine.request")
    engine.trace.clear()
    # re-baseline the time series at the same reset point, so summed
    # sampled deltas must equal the lifetime counter values exactly
    engine.timeseries.restart()

    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_req))
    results, i = [], 0
    # v6: the recompile contract — post-warmup, the open-loop region
    # must compile *nothing*: the chunk and the admit path were both
    # compiled by the warmup request, so any cache growth here means a
    # shape-unstable submit/tick path (the serving hazard repro-lint
    # exists to catch)
    detector = RecompileDetector()
    with detector:
        detector.track("chunk", engine._chunk, allowed=0)
        detector.track("admit_spikes", engine._admit_spikes_fn, allowed=0)
        start = time.perf_counter()
        while i < n_req or not engine.idle():
            now = time.perf_counter() - start
            while i < n_req and arrivals[i] <= now:
                engine.submit(reqs[i])
                i += 1
            if engine.idle() and i < n_req:
                time.sleep(
                    max(arrivals[i] - (time.perf_counter() - start), 0.0)
                )
                continue
            results.extend(engine.poll())
        elapsed_s = time.perf_counter() - start

    # aggregate over the collected results, not the engine's episode
    # counters: an arrival gap longer than the service time drains the
    # engine mid-trace, closing one episode and resetting counters at the
    # next submit — the trace-wide numbers must span every episode
    lat_ms = np.array([r.latency_s for r in results]) * 1e3
    wait_ms = np.array([r.queue_wait_s for r in results]) * 1e3
    miss_rate = sum(r.deadline_missed for r in results) / len(results)
    events_total = float(
        sum(r.events_per_layer.sum() for r in results)
    )

    # chunk-throughput cross-check: the engine's compiled device-resident
    # chunk on a fully-active micro-batch of staged rings — the tick
    # loop's real hot path — vs BENCH_snn.json's overhauled_jnp figure
    # (same config / batch / chunk length, but BENCH's path still pays
    # per-chunk layer-0 extraction, so healthy is > 1.0x)
    staged = engine.staged_chunk_args(trains[:slots])
    t_chunk = time_fn(
        engine.chunk_for_timing(), *staged,
        warmup=1, iters=3 if quick else 5,
    )
    steps_per_s = Tc * slots / (t_chunk * 1e-6)
    vs_bench = None
    bench_path = REPO_ROOT / "BENCH_snn.json"
    if bench_path.exists():
        ref = json.loads(bench_path.read_text())
        vs_bench = (
            steps_per_s / ref["paths"]["overhauled_jnp"]["steps_per_s"]
        )

    # dispatch attribution: split the tick's dominant dispatch_us bucket
    # (time in the chunk call) into host enqueue vs device-compute wait
    # — the ROADMAP item-2 question, answered with a blocking probe on
    # the very chunk the cross-check just timed
    attribution = dispatch_attribution(
        engine.chunk_for_timing(), *staged,
        warmup=1, iters=3 if quick else 5,
    )

    # instrumentation overhead: measured per-tick metrics+span recording
    # cost (scratch instruments, exact op mix of one tick) against the
    # run's measured mean tick
    tb = engine.tick_breakdown()
    mean_tick_us = (
        tb["host_prep_us"] + tb["dispatch_us"] + tb["stats_fetch_us"]
    )
    obs_us = tick_instrumentation_cost_us(num_slots=slots)
    obs_overhead = {
        "per_tick_obs_us": obs_us,
        "mean_tick_us": mean_tick_us,
        "overhead_frac": obs_us / max(mean_tick_us, 1e-9),
    }

    # v4: windowed time-series summary + counter-delta consistency.
    # The series was restarted at the post-warmup reset (which zeroed
    # the engine.request* counters too), so for never-reset lifetime
    # counters sum-of-deltas must equal the lifetime value exactly.
    ts = engine.timeseries
    snap = engine.metrics_snapshot()
    win_s = 1.0
    timeseries_block = {
        "samples": len(ts),
        "span_s": ts.span_s(),
        "window_s": win_s,
        "windowed": {
            "miss_rate": engine.windowed_miss_rate(win_s),
            "events_per_s": ts.rate("engine.episode.events", win_s),
            "ticks_per_s": ts.rate("engine.tick.dispatch_s.count", win_s),
            "requests_per_s": ts.rate("engine.requests.completed", win_s),
        },
        "consistency": {
            k: {
                "series_total": ts.cum(k),
                "lifetime": float(snap[k]["value"]),
            }
            for k in CONSISTENCY_KEYS
        },
    }

    # v4: the SLO verdict — engine.health() runs the multi-window
    # burn-rate evaluation and publishes the engine.slo.status gauge
    slo_report = engine.health()

    # v5: fault-tolerance evidence.  The clean run above had no
    # injector and no admission policy, so its fault/shed counters must
    # all be zero — recorded and validated as such; the chaos probe is
    # a second, seeded run on the same config with shedding on
    fault_tolerance = {
        "clean": {
            "counters": {
                k: float(snap[k]["value"]) for k in FT_CLEAN_ZERO_KEYS
            },
            "deadline_miss_rate": float(miss_rate),
        },
        "chaos": _fault_tolerance_run(cfg, params, plan.capacities),
    }
    # shedding-on chaos converts hopeless deadlines into sheds, so the
    # chaos miss rate sits *below* the clean run's planted-miss rate
    fault_tolerance["miss_rate_delta"] = (
        fault_tolerance["chaos"]["deadline_miss_rate"]
        - fault_tolerance["clean"]["deadline_miss_rate"]
    )

    # v7: crash-safety evidence — snapshot/warm-restart costs and
    # parity, checkpoint-corruption fallback, preemption round-trips
    recovery = _recovery_run(cfg, params, plan.capacities)

    # v6: the static-analysis contract.  The full repro-lint pass
    # (AST lint over src/repro + kernel VMEM/SMEM budgets + AER bounds)
    # runs in-process and must come back clean, and the open-loop
    # region above must have been recompile-free — both validated
    sa_report = analysis_run()
    static_analysis = {
        "lint_findings": sa_report["counts"]["findings"],
        "lint_suppressed": sa_report["counts"]["suppressed"],
        "kernel_vmem_bytes": {
            p["kernel"]: p["vmem_bytes"] for p in sa_report["kernels"]
        },
        "steady_state_recompiles": engine.steady_state_recompiles(),
        "recompile_detector": detector.report(),
    }

    # sidecar artifacts next to the JSON: the Perfetto-loadable span
    # trace, the full metrics snapshot and the time-series JSONL (CI
    # uploads all three)
    trace_path = json_path.with_name(json_path.stem + "_trace.json")
    metrics_path = json_path.with_name(json_path.stem + "_metrics.json")
    ts_path = json_path.with_name(json_path.stem + "_timeseries.jsonl")
    engine.export_trace(trace_path)
    engine.metrics.write_json(metrics_path)
    ts.write_jsonl(ts_path)
    doc = {
        "schema": SCHEMA,
        "mode": "quick" if quick else "full",
        "config": {
            "layer_sizes": list(cfg.layer_sizes),
            "num_steps": cfg.num_steps,
            "chunk_steps": Tc,
            "num_slots": slots,
            "requests": n_req,
            "arrival_rate_rps": arrival_rate,
            "deadline_ms": deadline_s * 1e3,
            "hopeless_deadlines": n_hopeless,
            "capacities": [int(c) for c in plan.capacities],
        },
        "open_loop": {
            "served": len(results),
            "p50_latency_ms": float(np.percentile(lat_ms, 50)),
            "p99_latency_ms": float(np.percentile(lat_ms, 99)),
            "mean_queue_wait_ms": float(wait_ms.mean()),
            "deadline_miss_rate": float(miss_rate),
            "events_per_s": events_total / max(elapsed_s, 1e-9),
        },
        "chunk": {
            "us_per_chunk": t_chunk,
            "steps_per_s": steps_per_s,
            "vs_bench_overhauled_jnp": vs_bench,
        },
        # per-request histograms straight from the engine's metrics
        # registry (log buckets, exact count/sum/min/max, approximate
        # percentiles) — warmup was reset out, so counts == served
        "histograms": {k: snap[k] for k in HIST_KEYS},
        # measured per-tick breakdown of the open-loop run above — the
        # evidence future PRs read to see where serving time goes.  NB
        # dispatch_us is time *in* the chunk call: with synchronous
        # dispatch (CPU) it includes the device compute wait; host
        # scheduling overhead proper is host_prep_us, and the D2H cost
        # is stats_fetch_us (see SNNStreamEngine.tick_breakdown)
        "host_overhead": tb,
        # the measured split of dispatch_us: host enqueue (the only part
        # that is actually host overhead) vs device-compute wait
        "dispatch_attribution": attribution,
        "obs_overhead": obs_overhead,
        # v4: windowed rates + delta/lifetime consistency proof
        "timeseries": timeseries_block,
        # v4: the full multi-window burn-rate report (engine.health())
        "slo": slo_report,
        # v5: clean-run zero counters + the seeded chaos probe
        "fault_tolerance": fault_tolerance,
        # v6: repro-lint pass + recompile contract over the open loop
        "static_analysis": static_analysis,
        # v7: snapshot/warm-restart + preemption probe
        "recovery": recovery,
        "artifacts": {
            "trace": trace_path.name,
            "metrics": metrics_path.name,
            "timeseries": ts_path.name,
        },
    }
    json_path.write_text(json.dumps(doc, indent=2) + "\n")
    emit(
        "stream_bench/open_loop", float(np.percentile(lat_ms, 50)) * 1e3,
        f"p99_ms={np.percentile(lat_ms, 99):.1f};"
        f"miss_rate={doc['open_loop']['deadline_miss_rate']:.3f};"
        f"events_per_s={doc['open_loop']['events_per_s']:.0f}",
    )
    emit(
        "stream_bench/chunk", t_chunk,
        f"steps_per_s={steps_per_s:.1f};"
        f"vs_bench={vs_bench if vs_bench is None else round(vs_bench, 3)};"
        f"json={json_path}",
    )
    emit(
        "stream_bench/dispatch_attribution", attribution["total_us"],
        f"host_enqueue_us={attribution['host_enqueue_us']:.0f};"
        f"device_wait_frac={attribution['device_wait_frac']:.3f};"
        f"obs_overhead_frac={obs_overhead['overhead_frac']:.5f}",
    )
    emit(
        "stream_bench/slo", float(slo_report["status_code"]),
        f"status={slo_report['status']};"
        f"samples={timeseries_block['samples']};"
        f"windowed_miss_rate="
        f"{timeseries_block['windowed']['miss_rate']:.3f}",
    )
    chaos = fault_tolerance["chaos"]
    emit(
        "stream_bench/fault_tolerance", float(chaos["shed_rate"]),
        f"quarantined={chaos['quarantined']};"
        f"recovery_ticks_max={chaos['recovery_ticks_max']};"
        f"chaos_miss_rate={chaos['deadline_miss_rate']:.3f};"
        f"crashes={chaos['crashes']};"
        f"diagnosis={chaos['diagnosis']}",
    )
    emit(
        "stream_bench/recovery", float(recovery["restore_us"]),
        f"snapshot_us={recovery['snapshot_us']:.0f};"
        f"preemptions={recovery['preemptions']};"
        f"park_round_trip_us={recovery['preempt_round_trip_us']:.0f};"
        f"resume_parity={recovery['resume_parity']};"
        f"fallbacks={recovery['checkpoint_fallbacks']}",
    )
    return doc


def validate(path: Path) -> List[str]:
    """Structural validation of a stream_bench.json; returns error strings."""
    errors: List[str] = []
    try:
        doc = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable JSON: {e}"]
    if not isinstance(doc, dict):
        return ["top level is not an object"]
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema {doc.get('schema')!r} != {SCHEMA!r}")
    ol = doc.get("open_loop", {})
    for k in ("p50_latency_ms", "p99_latency_ms", "events_per_s"):
        v = ol.get(k)
        if not isinstance(v, (int, float)) or not v > 0:
            errors.append(f"open_loop.{k} not a positive number: {v!r}")
    wait = ol.get("mean_queue_wait_ms")
    if not isinstance(wait, (int, float)) or wait < 0:
        errors.append(f"open_loop.mean_queue_wait_ms invalid: {wait!r}")
    served = ol.get("served")
    want = doc.get("config", {}).get("requests")
    if served != want:
        errors.append(f"open_loop.served {served!r} != requested {want!r}")
    miss = ol.get("deadline_miss_rate")
    # the run plants already-due deadlines, so the rate must be nonzero
    if not isinstance(miss, (int, float)) or not (0.0 < miss <= 1.0):
        errors.append(
            f"open_loop.deadline_miss_rate not in (0, 1]: {miss!r}"
        )
    chunk = doc.get("chunk", {})
    for k in ("us_per_chunk", "steps_per_s"):
        v = chunk.get(k)
        if not isinstance(v, (int, float)) or not v > 0:
            errors.append(f"chunk.{k} not a positive number: {v!r}")
    vs = chunk.get("vs_bench_overhauled_jnp")
    if vs is None:
        errors.append(
            "chunk.vs_bench_overhauled_jnp is null — generate "
            "BENCH_snn.json (benchmarks.run --quick) before this bench"
        )
    elif not isinstance(vs, (int, float)) or vs < MIN_VS_BENCH:
        errors.append(
            f"chunk throughput regression: engine chunk at {vs!r}x the "
            f"BENCH_snn.json overhauled_jnp path (floor {MIN_VS_BENCH})"
        )
    host = doc.get("host_overhead", {})
    for k in ("host_prep_us", "dispatch_us", "stats_fetch_us"):
        v = host.get(k)
        if not isinstance(v, (int, float)) or v < 0:
            errors.append(f"host_overhead.{k} invalid: {v!r}")
    ticks = host.get("ticks")
    if not isinstance(ticks, int) or ticks < 1:
        errors.append(f"host_overhead.ticks invalid: {ticks!r}")
    if host.get("pipeline_depth") != 1:
        errors.append(
            "host_overhead.pipeline_depth != 1 — the open-loop bench "
            "must exercise the pipelined tick"
        )
    # v3: per-request histograms, internally consistent and covering
    # every served request
    hists = doc.get("histograms", {})
    for key in HIST_KEYS:
        h = hists.get(key)
        if not isinstance(h, dict) or h.get("type") != "histogram":
            errors.append(f"histograms.{key} missing or not a histogram")
            continue
        count = h.get("count")
        if count != served:
            errors.append(
                f"histograms.{key}.count {count!r} != served {served!r}"
            )
        accounted = (
            h.get("underflow", 0)
            + h.get("overflow", 0)
            + sum(c for _, c in h.get("buckets", []))
        )
        if accounted != count:
            errors.append(
                f"histograms.{key}: bucket counts sum to {accounted}, "
                f"count says {count}"
            )
        p50, p90, p99 = h.get("p50"), h.get("p90"), h.get("p99")
        if not all(
            isinstance(p, (int, float)) and p > 0
            for p in (p50, p90, p99)
        ) or not (p50 <= p90 <= p99):
            errors.append(
                f"histograms.{key}: percentiles missing or not "
                f"monotone: p50={p50!r} p90={p90!r} p99={p99!r}"
            )
    # v3: measured host-enqueue vs device-wait split of dispatch_us
    att = doc.get("dispatch_attribution", {})
    enq, wait, total = (
        att.get("host_enqueue_us"),
        att.get("device_wait_us"),
        att.get("total_us"),
    )
    if not isinstance(enq, (int, float)) or not enq > 0:
        errors.append(f"dispatch_attribution.host_enqueue_us: {enq!r}")
    if not isinstance(wait, (int, float)) or wait < 0:
        errors.append(f"dispatch_attribution.device_wait_us: {wait!r}")
    if (
        not isinstance(total, (int, float))
        or not total > 0
        or abs(total - (enq or 0) - (wait or 0)) > 0.05 * total
    ):
        errors.append(
            f"dispatch_attribution.total_us {total!r} inconsistent with "
            f"enqueue {enq!r} + wait {wait!r}"
        )
    if not isinstance(att.get("verdict"), str):
        errors.append("dispatch_attribution.verdict missing")
    # v3: instrumentation must cost < 2% of a measured tick
    obs = doc.get("obs_overhead", {})
    frac = obs.get("overhead_frac")
    if not isinstance(frac, (int, float)) or frac < 0:
        errors.append(f"obs_overhead.overhead_frac invalid: {frac!r}")
    elif frac >= MAX_OBS_OVERHEAD_FRAC:
        errors.append(
            f"instrumentation overhead {frac:.4f} of a tick >= "
            f"{MAX_OBS_OVERHEAD_FRAC} budget "
            f"(per_tick_obs_us={obs.get('per_tick_obs_us')!r})"
        )
    # v4: the time series must be dense enough and its deltas must
    # reconcile with the lifetime counters
    ts = doc.get("timeseries", {})
    n_samples = ts.get("samples")
    if not isinstance(n_samples, int) or n_samples < MIN_TS_SAMPLES:
        errors.append(
            f"timeseries.samples {n_samples!r} < {MIN_TS_SAMPLES} — "
            f"sampler not firing per tick/submit"
        )
    if not isinstance(ts.get("span_s"), (int, float)) or ts["span_s"] <= 0:
        errors.append(f"timeseries.span_s invalid: {ts.get('span_s')!r}")
    wnd = ts.get("windowed", {})
    for k in ("miss_rate", "events_per_s", "ticks_per_s", "requests_per_s"):
        v = wnd.get(k)
        if not isinstance(v, (int, float)) or v < 0:
            errors.append(f"timeseries.windowed.{k} invalid: {v!r}")
    cons = ts.get("consistency", {})
    for k in CONSISTENCY_KEYS:
        c = cons.get(k)
        if not isinstance(c, dict):
            errors.append(f"timeseries.consistency.{k} missing")
            continue
        st, lt = c.get("series_total"), c.get("lifetime")
        if (
            not isinstance(st, (int, float))
            or not isinstance(lt, (int, float))
            or abs(st - lt) > 1e-6 * max(abs(lt), 1.0)
        ):
            errors.append(
                f"timeseries.consistency.{k}: sum of sampled deltas "
                f"{st!r} != lifetime counter {lt!r}"
            )
    # v4: the SLO verdict block is a full burn-rate report
    slo = doc.get("slo", {})
    status = slo.get("status")
    if status not in ("healthy", "degraded", "breach"):
        errors.append(f"slo.status invalid: {status!r}")
    codes = {"healthy": 0, "degraded": 1, "breach": 2}
    if slo.get("status_code") != codes.get(status):
        errors.append(
            f"slo.status_code {slo.get('status_code')!r} does not encode "
            f"status {status!r}"
        )
    slo_entries = {
        s.get("name"): s for s in slo.get("slos", [])
        if isinstance(s, dict)
    }
    for name in ("deadline_misses", "latency_p99"):
        if name not in slo_entries:
            errors.append(f"slo report missing the {name!r} SLO")
    dm = slo_entries.get("deadline_misses")
    if dm is not None:
        # the run plants already-due deadlines: the whole-series error
        # rate on the deadline SLO must be observed as nonzero
        er = dm.get("observed_error_rate")
        if not isinstance(er, (int, float)) or not er > 0:
            errors.append(
                f"deadline_misses SLO observed_error_rate {er!r} not > 0 "
                f"despite planted already-due deadlines"
            )
    for name, entry in slo_entries.items():
        rules = entry.get("rules")
        if not isinstance(rules, list) or not rules:
            errors.append(f"slo {name!r} has no burn-rate rules")
            continue
        for r in rules:
            for k in ("long_burn_rate", "short_burn_rate"):
                v = r.get(k, "absent")
                if v is not None and (
                    not isinstance(v, (int, float)) or v < 0
                ):
                    errors.append(f"slo {name!r} rule {k} invalid: {v!r}")
            if not isinstance(r.get("fired"), bool):
                errors.append(f"slo {name!r} rule missing 'fired'")
    # v5: fault tolerance — clean counters identically zero; the chaos
    # probe quarantined exactly its injected faults, recovered within
    # the tick bound, accounted every request, and never crashed
    ft = doc.get("fault_tolerance", {})
    counters = ft.get("clean", {}).get("counters", {})
    for k in FT_CLEAN_ZERO_KEYS:
        v = counters.get(k)
        if v != 0:
            errors.append(
                f"fault_tolerance.clean.counters[{k!r}] = {v!r} != 0 "
                f"on a fault-free run"
            )
    chaos = ft.get("chaos", {})
    n = chaos.get("requests")
    if not isinstance(n, int) or n < 1:
        errors.append(f"fault_tolerance.chaos.requests invalid: {n!r}")
    if chaos.get("crashes") != 0:
        errors.append(
            f"fault_tolerance.chaos.crashes = "
            f"{chaos.get('crashes')!r} — the chaos probe crashed"
        )
    inj_n = chaos.get("injected_faults")
    if not isinstance(inj_n, int) or inj_n < 1:
        errors.append(
            f"fault_tolerance.chaos.injected_faults {inj_n!r} < 1 — "
            f"the seeded schedule never fired"
        )
    q, qe = chaos.get("quarantined"), chaos.get("quarantine_expected")
    if not isinstance(qe, int) or qe < 1:
        errors.append(
            f"fault_tolerance.chaos.quarantine_expected {qe!r} < 1 — "
            f"no state/ring fault was ever applied"
        )
    if q != qe:
        errors.append(
            f"fault_tolerance.chaos quarantined {q!r} != faulted "
            f"requests {qe!r} — quarantine must hit exactly the "
            f"faulted slots"
        )
    acc = (chaos.get("served_ok"), chaos.get("shed"), q)
    if not all(isinstance(x, int) for x in acc) or sum(acc) != n:
        errors.append(
            f"fault_tolerance.chaos dispositions ok+shed+quarantined "
            f"{acc!r} do not sum to requests {n!r}"
        )
    sr = chaos.get("shed_rate")
    if not isinstance(sr, (int, float)) or not (0.0 <= sr <= 1.0):
        errors.append(
            f"fault_tolerance.chaos.shed_rate invalid: {sr!r}"
        )
    elif isinstance(chaos.get("shed"), int) and chaos["shed"] > 0 \
            and not sr > 0:
        errors.append(
            "fault_tolerance.chaos.shed_rate is 0 despite sheds"
        )
    mr = chaos.get("deadline_miss_rate")
    if not isinstance(mr, (int, float)) or not (0.0 <= mr <= 1.0):
        errors.append(
            f"fault_tolerance.chaos.deadline_miss_rate invalid: {mr!r}"
        )
    rt = chaos.get("recovery_ticks_max")
    if isinstance(q, int) and q > 0 and (
        not isinstance(rt, int) or not (1 <= rt <= MAX_RECOVERY_TICKS)
    ):
        errors.append(
            f"fault_tolerance.chaos.recovery_ticks_max {rt!r} outside "
            f"[1, {MAX_RECOVERY_TICKS}]"
        )
    if chaos.get("diagnosis") not in (
        "faulty", "overloaded", "breaching", "nominal"
    ):
        errors.append(
            f"fault_tolerance.chaos.diagnosis invalid: "
            f"{chaos.get('diagnosis')!r}"
        )
    # v6: static-analysis + recompile contract
    sa = doc.get("static_analysis", {})
    if not isinstance(sa, dict) or not sa:
        errors.append("static_analysis block missing")
    else:
        lf = sa.get("lint_findings")
        if lf != 0:
            errors.append(
                f"static_analysis.lint_findings = {lf!r} != 0 — the tree "
                "must lint clean (fix or suppress with a reason)"
            )
        rc = sa.get("steady_state_recompiles")
        if rc != 0:
            errors.append(
                f"static_analysis.steady_state_recompiles = {rc!r} != 0 — "
                "a dispatch path recompiled mid-serve"
            )
        det = sa.get("recompile_detector", {})
        tracked = det.get("tracked", {}) if isinstance(det, dict) else {}
        if not tracked:
            errors.append("static_analysis.recompile_detector.tracked empty")
        for name, rep in tracked.items():
            unexpected = rep.get("unexpected")
            if unexpected is None or unexpected > 0:
                errors.append(
                    f"static_analysis: `{name}` compiled "
                    f"{rep.get('cache_growth')!r} time(s) in the open-loop "
                    f"region (allowed {rep.get('allowed')!r})"
                )
        kv = sa.get("kernel_vmem_bytes")
        if not isinstance(kv, dict) or not kv:
            errors.append("static_analysis.kernel_vmem_bytes missing")

    # v7: crash-safety evidence — warm-restart parity, checksum
    # fallback, preemption round-trips
    rec = doc.get("recovery", {})
    if not isinstance(rec, dict) or not rec:
        errors.append("recovery block missing")
    else:
        for k in ("snapshot_us", "restore_us"):
            v = rec.get(k)
            if not isinstance(v, (int, float)) or not v > 0:
                errors.append(
                    f"recovery.{k} not a positive number: {v!r}"
                )
        if not isinstance(rec.get("snapshots_written"), int) or (
            rec.get("snapshots_written", 0) < 2
        ):
            errors.append(
                f"recovery.snapshots_written "
                f"{rec.get('snapshots_written')!r} < 2 — the rotation "
                "path was not exercised"
            )
        if rec.get("resume_parity") is not True:
            errors.append(
                "recovery.resume_parity is not true — a warm-restarted "
                "engine diverged from the uninterrupted oracle"
            )
        fb, inj = rec.get("checkpoint_fallbacks"), rec.get(
            "injected_corruptions"
        )
        if fb != inj:
            errors.append(
                f"recovery.checkpoint_fallbacks {fb!r} != injected "
                f"corruptions {inj!r} — a corrupt snapshot was either "
                "missed by the checksum or double-counted"
            )
        pre = rec.get("preemptions")
        if not isinstance(pre, int) or pre < 1:
            errors.append(
                f"recovery.preemptions {pre!r} < 1 — the urgent arrival "
                "did not preempt a resident slot"
            )
        if not isinstance(rec.get("preempt_resumes"), int) or (
            rec.get("preempt_resumes", 0) < 1
        ):
            errors.append(
                f"recovery.preempt_resumes "
                f"{rec.get('preempt_resumes')!r} < 1 — a parked window "
                "was never restored"
            )
        if rec.get("preempt_parity") is not True:
            errors.append(
                "recovery.preempt_parity is not true — a parked/"
                "restored window diverged from the oracle"
            )
        if isinstance(pre, int) and pre > 0:
            for k in (
                "preempt_park_us",
                "preempt_restore_us",
                "preempt_round_trip_us",
            ):
                v = rec.get(k)
                if not isinstance(v, (int, float)) or not v > 0:
                    errors.append(
                        f"recovery.{k} not a positive number: {v!r}"
                    )

    # sidecar artifacts exist and are structurally sound
    arts = doc.get("artifacts", {})
    base = Path(path).resolve().parent
    trace_name = arts.get("trace")
    if not isinstance(trace_name, str):
        errors.append("artifacts.trace missing")
    else:
        errors.extend(_validate_trace_file(base / trace_name))
    metrics_name = arts.get("metrics")
    if not isinstance(metrics_name, str):
        errors.append("artifacts.metrics missing")
    else:
        try:
            msnap = json.loads((base / metrics_name).read_text())
            missing = [k for k in HIST_KEYS if k not in msnap]
            if missing:
                errors.append(
                    f"metrics snapshot {metrics_name} missing {missing}"
                )
            if "engine.slo.status" not in msnap:
                errors.append(
                    f"metrics snapshot {metrics_name} missing the "
                    f"engine.slo.status gauge"
                )
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"metrics snapshot unreadable: {e}")
    ts_name = arts.get("timeseries")
    if not isinstance(ts_name, str):
        errors.append("artifacts.timeseries missing")
    else:
        errors.extend(
            _validate_timeseries_file(base / ts_name, n_samples, cons)
        )
    return errors


def _validate_timeseries_file(
    path: Path, n_samples, cons: Dict
) -> List[str]:
    """The JSONL sidecar must parse, carry one object per sample, and
    its per-line deltas must re-sum to the doc's consistency totals
    (ring never overflowed in a bench run, so the file is complete)."""
    try:
        lines = Path(path).read_text().splitlines()
    except OSError as e:
        return [f"timeseries sidecar unreadable: {e}"]
    errors: List[str] = []
    if isinstance(n_samples, int) and len(lines) != n_samples:
        errors.append(
            f"timeseries sidecar has {len(lines)} lines, doc says "
            f"{n_samples} samples"
        )
    sums: Dict[str, float] = {}
    for i, line in enumerate(lines):
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            return errors + [f"timeseries sidecar line {i + 1}: {e}"]
        for want in ("t", "dt", "values", "deltas"):
            if want not in obj:
                errors.append(
                    f"timeseries sidecar line {i + 1} missing {want!r}"
                )
        for k, v in obj.get("deltas", {}).items():
            sums[k] = sums.get(k, 0.0) + v
    for k, c in cons.items():
        if not isinstance(c, dict):
            continue
        st = c.get("series_total")
        if isinstance(st, (int, float)) and abs(
            sums.get(k, 0.0) - st
        ) > 1e-6 * max(abs(st), 1.0):
            errors.append(
                f"timeseries sidecar deltas for {k} sum to "
                f"{sums.get(k, 0.0)!r}, doc consistency says {st!r}"
            )
    return errors


def _validate_trace_file(path: Path) -> List[str]:
    """The exported Chrome trace must be loadable and carry both span
    families (request-lifecycle and tick-phase)."""
    try:
        trace = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"trace artifact unreadable: {e}"]
    evs = trace.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["trace artifact has no traceEvents"]
    errors = []
    spans = [e for e in evs if e.get("ph") == "X"]
    if not spans:
        errors.append("trace artifact has no complete ('X') spans")
    if not any(e.get("ph") == "M" for e in evs):
        errors.append("trace artifact has no thread metadata")
    names = {e.get("name") for e in spans}
    for needed in ("chunk", "dispatch", "queue"):
        if needed not in names:
            errors.append(f"trace artifact missing {needed!r} spans")
    bad = [
        e for e in spans
        if not isinstance(e.get("ts"), (int, float))
        or not isinstance(e.get("dur"), (int, float))
        or e["dur"] < 0
    ]
    if bad:
        errors.append(f"trace artifact has {len(bad)} malformed spans")
    return errors


def run() -> None:
    main([])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 4096-512-2 (slow on CPU)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--quick", action="store_true",
                    help="open-loop async serving bench -> stream_bench.json"
                         " (combine with --full for the longer trace)")
    ap.add_argument("--json", type=Path, default=None,
                    help="output path for --quick (default repo root)")
    ap.add_argument("--validate", type=Path, default=None,
                    help="validate an existing stream_bench.json and exit")
    args = ap.parse_args(argv)
    if args.validate is not None:
        errors = validate(args.validate)
        if errors:
            for e in errors:
                print(f"stream_bench.json INVALID: {e}", file=sys.stderr)
            return 1
        print(f"{args.validate}: OK")
        return 0
    if args.quick:
        open_loop_run(quick=not args.full, json_path=args.json)
        return 0

    sizes = (4096, 512, 2) if args.full else (1024, 256, 2)
    cfg = snn.SNNConfig(layer_sizes=sizes, num_steps=25)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    wq = quant.quantize(params["layer0"]["w"])  # for the kernel comparison
    B, T, K = args.batch, cfg.num_steps, sizes[0]
    rng = np.random.default_rng(0)

    dense_fwd = jax.jit(lambda s: snn.forward(params, s, cfg, train=False))
    event_fwd = jax.jit(lambda s: runtime.event_forward(params, s, cfg))
    dense_ops = energy.snn_inference_ops(
        sizes, T, [1.0] * cfg.num_layers, event_driven=False
    )

    print(f"# layer_sizes={sizes} T={T} B={B} (per-inference numbers)")
    print("rate,meas_events_l0,aer_adds,dense_adds,add_ratio,"
          "aer_energy_pj,dense_energy_pj,energy_ratio,"
          "dense_fwd_us,event_fwd_us,events_per_sec,"
          "spike_mm_us,aer_mm_us")
    for rate in RATES:
        spikes = (rng.random((T, B, K)) < rate).astype(np.float32)
        spikes_j = jnp.asarray(spikes)

        _, _, ev = event_fwd(spikes_j)
        ev_mean = np.asarray(ev).mean(axis=1)  # per-inference events/layer
        oc = energy.snn_ops_from_events(sizes, T, ev_mean)
        aer_adds = oc.ops.get("add_i32", 0.0)
        dense_adds = dense_ops.ops["add_i32"]

        t_dense = time_fn(dense_fwd, spikes_j, warmup=1, iters=3)
        t_event = time_fn(event_fwd, spikes_j, warmup=1, iters=3)
        ev_total = float(np.asarray(ev).sum())
        evps = ev_total / args.batch / (t_event * 1e-6) if t_event else 0.0

        # kernel-level: one step's integration, dense vs AER event list
        row = jnp.asarray(spikes[0, 0][None, :].astype(np.int8))
        t_mm = time_fn(ops.spike_matmul, row, wq, warmup=1, iters=3)
        idx = np.nonzero(spikes[0, 0])[0]
        cap = max(int(K * max(rate, 0.01)) + 8, 8)
        a = np.zeros(cap, np.int32)
        v = np.zeros(cap, np.int32)
        a[: len(idx[:cap])] = idx[:cap]
        v[: len(idx[:cap])] = 1
        t_aer = time_fn(
            ops.aer_spike_matmul, jnp.asarray(a), jnp.asarray(v), wq,
            warmup=1, iters=3,
        )

        print(
            f"{rate:.2f},{ev_mean[0]:.0f},{aer_adds:.3g},{dense_adds:.3g},"
            f"{aer_adds/dense_adds:.3f},"
            f"{oc.energy_pj():.3g},{dense_ops.energy_pj():.3g},"
            f"{oc.energy_pj()/dense_ops.energy_pj():.3f},"
            f"{t_dense:.0f},{t_event:.0f},{evps:.0f},"
            f"{t_mm:.0f},{t_aer:.0f}",
            flush=True,
        )


if __name__ == "__main__":
    raise SystemExit(main())
