"""Canonical SNN hot-path benchmark -> ``BENCH_snn.json`` at the repo root.

Tracks the perf trajectory of the event-driven chunk path across PRs on
the paper's own 4096-512-2 collision config.  Three paths, same inputs,
same run:

  - ``baseline_pr2_jnp``: faithful replica of the PR-2 hot loop —
    per-chunk requant of the full weight set, O(K log K) argsort event
    compaction, full fan-in event capacity.
  - ``overhauled_jnp``: this PR's jnp path — params prepared once, O(K)
    cumsum-scatter ``step_events``, capacities autotuned (lossless
    p100 * safety) from measured spike counts.
  - ``fused``: the single-invocation Pallas chunk kernel
    (``kernels.snn_chunk``) — Mosaic on TPU, interpret on CPU (recorded
    with its ``pallas_mode`` so numbers are never compared across modes
    silently).
  - ``serving_resident``: the stream engine's device-resident chunk —
    event tables staged once at admission, ``dynamic_slice``d per chunk
    by on-device ``slot_done`` offsets; no per-chunk host assembly, H2D
    transfer, or layer-0 re-extraction.  The ``host_overhead`` section
    records what that per-chunk haul used to cost (dense H2D upload +
    host chunk assembly), measured on this host.

Usage:
  PYTHONPATH=src python -m benchmarks.snn_bench [--quick] [--json PATH]
  PYTHONPATH=src python -m benchmarks.snn_bench --validate BENCH_snn.json
  PYTHONPATH=src python -m benchmarks.run --quick       # same, via run.py

CI runs ``--quick`` and then ``--validate`` — a malformed artifact fails
the job.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import coding, neuron, snn
from repro.events import capacity as cap_mod
from repro.events import runtime

REPO_ROOT = Path(__file__).resolve().parents[1]
DEFAULT_JSON = REPO_ROOT / "BENCH_snn.json"
SCHEMA = "bench_snn/v1"

REQUIRED_TOP = ("schema", "backend", "mode", "config", "capacity_plan",
                "paths", "step_events_us", "host_overhead", "speedup")
REQUIRED_PATHS = ("baseline_pr2_jnp", "overhauled_jnp", "fused",
                  "serving_resident")
REQUIRED_PATH_KEYS = ("us_per_chunk", "steps_per_s", "events_per_s")
REQUIRED_SPEEDUP = (
    "fused_vs_baseline_steps_per_s",
    "overhauled_jnp_vs_baseline_steps_per_s",
    "serving_resident_vs_overhauled_steps_per_s",
    "selected_vs_baseline_steps_per_s",
)
REQUIRED_HOST_OVERHEAD = ("dense_chunk_h2d_us", "host_assembly_us")


def _baseline_chunk(params, states, spikes, cfg: snn.SNNConfig):
    """PR-2 hot-path replica (pre-overhaul ``run_chunk``): requantizes the
    full weight set inside the traced chunk, extracts events by stable
    argsort at full fan-in capacity."""
    ncfg = cfg.neuron_cfg
    p = runtime.prepare_params(params, cfg)  # re-traced into every chunk

    def step(st, x_t):
        new, ev = [], []
        h = x_t
        for i in range(cfg.num_layers):
            lp = p[f"layer{i}"]
            a, v, c = runtime.step_events_argsort(h, cfg.layer_sizes[i])
            cur = runtime.gather_current(lp["w"], lp["b"], a, v)
            s2, spk = neuron.neuron_step(
                ncfg, st[i], cur,
                beta=snn.effective_beta(lp), threshold=lp["threshold"],
            )
            new.append(s2)
            ev.append(c.astype(jnp.float32))
            h = spk
        return tuple(new), (new[-1].u, h, jnp.stack(ev))

    fin, (m, s, e) = jax.lax.scan(step, tuple(states), spikes)
    return list(fin), m, s, e


def _time_host_assembly(trains, Tc: int, iters: int = 5) -> float:
    """Median microseconds to rebuild one dense (Tc, B, K) chunk on the
    host from per-request trains — the per-tick python loop the resident
    engine deleted (timed host-only; the H2D upload is timed apart)."""
    import time as _time

    B, K = len(trains), trains[0].shape[1]
    times = []
    for it in range(iters):
        d = (it * Tc) % max(trains[0].shape[0] - Tc, 1)
        t0 = _time.perf_counter()
        chunk = np.zeros((Tc, B, K), np.float32)
        for s, tr in enumerate(trains):
            take = min(Tc, tr.shape[0] - d)
            chunk[:take, s] = tr[d : d + take]
        times.append(_time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _path_stats(us_per_chunk: float, chunk_steps: int, batch: int,
                events_per_chunk: float, **extra) -> Dict:
    sec = us_per_chunk * 1e-6
    return {
        "us_per_chunk": us_per_chunk,
        # network time-steps advanced per second across the micro-batch
        "steps_per_s": chunk_steps * batch / sec,
        "events_per_s": events_per_chunk / sec,
        **extra,
    }


def run(quick: bool = False, json_path: Optional[Path] = None) -> Dict:
    from repro.configs.collision_snn import CONFIG as cfg
    from repro.kernels import ops

    json_path = Path(json_path) if json_path else DEFAULT_JSON
    on_tpu = ops.on_tpu()
    B = 4 if quick else 8
    Tc = 5
    warm, iters = (1, 3) if quick else (2, 5)
    K = cfg.layer_sizes[0]

    imgs = jax.random.uniform(jax.random.PRNGKey(1), (B, K)) * 0.4
    spikes_full = coding.rate_encode(
        jax.random.PRNGKey(2), imgs, cfg.num_steps
    )  # (T, B, K), ~0.2 mean rate — the paper's rate-coded regime
    chunk = spikes_full[:Tc]
    states = runtime.init_states(cfg, B)
    rate = float(chunk.mean())

    # lossless capacity plan measured on the full window
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    plan = cap_mod.autotune(
        params, cfg, spikes_full,
        percentile=100.0, safety=1.2, align=128,
    )
    prepared = runtime.prepare_params(params, cfg)

    # measured events of one chunk (identical for all paths by parity)
    _, _, _, ev = runtime.run_chunk(params, states, chunk, cfg)
    events_per_chunk = float(np.asarray(ev).sum())

    base_j = jax.jit(lambda st, sp: _baseline_chunk(params, st, sp, cfg))
    over_j = jax.jit(
        lambda st, sp: runtime.run_chunk(
            prepared, st, sp, cfg,
            prepared=True, capacities=plan.capacities, backend="jnp",
        )
    )
    fused_j = jax.jit(
        lambda st, sp: runtime.run_chunk(
            prepared, st, sp, cfg,
            prepared=True, capacities=plan.capacities, backend="fused",
        )
    )

    t_base = time_fn(base_j, states, chunk, warmup=warm, iters=iters)
    t_over = time_fn(over_j, states, chunk, warmup=warm, iters=iters)
    t_fused = time_fn(fused_j, states, chunk, warmup=warm, iters=iters)

    # serving-resident path: the stream engine's compiled chunk over
    # device-staged event rings (same geometry/capacities), plus the
    # host-overhead costs it deletes — the dense per-chunk H2D upload
    # and the host-side chunk assembly loop of the pre-residency tick
    from repro.serving.snn_engine import SNNStreamEngine

    engine = SNNStreamEngine(
        params, cfg, num_slots=B, chunk_steps=Tc, backend="jnp",
        capacities=plan.capacities,
    )
    trains = [np.asarray(spikes_full[:, b, :]) for b in range(B)]
    staged = engine.staged_chunk_args(trains)
    t_resident = time_fn(
        engine.chunk_for_timing(), *staged, warmup=warm, iters=iters
    )

    chunk_np = np.asarray(chunk)
    t_h2d = time_fn(
        lambda: jax.device_put(chunk_np), warmup=warm, iters=iters
    )
    t_assembly = _time_host_assembly(trains, Tc, iters=max(iters, 3))

    # event-extraction microbenchmark: the O(K log K) -> O(K) rewrite
    plane = chunk[0]
    t_argsort = time_fn(
        jax.jit(lambda x: runtime.step_events_argsort(x, K)),
        plane, warmup=warm, iters=iters,
    )
    t_cumsum = time_fn(
        jax.jit(lambda x: runtime.step_events(x, K)),
        plane, warmup=warm, iters=iters,
    )

    paths = {
        "baseline_pr2_jnp": _path_stats(
            t_base, Tc, B, events_per_chunk,
            detail="argsort events, full fan-in capacity, requant/chunk",
        ),
        "overhauled_jnp": _path_stats(
            t_over, Tc, B, events_per_chunk,
            detail="O(K) step_events, autotuned capacity, prepared params",
        ),
        "fused": _path_stats(
            t_fused, Tc, B, events_per_chunk,
            pallas_mode="mosaic" if on_tpu else "interpret",
            detail="kernels.snn_chunk single-invocation chunk",
        ),
        "serving_resident": _path_stats(
            t_resident, Tc, B, events_per_chunk,
            detail="engine ring-sliced pre-staged events: no per-chunk "
                   "assembly/H2D/extraction",
        ),
    }
    # the path backend="auto" actually selects on this host
    selected = "fused" if on_tpu else "overhauled_jnp"
    result = {
        "schema": SCHEMA,
        "backend": jax.default_backend(),
        "mode": "quick" if quick else "full",
        "config": {
            "layer_sizes": list(cfg.layer_sizes),
            "num_steps": cfg.num_steps,
            "chunk_steps": Tc,
            "batch": B,
            "measured_input_rate": rate,
            "quant_q115": cfg.quant_q115,
            "events_per_chunk": events_per_chunk,
        },
        "capacity_plan": plan.as_dict(),
        "paths": paths,
        "step_events_us": {"argsort": t_argsort, "cumsum_scatter": t_cumsum},
        # what the pre-residency tick paid per chunk on top of compute:
        # host-assembling the dense (Tc, B, K) plane and shipping it H2D
        "host_overhead": {
            "dense_chunk_h2d_us": t_h2d,
            "host_assembly_us": t_assembly,
            "dense_chunk_bytes": int(chunk_np.nbytes),
            # from the engine's actual staged dtypes (addr width depends
            # on fan-in) incl. the per-step counts lane
            "resident_chunk_bytes": int(
                Tc * B * plan.capacities[0]
                * (staged[2]["addrs"].dtype.itemsize
                   + staged[2]["values"].dtype.itemsize)
                + Tc * B * staged[2]["counts"].dtype.itemsize
            ),
        },
        "speedup": {
            "fused_vs_baseline_steps_per_s": (
                paths["fused"]["steps_per_s"]
                / paths["baseline_pr2_jnp"]["steps_per_s"]
            ),
            "overhauled_jnp_vs_baseline_steps_per_s": (
                paths["overhauled_jnp"]["steps_per_s"]
                / paths["baseline_pr2_jnp"]["steps_per_s"]
            ),
            "serving_resident_vs_overhauled_steps_per_s": (
                paths["serving_resident"]["steps_per_s"]
                / paths["overhauled_jnp"]["steps_per_s"]
            ),
            "selected_path": selected,
            "selected_vs_baseline_steps_per_s": (
                paths[selected]["steps_per_s"]
                / paths["baseline_pr2_jnp"]["steps_per_s"]
            ),
        },
    }
    json_path.write_text(json.dumps(result, indent=2) + "\n")

    for name, st in paths.items():
        emit(
            f"snn_bench/{name}", st["us_per_chunk"],
            f"steps_per_s={st['steps_per_s']:.1f};"
            f"events_per_s={st['events_per_s']:.0f}",
        )
    emit(
        "snn_bench/speedup_selected_vs_baseline",
        0.0,
        f"{result['speedup']['selected_vs_baseline_steps_per_s']:.2f}x;"
        f"json={json_path}",
    )
    return result


def validate(path: Path) -> List[str]:
    """Structural validation of a BENCH_snn.json; returns error strings."""
    errors: List[str] = []
    try:
        doc = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable JSON: {e}"]
    if not isinstance(doc, dict):
        return ["top level is not an object"]
    for k in REQUIRED_TOP:
        if k not in doc:
            errors.append(f"missing top-level key {k!r}")
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema {doc.get('schema')!r} != {SCHEMA!r}")
    paths = doc.get("paths", {})
    for p in REQUIRED_PATHS:
        if p not in paths:
            errors.append(f"missing path {p!r}")
            continue
        for k in REQUIRED_PATH_KEYS:
            v = paths[p].get(k)
            if not isinstance(v, (int, float)) or not v > 0:
                errors.append(f"paths.{p}.{k} not a positive number: {v!r}")
    speedup = doc.get("speedup", {})
    for k in REQUIRED_SPEEDUP:
        v = speedup.get(k)
        if not isinstance(v, (int, float)) or not v > 0:
            errors.append(f"speedup.{k} not a positive number: {v!r}")
    host = doc.get("host_overhead", {})
    for k in REQUIRED_HOST_OVERHEAD:
        v = host.get(k)
        if not isinstance(v, (int, float)) or not v > 0:
            errors.append(f"host_overhead.{k} not a positive number: {v!r}")
    caps = doc.get("capacity_plan", {}).get("capacities")
    if not (isinstance(caps, list) and caps
            and all(isinstance(c, int) and c >= 1 for c in caps)):
        errors.append(f"capacity_plan.capacities malformed: {caps!r}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", type=Path, default=None)
    ap.add_argument("--validate", type=Path, default=None,
                    help="validate an existing BENCH_snn.json and exit")
    args = ap.parse_args(argv)
    if args.validate is not None:
        errors = validate(args.validate)
        if errors:
            for e in errors:
                print(f"BENCH_snn.json INVALID: {e}", file=sys.stderr)
            return 1
        print(f"{args.validate}: OK")
        return 0
    run(quick=args.quick, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
