"""Paper Table 3 analog: neuron-model micro-costs.

The paper compares FPGA slice/LUT/power for single-neuron designs.  The
TPU analog of 'resources per neuron' is (a) per-step arithmetic cost from
the energy model, (b) measured microbenchmark time for a batch of
neurons, (c) VMEM bytes per neuron tile in the fused kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import energy, neuron
from repro.kernels import ops

T, B, N = 25, 8, 512


def run() -> None:
    rng = np.random.default_rng(0)
    cur = jnp.asarray(rng.normal(0, 0.7, (T, B, N)).astype(np.float32))
    beta = jnp.asarray(rng.uniform(0.6, 0.95, N).astype(np.float32))
    thr = jnp.ones((N,), jnp.float32)

    # scan-based LIF / Lapicque (the software model)
    for kind in ("lif", "lapicque"):
        cfg = neuron.NeuronConfig(kind=kind, surrogate="boxcar")
        fn = jax.jit(
            lambda c, b, t: neuron.run_neuron(cfg, c, beta=b, threshold=t)[0]
        )
        us = time_fn(fn, cur, beta, thr)
        # per-neuron-step energy (pJ): LIF = mul+add+cmp, Lapicque drops mul
        e = energy.ENERGY_PJ
        pj = (
            e["mul_i16"] + e["add_i16"] + e["cmp_i16"]
            if kind == "lif"
            else e["add_i16"] + e["cmp_i16"]
        )
        emit(
            f"table3/{kind}_scan",
            us,
            f"neuron_steps={T*B*N};pj_per_step={pj:.2f};"
            f"paper_power_mw=85;paper_device=Artix-7",
        )

    # fused Pallas kernel (interpret mode on CPU; Mosaic on TPU)
    for refrac in (0, 5):
        fn = jax.jit(
            lambda c, b, t: ops.lif_fused(
                c, b, t, refractory_steps=refrac
            )[0]
        )
        us = time_fn(fn, cur, beta, thr, warmup=1, iters=3)
        vmem_bytes = T * 8 * 128 * 4 * 2 + 8 * 128 * (4 + 4)
        emit(
            f"table3/lif_fused_kernel_refrac{refrac}",
            us,
            f"vmem_per_tile_bytes={vmem_bytes};"
            "hbm_traffic=in_once_out_once",
        )


if __name__ == "__main__":
    run()
