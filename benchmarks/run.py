# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

  PYTHONPATH=src python -m benchmarks.run           # all tables (reduced)
  PYTHONPATH=src python -m benchmarks.run table2    # one table
  PYTHONPATH=src python -m benchmarks.run --quick   # quick snn hot-path
                                                    # bench -> BENCH_snn.json

Tables map 1:1 to the paper (see DESIGN.md §8):
  table1 -> LIF vs Lapicque accuracy x image size
  table2 -> SNN vs BCNN energy efficiency (GOPS/W analog)
  table3 -> neuron-unit micro-costs
  table4 -> network-level end-to-end inference
Plus `roofline` (beyond paper): the 40-cell dry-run roofline table, and
`snn`: the canonical event-driven chunk benchmark that emits
``BENCH_snn.json`` at the repo root (fused vs PR-2 baseline trajectory).
"""

from __future__ import annotations

import sys

from benchmarks.common import header


def main() -> None:
    argv = sys.argv[1:]
    quick = "--quick" in argv
    which = {a for a in argv if not a.startswith("-")}
    if not which:
        which = (
            {"snn"}
            if quick
            else {"table1", "table2", "table3", "table4", "kernels", "snn"}
        )
    header()
    if "table1" in which:
        from benchmarks import table1_accuracy

        table1_accuracy.run()
    if "table2" in which:
        from benchmarks import table2_energy

        table2_energy.run()
    if "table3" in which:
        from benchmarks import table3_neuron

        table3_neuron.run()
    if "table4" in which:
        from benchmarks import table4_network

        table4_network.run()
    if "kernels" in which:
        from benchmarks import kernel_bench

        kernel_bench.run()
    if "roofline" in which:
        from benchmarks import roofline

        roofline.run()
    if "stream" in which:
        from benchmarks import stream_bench

        stream_bench.run()
    if "sparse_train" in which:
        from benchmarks import sparse_train_bench

        sparse_train_bench.run()
    if "snn" in which:
        from benchmarks import snn_bench

        snn_bench.run(quick=quick)


if __name__ == "__main__":
    main()
