"""Training cost vs input spike rate: event-driven vs dense BPTT.

For each input spike rate r:
  - run one jitted ``value_and_grad`` step of the event-driven loss
    (sparse_train) and of the dense ``core/snn`` loss, and time both;
  - read the *measured* per-layer event counts from the event path's aux
    and price one training example with
    ``core.energy.snn_train_ops_from_events`` — against the dense
    trainer's flat cost (``dense=True``).

The acceptance signal: event-driven training ops scale monotonically with
the input spike rate (sparser activity -> monotonically fewer ops) while
the dense baseline stays flat (wall times on CPU are indicative only; the
op/energy scaling is the portable claim).

Usage:  PYTHONPATH=src python -m benchmarks.sparse_train_bench
            [--full] [--quick] [--json out.json]
   or:  PYTHONPATH=src python -m benchmarks.run sparse_train
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import energy, snn
from repro.sparse_train import event_loss_fn

RATES = (0.02, 0.05, 0.1, 0.25, 0.5, 0.75, 1.0)


def run() -> None:
    main([])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 4096-512-2 (slow on CPU)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny config + 3 rates (CI smoke)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--json", default=None,
                    help="also write results as JSON to this path")
    args = ap.parse_args(argv)

    if args.full:
        sizes, T = (4096, 512, 2), 25
    elif args.quick:
        sizes, T = (256, 64, 2), 10
    else:
        sizes, T = (1024, 256, 2), 25
    rates = RATES[1::2] if args.quick else RATES
    cfg = snn.SNNConfig(layer_sizes=sizes, num_steps=T, dropout_rate=0.0)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    B, K = args.batch, sizes[0]
    rng = np.random.default_rng(0)
    labels = jnp.asarray(rng.integers(0, 2, B))

    ev_grad = jax.jit(
        jax.value_and_grad(
            lambda p, s: event_loss_fn(
                p, s, labels, cfg, energy_lambda=0.0, train=False
            ),
            has_aux=True,
        )
    )
    dn_grad = jax.jit(
        jax.value_and_grad(
            lambda p, s: snn.loss_fn(p, s, labels, cfg, train=False)[0]
        )
    )

    rows = []
    print(f"# layer_sizes={sizes} T={T} B={B} (per-example training cost)")
    print("rate,events_l0,events_l1,event_train_ops,dense_train_ops,"
          "ops_ratio,event_train_pj,dense_train_pj,"
          "event_grad_us,dense_grad_us")
    for rate in rates:
        spikes = jnp.asarray(
            (rng.random((T, B, K)) < rate).astype(np.float32)
        )
        (_, aux), _ = ev_grad(params, spikes)
        ev = [float(aux[f"events_l{i}"]) for i in range(cfg.num_layers)]
        oc = energy.snn_train_ops_from_events(sizes, T, ev)
        # priced per-rate with this rate's measured events, so dense_flat
        # below genuinely checks the dense cost is activity-independent
        dense_oc = energy.snn_train_ops_from_events(sizes, T, ev, dense=True)
        t_ev = time_fn(ev_grad, params, spikes, warmup=1, iters=3)
        t_dn = time_fn(dn_grad, params, spikes, warmup=1, iters=3)
        row = {
            "rate": rate,
            "events_l0": ev[0],
            "events_l1": ev[1],
            "event_train_ops": oc.total_ops(),
            "dense_train_ops": dense_oc.total_ops(),
            "ops_ratio": oc.total_ops() / dense_oc.total_ops(),
            "event_train_pj": oc.energy_pj(),
            "dense_train_pj": dense_oc.energy_pj(),
            "event_grad_us": t_ev,
            "dense_grad_us": t_dn,
        }
        rows.append(row)
        print(
            f"{rate:.2f},{ev[0]:.0f},{ev[1]:.0f},"
            f"{row['event_train_ops']:.3g},{row['dense_train_ops']:.3g},"
            f"{row['ops_ratio']:.3f},"
            f"{row['event_train_pj']:.3g},{row['dense_train_pj']:.3g},"
            f"{t_ev:.0f},{t_dn:.0f}",
            flush=True,
        )

    result = {
        "layer_sizes": list(sizes),
        "num_steps": T,
        "batch": B,
        "rows": rows,
        # acceptance: op count rises with rate (i.e. falls with sparsity)
        # while the dense column is constant
        "ops_scale_with_rate": all(
            a["event_train_ops"] <= b["event_train_ops"]
            for a, b in zip(rows, rows[1:])
        ),
        "dense_flat": len({r["dense_train_ops"] for r in rows}) == 1,
    }
    print(f"# ops_scale_with_rate={result['ops_scale_with_rate']} "
          f"dense_flat={result['dense_flat']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
