"""Paper Table 1 analog: LIF vs Lapicque accuracy by image size.

Trains the paper's SNN architecture (scaled input layer per image size,
hidden layer scaled for CPU runtime) on the synthetic collision dataset.
Paper values (DroNet): LIF 93/79 (32px), 92/85 (64px), 88/78 (128px);
Lapicque 93/84, 95/81, 92/80.  Our dataset is a synthetic analog (see
DESIGN.md §7) — the claim under test is the *structure*: both neuron
models reach high accuracy, LIF ~ Lapicque, across image sizes.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import coding, snn
from repro.data import collision
from repro.optim import adam, chain_clip
from repro.optim.adam import apply_updates

# reduced-scale knobs (full scale: examples/collision_avoidance.py)
HIDDEN = 128
EPOCHS = 6
NUM_TRAIN, NUM_TEST = 1024, 256
NUM_STEPS = 15


def train_one(image_hw: int, neuron_kind: str, seed: int = 0):
    cfg = snn.SNNConfig(
        layer_sizes=(image_hw * image_hw, HIDDEN, 2),
        num_steps=NUM_STEPS,
        neuron_kind=neuron_kind,
        dropout_rate=0.2,
    )
    data = collision.generate(
        collision.CollisionConfig(
            image_hw=image_hw, num_train=NUM_TRAIN, num_test=NUM_TEST,
            seed=seed,
        )
    )
    trx, trY, tex, teY = data
    key = jax.random.PRNGKey(seed)
    params = snn.init_params(key, cfg)
    opt = chain_clip(adam(5e-4), 1.0)  # paper: Adam lr 5e-4
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y, key):
        ekey, dkey = jax.random.split(key)
        spikes = coding.rate_encode(ekey, x, cfg.num_steps)
        (l, aux), g = jax.value_and_grad(snn.loss_fn, has_aux=True)(
            params, spikes, y, cfg, train=True, dropout_key=dkey
        )
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, l, aux

    for epoch in range(EPOCHS):
        for x, y in collision.batches(trx, trY, 64, seed=epoch):
            key, sk = jax.random.split(key)
            params, state, _, _ = step(params, state, x, y, sk)

    def acc(x, y, k):
        spikes = coding.rate_encode(
            k, jnp.asarray(x.reshape(len(x), -1)), cfg.num_steps
        )
        _, aux = snn.loss_fn(params, spikes, jnp.asarray(y), cfg, train=False)
        return float(aux["accuracy"])

    tr_acc = acc(trx[:NUM_TEST], trY[:NUM_TEST], jax.random.PRNGKey(101))
    te_acc = acc(tex, teY, jax.random.PRNGKey(102))
    return tr_acc, te_acc


def run(image_sizes=(32, 64)) -> None:
    paper = {
        (32, "lif"): (0.93, 0.79), (64, "lif"): (0.92, 0.85),
        (128, "lif"): (0.88, 0.78),
        (32, "lapicque"): (0.93, 0.84), (64, "lapicque"): (0.95, 0.81),
        (128, "lapicque"): (0.92, 0.80),
    }
    for hw in image_sizes:
        for kind in ("lif", "lapicque"):
            t0 = time.time()
            tr, te = train_one(hw, kind)
            p_tr, p_te = paper[(hw, kind)]
            emit(
                f"table1/{kind}_{hw}px",
                (time.time() - t0) * 1e6,
                f"train_acc={tr:.3f};test_acc={te:.3f};"
                f"paper_train={p_tr};paper_test={p_te}",
            )


if __name__ == "__main__":
    run()
