"""Streaming SNN serving engine: correctness of the scheduler (state
persistence across chunks, continuous batching, async admission with
deadlines/priorities, slot isolation) and of the measured per-request
energy accounting."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import energy, snn
from repro.events import runtime
from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

CFG = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=20)


def _params(seed=0):
    return snn.init_params(jax.random.PRNGKey(seed), CFG)


def _train(rate, seed, T=None):
    rng = np.random.default_rng(seed)
    T = T or CFG.num_steps
    return (rng.random((T, CFG.layer_sizes[0])) < rate).astype(np.float32)


def test_engine_matches_direct_event_forward():
    """One slot, one chunk covering the whole window == plain forward."""
    params = _params()
    train = _train(0.3, 0)
    eng = SNNStreamEngine(params, CFG, num_slots=1,
                          chunk_steps=CFG.num_steps)
    res = eng.run([StreamRequest(spikes=train)])[0]
    _, out_spikes, ev = runtime.event_forward(
        params, jnp.asarray(train)[:, None, :], CFG
    )
    np.testing.assert_allclose(
        res.spike_counts, np.asarray(out_spikes.sum(0))[0]
    )
    np.testing.assert_allclose(res.events_per_layer, np.asarray(ev)[:, 0])
    assert res.steps == CFG.num_steps
    assert res.latency_s > 0


def test_chunking_is_invisible():
    """Splitting the window into chunks (incl. a ragged final chunk) must
    not change results — membrane state persists across chunks."""
    params = _params()
    trains = [_train(0.25, s) for s in range(3)]
    ref_eng = SNNStreamEngine(params, CFG, num_slots=3,
                              chunk_steps=CFG.num_steps)
    ref_res = ref_eng.run([StreamRequest(spikes=t) for t in trains])
    # 7 does not divide 20: the last chunk is ragged
    chunked = SNNStreamEngine(params, CFG, num_slots=3, chunk_steps=7)
    chk_res = chunked.run([StreamRequest(spikes=t) for t in trains])
    for a, b in zip(ref_res, chk_res):
        np.testing.assert_allclose(a.spike_counts, b.spike_counts)
        np.testing.assert_allclose(a.events_per_layer, b.events_per_layer)
        assert a.prediction == b.prediction


def test_continuous_batching_refills_slots():
    params = _params()
    n_req = 7
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=6)
    reqs = [StreamRequest(spikes=_train(0.2, s)) for s in range(n_req)]
    results = eng.run(reqs)
    assert [r.request_id for r in results] == list(range(n_req))
    assert all(r.steps == CFG.num_steps for r in results)
    # every request's layer-0 events == nnz of its own train
    for s, r in enumerate(results):
        assert r.events_per_layer[0] == _train(0.2, s).sum()


def test_slot_isolation():
    """A request's result is identical whether served alone or packed with
    different requests (fresh state per admitted request)."""
    params = _params()
    probe = _train(0.3, 42)
    solo = SNNStreamEngine(params, CFG, num_slots=1, chunk_steps=5).run(
        [StreamRequest(spikes=probe)]
    )[0]
    packed = SNNStreamEngine(params, CFG, num_slots=3, chunk_steps=5).run(
        [StreamRequest(spikes=_train(0.6, 1)),
         StreamRequest(spikes=probe),
         StreamRequest(spikes=_train(0.1, 2)),
         StreamRequest(spikes=_train(0.9, 3))]
    )[1]
    np.testing.assert_allclose(solo.spike_counts, packed.spike_counts)
    np.testing.assert_allclose(
        solo.events_per_layer, packed.events_per_layer
    )


def test_measured_energy_tracks_activity():
    params = _params()
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=10)
    res = eng.run(
        [StreamRequest(spikes=_train(0.05, 0)),
         StreamRequest(spikes=_train(0.7, 1))]
    )
    sparse, busy = res
    assert sparse.spike_rate < busy.spike_rate
    assert sparse.energy_pj < busy.energy_pj
    assert sparse.events_per_layer[0] < busy.events_per_layer[0]
    assert eng.events_per_sec() > 0


def test_throughput_counters_are_per_run():
    params = _params()
    eng = SNNStreamEngine(params, CFG, num_slots=1, chunk_steps=10)
    assert eng.events_per_sec() == 0.0  # no run yet
    eng.run([StreamRequest(spikes=_train(0.3, 0))])
    first_events = eng.total_events
    eng.run([StreamRequest(spikes=_train(0.3, 0))])
    assert eng.total_events == first_events  # counters reset, not stacked


def test_rate_coded_image_requests():
    params = _params()
    rng = np.random.default_rng(5)
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=5)
    imgs = rng.random((3, CFG.layer_sizes[0])).astype(np.float32)
    results = eng.run([StreamRequest(image=im) for im in imgs])
    assert len(results) == 3
    for r in results:
        assert r.prediction in (0, 1)
        assert 0.0 < r.spike_rate < 1.0


# ------------------------------------------------- async admission + EDF
def _oracle(params, train):
    """Batch-oracle result for one request: plain event-driven forward."""
    _, out_spikes, ev = runtime.event_forward(
        params, jnp.asarray(train)[:, None, :], CFG
    )
    return np.asarray(out_spikes.sum(0))[0], np.asarray(ev)[:, 0]


def test_num_steps_zero_rejected():
    """Regression: ``req.num_steps or cfg.num_steps`` silently treated
    num_steps=0 as unset; 0 (and negatives) must be rejected loudly."""
    eng = SNNStreamEngine(_params(), CFG, num_slots=1)
    with pytest.raises(ValueError, match="num_steps"):
        eng.submit(StreamRequest(spikes=_train(0.3, 0), num_steps=0))
    with pytest.raises(ValueError, match="num_steps"):
        eng.submit(StreamRequest(spikes=_train(0.3, 0), num_steps=-3))
    # None still defaults to cfg.num_steps
    rid = eng.submit(StreamRequest(spikes=_train(0.3, 0), num_steps=None))
    res = eng.drain()
    assert [r.request_id for r in res] == [rid]
    assert res[0].steps == CFG.num_steps


def test_submit_validates_shapes_early():
    """Bad requests fail at submit(), not rounds later inside poll()."""
    eng = SNNStreamEngine(_params(), CFG, num_slots=1)
    with pytest.raises(ValueError, match="image shape"):
        eng.submit(StreamRequest(image=np.zeros(5, np.float32)))
    with pytest.raises(ValueError, match="spikes shape"):
        eng.submit(StreamRequest(spikes=np.zeros((3, 3), np.float32)))
    with pytest.raises(ValueError, match="image or spikes"):
        eng.submit(StreamRequest())
    assert eng.idle()  # nothing bad was enqueued


def test_mid_flight_admission_matches_batch_oracle():
    """Requests submitted while chunks are in flight get the same
    per-request results as the batch oracle."""
    params = _params()
    trains = [_train(0.25, s) for s in range(5)]
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=5)
    for t in trains[:2]:
        eng.submit(StreamRequest(spikes=t))
    results = []
    results += eng.poll()  # 2 slots mid-window ...
    results += eng.poll()
    for t in trains[2:]:  # ... when three more arrive
        eng.submit(StreamRequest(spikes=t))
    results += eng.drain()
    assert sorted(r.request_id for r in results) == list(range(5))
    for r in results:
        counts, ev = _oracle(params, trains[r.request_id])
        np.testing.assert_allclose(r.spike_counts, counts)
        np.testing.assert_allclose(r.events_per_layer, ev)
        assert r.queue_wait_s >= 0.0
        assert r.latency_s >= r.queue_wait_s


def test_edf_admission_under_contention():
    """With one slot, queued requests are admitted earliest-deadline-first
    (deadline-less requests last, FIFO within a class)."""
    eng = SNNStreamEngine(_params(), CFG, num_slots=1,
                          chunk_steps=CFG.num_steps)
    t = _train(0.2, 0)
    eng.submit(StreamRequest(spikes=t))                  # rid 0: no deadline
    eng.submit(StreamRequest(spikes=t, deadline_s=100))  # rid 1
    eng.submit(StreamRequest(spikes=t, deadline_s=10))   # rid 2
    eng.submit(StreamRequest(spikes=t, deadline_s=50))   # rid 3
    done = eng.drain()  # one request completes per poll (chunk == window)
    assert [r.request_id for r in done] == [2, 3, 1, 0]


def test_priority_overrides_deadline_order():
    eng = SNNStreamEngine(_params(), CFG, num_slots=1,
                          chunk_steps=CFG.num_steps)
    t = _train(0.2, 0)
    eng.submit(StreamRequest(spikes=t, deadline_s=1.0))       # rid 0, prio 0
    eng.submit(StreamRequest(spikes=t, priority=5))           # rid 1
    eng.submit(StreamRequest(spikes=t, deadline_s=2.0, priority=5))  # rid 2
    done = eng.drain()
    # priority class first; EDF inside the class, deadline-less last
    assert [r.request_id for r in done] == [2, 1, 0]


def test_deadline_miss_accounting():
    eng = SNNStreamEngine(_params(), CFG, num_slots=2, chunk_steps=5)
    t = _train(0.2, 0)
    eng.submit(StreamRequest(spikes=t, deadline_s=0.0))   # already due
    eng.submit(StreamRequest(spikes=t, deadline_s=1e4))   # generous
    eng.submit(StreamRequest(spikes=t))                   # no deadline
    done = eng.drain()
    by_id = {r.request_id: r for r in done}
    assert by_id[0].deadline_missed and by_id[0].deadline_s == 0.0
    assert not by_id[1].deadline_missed
    assert not by_id[2].deadline_missed and by_id[2].deadline_s is None
    assert eng.completed == 3 and eng.deadline_misses == 1
    assert eng.deadline_miss_rate() == pytest.approx(1 / 3)


def test_in_jit_slot_reset_isolates_sequential_admits():
    """The admit-mask reset inside the jitted chunk must give every
    request fresh state, including back-to-back reuse of one slot."""
    params = _params()
    probe = _train(0.3, 42)
    solo, _ = _oracle(params, probe)
    eng = SNNStreamEngine(params, CFG, num_slots=1, chunk_steps=5)
    # busy request first, then the probe lands on the same (dirty) slot,
    # twice — with a second episode in between
    first = eng.run([StreamRequest(spikes=_train(0.9, 1)),
                     StreamRequest(spikes=probe)])
    np.testing.assert_allclose(first[1].spike_counts, solo)
    again = eng.run([StreamRequest(spikes=probe)])
    np.testing.assert_allclose(again[0].spike_counts, solo)


def test_events_per_sec_mid_episode():
    """Mid-episode reads must use the episode clock, not the previous
    episode's wall time (counters and denominator move together)."""
    eng = SNNStreamEngine(_params(), CFG, num_slots=1, chunk_steps=5)
    eng.run([StreamRequest(spikes=_train(0.5, 0))])
    finished_rate = eng.events_per_sec()
    assert finished_rate > 0 and eng.wall_s > 0
    # new episode: counters reset at submit, mid-flight read is coherent
    eng.submit(StreamRequest(spikes=_train(0.5, 1)))
    # regression: wall_s is episode-scoped — a freshly-opened episode
    # must not carry the previous episode's wall time (it used to be
    # initialized once in __init__ and never reset)
    assert eng.wall_s == 0.0
    # two polls = dispatch chunks 1+2 and retire chunk 1's stats (the
    # pipelined tick holds one chunk's stats in flight); episode still
    # open with two chunks of four outstanding
    eng.poll()
    eng.poll()
    assert not eng.idle()
    mid = eng.events_per_sec()
    assert 0 < mid < np.inf
    assert eng.total_events < _train(0.5, 1).size  # episode-local numerator
    eng.drain()
    assert eng.events_per_sec() > 0


def test_submit_drain_equals_run():
    params = _params()
    trains = [_train(0.3, s) for s in range(4)]
    a = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=7).run(
        [StreamRequest(spikes=t) for t in trains]
    )
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=7)
    for t in trains:
        eng.submit(StreamRequest(spikes=t))
    b = sorted(eng.drain(), key=lambda r: r.request_id)
    for ra, rb in zip(a, b):
        np.testing.assert_allclose(ra.spike_counts, rb.spike_counts)
        np.testing.assert_allclose(ra.events_per_layer, rb.events_per_layer)
        assert ra.prediction == rb.prediction


# ---------------------------------------- acceptance: collision config
@pytest.mark.slow
@pytest.mark.parametrize("backend", ["jnp", "fused"])
def test_collision_config_parity_with_batch_oracle(backend):
    """Acceptance: on the paper's 4096-512-2 config, the async engine's
    predictions/energy match the batch-oracle event forward under
    mid-flight admission — for both the jnp and the fused (interpret on
    CPU) chunk backends."""
    from repro.configs.collision_snn import CONFIG

    cfg = dataclasses.replace(CONFIG, num_steps=8)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    trains = [
        (rng.random((cfg.num_steps, cfg.layer_sizes[0])) < 0.2).astype(
            np.float32
        )
        for _ in range(3)
    ]
    eng = SNNStreamEngine(params, cfg, num_slots=2, chunk_steps=3,
                          backend=backend)
    eng.submit(StreamRequest(spikes=trains[0], deadline_s=1e4))
    eng.submit(StreamRequest(spikes=trains[1]))
    results = eng.poll()  # mid-flight ...
    eng.submit(StreamRequest(spikes=trains[2], deadline_s=1e4))
    results += eng.drain()
    assert sorted(r.request_id for r in results) == [0, 1, 2]
    for r in results:
        out_mem, out_spikes, ev = runtime.event_forward(
            params, jnp.asarray(trains[r.request_id])[:, None, :], cfg
        )
        counts = np.asarray(out_spikes.sum(0))[0]
        memsum = np.asarray(out_mem.sum(0))[0]
        ev = np.asarray(ev)[:, 0]
        np.testing.assert_allclose(r.spike_counts, counts)
        np.testing.assert_allclose(r.events_per_layer, ev)
        # the engine's tie-break rule, applied to the oracle traces
        assert r.prediction == int(np.argmax(counts + 1e-6 * memsum))
        oc = energy.snn_ops_from_events(
            cfg.layer_sizes, cfg.num_steps, ev, neuron_kind=cfg.neuron_kind
        )
        assert r.energy_pj == pytest.approx(oc.energy_pj())
        assert not r.deadline_missed
