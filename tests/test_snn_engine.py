"""Streaming SNN serving engine: correctness of the scheduler (state
persistence across chunks, continuous batching, slot isolation) and of the
measured per-request energy accounting."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import snn
from repro.events import runtime
from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

CFG = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=20)


def _params(seed=0):
    return snn.init_params(jax.random.PRNGKey(seed), CFG)


def _train(rate, seed, T=None):
    rng = np.random.default_rng(seed)
    T = T or CFG.num_steps
    return (rng.random((T, CFG.layer_sizes[0])) < rate).astype(np.float32)


def test_engine_matches_direct_event_forward():
    """One slot, one chunk covering the whole window == plain forward."""
    params = _params()
    train = _train(0.3, 0)
    eng = SNNStreamEngine(params, CFG, num_slots=1,
                          chunk_steps=CFG.num_steps)
    res = eng.run([StreamRequest(spikes=train)])[0]
    _, out_spikes, ev = runtime.event_forward(
        params, jnp.asarray(train)[:, None, :], CFG
    )
    np.testing.assert_allclose(
        res.spike_counts, np.asarray(out_spikes.sum(0))[0]
    )
    np.testing.assert_allclose(res.events_per_layer, np.asarray(ev)[:, 0])
    assert res.steps == CFG.num_steps
    assert res.latency_s > 0


def test_chunking_is_invisible():
    """Splitting the window into chunks (incl. a ragged final chunk) must
    not change results — membrane state persists across chunks."""
    params = _params()
    trains = [_train(0.25, s) for s in range(3)]
    ref_eng = SNNStreamEngine(params, CFG, num_slots=3,
                              chunk_steps=CFG.num_steps)
    ref_res = ref_eng.run([StreamRequest(spikes=t) for t in trains])
    # 7 does not divide 20: the last chunk is ragged
    chunked = SNNStreamEngine(params, CFG, num_slots=3, chunk_steps=7)
    chk_res = chunked.run([StreamRequest(spikes=t) for t in trains])
    for a, b in zip(ref_res, chk_res):
        np.testing.assert_allclose(a.spike_counts, b.spike_counts)
        np.testing.assert_allclose(a.events_per_layer, b.events_per_layer)
        assert a.prediction == b.prediction


def test_continuous_batching_refills_slots():
    params = _params()
    n_req = 7
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=6)
    reqs = [StreamRequest(spikes=_train(0.2, s)) for s in range(n_req)]
    results = eng.run(reqs)
    assert [r.request_id for r in results] == list(range(n_req))
    assert all(r.steps == CFG.num_steps for r in results)
    # every request's layer-0 events == nnz of its own train
    for s, r in enumerate(results):
        assert r.events_per_layer[0] == _train(0.2, s).sum()


def test_slot_isolation():
    """A request's result is identical whether served alone or packed with
    different requests (fresh state per admitted request)."""
    params = _params()
    probe = _train(0.3, 42)
    solo = SNNStreamEngine(params, CFG, num_slots=1, chunk_steps=5).run(
        [StreamRequest(spikes=probe)]
    )[0]
    packed = SNNStreamEngine(params, CFG, num_slots=3, chunk_steps=5).run(
        [StreamRequest(spikes=_train(0.6, 1)),
         StreamRequest(spikes=probe),
         StreamRequest(spikes=_train(0.1, 2)),
         StreamRequest(spikes=_train(0.9, 3))]
    )[1]
    np.testing.assert_allclose(solo.spike_counts, packed.spike_counts)
    np.testing.assert_allclose(
        solo.events_per_layer, packed.events_per_layer
    )


def test_measured_energy_tracks_activity():
    params = _params()
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=10)
    res = eng.run(
        [StreamRequest(spikes=_train(0.05, 0)),
         StreamRequest(spikes=_train(0.7, 1))]
    )
    sparse, busy = res
    assert sparse.spike_rate < busy.spike_rate
    assert sparse.energy_pj < busy.energy_pj
    assert sparse.events_per_layer[0] < busy.events_per_layer[0]
    assert eng.events_per_sec() > 0


def test_throughput_counters_are_per_run():
    params = _params()
    eng = SNNStreamEngine(params, CFG, num_slots=1, chunk_steps=10)
    assert eng.events_per_sec() == 0.0  # no run yet
    eng.run([StreamRequest(spikes=_train(0.3, 0))])
    first_events = eng.total_events
    eng.run([StreamRequest(spikes=_train(0.3, 0))])
    assert eng.total_events == first_events  # counters reset, not stacked


def test_rate_coded_image_requests():
    params = _params()
    rng = np.random.default_rng(5)
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=5)
    imgs = rng.random((3, CFG.layer_sizes[0])).astype(np.float32)
    results = eng.run([StreamRequest(image=im) for im in imgs])
    assert len(results) == 3
    for r in results:
        assert r.prediction in (0, 1)
        assert 0.0 < r.spike_rate < 1.0
