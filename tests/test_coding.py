"""Input-coding tests (paper §3.2) incl. hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import coding


def test_rate_coding_matches_intensity():
    """Fig. 2: spike frequency tracks pixel intensity (0 / 0.5 / 1)."""
    key = jax.random.PRNGKey(0)
    x = jnp.asarray([0.0, 0.5, 1.0])
    spikes = coding.rate_encode(key, x, num_steps=2000)
    rates = np.asarray(spikes.mean(axis=0))
    assert rates[0] == 0.0
    assert abs(rates[1] - 0.5) < 0.05
    assert rates[2] == 1.0


@settings(max_examples=25, deadline=None)
@given(
    p=st.floats(0.0, 1.0),
    T=st.integers(1, 64),
)
def test_deterministic_rate_spike_count(p, T):
    """Deterministic encoder emits exactly round-ish(p*T) spikes."""
    spikes = coding.rate_encode_deterministic(jnp.asarray([p]), T)
    n = float(np.asarray(spikes).sum())
    assert abs(n - p * T) <= 1.0


def test_ttfs_brighter_fires_earlier():
    x = jnp.asarray([0.1, 0.5, 0.9])
    spikes = np.asarray(coding.ttfs_encode(x, 32))
    t_fire = spikes.argmax(axis=0)
    assert t_fire[2] < t_fire[1] < t_fire[0]
    assert spikes.sum(axis=0).max() <= 1  # at most one spike each


def test_ttfs_zero_never_fires():
    spikes = np.asarray(coding.ttfs_encode(jnp.asarray([0.0]), 16))
    assert spikes.sum() == 0


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-1.0, 1.0), min_size=2, max_size=40))
def test_delta_encoding_tracks_signal(sig):
    """Accumulated delta spikes reconstruct the signal within threshold."""
    thr = 0.1
    x = jnp.asarray(sig)[:, None]
    spikes = coding.delta_encode(x, threshold=thr)
    recon = np.cumsum(np.asarray(spikes)[:, 0]) * thr
    # reconstruction error bounded by threshold (plus slack for cumulative
    # quantization before the tracker catches up on big jumps)
    final_err = abs(recon[-1] - sig[-1])
    assert final_err <= thr + max(
        abs(np.diff(np.asarray(sig), prepend=0.0)).max(), thr
    )


def test_spike_trains_are_binary():
    key = jax.random.PRNGKey(1)
    x = jax.random.uniform(key, (8, 8))
    spikes = coding.rate_encode(key, x, 25)
    assert set(np.unique(np.asarray(spikes))) <= {0.0, 1.0}
