"""RG-LRU: associative scan == sequential loop; decode continuation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import griffin
from repro.models.config import ModelConfig

RNG = np.random.default_rng(5)

CFG = ModelConfig(
    family="hybrid", num_layers=3, d_model=32, num_heads=2, num_kv_heads=1,
    head_dim=16, d_ff=64, vocab_size=64, lru_width=24, dtype="float32",
    block_pattern=("rg", "rg", "attn"), window=8, attention_kind="local",
)


def test_associative_scan_matches_sequential():
    B, L, R = 2, 13, 6
    log_a = jnp.asarray(
        -np.abs(RNG.normal(0, 0.4, (B, L, R))).astype(np.float32)
    )
    bx = jnp.asarray(RNG.normal(0, 1, (B, L, R)).astype(np.float32))
    h = np.zeros((B, R), np.float64)
    want = np.zeros((B, L, R), np.float64)
    for t in range(L):
        h = np.exp(np.asarray(log_a[:, t], np.float64)) * h + np.asarray(
            bx[:, t], np.float64
        )
        want[:, t] = h
    got = griffin.rglru_scan(log_a, bx)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_rglru_decode_continues_prefill():
    p, _ = griffin.rglru_block_init(jax.random.PRNGKey(0), CFG)
    B, L = 2, 10
    x = jnp.asarray(RNG.normal(0, 0.5, (B, L, 32)).astype(np.float32))
    full = griffin.rglru_block_forward(p, x, CFG)
    Lp = 6
    _, cache = griffin.rglru_block_forward(
        p, x[:, :Lp], CFG, return_state=True
    )
    outs = []
    for t in range(Lp, L):
        o, cache = griffin.rglru_block_decode(p, x[:, t : t + 1], cache, CFG)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full[:, Lp:]), np.asarray(got), rtol=5e-4, atol=5e-4
    )


def test_gate_bounds():
    """a_t in (0,1); sqrt(1-a^2) real."""
    p, _ = griffin.rglru_block_init(jax.random.PRNGKey(1), CFG)
    x = jnp.asarray(RNG.normal(0, 2.0, (2, 5, 24)).astype(np.float32))
    log_a, bx = griffin._rglru_gates(p, x, CFG)
    a = np.exp(np.asarray(log_a))
    assert np.all((a > 0) & (a < 1))
    assert np.all(np.isfinite(np.asarray(bx)))
