"""Dry-run machinery unit tests (no 512-device compile here — the real
sweep is `python -m repro.launch.dryrun --all`, cached under
experiments/dryrun/)."""

import jax
import jax.numpy as jnp
import pytest

import repro.configs as configs
from repro.launch import shapes as shp


def _parse(hlo):
    # repro.launch.dryrun sets XLA_FLAGS at import time; lock the device
    # count to 1 first so the flag cannot affect this pytest process.
    jax.devices()
    from repro.launch import dryrun

    return dryrun.parse_collectives(hlo)


HLO = """
  %ag = bf16[16,4096]{1,0} all-gather(%x), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = f32[448,1024]{1,0} all-reduce(%y), replica_groups=[16,32]<=[512]T(1,0), to_apply=%add
  %cp = f32[8,128]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %rs = f32[28,1024]{1,0} reduce-scatter(%w), replica_groups=[32,16]<=[512], dimensions={0}
  %tup = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b), replica_groups=[64,8]<=[512]
"""


def test_parse_collectives_counts_and_traffic():
    res = _parse(HLO)
    ops = res["ops"]
    assert ops["all-gather"]["count"] == 1
    assert ops["all-reduce"]["count"] == 1
    assert ops["collective-permute"]["count"] == 1
    assert ops["reduce-scatter"]["count"] == 1
    assert ops["all-to-all"]["count"] == 1
    ag_bytes = 16 * 4096 * 2
    assert ops["all-gather"]["result_bytes"] == ag_bytes
    assert abs(ops["all-gather"]["traffic_bytes"] - ag_bytes * 15 / 16) < 1
    ar_bytes = 448 * 1024 * 4
    assert abs(ops["all-reduce"]["traffic_bytes"] - 2 * ar_bytes * 31 / 32) < 1
    rs_bytes = 28 * 1024 * 4
    assert ops["reduce-scatter"]["traffic_bytes"] == rs_bytes * 15
    a2a_bytes = 2 * 4 * 4 * 4
    assert abs(ops["all-to-all"]["traffic_bytes"] - a2a_bytes * 7 / 8) < 1


def test_extrapolation_linear():
    from repro.launch import dryrun

    c1 = {"flops": 10.0, "bytes": 100.0,
          "collectives": {"ops": {"all-reduce": {
              "count": 2, "result_bytes": 10.0, "traffic_bytes": 20.0}},
              "traffic_bytes": 20.0}}
    c2 = {"flops": 30.0, "bytes": 300.0,
          "collectives": {"ops": {"all-reduce": {
              "count": 6, "result_bytes": 30.0, "traffic_bytes": 60.0}},
              "traffic_bytes": 60.0}}
    ext = dryrun._extrapolate(c1, c2, 1, 3, 10)
    assert ext["flops"] == 10 + 10 * 9  # base 0 + 10/layer
    assert ext["collectives"]["traffic_bytes"] == 20 * 10


def test_long500k_skip_policy():
    skips = {a: shp.runnable(configs.get(a), "long_500k")[0]
             for a in configs.ARCH_IDS}
    assert skips["mamba2-130m"] is True  # SSM
    assert skips["recurrentgemma-2b"] is True  # hybrid
    assert skips["mixtral-8x7b"] is True  # SWA
    for full_attn in ("yi-34b", "stablelm-1.6b", "codeqwen1.5-7b",
                      "minicpm3-4b", "phi-3-vision-4.2b", "musicgen-medium",
                      "granite-moe-1b-a400m"):
        assert skips[full_attn] is False, full_attn


@pytest.mark.parametrize("shape", list(shp.SHAPES))
def test_input_specs_shapes(shape):
    cfg = configs.get("stablelm-1.6b")
    kind, inputs, axes = shp.batch_specs(cfg, shape)
    sp = shp.SHAPES[shape]
    if kind == "train":
        assert inputs["tokens"].shape == (sp.global_batch, sp.seq_len)
        assert inputs["tokens"].dtype == jnp.int32
    elif kind == "decode":
        assert inputs["token"].shape == (sp.global_batch, 1)
        assert inputs["pos"].shape == (sp.global_batch,)
    assert set(inputs) == set(axes)


def test_vlm_input_specs_include_image_embeds():
    cfg = configs.get("phi-3-vision-4.2b")
    _, inputs, _ = shp.batch_specs(cfg, "train_4k")
    assert "img_embeds" in inputs
    assert inputs["img_embeds"].shape[1] == 576
    # text + image positions == assigned seq_len
    assert inputs["tokens"].shape[1] + 576 == 4096


def test_audio_input_specs_have_codebooks():
    cfg = configs.get("musicgen-medium")
    _, inputs, _ = shp.batch_specs(cfg, "train_4k")
    assert inputs["tokens"].shape == (256, 4096, 4)


def test_abstract_cache_no_allocation():
    cfg = configs.get("mixtral-8x7b")
    cache = shp.abstract_cache(cfg, "long_500k")
    leaves = jax.tree_util.tree_leaves(cache)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    # SWA ring cache is bounded by the window, not 500k
    k = cache["main"]["b0"]["k"]
    assert k.shape[2] == cfg.window
