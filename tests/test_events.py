"""AER event subsystem: format round-trips, kernel contract, and
event-driven forward parity with the dense reference (incl. the paper's
collision config) + measured-op scaling with spike rate."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core import coding, energy, quant, snn
from repro.events import aer, runtime
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _rand_spikes(T, B, N, rate, signed=False):
    s = (RNG.random((T, B, N)) < rate).astype(np.float32)
    if signed:
        s *= RNG.choice([-1.0, 1.0], (T, B, N))
    return jnp.asarray(s)


# ------------------------------------------------------------------ format
@pytest.mark.parametrize("rate", [0.0, 0.1, 0.5, 1.0])
def test_dense_aer_roundtrip_identity(rate):
    T, B, N = 7, 3, 40
    spikes = _rand_spikes(T, B, N, rate)
    stream = aer.dense_to_aer(spikes, capacity=T * N)
    assert int(stream.count.sum()) == int(spikes.sum())
    back = aer.aer_to_dense(stream, T, N)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(spikes))


def test_roundtrip_signed_polarity():
    T, N = 9, 33
    spikes = _rand_spikes(T, 2, N, 0.3, signed=True)
    stream = aer.dense_to_aer(spikes, capacity=T * N)
    back = aer.aer_to_dense(stream, T, N)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(spikes))


def test_overflow_keeps_earliest_events():
    """At capacity the stream truncates the *latest* events: the decoded
    train is exactly the time-major prefix of the original."""
    T, N, cap = 6, 25, 17
    spikes = np.asarray(_rand_spikes(T, 1, N, 0.5))[:, 0]  # (T, N)
    stream = aer.dense_to_aer(jnp.asarray(spikes[:, None]), capacity=cap)
    assert int(stream.count[0]) == cap < spikes.sum()
    back = np.asarray(aer.aer_to_dense(stream, T, N))[:, 0]
    flat = spikes.reshape(-1).copy()
    keep = np.cumsum(flat != 0) <= cap  # first cap active entries
    expected = (flat * keep).reshape(T, N)
    np.testing.assert_array_equal(back, expected)


def test_padding_convention():
    T, N = 5, 10
    spikes = _rand_spikes(T, 1, N, 0.2)
    stream = aer.dense_to_aer(spikes, capacity=T * N)
    c = int(stream.count[0])
    assert np.all(np.asarray(stream.times[0, c:]) == T)
    assert np.all(np.asarray(stream.addrs[0, c:]) == 0)
    assert np.all(np.asarray(stream.polarity[0, c:]) == 0)
    # valid events time-sorted ascending
    assert np.all(np.diff(np.asarray(stream.times[0, :c])) >= 0)


def test_merge_streams():
    T, N = 8, 30
    a_dense = _rand_spikes(T, 2, N, 0.15)
    b_dense = _rand_spikes(T, 2, N, 0.15)
    # disjoint support so the merged dense train is just the sum
    b_dense = b_dense * (a_dense == 0)
    sa = aer.dense_to_aer(a_dense, capacity=T * N)
    sb = aer.dense_to_aer(b_dense, capacity=T * N)
    merged = aer.merge(sa, sb, num_addrs=N, capacity=2 * T * N)
    back = aer.aer_to_dense(merged, T, N)
    np.testing.assert_array_equal(
        np.asarray(back), np.asarray(a_dense + b_dense)
    )
    c = int(merged.count[0])
    assert np.all(np.diff(np.asarray(merged.times[0, :c])) >= 0)


def test_merge_with_capacity_headroom():
    """Output capacity beyond the combined inputs (headroom for further
    merges) must pad, not crash, and keep the padding convention."""
    T, N = 4, 8
    a_dense = _rand_spikes(T, 1, N, 0.9)  # nearly-full streams
    b_dense = _rand_spikes(T, 1, N, 0.9) * (a_dense == 0)
    sa = aer.dense_to_aer(a_dense, capacity=int(a_dense.sum()))
    sb = aer.dense_to_aer(b_dense, capacity=max(int(b_dense.sum()), 1))
    cap = 3 * T * N  # > Ea + Eb
    merged = aer.merge(sa, sb, num_addrs=N, capacity=cap, num_steps=T)
    assert merged.capacity == cap
    c = int(merged.count[0])
    assert np.all(np.asarray(merged.times[0, c:]) == T)
    assert np.all(np.asarray(merged.polarity[0, c:]) == 0)
    back = aer.aer_to_dense(merged, T, N)
    np.testing.assert_array_equal(
        np.asarray(back), np.asarray(a_dense + b_dense)
    )


def test_dvs_generator_wellformed():
    T, hw, cap = 12, 16, 1024
    stream, labels = aer.dvs_collision_batch(
        jax.random.PRNGKey(3), 4, image_hw=hw, num_steps=T, capacity=cap
    )
    assert stream.times.shape == (4, cap)
    assert set(np.asarray(labels).tolist()) <= {0, 1}
    counts = np.asarray(stream.count)
    assert np.all(counts > 0) and np.all(counts <= cap)
    for i in range(4):
        c = counts[i]
        t = np.asarray(stream.times[i])
        a = np.asarray(stream.addrs[i])
        assert np.all(np.diff(t[:c]) >= 0)
        assert np.all((a[:c] >= 0) & (a[:c] < hw * hw))
        assert np.all(t[c:] == T)


# ------------------------------------------------------------------ kernel
@pytest.mark.parametrize("rate", [0.0, 0.05, 0.25, 0.5, 0.75, 1.0])
@pytest.mark.parametrize("K,N", [(64, 32), (300, 70), (257, 129)])
def test_aer_kernel_matches_ref_and_dense(rate, K, N):
    """aer_spike_matmul == oracle == dense spike_matmul on the same row,
    across the whole spike-rate range (bit-exact integer contract)."""
    wq = jnp.asarray(RNG.integers(-(2**15), 2**15, (K, N)).astype(np.int16))
    row = (RNG.random(K) < rate).astype(np.int8)
    idx = np.nonzero(row)[0]
    E = K + 5  # capacity with padding tail
    addrs = np.zeros(E, np.int32)
    values = np.zeros(E, np.int32)
    addrs[: len(idx)] = idx
    values[: len(idx)] = 1
    out_k = ops.aer_spike_matmul(jnp.asarray(addrs), jnp.asarray(values), wq)
    out_r = ref.aer_spike_matmul_ref(
        jnp.asarray(addrs), jnp.asarray(values), wq
    )
    dense = ops.spike_matmul(jnp.asarray(row)[None, :], wq)[0]
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(dense))


def test_aer_kernel_polarity():
    K, N = 50, 20
    wq = jnp.asarray(RNG.integers(-(2**15), 2**15, (K, N)).astype(np.int16))
    addrs = jnp.asarray([3, 3, 10, 0], jnp.int32)
    values = jnp.asarray([1, -1, 1, 0], jnp.int32)  # cancel + pad
    out = ops.aer_spike_matmul(addrs, values, wq)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(wq[10].astype(np.int32))
    )


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(1, 80),
    n=st.integers(1, 40),
    e=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_aer_kernel_property(k, n, e, seed):
    rng = np.random.default_rng(seed)
    wq = jnp.asarray(rng.integers(-(2**15), 2**15, (k, n)).astype(np.int16))
    addrs = jnp.asarray(rng.integers(0, k, e).astype(np.int32))
    values = jnp.asarray(rng.integers(-1, 2, e).astype(np.int32))
    out_k = ops.aer_spike_matmul(addrs, values, wq)
    out_r = ref.aer_spike_matmul_ref(addrs, values, wq)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


# ---------------------------------------------------------- batched kernel
def test_aer_batched_matches_per_stream_oracle():
    """Batched kernel == per-stream oracle, including an empty stream
    (all padding) and a full-capacity stream (every slot a valid event)."""
    B, K, N, E = 5, 96, 40, 48
    wq = jnp.asarray(RNG.integers(-(2**15), 2**15, (K, N)).astype(np.int16))
    addrs = RNG.integers(0, K, (B, E)).astype(np.int32)
    values = RNG.integers(-1, 2, (B, E)).astype(np.int32)
    values[0] = 0  # empty stream: gate must skip every E block
    values[1] = 1  # full capacity: all E slots valid
    out = ops.aer_spike_matmul_batched(
        jnp.asarray(addrs), jnp.asarray(values), wq
    )
    assert out.dtype == jnp.int32
    for b in range(B):
        exp = ref.aer_spike_matmul_ref(
            jnp.asarray(addrs[b]), jnp.asarray(values[b]), wq
        )
        np.testing.assert_array_equal(
            np.asarray(out[b]), np.asarray(exp), err_msg=f"stream {b}"
        )
    assert not np.asarray(out[0]).any()


def test_aer_batched_vmap_parity_with_single_stream():
    """Batched launch == vmap semantics of the single-stream contract."""
    B, K, N, E = 3, 70, 30, 33  # non-aligned shapes exercise padding
    wq = jnp.asarray(RNG.integers(-(2**15), 2**15, (K, N)).astype(np.int16))
    addrs = jnp.asarray(RNG.integers(0, K, (B, E)).astype(np.int32))
    values = jnp.asarray(RNG.integers(-1, 2, (B, E)).astype(np.int32))
    out = ops.aer_spike_matmul_batched(addrs, values, wq)
    exp = jax.vmap(ref.aer_spike_matmul_ref, in_axes=(0, 0, None))(
        addrs, values, wq
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    singles = jnp.stack(
        [ops.aer_spike_matmul(addrs[b], values[b], wq) for b in range(B)]
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(singles))


def test_aer_batched_float_weights_matches_gather():
    """float32 weights: the surrogate-training forward path.  Values
    include magnitudes < 1 (e.g. dropout-scaled spikes) — the block gate
    must count them as events, not truncate them to zero."""
    B, K, N, E = 4, 64, 24, 40
    w = jnp.asarray(RNG.normal(size=(K, N)).astype(np.float32))
    addrs = jnp.asarray(RNG.integers(0, K, (B, E)).astype(np.int32))
    values = RNG.integers(-1, 2, (B, E)).astype(np.float32)
    values[1] *= 0.5  # sub-unit magnitudes must survive the event gate
    values = jnp.asarray(values)
    out = ops.aer_spike_matmul_batched(addrs, values, w)
    assert out.dtype == jnp.float32
    exp = jnp.einsum("be,ben->bn", values, jnp.take(w, addrs, axis=0))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5
    )


def test_dense_to_aer_capacity_headroom():
    """capacity > T*N (headroom for merges) pads canonically."""
    T, N = 4, 10
    spikes = _rand_spikes(T, 2, N, 0.3)
    cap = 3 * T * N
    stream = aer.dense_to_aer(spikes, capacity=cap)
    assert stream.capacity == cap
    back = aer.aer_to_dense(stream, T, N)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(spikes))
    c = int(stream.count[0])
    assert np.all(np.asarray(stream.times[0, c:]) == T)
    assert np.all(np.asarray(stream.polarity[0, c:]) == 0)


# ----------------------------------------------------------------- runtime
@pytest.mark.parametrize("rate", [0.0, 0.1, 0.5, 1.0])
def test_event_forward_matches_dense(rate):
    cfg = snn.SNNConfig(layer_sizes=(128, 32, 2), num_steps=12)
    params = snn.init_params(jax.random.PRNGKey(1), cfg)
    spikes = _rand_spikes(cfg.num_steps, 3, 128, rate)
    dm, ds = snn.forward(params, spikes, cfg, train=False)
    em, es, ev = runtime.event_forward(params, spikes, cfg)
    np.testing.assert_allclose(
        np.asarray(em), np.asarray(dm), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(es), np.asarray(ds))
    # measured layer-0 events == nnz of the input train, per batch row
    np.testing.assert_array_equal(
        np.asarray(ev[0]), np.asarray(spikes.sum(axis=(0, 2)))
    )


def test_event_forward_matches_dense_collision_config():
    """Acceptance: event-driven forward == core/snn.forward on the paper's
    4096-512-2 collision architecture under rate coding."""
    from repro.configs.collision_snn import CONFIG as cfg

    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (2, 4096)) * 0.4
    spikes = coding.rate_encode(jax.random.PRNGKey(2), imgs, cfg.num_steps)
    dm, ds = snn.forward(params, spikes, cfg, train=False)
    em, es, ev = runtime.event_forward(params, spikes, cfg)
    np.testing.assert_allclose(
        np.asarray(em), np.asarray(dm), atol=1e-4, rtol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(es), np.asarray(ds))


def test_event_forward_quantized_matches_dense():
    cfg = snn.SNNConfig(layer_sizes=(64, 16, 2), num_steps=8, quant_q115=True)
    params = snn.init_params(jax.random.PRNGKey(4), cfg)
    spikes = _rand_spikes(cfg.num_steps, 2, 64, 0.3)
    dm, _ = snn.forward(params, spikes, cfg, train=False)
    em, _, _ = runtime.event_forward(params, spikes, cfg)
    np.testing.assert_allclose(
        np.asarray(em), np.asarray(dm), atol=1e-5, rtol=1e-5
    )


def test_event_forward_aer_matches_event_forward():
    cfg = snn.SNNConfig(layer_sizes=(100, 24, 2), num_steps=10)
    params = snn.init_params(jax.random.PRNGKey(2), cfg)
    spikes = _rand_spikes(cfg.num_steps, 3, 100, 0.2)
    stream = aer.dense_to_aer(spikes, capacity=cfg.num_steps * 100)
    em, es, eev = runtime.event_forward(params, spikes, cfg)
    am, asp, aev = runtime.event_forward_aer(params, stream, cfg)
    np.testing.assert_allclose(
        np.asarray(am), np.asarray(em), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(asp), np.asarray(es))
    np.testing.assert_allclose(np.asarray(aev), np.asarray(eev))


def test_event_forward_aer_ignores_in_window_padding():
    """Regression: ``merge`` without ``num_steps`` stamps pad slots at
    max(times)+1; for streams encoded with a window shorter than the
    network's T those pads land *inside* [0, T).  The old layer-0 count
    (end - start) billed them as events, inflating measured events and
    energy — counts must cover valid (polarity != 0) events only."""
    N = 40
    cfg = snn.SNNConfig(layer_sizes=(N, 12, 2), num_steps=10)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    T_enc = 3  # events confined to the first 3 steps of the T=10 window
    a_dense = _rand_spikes(T_enc, 2, N, 0.3)
    b_dense = _rand_spikes(T_enc, 2, N, 0.3) * (a_dense == 0)
    sa = aer.dense_to_aer(a_dense, capacity=T_enc * N)
    sb = aer.dense_to_aer(b_dense, capacity=T_enc * N)
    merged = aer.merge(sa, sb, num_addrs=N, capacity=2 * T_enc * N)
    # the trap is armed: pad slots sit strictly inside the [0, T) window
    assert int(np.asarray(merged.times).max()) < cfg.num_steps
    _, _, ev = runtime.event_forward_aer(params, merged, cfg)
    # measured layer-0 events == the stream's valid-event total
    np.testing.assert_allclose(
        np.asarray(ev)[0], np.asarray(merged.count, np.float32)
    )
    # and full parity (outputs + all layer counts) with the dense path
    dense = aer.input_planes(merged, cfg.num_steps, N, polarity_mode="signed")
    em, es, eev = runtime.event_forward(params, dense, cfg)
    am, asp, aev = runtime.event_forward_aer(params, merged, cfg)
    np.testing.assert_allclose(
        np.asarray(am), np.asarray(em), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(asp), np.asarray(es))
    np.testing.assert_allclose(np.asarray(aev), np.asarray(eev))


def test_measured_ops_scale_with_rate():
    """Acceptance: the AER path's op count scales with spike rate — fewer
    accumulator adds than dense at rate < 1.0 (via core.energy.OpCount)."""
    cfg = snn.SNNConfig(layer_sizes=(256, 64, 2), num_steps=15)
    params = snn.init_params(jax.random.PRNGKey(3), cfg)
    dense_oc = energy.snn_inference_ops(
        cfg.layer_sizes, cfg.num_steps, [1.0, 1.0], event_driven=False
    )
    prev_adds = -1.0
    for rate in (0.05, 0.3, 0.9):
        spikes = _rand_spikes(cfg.num_steps, 1, 256, rate)
        _, _, ev = runtime.event_forward(params, spikes, cfg)
        oc = energy.snn_ops_from_events(
            cfg.layer_sizes, cfg.num_steps, np.asarray(ev)[:, 0]
        )
        adds = oc.ops["add_i32"]
        assert adds < dense_oc.ops["add_i32"]
        assert adds > prev_adds  # monotone in measured activity
        assert oc.energy_pj() < dense_oc.energy_pj()
        prev_adds = adds
