"""Trainer: loss decreases, grad accumulation equivalence, watchdog,
checkpoint/restart."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models.model import Model
from repro.optim import adam, chain_clip
from repro.train.loop import StragglerWatchdog, Trainer, make_train_step


def _tiny_model():
    cfg = configs.get("stablelm-1.6b").reduced(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128,
    )
    return Model(cfg)


def _batches(model, B=4, L=16):
    from repro.data.tokens import MarkovTokenStream, TokenStreamConfig

    stream = MarkovTokenStream(
        TokenStreamConfig(
            vocab_size=model.cfg.vocab_size, seq_len=L, batch_size=B
        )
    )
    for x, y in stream.batches():
        yield {"tokens": jnp.asarray(x), "targets": jnp.asarray(y)}


def test_loss_decreases_on_markov_stream(tmp_path):
    model = _tiny_model()
    trainer = Trainer(model, chain_clip(adam(3e-3), 1.0))
    state = trainer.init_state(jax.random.PRNGKey(0))
    logs = []
    state, metrics = trainer.run(
        state, _batches(model), num_steps=30, log_every=29,
        log_fn=lambda s: logs.append(s),
    )
    first = float(logs[0].split("loss=")[1].split(" ")[0])
    last = metrics["loss"]
    assert last < first


def test_grad_accumulation_matches_full_batch():
    """accum_steps=2 over a 2x batch == one step over the full batch.

    Compared through an SGD step (update linear in grads) — Adam's
    g/sqrt(v) normalization amplifies fp summation-order noise on
    near-zero grads into O(lr) deltas, which is not what this test is
    about."""
    from repro.optim import sgd

    model = _tiny_model()
    opt = sgd(lr=0.1, momentum=0.0)
    batch = next(_batches(model, B=8))

    s1 = make_train_step(model, opt, accum_steps=1)
    s2 = make_train_step(model, opt, accum_steps=2)
    from repro.train.loop import TrainState

    params, _ = model.init(jax.random.PRNGKey(0))
    st = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    out1, _ = s1(st, batch)
    st2 = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    out2, _ = s2(st2, batch)
    for a, b, p0 in zip(
        jax.tree_util.tree_leaves(out1.params),
        jax.tree_util.tree_leaves(out2.params),
        jax.tree_util.tree_leaves(params),
    ):
        # compare the applied updates (param deltas)
        np.testing.assert_allclose(
            np.asarray(a - p0, np.float32), np.asarray(b - p0, np.float32),
            rtol=1e-3, atol=1e-6,
        )


def test_checkpoint_restart_resumes(tmp_path):
    model = _tiny_model()
    trainer = Trainer(
        model, adam(1e-3), ckpt_dir=str(tmp_path), ckpt_every=5
    )
    state = trainer.restore_or_init(jax.random.PRNGKey(0))
    state, _ = trainer.run(state, _batches(model), num_steps=6, log_fn=lambda s: None)
    # simulate failure: new trainer, restore
    trainer2 = Trainer(
        model, adam(1e-3), ckpt_dir=str(tmp_path), ckpt_every=5
    )
    state2 = trainer2.restore_or_init(jax.random.PRNGKey(99))
    assert int(state2.step) == int(state.step)


def test_straggler_watchdog_flags_slow_step():
    wd = StragglerWatchdog(factor=3.0, warmup=3)
    for _ in range(5):
        assert wd.observe(0.1) is None
    msg = wd.observe(1.0)
    assert msg is not None and "straggler" in msg
