"""Tests for the paper's SNN model (4096-512-2 family, §4.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coding, snn


CFG = snn.SNNConfig(layer_sizes=(64, 32, 2), num_steps=8, dropout_rate=0.2)


def _batch(cfg=CFG, B=4, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((B, cfg.layer_sizes[0])).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 2, B).astype(np.int32))
    key = jax.random.PRNGKey(seed)
    spikes = coding.rate_encode(key, x, cfg.num_steps)
    return spikes, y


def test_forward_shapes_and_finite():
    params = snn.init_params(jax.random.PRNGKey(0), CFG)
    spikes, _ = _batch()
    mem, spk = snn.forward(params, spikes, CFG, train=False)
    assert mem.shape == (8, 4, 2)
    assert spk.shape == (8, 4, 2)
    assert np.all(np.isfinite(np.asarray(mem)))
    assert set(np.unique(np.asarray(spk))) <= {0.0, 1.0}


@pytest.mark.parametrize("kind", ["lif", "lapicque"])
def test_loss_decreases(kind):
    import dataclasses

    cfg = dataclasses.replace(CFG, neuron_kind=kind)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    spikes, y = _batch(cfg)
    from repro.optim import adam
    from repro.optim.adam import apply_updates

    opt = adam(5e-3)
    state = opt.init(params)
    losses = []
    for i in range(20):
        (l, _), g = jax.value_and_grad(snn.loss_fn, has_aux=True)(
            params, spikes, y, cfg, train=True,
            dropout_key=jax.random.PRNGKey(i),
        )
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
        losses.append(float(l))
    assert losses[-1] < losses[0]


def test_refractory_variant_reduces_output_rate():
    import dataclasses

    cfg5 = dataclasses.replace(CFG, refractory_steps=5, num_steps=20)
    cfg0 = dataclasses.replace(CFG, refractory_steps=0, num_steps=20)
    params = snn.init_params(jax.random.PRNGKey(0), cfg0)
    spikes, _ = _batch(cfg0)
    _, spk0 = snn.forward(params, spikes, cfg0, train=False)
    _, spk5 = snn.forward(params, spikes, cfg5, train=False)
    assert np.asarray(spk5).mean() <= np.asarray(spk0).mean() + 1e-9


def test_q115_mode_runs_and_stays_close():
    import dataclasses

    cfgq = dataclasses.replace(CFG, quant_q115=True)
    params = snn.init_params(jax.random.PRNGKey(0), CFG)
    spikes, y = _batch()
    l_f, _ = snn.loss_fn(params, spikes, y, CFG, train=False)
    l_q, _ = snn.loss_fn(params, spikes, y, cfgq, train=False)
    assert np.isfinite(float(l_q))
    assert abs(float(l_q) - float(l_f)) / abs(float(l_f)) < 0.2


def test_learnable_beta_stays_in_unit_interval():
    params = snn.init_params(jax.random.PRNGKey(0), CFG)
    for lp in params.values():
        b = np.asarray(snn.effective_beta(lp))
        assert np.all((b > 0) & (b < 1))


def test_paper_config_is_4096_512_2():
    from repro.configs.collision_snn import CONFIG

    assert tuple(CONFIG.layer_sizes) == (4096, 512, 2)
    assert CONFIG.num_steps == 25
    assert CONFIG.neuron_kind == "lif"


def test_hidden_spike_rates_bounded():
    params = snn.init_params(jax.random.PRNGKey(0), CFG)
    spikes, _ = _batch()
    rates = np.asarray(snn.hidden_spike_rates(params, spikes, CFG))
    assert rates.shape == (2,)
    assert np.all((rates >= 0) & (rates <= 1))
