"""Fused event-driven chunk kernel: parity with ``runtime.run_chunk`` in
interpret mode across the semantic matrix (empty/full event streams,
frozen continuous-batching slots, refractory, both reset modes, Q1.15),
plus the O(K) ``step_events`` rewrite and the capacity autotuner."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import neuron, snn
from repro.events import capacity as cap_mod
from repro.events import runtime

RNG = np.random.default_rng(11)


def _spikes(Tc, B, K, rate, signed=False):
    s = (RNG.random((Tc, B, K)) < rate).astype(np.float32)
    if signed:
        s *= RNG.choice([-1.0, 1.0], (Tc, B, K))
    return jnp.asarray(s)


def _states(cfg, B, *, nonzero=False, refrac=False):
    states = runtime.init_states(cfg, B)
    if nonzero:
        out = []
        for i, st in enumerate(states):
            u = jnp.asarray(
                RNG.normal(0, 0.3, st.u.shape).astype(np.float32)
            )
            r = (
                jnp.asarray(
                    RNG.integers(0, 3, st.refrac.shape).astype(np.int32)
                )
                if refrac
                else st.refrac
            )
            out.append(neuron.NeuronState(u=u, refrac=r))
        return out
    return states


def _assert_chunk_parity(cfg, spikes, states, active=None, capacities=None):
    sj, mj, pj, ej = runtime.run_chunk(
        params_for(cfg), states, spikes, cfg,
        active=active, capacities=capacities, backend="jnp",
    )
    sf, mf, pf, ef = runtime.run_chunk(
        params_for(cfg), states, spikes, cfg,
        active=active, capacities=capacities, backend="fused",
    )
    np.testing.assert_allclose(
        np.asarray(mf), np.asarray(mj), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(pf), np.asarray(pj))
    np.testing.assert_allclose(np.asarray(ef), np.asarray(ej))
    for a, b in zip(sf, sj):
        np.testing.assert_allclose(
            np.asarray(a.u), np.asarray(b.u), atol=1e-5, rtol=1e-5
        )
        np.testing.assert_array_equal(
            np.asarray(a.refrac), np.asarray(b.refrac)
        )


_PARAM_CACHE = {}


def params_for(cfg):
    key = (cfg.layer_sizes, cfg.quant_q115)
    if key not in _PARAM_CACHE:
        _PARAM_CACHE[key] = snn.init_params(jax.random.PRNGKey(5), cfg)
    return _PARAM_CACHE[key]


# ------------------------------------------------------------- parity matrix
@pytest.mark.parametrize("rate", [0.0, 0.3, 1.0])
def test_fused_parity_across_rates(rate):
    """Empty, sparse, and full event streams."""
    cfg = snn.SNNConfig(layer_sizes=(48, 16, 2), num_steps=6)
    _assert_chunk_parity(cfg, _spikes(6, 3, 48, rate), _states(cfg, 3))


@pytest.mark.parametrize("reset", ["zero", "subtract"])
def test_fused_parity_reset_modes(reset):
    cfg = snn.SNNConfig(layer_sizes=(40, 12, 2), num_steps=5, reset=reset)
    _assert_chunk_parity(
        cfg, _spikes(5, 2, 40, 0.4), _states(cfg, 2, nonzero=True)
    )


def test_fused_parity_refractory():
    """refractory > 0, including nonzero incoming countdowns."""
    cfg = snn.SNNConfig(layer_sizes=(40, 12, 2), num_steps=8,
                        refractory_steps=2)
    _assert_chunk_parity(
        cfg, _spikes(8, 2, 40, 0.6),
        _states(cfg, 2, nonzero=True, refrac=True),
    )


def test_fused_parity_q115():
    cfg = snn.SNNConfig(layer_sizes=(48, 16, 2), num_steps=6,
                        quant_q115=True)
    _assert_chunk_parity(cfg, _spikes(6, 2, 48, 0.3), _states(cfg, 2))


def test_fused_parity_lapicque():
    cfg = snn.SNNConfig(layer_sizes=(32, 10, 2), num_steps=5,
                        neuron_kind="lapicque")
    _assert_chunk_parity(cfg, _spikes(5, 2, 32, 0.3), _states(cfg, 2))


def test_fused_parity_three_layers():
    cfg = snn.SNNConfig(layer_sizes=(40, 20, 10, 2), num_steps=5)
    _assert_chunk_parity(cfg, _spikes(5, 2, 40, 0.3), _states(cfg, 2))


def test_fused_parity_frozen_slots():
    """Continuous batching: frozen slots hold state, emit nothing."""
    cfg = snn.SNNConfig(layer_sizes=(48, 16, 2), num_steps=6)
    states = _states(cfg, 4, nonzero=True, refrac=False)
    active = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    spikes = _spikes(6, 4, 48, 0.5)
    _assert_chunk_parity(cfg, spikes, states, active=active)
    # frozen slots explicitly: held state, zero spikes/events, pinned mem
    sf, mf, pf, ef = runtime.run_chunk(
        params_for(cfg), states, spikes, cfg, active=active,
        backend="fused",
    )
    for i, st in enumerate(sf):
        np.testing.assert_array_equal(
            np.asarray(st.u[1]), np.asarray(states[i].u[1])
        )
    assert not np.asarray(pf[:, 1]).any()
    assert not np.asarray(ef[:, :, 1]).any()
    np.testing.assert_array_equal(
        np.asarray(mf[:, 1]),
        np.broadcast_to(np.asarray(states[-1].u[1]), mf[:, 1].shape),
    )


def test_fused_rejects_truncating_hidden_capacity():
    """The fused kernel cannot truncate hidden layers (dense in-VMEM
    matvecs); a plan that would make fused and jnp diverge must be
    rejected loudly, not executed platform-dependently."""
    cfg = snn.SNNConfig(layer_sizes=(48, 16, 2), num_steps=4)
    spikes = _spikes(4, 2, 48, 0.5)
    with pytest.raises(ValueError, match="hidden"):
        runtime.run_chunk(
            params_for(cfg), _states(cfg, 2), spikes, cfg,
            capacities=(48, 8), backend="fused",
        )
    # default autotune plans are fused-safe: hidden caps pinned at fan-in
    plan = cap_mod.autotune(
        params_for(cfg), cfg, spikes, percentile=50.0, safety=1.0, align=8
    )
    assert plan.capacities[1] == cfg.layer_sizes[1]
    runtime.run_chunk(
        params_for(cfg), _states(cfg, 2), spikes, cfg,
        capacities=plan.capacities, backend="fused",
    )


def test_fused_parity_with_truncating_capacity():
    """capacities[0] below the event count: both paths drop the same
    (latest-address) events and report the same truncated counts."""
    cfg = snn.SNNConfig(layer_sizes=(48, 16, 2), num_steps=6)
    spikes = _spikes(6, 3, 48, 0.9)
    caps = (16, 16)
    _assert_chunk_parity(cfg, spikes, _states(cfg, 3), capacities=caps)
    _, _, _, ej = runtime.run_chunk(
        params_for(cfg), _states(cfg, 3), spikes, cfg,
        capacities=caps, backend="jnp",
    )
    assert np.asarray(ej)[:, 0].max() <= caps[0]


def test_fused_chunk_state_carry_matches_whole_window():
    """Two fused chunks == one fused window (VMEM state round-trips
    exactly through the u_fin/refrac_fin outputs)."""
    cfg = snn.SNNConfig(layer_sizes=(40, 12, 2), num_steps=10,
                        refractory_steps=2)
    params = params_for(cfg)
    spikes = _spikes(10, 2, 40, 0.4)
    s0 = _states(cfg, 2)
    _, m_all, p_all, _ = runtime.run_chunk(
        params, s0, spikes, cfg, backend="fused"
    )
    s_mid, m1, p1, _ = runtime.run_chunk(
        params, s0, spikes[:4], cfg, backend="fused"
    )
    _, m2, p2, _ = runtime.run_chunk(
        params, s_mid, spikes[4:], cfg, backend="fused"
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([m1, m2])), np.asarray(m_all),
        atol=1e-5, rtol=1e-5,
    )
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate([p1, p2])), np.asarray(p_all)
    )


# ------------------------------------------------------- O(K) step_events
@pytest.mark.parametrize("cap", [1, 7, 20, 33])
def test_step_events_matches_argsort_oracle(cap):
    x = jnp.asarray(
        RNG.normal(size=(4, 5, 33))
        * (RNG.random((4, 5, 33)) < 0.4)
    )
    a1, v1, c1 = runtime.step_events(x, cap)
    a2, v2, c2 = runtime.step_events_argsort(x, cap)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_step_events_truncation_keeps_first_capacity():
    x = jnp.asarray([0.0, 1.0, -2.0, 0.0, 3.0, 1.0])
    addrs, values, count = runtime.step_events(x, 2)
    assert int(count) == 2
    np.testing.assert_array_equal(np.asarray(addrs), [1, 2])
    np.testing.assert_array_equal(np.asarray(values), [1.0, -2.0])


def test_step_events_capacity_beyond_fanin_pads():
    x = jnp.asarray([[0.0, 2.0, 0.0, -1.0]])
    addrs, values, count = runtime.step_events(x, 6)
    assert addrs.shape == (1, 6) and int(count[0]) == 2
    np.testing.assert_array_equal(np.asarray(addrs[0]), [1, 3, 0, 0, 0, 0])
    np.testing.assert_array_equal(
        np.asarray(values[0]), [2.0, -1.0, 0, 0, 0, 0]
    )


def test_step_events_empty_plane():
    addrs, values, count = runtime.step_events(jnp.zeros((2, 8)), 4)
    assert not np.asarray(addrs).any()
    assert not np.asarray(values).any()
    assert not np.asarray(count).any()


# --------------------------------------------------------------- autotuner
def test_autotune_capacity_bounds_and_report():
    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=10)
    params = params_for(cfg)
    spikes = _spikes(10, 4, 64, 0.25)
    plan = cap_mod.autotune(
        params, cfg, spikes, percentile=100.0, safety=1.2, align=8
    )
    assert len(plan.capacities) == cfg.num_layers
    for cap, fan_in, mx in zip(plan.capacities, plan.fan_in, plan.max_count):
        assert 1 <= cap <= fan_in
        assert cap % 8 == 0 or cap == fan_in
        assert cap >= min(mx, fan_in)  # p100 + safety: lossless on sample
    assert all(f == 0.0 for f in plan.truncated_lists_frac)
    assert all(f == 0.0 for f in plan.dropped_events_frac)
    report = cap_mod.truncation_report(params, cfg, spikes, plan)
    assert report["pred_agreement"] == 1.0
    assert report["events_dropped_frac"] == 0.0
    assert report["out_mem_max_abs_diff"] < 1e-5


def test_autotune_lossless_plan_preserves_run_chunk_outputs():
    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=8)
    params = params_for(cfg)
    spikes = _spikes(8, 3, 64, 0.2)
    plan = cap_mod.autotune(
        params, cfg, spikes, percentile=100.0, safety=1.5, align=8
    )
    states = _states(cfg, 3)
    _, m_full, p_full, e_full = runtime.run_chunk(
        params, states, spikes, cfg
    )
    _, m_cap, p_cap, e_cap = runtime.run_chunk(
        params, states, spikes, cfg, capacities=plan.capacities
    )
    np.testing.assert_allclose(
        np.asarray(m_cap), np.asarray(m_full), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(p_cap), np.asarray(p_full))
    np.testing.assert_allclose(np.asarray(e_cap), np.asarray(e_full))


def test_aggressive_truncation_reported_honestly():
    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=10)
    params = params_for(cfg)
    spikes = _spikes(10, 4, 64, 0.8)  # busy stream
    plan = cap_mod.autotune(
        params, cfg, spikes, percentile=50.0, safety=1.0, align=8
    )
    assert plan.capacities[0] < plan.max_count[0]
    assert plan.dropped_events_frac[0] > 0.0
    report = cap_mod.truncation_report(params, cfg, spikes, plan)
    assert report["events_dropped_frac"] > 0.0
    assert report["events_truncated"] < report["events_full"]


# ------------------------------------------------------- prepared params
def test_prepare_params_matches_on_the_fly_quant():
    cfg = snn.SNNConfig(layer_sizes=(48, 16, 2), num_steps=6,
                        quant_q115=True)
    params = params_for(cfg)
    spikes = _spikes(6, 2, 48, 0.3)
    states = _states(cfg, 2)
    prepared = runtime.prepare_params(params, cfg)
    _, m_a, p_a, e_a = runtime.run_chunk(params, states, spikes, cfg)
    _, m_b, p_b, e_b = runtime.run_chunk(
        prepared, states, spikes, cfg, prepared=True
    )
    np.testing.assert_array_equal(np.asarray(m_a), np.asarray(m_b))
    np.testing.assert_array_equal(np.asarray(p_a), np.asarray(p_b))
    np.testing.assert_array_equal(np.asarray(e_a), np.asarray(e_b))


@pytest.mark.parametrize("quant", [False, True])
def test_event_eval_forward_matches_bptt_inference(quant):
    """The serving-path eval (event_eval_forward / EventTrainer.evaluate)
    must match the BPTT-graph inference it replaced — including QAT
    configs, where prepare_params must not double-apply."""
    from repro.sparse_train import event_layer

    cfg = snn.SNNConfig(
        layer_sizes=(64, 24, 2), num_steps=8, dropout_rate=0.0,
        quant_q115=quant,
    )
    params = params_for(cfg)
    spikes = _spikes(8, 3, 64, 0.3)
    bm, bs, bev, _ = event_layer.event_bptt_forward(
        params, spikes, cfg, train=False
    )
    em, es, eev = event_layer.event_eval_forward(params, spikes, cfg)
    np.testing.assert_allclose(
        np.asarray(em), np.asarray(bm), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(es), np.asarray(bs))
    np.testing.assert_allclose(np.asarray(eev), np.asarray(bev))
    # prepared params short-circuit: same outputs, no re-quantization
    prepared = runtime.prepare_params(params, cfg)
    pm, ps, pev = event_layer.event_eval_forward(
        prepared, spikes, cfg, prepared=True
    )
    np.testing.assert_array_equal(np.asarray(pm), np.asarray(em))


def test_trainer_evaluate_on_dvs_batch():
    """EventTrainer.evaluate end-to-end on a DVS batch: metrics well-
    formed and predictions consistent with the underlying eval path."""
    from repro.sparse_train import event_layer, trainer

    tcfg = trainer.EventTrainConfig(image_hw=8, num_steps=6, hidden=16)
    t = trainer.EventTrainer(tcfg)
    state = t.init_state(jax.random.PRNGKey(0))
    batch = next(trainer.dvs_batches(0, 4, tcfg))
    ev = t.evaluate(state.params, batch)
    assert 0.0 <= float(ev["accuracy"]) <= 1.0
    assert ev["events_per_layer"].shape == (t.snn_cfg.num_layers,)
    spikes = jnp.moveaxis(batch["spikes"], 0, 1)
    em, es, _ = event_layer.event_eval_forward(
        state.params, spikes, t.snn_cfg
    )
    np.testing.assert_array_equal(
        np.asarray(ev["predictions"]),
        np.asarray(snn.predict_from_traces(em, es)),
    )


def test_engine_backend_knob_jnp_vs_default():
    """The engine's backend/capacities knobs don't change results (auto
    == jnp on CPU; a lossless capacity plan is invisible)."""
    from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=12)
    params = params_for(cfg)
    rng = np.random.default_rng(3)
    trains = [
        (rng.random((12, 64)) < 0.3).astype(np.float32) for _ in range(3)
    ]
    ref = SNNStreamEngine(params, cfg, num_slots=2, chunk_steps=5).run(
        [StreamRequest(spikes=t) for t in trains]
    )
    capped = SNNStreamEngine(
        params, cfg, num_slots=2, chunk_steps=5,
        backend="jnp", capacities=(64, 24),
    ).run([StreamRequest(spikes=t) for t in trains])
    for a, b in zip(ref, capped):
        np.testing.assert_allclose(a.spike_counts, b.spike_counts)
        np.testing.assert_allclose(a.events_per_layer, b.events_per_layer)
        assert a.prediction == b.prediction
