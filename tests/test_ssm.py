"""Mamba2 SSD: chunked matmul form == sequential recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm
from repro.models.config import ModelConfig

RNG = np.random.default_rng(11)


def _sequential_ssd(xdt, dA, Bm, Cm):
    """Per-step recurrence oracle: h = exp(dA)*h + B*xdt; y = C.h"""
    B, L, H, P = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    h = np.zeros((B, H, P, N), np.float64)
    ys = np.zeros((B, L, H, P), np.float64)
    for t in range(L):
        for b in range(B):
            for hh in range(H):
                g = hh // rep
                a = np.exp(float(dA[b, t, hh]))
                h[b, hh] = a * h[b, hh] + np.outer(
                    np.asarray(xdt[b, t, hh], np.float64),
                    np.asarray(Bm[b, t, g], np.float64),
                )
                ys[b, t, hh] = h[b, hh] @ np.asarray(Cm[b, t, g], np.float64)
    return ys, h


@pytest.mark.parametrize("L,chunk", [(8, 4), (12, 5), (16, 16), (7, 32)])
def test_ssd_chunked_matches_sequential(L, chunk):
    B, H, P, G, N = 2, 4, 3, 2, 5
    xdt = jnp.asarray(RNG.normal(0, 1, (B, L, H, P)).astype(np.float32))
    dA = jnp.asarray(-np.abs(RNG.normal(0, 0.5, (B, L, H))).astype(np.float32))
    Bm = jnp.asarray(RNG.normal(0, 1, (B, L, G, N)).astype(np.float32))
    Cm = jnp.asarray(RNG.normal(0, 1, (B, L, G, N)).astype(np.float32))
    y, state = ssm.ssd_chunked(xdt, dA, Bm, Cm, chunk)
    y_ref, state_ref = _sequential_ssd(xdt, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(state), state_ref, rtol=1e-4, atol=1e-4
    )


def test_ssm_decode_continues_prefill():
    """ssm_forward(return_state) + ssm_decode == ssm_forward on full seq."""
    cfg = ModelConfig(
        family="ssm", num_layers=1, d_model=32, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=64, ssm_state=8, ssm_expand=2, ssm_headdim=16,
        ssm_chunk=4, dtype="float32",
    )
    p, _ = ssm.ssm_init(jax.random.PRNGKey(0), cfg)
    B, L = 2, 12
    x = jnp.asarray(RNG.normal(0, 0.5, (B, L, 32)).astype(np.float32))
    full = ssm.ssm_forward(p, x, cfg)

    Lp = 8
    from repro.models.transformer import _ssm_prefill_cache

    _, state = ssm.ssm_forward(p, x[:, :Lp], cfg, return_state=True)
    cache = _ssm_prefill_cache(p, x[:, :Lp], state, cfg)
    outs = []
    for t in range(Lp, L):
        o, cache = ssm.ssm_decode(p, x[:, t : t + 1], cache, cfg)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full[:, Lp:]), np.asarray(got), rtol=5e-4, atol=5e-4
    )


def test_ssd_initial_state_threading():
    """Splitting a sequence in two with state carry == one pass."""
    B, L, H, P, G, N = 1, 10, 2, 4, 1, 6
    xdt = jnp.asarray(RNG.normal(0, 1, (B, L, H, P)).astype(np.float32))
    dA = jnp.asarray(-np.abs(RNG.normal(0, 0.3, (B, L, H))).astype(np.float32))
    Bm = jnp.asarray(RNG.normal(0, 1, (B, L, G, N)).astype(np.float32))
    Cm = jnp.asarray(RNG.normal(0, 1, (B, L, G, N)).astype(np.float32))
    y_full, s_full = ssm.ssd_chunked(xdt, dA, Bm, Cm, 4)
    y1, s1 = ssm.ssd_chunked(
        xdt[:, :6], dA[:, :6], Bm[:, :6], Cm[:, :6], 4
    )
    y2, s2 = ssm.ssd_chunked(
        xdt[:, 6:], dA[:, 6:], Bm[:, 6:], Cm[:, 6:], 4, h0=s1
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(s2), np.asarray(s_full), rtol=1e-4, atol=1e-4
    )
