"""Event-driven surrogate-gradient training subsystem.

The correctness anchor: gradients through the event-driven path (gather
forward, event-set scatter backward) match dense ``core/snn`` BPTT
gradients to float tolerance at matched inputs — plus the energy-aware
loss, the polarity-aware input layer, the training-cost model, and the
EventTrainer on the train/loop substrate.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import energy, snn
from repro.events import aer
from repro.sparse_train import (
    EventTrainConfig,
    EventTrainer,
    dvs_batches,
    event_bptt_forward,
    event_linear,
    event_loss_fn,
)
from repro.sparse_train import loss as st_loss

RNG = np.random.default_rng(11)


def _rand_spikes(T, B, N, rate, signed=False):
    s = (RNG.random((T, B, N)) < rate).astype(np.float32)
    if signed:
        s *= RNG.choice([-1.0, 1.0], (T, B, N))
    return jnp.asarray(s)


def _tree_allclose(a, b, atol, rtol):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), atol=atol, rtol=rtol
        )


# ------------------------------------------------------------- event layer
def test_event_linear_forward_matches_dense():
    B, K, N = 3, 60, 20
    h = _rand_spikes(1, B, K, 0.3)[0]
    w = jnp.asarray(RNG.normal(size=(K, N)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(N,)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(event_linear(h, w, b)),
        np.asarray(h @ w + b),
        atol=1e-5, rtol=1e-5,
    )


def test_event_linear_grads_match_dense_layer():
    """w-grad (event-set scatter), b-grad and h-grad (dense support) all
    equal the dense layer's gradients."""
    B, K, N = 4, 50, 16
    h = _rand_spikes(1, B, K, 0.25, signed=True)[0]
    w = jnp.asarray(RNG.normal(size=(K, N)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(N,)).astype(np.float32))
    t = jnp.asarray(RNG.normal(size=(B, N)).astype(np.float32))

    def ev(h, w, b):
        return jnp.sum((event_linear(h, w, b) - t) ** 2)

    def dn(h, w, b):
        return jnp.sum((h @ w + b[None, :] - t) ** 2)

    ge = jax.grad(ev, argnums=(0, 1, 2))(h, w, b)
    gd = jax.grad(dn, argnums=(0, 1, 2))(h, w, b)
    _tree_allclose(ge, gd, 1e-4, 1e-4)
    # the weight cotangent is supported only on rows that spiked
    active_rows = np.asarray(jnp.any(h != 0, axis=0))
    wg = np.asarray(ge[1])
    assert not wg[~active_rows].any()


@pytest.mark.parametrize("use_kernel", [False, True])
def test_event_linear_kernel_backend_parity(use_kernel):
    B, K, N = 2, 40, 12
    h = _rand_spikes(1, B, K, 0.4)[0]
    w = jnp.asarray(RNG.normal(size=(K, N)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(N,)).astype(np.float32))
    out = event_linear(h, w, b, use_kernel=use_kernel)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(h @ w + b), atol=1e-5, rtol=1e-5
    )


# -------------------------------------------------------- gradient parity
@pytest.mark.parametrize("rate", [0.05, 0.3, 0.8])
def test_gradient_parity_event_vs_dense_bptt(rate):
    """Acceptance anchor: event-driven surrogate gradients == dense
    core/snn BPTT gradients (all params incl. beta/threshold)."""
    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=8,
                        dropout_rate=0.0)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    spikes = _rand_spikes(cfg.num_steps, 3, 64, rate)
    labels = jnp.asarray(RNG.integers(0, 2, 3))

    gd = jax.grad(
        lambda p: snn.loss_fn(p, spikes, labels, cfg, train=False)[0]
    )(params)
    ge = jax.grad(
        lambda p: event_loss_fn(
            p, spikes, labels, cfg, energy_lambda=0.0, train=False
        )[0]
    )(params)
    _tree_allclose(ge, gd, 2e-5, 2e-5)


def test_gradient_parity_quantized():
    """QAT mode: both paths fake-quant weights (STE) before the layer."""
    cfg = snn.SNNConfig(layer_sizes=(48, 16, 2), num_steps=6,
                        dropout_rate=0.0, quant_q115=True)
    params = snn.init_params(jax.random.PRNGKey(3), cfg)
    spikes = _rand_spikes(cfg.num_steps, 2, 48, 0.3)
    labels = jnp.asarray(RNG.integers(0, 2, 2))
    gd = jax.grad(
        lambda p: snn.loss_fn(p, spikes, labels, cfg, train=False)[0]
    )(params)
    ge = jax.grad(
        lambda p: event_loss_fn(
            p, spikes, labels, cfg, energy_lambda=0.0, train=False
        )[0]
    )(params)
    _tree_allclose(ge, gd, 2e-5, 2e-5)


def test_event_bptt_forward_matches_dense_and_counts_events():
    cfg = snn.SNNConfig(layer_sizes=(80, 20, 2), num_steps=10,
                        dropout_rate=0.0)
    params = snn.init_params(jax.random.PRNGKey(1), cfg)
    spikes = _rand_spikes(cfg.num_steps, 3, 80, 0.2)
    dm, ds = snn.forward(params, spikes, cfg, train=False)
    em, es, ev, act = event_bptt_forward(params, spikes, cfg, train=False)
    np.testing.assert_allclose(np.asarray(em), np.asarray(dm),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(es), np.asarray(ds))
    np.testing.assert_array_equal(
        np.asarray(ev[0]), np.asarray(spikes.sum(axis=(0, 2)))
    )
    # differentiable hidden activity == measured layer-1 input events
    np.testing.assert_allclose(
        float(act[0]), float(jnp.mean(ev[1])), rtol=1e-6
    )


# ------------------------------------------------------- energy-aware loss
def test_measured_energy_jnp_mirror_matches_opcount():
    sizes, T = (256, 64, 2), 15
    ev = np.array([731.0, 88.0])
    want = energy.snn_ops_from_events(sizes, T, ev).energy_pj()
    got = float(st_loss.measured_energy_pj(sizes, T, jnp.asarray(ev)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_energy_regularizer_penalizes_activity_differentiably():
    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=8,
                        dropout_rate=0.0)
    params = snn.init_params(jax.random.PRNGKey(2), cfg)
    spikes = _rand_spikes(cfg.num_steps, 2, 64, 0.4)
    labels = jnp.asarray(RNG.integers(0, 2, 2))
    l0, m0 = event_loss_fn(params, spikes, labels, cfg,
                           energy_lambda=0.0, train=False)
    l1, m1 = event_loss_fn(params, spikes, labels, cfg,
                           energy_lambda=0.5, train=False)
    assert float(l1) > float(l0)
    np.testing.assert_allclose(
        float(l1 - l0), 0.5 * float(m1["energy_reg_nj"]), rtol=1e-4
    )
    # the regularizer carries gradient (through the surrogate VJPs)
    g = jax.grad(
        lambda p: event_loss_fn(
            p, spikes, labels, cfg, energy_lambda=1.0, train=False
        )[1]["energy_reg_nj"]
    )(params)
    assert float(jnp.sum(jnp.abs(g["layer0"]["w"]))) > 0.0


def test_train_ops_scale_with_rate_dense_flat():
    """Acceptance: training op count decreases monotonically with input
    sparsity while the dense baseline stays flat."""
    cfg = snn.SNNConfig(layer_sizes=(128, 32, 2), num_steps=10,
                        dropout_rate=0.0)
    params = snn.init_params(jax.random.PRNGKey(5), cfg)
    labels = jnp.asarray(RNG.integers(0, 2, 2))
    dense = energy.snn_train_ops_from_events(
        cfg.layer_sizes, cfg.num_steps, [], dense=True
    )
    prev = -1.0
    for rate in (0.05, 0.3, 0.9):
        spikes = _rand_spikes(cfg.num_steps, 2, 128, rate)
        _, metrics = event_loss_fn(params, spikes, labels, cfg,
                                   train=False)
        ev = [float(metrics["events_l0"]), float(metrics["events_l1"])]
        oc = energy.snn_train_ops_from_events(cfg.layer_sizes,
                                              cfg.num_steps, ev)
        assert oc.total_ops() < dense.total_ops()
        assert oc.total_ops() > prev  # monotone in measured activity
        prev = oc.total_ops()
        # dense baseline is activity-independent
        again = energy.snn_train_ops_from_events(
            cfg.layer_sizes, cfg.num_steps, [0.0, 0.0], dense=True
        )
        assert again.total_ops() == dense.total_ops()


# --------------------------------------------------------- polarity input
def test_polarity_two_channel_planes():
    T, hw = 8, 8
    stream, _ = aer.dvs_collision_stream(
        jax.random.PRNGKey(0), image_hw=hw, num_steps=T, capacity=512
    )
    stream = aer.EventStream(*(x[None] for x in stream))  # add batch dim
    K = hw * hw
    planes = aer.input_planes(stream, T, K, polarity_mode="two_channel")
    assert planes.shape == (T, 1, 2 * K)
    signed = aer.input_planes(stream, T, K, polarity_mode="signed")
    on, off = planes[..., :K], planes[..., K:]
    np.testing.assert_array_equal(np.asarray(on - off), np.asarray(signed))
    # channels are disjoint: a pixel is ON or OFF at a step, never both
    assert not np.asarray((on > 0) & (off > 0)).any()
    on_only = aer.input_planes(stream, T, K, polarity_mode="on_only")
    np.testing.assert_array_equal(np.asarray(on_only), np.asarray(on))
    assert aer.input_size_for(K, "two_channel") == 2 * K
    assert aer.input_size_for(K, "signed") == K
    with pytest.raises(ValueError):
        aer.input_planes(stream, T, K, polarity_mode="nope")


def test_polarity_coincident_on_off_events_keep_both_channels():
    """ON+OFF at the same (step, pixel) — e.g. after merging recordings —
    must land in both channels, not cancel (signed mode nets to zero, as
    the shared wire physically would)."""
    T, K = 3, 5
    co = aer.EventStream(
        times=jnp.asarray([[1, 1, 2]], jnp.int32),
        addrs=jnp.asarray([[2, 2, 4]], jnp.int32),
        polarity=jnp.asarray([[1, -1, 1]], jnp.int8),
        count=jnp.asarray([3], jnp.int32),
    )
    planes = aer.input_planes(co, T, K, polarity_mode="two_channel")
    on, off = np.asarray(planes[..., :K]), np.asarray(planes[..., K:])
    assert on[1, 0, 2] == 1.0 and off[1, 0, 2] == 1.0
    assert on[2, 0, 4] == 1.0 and off[2, 0, 4] == 0.0
    signed = np.asarray(aer.input_planes(co, T, K, polarity_mode="signed"))
    assert signed[1, 0, 2] == 0.0 and signed[2, 0, 4] == 1.0


def test_signed_spikes_gradient_parity():
    """Signed (polarity) inputs flow through both paths identically."""
    cfg = snn.SNNConfig(layer_sizes=(40, 12, 2), num_steps=6,
                        dropout_rate=0.0)
    params = snn.init_params(jax.random.PRNGKey(4), cfg)
    spikes = _rand_spikes(cfg.num_steps, 2, 40, 0.3, signed=True)
    labels = jnp.asarray(RNG.integers(0, 2, 2))
    gd = jax.grad(
        lambda p: snn.loss_fn(p, spikes, labels, cfg, train=False)[0]
    )(params)
    ge = jax.grad(
        lambda p: event_loss_fn(
            p, spikes, labels, cfg, energy_lambda=0.0, train=False
        )[0]
    )(params)
    _tree_allclose(ge, gd, 2e-5, 2e-5)


# -------------------------------------------------------------- trainer
def test_event_trainer_smoke_and_checkpoint(tmp_path):
    tcfg = EventTrainConfig(image_hw=8, num_steps=6, hidden=16)
    assert tcfg.input_size == 2 * 64  # two_channel default
    t = EventTrainer(tcfg, energy_lambda=0.01,
                     ckpt_dir=str(tmp_path), ckpt_every=2)
    state = t.init_state(jax.random.PRNGKey(0))
    state, metrics = t.run(
        state, dvs_batches(0, 8, tcfg), 3, log_every=10, log_fn=lambda _: None
    )
    assert int(state.step) == 3
    assert np.isfinite(metrics["loss"])
    for k in ("events_l0", "events_l1", "energy_pj", "accuracy"):
        assert k in metrics
    # checkpoint/restart substrate is live
    t2 = EventTrainer(tcfg, ckpt_dir=str(tmp_path))
    restored = t2.restore_or_init(jax.random.PRNGKey(1))
    assert int(restored.step) == 3


def test_event_trainer_accum_matches_batch_shapes():
    tcfg = EventTrainConfig(image_hw=8, num_steps=5, hidden=12)
    t = EventTrainer(tcfg, accum_steps=2)
    state = t.init_state(jax.random.PRNGKey(0))
    state, metrics = t.run(
        state, dvs_batches(1, 8, tcfg), 2, log_every=10, log_fn=lambda _: None
    )
    assert int(state.step) == 2
    assert np.isfinite(metrics["loss"])


def test_event_trainer_learns_dvs_task():
    """A short run on the synthetic DVS collision task reduces the loss."""
    tcfg = EventTrainConfig(image_hw=12, num_steps=8, hidden=24)
    t = EventTrainer(tcfg, lr=1e-3)
    state = t.init_state(jax.random.PRNGKey(0))
    batches = dvs_batches(0, 32, tcfg)
    first = next(batches)
    l0 = float(t.model.loss(state.params, first)[0])
    state, _ = t.run(state, batches, 20, log_every=50,
                     log_fn=lambda _: None)
    l1 = float(t.model.loss(state.params, first)[0])
    assert l1 < l0
