"""repro-lint static analysis: per-rule lint fixtures, suppression
syntax, kernel VMEM/SMEM budget plans, recompile / donation / AER
runtime contracts, and the repo-wide zero-findings invariant."""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import (
    DEFAULT_SMEM_BUDGET,
    DEFAULT_VMEM_BUDGET,
    ContractViolation,
    RecompileDetector,
    RULES,
    aer_bounds_report,
    check_aer_bounds,
    check_kernel_budgets,
    donation_report,
    lint_paths,
    lint_source,
    runtime_donation_check,
    verify_donation,
)
from repro.analysis.kernel_budget import KERNEL_PLANNERS
from repro.events import aer, runtime


def codes(src, path="fixture.py"):
    return sorted(f.code for f in lint_source(src, path).findings)


# ------------------------------------------------------------------ lint rules
def test_rl000_parse_error():
    assert codes("def f(:\n") == ["RL000"]


def test_rl101_host_call_in_jit_body():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "import time\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    print(x)\n"
        "    time.sleep(0.1)\n"
        "    return np.sum(x)\n"
    )
    assert codes(src) == ["RL101", "RL101", "RL101"]


def test_rl101_pallas_kernel_body():
    src = (
        "import numpy as np\n"
        "from jax.experimental import pallas as pl\n"
        "def kernel(x_ref, o_ref):\n"
        "    o_ref[...] = np.tanh(x_ref[...])\n"
        "def run(x):\n"
        "    return pl.pallas_call(kernel, out_shape=x)(x)\n"
    )
    assert "RL101" in codes(src)


def test_rl101_host_call_outside_jit_ok():
    src = "import numpy as np\ndef f(x):\n    return np.sum(x)\n"
    assert codes(src) == []


def test_rl102_tracer_leak():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(x) + x.item()\n"
    )
    assert codes(src) == ["RL102", "RL102"]


def test_rl103_traced_branch():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    while x < 3:\n"
        "        x = x + 1\n"
        "    return x\n"
    )
    assert codes(src) == ["RL103", "RL103"]


def test_rl103_static_shape_branch_ok():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x.shape[0] > 1:\n"
        "        return x[0]\n"
        "    return x\n"
    )
    assert codes(src) == []


def test_rl103_static_argnames_exempt():
    src = (
        "import jax\n"
        "from functools import partial\n"
        '@partial(jax.jit, static_argnames=("n",))\n'
        "def f(x, n):\n"
        "    if n > 2:\n"
        "        return x\n"
        "    return x * n\n"
    )
    assert codes(src) == []


_DONATE_PRELUDE = (
    "import jax\n"
    "def step(state, x):\n"
    "    return state + x\n"
    "step_j = jax.jit(step, donate_argnums=(0,))\n"
)


def test_rl104_at_set_on_donated():
    src = _DONATE_PRELUDE + (
        "def run(state, x):\n"
        "    out = step_j(state, x)\n"
        "    return out, state.at[0].set(1.0)\n"
    )
    assert "RL104" in codes(src)


def test_rl105_donated_reuse():
    src = _DONATE_PRELUDE + (
        "def run(state, x):\n"
        "    y = step_j(state, x)\n"
        "    return state + y\n"
    )
    assert "RL105" in codes(src)


def test_rl105_device_get_after_donation():
    # the snapshot-path hazard: fetching donated device state on the
    # host *after* the donating dispatch reads freed storage
    src = _DONATE_PRELUDE + (
        "def snapshot(state, x):\n"
        "    out = step_j(state, x)\n"
        "    host = jax.device_get(state)\n"
        "    return out, host\n"
    )
    res = lint_source(src, "fixture.py")
    assert [f.code for f in res.findings] == ["RL105"]
    assert "device_get" in res.findings[0].message


def test_rl105_device_get_before_donation_ok():
    # the correct snapshot ordering: host fetch first, dispatch second
    src = _DONATE_PRELUDE + (
        "def snapshot(state, x):\n"
        "    host = jax.device_get(state)\n"
        "    out = step_j(state, x)\n"
        "    return out, host\n"
    )
    assert codes(src) == []


def test_rl105_loop_rebind_ok():
    # the engine/train-loop idiom: the loop rebinds the donated buffer
    # from the call's output each iteration, so reuse is fine
    src = _DONATE_PRELUDE + (
        "def run(state, xs):\n"
        "    for x in xs:\n"
        "        state = step_j(state, x)\n"
        "    return state\n"
    )
    assert codes(src) == []


def test_rl106_float64():
    src = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        '    return jnp.asarray(x, dtype="float64") + jnp.float64(0)\n'
    )
    assert codes(src) == ["RL106", "RL106"]


def test_rl106_host_numpy_f64_ok():
    src = "import numpy as np\ndef f(x):\n    return np.float64(x)\n"
    assert codes(src) == []


def test_rl107_unshaped_blockspec():
    src = (
        "from jax.experimental import pallas as pl\n"
        "def f():\n"
        "    return pl.BlockSpec()\n"
    )
    assert codes(src) == ["RL107"]


def test_rl107_shaped_or_memory_space_ok():
    src = (
        "from jax.experimental import pallas as pl\n"
        "from jax.experimental.pallas import tpu as pltpu\n"
        "def f():\n"
        "    a = pl.BlockSpec((8, 128), lambda i: (i, 0))\n"
        "    b = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.SMEM)\n"
        "    return a, b\n"
    )
    assert codes(src) == []


def test_rl201_unused_import():
    src = "import os\nimport sys\nprint(sys.argv)\n"
    assert codes(src) == ["RL201"]


def test_rl201_init_py_exempt():
    assert codes("import os\n", path="pkg/__init__.py") == []


def test_rl202_unreachable():
    src = "def f():\n    return 1\n    x = 2\n"
    assert codes(src) == ["RL202"]


# ------------------------------------------------------------------ suppression
def test_line_suppression_moves_to_suppressed():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    print(x)  # repro-lint: disable=RL101 -- debugging aid\n"
        "    return x\n"
    )
    res = lint_source(src, "fixture.py")
    assert [f.code for f in res.findings] == []
    assert [f.code for f in res.suppressed] == ["RL101"]


def test_file_level_suppression():
    src = (
        "# repro-lint: disable-file=RL201 -- fixture\n"
        "import os\n"
        "import sys\n"
    )
    res = lint_source(src, "fixture.py")
    assert [f.code for f in res.findings] == []
    assert sorted(f.code for f in res.suppressed) == ["RL201", "RL201"]


def test_unrelated_suppression_does_not_hide():
    src = "import os  # repro-lint: disable=RL106 -- wrong code\n"
    assert codes(src) == ["RL201"]


def test_rules_table_covers_emitted_codes():
    assert {"RL000", "RL101", "RL102", "RL103", "RL104", "RL105", "RL106",
            "RL107", "RL201", "RL202"} <= set(RULES)


# ------------------------------------------------------------------ kernel budgets
def test_kernel_budgets_all_kernels_fit():
    plans, findings = check_kernel_budgets()
    assert [f.render() for f in findings] == []
    assert {p.kernel for p in plans} == set(KERNEL_PLANNERS)
    for p in plans:
        assert p.errors == []
        assert 0 < p.vmem_bytes <= DEFAULT_VMEM_BUDGET
        assert p.smem_bytes <= DEFAULT_SMEM_BUDGET
        assert p.grid, p.kernel


def test_kernel_budget_overflow_flagged():
    plans, findings = check_kernel_budgets(vmem_budget=1024)
    assert findings and all(f.code == "RB301" for f in findings)
    assert len(findings) == len(plans)


def test_snn_chunk_plan_shape():
    (plan,), findings = check_kernel_budgets(kernels=["snn_chunk"])
    assert not findings
    roles = {b.role for b in plan.buffers}
    assert "scratch" in roles
    assert plan.num_scalar_prefetch >= 1
    assert plan.smem_bytes > 0


# ------------------------------------------------------------------ recompile detector
def test_recompile_detector_catches_shape_unstable_fn():
    @jax.jit
    def f(x):
        return x * 2.0

    with RecompileDetector() as det:
        det.track("f", f, allowed=1)  # cold start
        for n in (4, 8, 16):  # shape-unstable: one compile per shape
            f(jnp.zeros((n,), jnp.float32))
    assert det.cache_growth("f") == 3
    assert det.unexpected()
    with pytest.raises(ContractViolation):
        det.raise_on_unexpected()


def test_recompile_detector_clean_on_stable_shapes():
    @jax.jit
    def f(x):
        return x + 1.0

    x = jnp.zeros((8,), jnp.float32)
    f(x)  # warm outside the region
    with RecompileDetector() as det:
        det.track("f", f, allowed=0)
        for _ in range(5):
            f(x)
    rep = det.report()
    assert rep["tracked"]["f"]["unexpected"] == 0
    assert det.unexpected() == []


def test_recompile_detector_freezes_growth_at_exit():
    @jax.jit
    def f(x):
        return x - 1.0

    f(jnp.zeros((4,), jnp.float32))
    with RecompileDetector() as det:
        det.track("f", f, allowed=0)
    f(jnp.zeros((16,), jnp.float32))  # after the region: must not count
    assert det.cache_growth("f") == 0
    assert det.unexpected() == []


# ------------------------------------------------------------------ donation
def _donating_fn():
    def body(state, x):
        return state + x

    return jax.jit(body, donate_argnums=(0,))


def test_donation_report_and_verify():
    fn = _donating_fn()
    args = (jnp.ones((8,), jnp.float32), jnp.ones((8,), jnp.float32))
    rep = verify_donation(fn, args, expect_donated=[0])
    assert rep["donated_argnums"] == [0]
    with pytest.raises(ContractViolation):
        verify_donation(fn, args, expect_donated=[0, 1])


def test_runtime_donation_check():
    fn = _donating_fn()
    state = jax.device_put(np.ones((8,), np.float32))
    x = jax.device_put(np.ones((8,), np.float32))
    out = runtime_donation_check(fn, (state, x), donated=[0])
    np.testing.assert_allclose(np.asarray(out), 2.0)
    assert state.is_deleted()

    nodonate = jax.jit(lambda s, x: s + x)
    s2 = jax.device_put(np.ones((8,), np.float32))
    with pytest.raises(ContractViolation):
        runtime_donation_check(nodonate, (s2, x), donated=[0])


def test_engine_chunk_donation_contract():
    # the contract the tick loop relies on: states + meta are donated,
    # weights (prepared) and the spike ring are not
    from repro.core import snn
    from repro.serving.snn_engine import SNNStreamEngine

    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=6)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    eng = SNNStreamEngine(params, cfg, num_slots=2, chunk_steps=3)
    trains = [np.zeros((6, 64), np.float32)] * 2
    rep = donation_report(eng._chunk, *eng.staged_chunk_args(trains))
    assert rep["donated_argnums"] == [1, 3]


# ------------------------------------------------------------------ AER bounds
def test_aer_bounds_collision_config_clean():
    from repro.configs.collision_snn import CONFIG

    assert check_aer_bounds(CONFIG.layer_sizes) == []
    rep = aer_bounds_report(CONFIG.layer_sizes, num_steps=CONFIG.num_steps)
    assert rep["ok"]
    assert [lay["addr_fits"] for lay in rep["layers"]] == [True] * 3


def test_aer_bounds_flags_overflow():
    # force an int16-indexed layer wider than int16 can address
    wide = int(np.iinfo(np.int16).max) + 2
    if np.dtype(aer.addr_dtype_for(wide)) != np.dtype(np.int16):
        pytest.skip("addr_dtype_for already promotes past int16")
    assert check_aer_bounds([wide])


def test_check_addr_dtype_guard():
    aer.check_addr_dtype(4096, jnp.int16)  # fits
    with pytest.raises(ValueError, match="int16"):
        aer.check_addr_dtype(70_000, jnp.int16)


def test_encode_step_table_rejects_narrow_dtype():
    spikes = jnp.zeros((2, 70_000), jnp.float32)
    with pytest.raises(ValueError, match="silently wrap"):
        runtime.encode_step_table(spikes, capacity=8, addr_dtype=jnp.int16)


# ------------------------------------------------------------------ repo-wide
def test_repo_tree_is_lint_clean():
    from repro.analysis.__main__ import REPO_ROOT

    res = lint_paths([REPO_ROOT / "src" / "repro"], rel_to=REPO_ROOT)
    assert [f.render() for f in res.findings] == []


def test_cli_exits_zero_and_writes_json(tmp_path):
    from repro.analysis.__main__ import main

    out = tmp_path / "report.json"
    rc = main(["--json", str(out), "--no-kernels", "--no-aer"])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["schema"] == "repro-analysis/v1"
    assert doc["counts"]["findings"] == 0
    assert doc["counts"]["new"] == 0


def test_cli_full_run_reports_kernels():
    from repro.analysis.__main__ import run

    doc = run()
    assert doc["counts"]["findings"] == 0
    assert {p["kernel"] for p in doc["kernels"]} == set(KERNEL_PLANNERS)
    assert all(p["errors"] == [] for p in doc["kernels"])
