"""Mesh-sharded slot axis of the SNN stream engine (subprocess: needs >1
device).  Parity with the unsharded engine over a 2-device CPU mesh, the
loud misconfiguration error for non-divisible slot counts, and elastic
snapshot restore: a snapshot taken on a 2-device slot-sharded engine
warm-restarts a 1-device (unsharded) survivor bit-exactly."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys
    sys.path.insert(0, "src")
    import jax, numpy as np
    from repro.core import snn
    from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=12)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    trains = [(rng.random((12, 64)) < 0.3).astype(np.float32)
              for _ in range(5)]
    reqs = lambda: [StreamRequest(spikes=t, deadline_s=1e4) for t in trains]
    mesh = jax.make_mesh((2,), ("data",))

    ref = SNNStreamEngine(params, cfg, num_slots=2, chunk_steps=5).run(reqs())
    shr = SNNStreamEngine(params, cfg, num_slots=2, chunk_steps=5,
                          mesh=mesh).run(reqs())
    for a, b in zip(ref, shr):
        np.testing.assert_allclose(a.spike_counts, b.spike_counts)
        np.testing.assert_allclose(a.events_per_layer, b.events_per_layer)
        assert a.prediction == b.prediction
        assert not b.deadline_missed

    # slot counts that don't divide over the mesh fail loudly, not silently
    try:
        SNNStreamEngine(params, cfg, num_slots=3, chunk_steps=5, mesh=mesh)
    except ValueError as e:
        assert "num_slots" in str(e)
    else:
        raise AssertionError("non-divisible num_slots did not raise")
    print("SHARDED_SNN_OK")
    """
)


@pytest.mark.slow
def test_sharded_slots_match_unsharded():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=600,
    )
    assert "SHARDED_SNN_OK" in r.stdout, r.stdout + r.stderr


ELASTIC_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys, tempfile
    sys.path.insert(0, "src")
    import jax, numpy as np
    from repro.core import snn
    from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=12)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    trains = [(rng.random((12, 64)) < 0.3).astype(np.float32)
              for _ in range(5)]
    reqs = lambda: [StreamRequest(spikes=t) for t in trains]
    mesh = jax.make_mesh((2,), ("data",))

    oracle = SNNStreamEngine(params, cfg, num_slots=2,
                             chunk_steps=5).run(reqs())

    # snapshot mid-flight on the 2-device slot-sharded engine ...
    shr = SNNStreamEngine(params, cfg, num_slots=2, chunk_steps=5,
                          mesh=mesh)
    for r in reqs():
        shr.submit(r)
    early = []
    for _ in range(2):
        early.extend(shr.poll())
    snap = os.path.join(tempfile.mkdtemp(), "snap")
    shr.snapshot(snap)

    # ... restore onto a survivor with no mesh (1-device layout)
    surv = SNNStreamEngine(params, cfg, num_slots=2, chunk_steps=5)
    surv.restore(snap)
    got = {r.request_id: r for r in early + surv.drain()}
    assert sorted(got) == [0, 1, 2, 3, 4], sorted(got)
    for ref in oracle:
        r = got[ref.request_id]
        np.testing.assert_array_equal(r.spike_counts, ref.spike_counts)
        np.testing.assert_array_equal(r.events_per_layer,
                                      ref.events_per_layer)
        assert r.prediction == ref.prediction
        assert r.energy_pj == ref.energy_pj
    print("ELASTIC_RESTORE_OK")
    """
)


@pytest.mark.slow
def test_snapshot_from_sharded_restores_onto_single_device():
    """Elastic restore: snapshots are host-resident numpy, so a slot
    snapshot taken on a 2-device mesh warm-restarts an unsharded
    single-device engine with bit-identical results."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=600,
    )
    assert "ELASTIC_RESTORE_OK" in r.stdout, r.stdout + r.stderr
