"""Mesh-sharded slot axis of the SNN stream engine (subprocess: needs >1
device).  Parity with the unsharded engine over a 2-device CPU mesh, plus
the loud misconfiguration error for non-divisible slot counts."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys
    sys.path.insert(0, "src")
    import jax, numpy as np
    from repro.core import snn
    from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=12)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    trains = [(rng.random((12, 64)) < 0.3).astype(np.float32)
              for _ in range(5)]
    reqs = lambda: [StreamRequest(spikes=t, deadline_s=1e4) for t in trains]
    mesh = jax.make_mesh((2,), ("data",))

    ref = SNNStreamEngine(params, cfg, num_slots=2, chunk_steps=5).run(reqs())
    shr = SNNStreamEngine(params, cfg, num_slots=2, chunk_steps=5,
                          mesh=mesh).run(reqs())
    for a, b in zip(ref, shr):
        np.testing.assert_allclose(a.spike_counts, b.spike_counts)
        np.testing.assert_allclose(a.events_per_layer, b.events_per_layer)
        assert a.prediction == b.prediction
        assert not b.deadline_missed

    # slot counts that don't divide over the mesh fail loudly, not silently
    try:
        SNNStreamEngine(params, cfg, num_slots=3, chunk_steps=5, mesh=mesh)
    except ValueError as e:
        assert "num_slots" in str(e)
    else:
        raise AssertionError("non-divisible num_slots did not raise")
    print("SHARDED_SNN_OK")
    """
)


@pytest.mark.slow
def test_sharded_slots_match_unsharded():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=600,
    )
    assert "SHARDED_SNN_OK" in r.stdout, r.stdout + r.stderr
