"""Serving engine: batched greedy decode is deterministic and consistent."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine


def _engine(B=4):
    cfg = configs.get("stablelm-1.6b").reduced(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128,
    )
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, batch_size=B, cache_len=64)


def test_generates_requested_lengths():
    eng = _engine()
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, 128, 8).astype(np.int32),
                max_new_tokens=n)
        for n in (4, 7, 3, 5)
    ]
    outs = eng.generate(reqs)
    assert [len(o) for o in outs] == [4, 7, 3, 5]
    for o in outs:
        assert np.all((o >= 0) & (o < 128))


def test_greedy_is_deterministic():
    eng = _engine()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 128, 8).astype(np.int32)
    r1 = eng.generate([Request(prompt=prompt, max_new_tokens=6)])
    r2 = eng.generate([Request(prompt=prompt, max_new_tokens=6)])
    np.testing.assert_array_equal(r1[0], r2[0])


def test_per_request_temperature():
    """A greedy (T=0) request must stay greedy even when batched with a
    hot-temperature request (regression: the engine used to apply
    reqs[0].temperature to every row)."""
    eng = _engine(B=2)
    rng = np.random.default_rng(3)
    p_greedy = rng.integers(0, 128, 8).astype(np.int32)
    p_hot = rng.integers(0, 128, 8).astype(np.int32)
    solo = eng.generate([Request(prompt=p_greedy, max_new_tokens=6)])[0]
    # greedy request in slot 1, hot request in slot 0 -> old code would
    # sample slot 1 at temperature 5.0
    mixed = eng.generate(
        [Request(prompt=p_hot, max_new_tokens=6, temperature=5.0),
         Request(prompt=p_greedy, max_new_tokens=6)]
    )[1]
    np.testing.assert_array_equal(solo, mixed)


def test_batch_slots_do_not_interfere():
    """Same-length prompts: a request's greedy output is identical whether
    served alone or alongside different requests."""
    eng = _engine(B=2)
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, 128, 8).astype(np.int32)
    p2 = rng.integers(0, 128, 8).astype(np.int32)
    solo = eng.generate([Request(prompt=p1, max_new_tokens=5)])[0]
    both = eng.generate(
        [Request(prompt=p1, max_new_tokens=5),
         Request(prompt=p2, max_new_tokens=5)]
    )[0]
    np.testing.assert_array_equal(solo, both)
