"""Fault-tolerance chaos suite for the SNN stream engine.

Covers the four pillars of ``repro.faults`` end to end: admission-plane
load shedding (backpressure + EDF feasibility), slot quarantine under
injected NaN membranes / corrupted rings / staging capacity overflow,
the chunk-dispatch retry supervisor with fused->jnp demotion, and the
deterministic fault-injection harness itself — including the
acceptance-scale chaos run (200 requests, >= 20 seeded faults, zero
crashes, exact quarantine set, bit-exact survivors) and a
hypothesis-optional never-crash property over random schedules on both
backends.
"""

import warnings

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from repro.core import coding, snn
from repro.faults import (
    AdmissionPolicy,
    ChunkDispatchError,
    Fault,
    FaultInjector,
    FaultSchedule,
    RetryPolicy,
    backpressure,
    feasibility,
)
from repro.serving.snn_engine import (
    EngineStallError,
    SNNStreamEngine,
    StreamRequest,
)

CFG = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=20)
TINY = snn.SNNConfig(layer_sizes=(16, 8, 2), num_steps=10)


def _params(cfg=CFG, seed=0):
    return snn.init_params(jax.random.PRNGKey(seed), cfg)


def _train(seed, cfg=CFG, rate=0.3, T=None):
    rng = np.random.default_rng(seed)
    T = T or cfg.num_steps
    return (rng.random((T, cfg.layer_sizes[0])) < rate).astype(np.float32)


# ------------------------------------------------- admission-plane units
def test_admission_policy_validation():
    with pytest.raises(ValueError):
        AdmissionPolicy(max_queue_depth=0)
    with pytest.raises(ValueError):
        AdmissionPolicy(rate_window_s=0.0)
    assert AdmissionPolicy().max_queue_depth is None


def test_backpressure_verdicts():
    pol = AdmissionPolicy(max_queue_depth=2)
    assert backpressure(
        pol, queue_depth=1, parked_depth=0, priority=0
    ) == ("admit", None)
    # full queue: priority 0 sheds, priority > 0 parks
    assert backpressure(
        pol, queue_depth=2, parked_depth=0, priority=0
    ) == ("shed", "queue_full")
    assert backpressure(
        pol, queue_depth=2, parked_depth=0, priority=1
    ) == ("park", "queue_full")
    # the parked list is bounded by the same depth
    assert backpressure(
        pol, queue_depth=2, parked_depth=2, priority=1
    ) == ("shed", "queue_full")
    # unbounded policy never sheds
    assert backpressure(
        AdmissionPolicy(), queue_depth=10**6, parked_depth=0, priority=0
    ) == ("admit", None)


def test_feasibility_verdicts():
    pol = AdmissionPolicy(shed_unmeetable=True)
    common = dict(steps=20, chunk_steps=5, now=100.0)
    # no deadline, or no measured evidence: admit
    assert feasibility(
        pol, deadline_abs=None, ticks_per_s=50.0, priority=0, **common
    ) == ("admit", None)
    assert feasibility(
        pol, deadline_abs=100.1, ticks_per_s=0.0, priority=0, **common
    ) == ("admit", None)
    # 4 ticks at 50/s = 0.08s: a 0.5s budget is meetable
    assert feasibility(
        pol, deadline_abs=100.5, ticks_per_s=50.0, priority=0, **common
    ) == ("admit", None)
    # 4 ticks at 2/s = 2s: a 0.5s budget is provably unmeetable
    assert feasibility(
        pol, deadline_abs=100.5, ticks_per_s=2.0, priority=0, **common
    ) == ("shed", "deadline_unmeetable")
    assert feasibility(
        pol, deadline_abs=100.5, ticks_per_s=2.0, priority=1, **common
    ) == ("park", "deadline_unmeetable")
    # shedder disabled: always admit
    assert feasibility(
        AdmissionPolicy(shed_unmeetable=False),
        deadline_abs=100.5, ticks_per_s=2.0, priority=0, **common
    ) == ("admit", None)


# -------------------------------------------- payload value validation
def test_nonfinite_payloads_rejected_at_submit():
    eng = SNNStreamEngine(_params(), CFG, num_slots=1, chunk_steps=5)
    img = np.full(CFG.layer_sizes[0], 0.5, np.float32)
    img[3] = np.nan
    with pytest.raises(ValueError, match="NaN/inf"):
        eng.submit(StreamRequest(image=img))
    img[3] = np.inf
    with pytest.raises(ValueError, match="NaN/inf"):
        eng.submit(StreamRequest(image=img))
    train = _train(0)
    train[2, 5] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        eng.submit(StreamRequest(spikes=train))
    assert eng.idle()


def test_nan_image_regression_silent_garbage():
    """Why image *values* must be validated: a NaN pixel does not crash
    or poison the membrane — ``rate_encode`` compares ``uniform < NaN``
    (always False), so the pixel silently encodes as an all-zero train
    and the engine would serve a confidently wrong answer."""
    key = jax.random.PRNGKey(0)
    img = np.full(CFG.layer_sizes[0], 0.9, np.float32)
    img[7] = np.nan
    train = np.asarray(coding.rate_encode(key, img, 16))
    assert np.all(np.isfinite(train))  # no NaN propagates...
    assert train[:, 7].sum() == 0  # ...the pixel is just silently dark
    assert train[:, 0].sum() > 0  # while its neighbors fire


# ------------------------------------------------------ slot quarantine
def test_nan_membrane_quarantines_only_faulted_slot():
    params = _params()
    trains = [_train(i) for i in range(2)]
    inj = FaultInjector(FaultSchedule(
        faults=(Fault(tick=1, kind="nan_membrane", slot=0),)
    ))
    eng = SNNStreamEngine(
        params, CFG, num_slots=2, chunk_steps=5, injector=inj
    )
    results = eng.run([StreamRequest(spikes=t) for t in trains])
    clean = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=5)
    oracle = clean.run([StreamRequest(spikes=t) for t in trains])

    assert len(inj.applied) == 1
    bad_rid = inj.applied[0]["rid"]
    by_rid = {r.request_id: r for r in results}
    assert by_rid[bad_rid].disposition == "quarantined"
    assert by_rid[bad_rid].fault == "nonfinite_state"
    # the other slot's request is untouched — bit-exact vs fault-free
    for r in results:
        if r.request_id == bad_rid:
            continue
        assert r.disposition == "ok"
        ref = [o for o in oracle if o.request_id == r.request_id][0]
        np.testing.assert_array_equal(r.spike_counts, ref.spike_counts)
        np.testing.assert_array_equal(
            r.events_per_layer, ref.events_per_layer
        )
    assert eng.metrics.get("engine.requests.quarantined").value == 1
    assert len(eng.fault_events) == 1
    assert eng.fault_events[0]["code"] == 1
    # quarantine is not a completion: miss accounting untouched
    assert eng.completed == 1
    assert eng.health()["diagnosis"]["verdict"] == "faulty"


def test_quarantined_slot_serves_later_requests_cleanly():
    """The freed slot must be safe to re-admit into: in-graph
    sanitization plus admit-time zeroing means a post-quarantine request
    bit-matches a fault-free engine."""
    params = _params()
    inj = FaultInjector(FaultSchedule(
        faults=(Fault(tick=1, kind="nan_membrane", slot=0),)
    ))
    eng = SNNStreamEngine(
        params, CFG, num_slots=1, chunk_steps=5, injector=inj
    )
    r0 = eng.run([StreamRequest(spikes=_train(0))])[0]
    assert r0.disposition == "quarantined"
    r1 = eng.run([StreamRequest(spikes=_train(1))])[0]
    clean = SNNStreamEngine(params, CFG, num_slots=1, chunk_steps=5)
    ref = clean.run([StreamRequest(spikes=_train(1))])[0]
    assert r1.disposition == "ok"
    np.testing.assert_array_equal(r1.spike_counts, ref.spike_counts)


def test_corrupt_ring_quarantines():
    inj = FaultInjector(FaultSchedule(
        faults=(Fault(tick=1, kind="corrupt_ring", slot=0),)
    ))
    eng = SNNStreamEngine(
        _params(), CFG, num_slots=1, chunk_steps=5, injector=inj
    )
    res = eng.run([StreamRequest(spikes=_train(0))])[0]
    assert res.disposition == "quarantined"
    assert res.fault == "ring_corrupt"


def test_capacity_overflow_quarantines():
    """A train denser than the staged layer-0 capacity would be silently
    truncated by the packed event table — it must quarantine at the
    first chunk instead of serving a wrong-by-construction result."""
    eng = SNNStreamEngine(
        _params(), CFG, num_slots=1, chunk_steps=5, capacities=(8, 24)
    )
    dense = np.ones((CFG.num_steps, CFG.layer_sizes[0]), np.float32)
    res = eng.run([StreamRequest(spikes=dense)])[0]
    assert res.disposition == "quarantined"
    assert res.fault == "capacity_overflow"
    # a fitting train on the same engine still serves
    sparse = np.zeros_like(dense)
    sparse[:, :4] = 1.0
    res2 = eng.run([StreamRequest(spikes=sparse)])[0]
    assert res2.disposition == "ok"


def test_events_per_sec_excludes_quarantined_work():
    inj = FaultInjector(FaultSchedule(
        faults=(Fault(tick=2, kind="nan_membrane", slot=0),)
    ))
    eng = SNNStreamEngine(
        _params(), CFG, num_slots=2, chunk_steps=5, injector=inj
    )
    eng.run([StreamRequest(spikes=_train(i, rate=0.5)) for i in range(2)])
    q_ev = eng.metrics.get("engine.episode.quarantined_events").value
    assert q_ev > 0  # the poisoned slot had folded work before detection
    # throughput counts only the served request's events
    assert eng.events_per_sec() * max(eng.wall_s, 1e-9) == pytest.approx(
        eng.total_events - q_ev, rel=1e-6
    )


# ------------------------------------------------- supervisor / failover
def test_transient_chunk_exception_is_retried():
    params = _params()
    inj = FaultInjector(FaultSchedule(
        faults=(Fault(tick=1, kind="chunk_exception", times=2),)
    ))
    eng = SNNStreamEngine(
        params, CFG, num_slots=1, chunk_steps=5, injector=inj,
        retry=RetryPolicy(max_retries=2, backoff_s=0.0),
    )
    res = eng.run([StreamRequest(spikes=_train(0))])[0]
    clean = SNNStreamEngine(params, CFG, num_slots=1, chunk_steps=5)
    ref = clean.run([StreamRequest(spikes=_train(0))])[0]
    assert res.disposition == "ok"
    np.testing.assert_array_equal(res.spike_counts, ref.spike_counts)
    assert eng.metrics.get("engine.faults.chunk_retries").value == 2
    assert eng.metrics.get("engine.requests.quarantined").value == 0


def test_persistent_fused_failure_demotes_to_jnp():
    params = _params()
    inj = FaultInjector(FaultSchedule(faults=(
        Fault(tick=0, kind="chunk_exception", times=10**6,
              only_backend="fused"),
    )))
    eng = SNNStreamEngine(
        params, CFG, num_slots=1, chunk_steps=5, backend="fused",
        injector=inj, retry=RetryPolicy(max_retries=1, backoff_s=0.0),
    )
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = eng.run([StreamRequest(spikes=_train(0))])[0]
    demotion_warns = [
        w for w in caught
        if issubclass(w.category, RuntimeWarning)
        and "demoting backend" in str(w.message)
    ]
    assert len(demotion_warns) == 1  # one loud warning, not one per tick
    assert eng.backend == "jnp"
    assert eng.metrics.get("engine.faults.backend_demoted").value == 1
    assert res.disposition == "ok"
    # post-demotion results match the jnp reference engine bit-exactly
    ref = SNNStreamEngine(params, CFG, num_slots=1, chunk_steps=5,
                          backend="jnp")
    ref_res = ref.run([StreamRequest(spikes=_train(0))])[0]
    np.testing.assert_array_equal(res.spike_counts, ref_res.spike_counts)
    assert eng.health()["diagnosis"]["verdict"] == "faulty"


def test_persistent_jnp_failure_raises_dispatch_error():
    """No fallback below the reference backend: the supervisor's failure
    is loud, not a silent wedge."""
    inj = FaultInjector(FaultSchedule(faults=(
        Fault(tick=0, kind="chunk_exception", times=10**6),
    )))
    eng = SNNStreamEngine(
        _params(), CFG, num_slots=1, chunk_steps=5, backend="jnp",
        injector=inj, retry=RetryPolicy(max_retries=1, backoff_s=0.0),
    )
    eng.submit(StreamRequest(spikes=_train(0)))
    with pytest.raises(ChunkDispatchError):
        eng.drain()


# ----------------------------------------------------- drain hardening
def test_drain_timeout_raises_with_stall_snapshot():
    inj = FaultInjector(FaultSchedule(
        faults=(Fault(tick=1, kind="stall", ticks=10**9),)
    ))
    eng = SNNStreamEngine(
        _params(), CFG, num_slots=2, chunk_steps=5, injector=inj
    )
    eng.submit(StreamRequest(spikes=_train(0)))
    with pytest.raises(EngineStallError) as ei:
        eng.drain(timeout_s=0.3)
    snap = ei.value.snapshot
    stuck = [d for d in snap["slots"] if d["rid"] is not None]
    assert len(stuck) == 1
    assert stuck[0]["done"] < stuck[0]["total"]
    assert {"tick", "queue_depth", "parked_depth", "inflight"} <= set(snap)


def test_drain_without_timeout_unchanged():
    eng = SNNStreamEngine(_params(), CFG, num_slots=2, chunk_steps=5)
    eng.submit(StreamRequest(spikes=_train(0)))
    assert len(eng.drain()) == 1  # no timeout arg: legacy behavior


# ------------------------------------------------ load shedding e2e
def test_backpressure_sheds_and_parks_end_to_end():
    pol = AdmissionPolicy(max_queue_depth=2)
    eng = SNNStreamEngine(
        _params(), CFG, num_slots=1, chunk_steps=5, admission=pol
    )
    # 6 arrivals before any poll: 2 queue, priority-0 overflow sheds,
    # the priority-1 arrival parks and is served best-effort
    rids = [
        eng.submit(StreamRequest(
            spikes=_train(i), priority=1 if i == 5 else 0
        ))
        for i in range(6)
    ]
    results = eng.drain()
    by_rid = {r.request_id: r for r in results}
    assert set(by_rid) == set(rids)  # every submission gets a result
    dispositions = [by_rid[r].disposition for r in rids]
    assert dispositions == ["ok", "ok", "shed", "shed", "shed", "ok"]
    assert by_rid[rids[5]].parked
    for r in rids[2:5]:
        assert by_rid[r].fault == "queue_full"
        assert by_rid[r].prediction == -1
    assert eng.shed_rate() == pytest.approx(0.5)
    assert eng.metrics.get("engine.requests.parked").value == 1
    # shedding under overload is the admission plane working, not a
    # fault: diagnosis must separate it from the quarantine path
    assert eng.health()["diagnosis"]["verdict"] in (
        "overloaded", "nominal"
    )


def test_feasibility_sheds_provably_unmeetable_deadline():
    eng = SNNStreamEngine(
        _params(), CFG, num_slots=1, chunk_steps=5,
        admission=AdmissionPolicy(),
    )
    # warm: establish a measured tick rate on the time series
    eng.run([StreamRequest(spikes=_train(0))])
    assert eng.measured_ticks_per_s() > 0
    # a zero budget is provably unmeetable at any measured rate
    r_hopeless = eng.submit(StreamRequest(spikes=_train(1),
                                          deadline_s=0.0))
    r_fine = eng.submit(StreamRequest(spikes=_train(2)))
    results = eng.drain()
    by_rid = {r.request_id: r for r in results}
    assert by_rid[r_hopeless].disposition == "shed"
    assert by_rid[r_hopeless].fault == "deadline_unmeetable"
    assert by_rid[r_fine].disposition == "ok"
    # shed request is NOT a completion and NOT a deadline miss
    assert eng.deadline_misses == 0


def test_shed_rate_slo_opt_in():
    """The opt-in ``shed_rate`` SLO rides next to the default pair and
    observes a nonzero error rate once the bounded queue sheds (it is
    deliberately NOT in default_slos — see repro.obs.slo)."""
    from repro.obs import default_slos, shed_rate_slo

    eng = SNNStreamEngine(
        _params(TINY), TINY, num_slots=1, chunk_steps=5,
        admission=AdmissionPolicy(max_queue_depth=1),
        slos=default_slos() + (shed_rate_slo(objective=0.99),),
    )
    for i in range(4):  # 1 queued + 3 shed before any poll
        eng.submit(StreamRequest(spikes=_train(i, cfg=TINY)))
    eng.drain()
    report = eng.health()
    entries = {s["name"]: s for s in report["slos"]}
    assert set(entries) == {"deadline_misses", "latency_p99", "shed_rate"}
    # exact value depends on the sampler's first-interval exclusion;
    # the invariant is that shedding is *observed* as error-budget burn
    err = entries["shed_rate"]["observed_error_rate"]
    assert err is not None and 0.0 < err <= 1.0
    assert eng.shed_rate() == pytest.approx(0.75)


def test_no_admission_policy_serves_hopeless_deadlines():
    """Without an admission policy the historical contract holds: an
    already-due request is still served and counted as a miss."""
    eng = SNNStreamEngine(_params(), CFG, num_slots=1, chunk_steps=5)
    eng.run([StreamRequest(spikes=_train(0))])  # warm (measured rate)
    res = eng.run([StreamRequest(spikes=_train(1), deadline_s=0.0)])[0]
    assert res.disposition == "ok"
    assert res.deadline_missed


# --------------------------------------------------- chaos invariants
def _chaos_run(cfg, params, schedule, n_req, *, backend="jnp",
               num_slots=2, chunk_steps=5, seed0=100):
    inj = FaultInjector(schedule) if schedule is not None else None
    eng = SNNStreamEngine(
        params, cfg, num_slots=num_slots, chunk_steps=chunk_steps,
        backend=backend, injector=inj,
        # budget above the worst-case pile-up of same-tick injected
        # exceptions, so generated (transient-only) schedules can never
        # exhaust the supervisor — persistence is tested explicitly
        retry=RetryPolicy(max_retries=8, backoff_s=0.0),
    )
    reqs = [
        StreamRequest(spikes=_train(seed0 + i, cfg=cfg))
        for i in range(n_req)
    ]
    for r in reqs:
        eng.submit(r)
    results = eng.drain(timeout_s=120.0)
    return eng, inj, results


@pytest.mark.parametrize("backend", ["jnp", "fused"])
def test_empty_schedule_bitmatches_oracle(backend):
    """An injector with an empty schedule is a no-op: results bit-match
    an engine with no injector at all, and every fault counter is 0."""
    params = _params(TINY)
    eng, _, results = _chaos_run(
        TINY, params, FaultSchedule(), 4, backend=backend
    )
    oracle_eng, _, oracle = _chaos_run(
        TINY, params, None, 4, backend=backend
    )
    assert [r.disposition for r in results] == ["ok"] * 4
    for r, o in zip(
        sorted(results, key=lambda r: r.request_id),
        sorted(oracle, key=lambda r: r.request_id),
    ):
        np.testing.assert_array_equal(r.spike_counts, o.spike_counts)
        np.testing.assert_array_equal(
            r.events_per_layer, o.events_per_layer
        )
    for name in ("engine.requests.shed", "engine.requests.quarantined",
                 "engine.faults.chunk_retries",
                 "engine.faults.backend_demoted",
                 "engine.faults.injected"):
        assert eng.metrics.get(name).value == 0, name


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_chaos_never_crashes_property(seed):
    """Under *any* seeded schedule, on both backends: the engine never
    crashes, every submitted request gets exactly one result, and the
    episode drains.  (Backends loop inside the body — the hypothesis
    compat shim's skipper hides the signature from parametrize.)"""
    schedule = FaultSchedule.generate(
        seed, 6, ticks=30, num_slots=2,
        kinds=("nan_membrane", "corrupt_ring", "chunk_exception",
               "stall"),
        num_layers=2,
    )
    params = _params(TINY)
    for backend in ("jnp", "fused"):
        eng, inj, results = _chaos_run(TINY, params, schedule, 8,
                                       backend=backend)
        assert len(results) == 8
        assert sorted(r.request_id for r in results) == list(range(8))
        for r in results:
            assert r.disposition in ("ok", "quarantined")
            if r.disposition == "quarantined":
                assert r.fault is not None
        assert eng.idle()


@pytest.mark.parametrize("backend", ["jnp", "fused"])
def test_chaos_seeded_examples(backend):
    """Explicit seeded schedules (hypothesis-free floor for minimal
    containers): same invariants as the property test."""
    for seed in (3, 11):
        schedule = FaultSchedule.generate(
            seed, 6, ticks=30, num_slots=2,
            kinds=("nan_membrane", "corrupt_ring", "chunk_exception",
                   "stall"),
            num_layers=2,
        )
        eng, inj, results = _chaos_run(
            TINY, _params(TINY), schedule, 8, backend=backend
        )
        assert sorted(r.request_id for r in results) == list(range(8))
        assert all(
            r.disposition in ("ok", "quarantined") for r in results
        )
        assert eng.idle()


def test_chaos_acceptance_200_requests_20_faults():
    """The ISSUE acceptance run: >= 20 seeded faults (NaN membrane,
    corrupted ring, transient chunk exceptions) across a 200-request
    run — zero crashes, quarantines exactly the faulted requests,
    non-faulted results bit-match the fault-free oracle."""
    params = _params()
    n_req, n_faults = 200, 24
    schedule = FaultSchedule.generate(
        7, n_faults, ticks=180, num_slots=4, num_layers=2,
        kinds=("nan_membrane", "corrupt_ring", "chunk_exception"),
    )
    assert len(schedule) >= 20
    eng, inj, results = _chaos_run(
        CFG, params, schedule, n_req, num_slots=4, chunk_steps=5
    )
    # zero crashes: drain returned with every request accounted for
    assert sorted(r.request_id for r in results) == list(range(n_req))
    assert eng.idle()

    faulted_rids = {
        rec["rid"] for rec in inj.applied
        if rec["kind"] in ("nan_membrane", "corrupt_ring")
    }
    assert len(faulted_rids) >= 10  # the schedule really did fire
    quarantined = {
        r.request_id for r in results if r.disposition == "quarantined"
    }
    # quarantines exactly the faulted requests — no more, no fewer
    assert quarantined == faulted_rids
    assert (
        eng.metrics.get("engine.requests.quarantined").value
        == len(quarantined)
    )

    # non-faulted results bit-match the fault-free oracle
    oracle_eng, _, oracle = _chaos_run(
        CFG, params, None, n_req, num_slots=4, chunk_steps=5
    )
    oracle_by_rid = {r.request_id: r for r in oracle}
    checked = 0
    for r in results:
        if r.request_id in faulted_rids:
            continue
        assert r.disposition == "ok"
        ref = oracle_by_rid[r.request_id]
        np.testing.assert_array_equal(r.spike_counts, ref.spike_counts)
        np.testing.assert_array_equal(
            r.events_per_layer, ref.events_per_layer
        )
        assert r.prediction == ref.prediction
        checked += 1
    assert checked == n_req - len(faulted_rids)

    # recovery is bounded: every quarantine lands within a few ticks of
    # its injection (pipeline depth + eager finishing drain)
    applied_by_rid = {
        rec["rid"]: rec["tick"] for rec in inj.applied
        if rec["kind"] in ("nan_membrane", "corrupt_ring")
    }
    for ev in eng.fault_events:
        lag = ev["tick"] - applied_by_rid[ev["rid"]]
        assert 1 <= lag <= 6, (ev, applied_by_rid[ev["rid"]])


def test_fault_checks_off_matches_checks_on_clean_traffic():
    """The in-graph detection must be a bit-exact no-op on clean
    traffic — the quarantine pillar's parity guarantee."""
    params = _params()
    trains = [_train(i) for i in range(4)]
    on = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=7,
                         fault_checks=True)
    off = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=7,
                          fault_checks=False)
    r_on = on.run([StreamRequest(spikes=t) for t in trains])
    r_off = off.run([StreamRequest(spikes=t) for t in trains])
    for a, b in zip(r_on, r_off):
        assert a.disposition == b.disposition == "ok"
        np.testing.assert_array_equal(a.spike_counts, b.spike_counts)
        np.testing.assert_array_equal(
            a.events_per_layer, b.events_per_layer
        )


def test_fault_checks_off_nan_poisons_silently():
    """The negative control for the quarantine pillar: with
    ``fault_checks=False`` an injected NaN membrane is *not* caught —
    the request is served as ``ok`` while its accumulated membrane sum
    (the prediction tiebreaker) is NaN.  A NaN membrane never crosses
    threshold (``NaN > thresh`` is False), so the corruption is
    *silent*: the neuron just goes dark and the stats rot.  This is the
    failure mode the in-graph checks exist to prevent."""
    inj = FaultInjector(FaultSchedule(
        # poison the *output* layer so the corruption reaches the
        # folded memsum stats directly
        faults=(Fault(tick=1, kind="nan_membrane", slot=0, layer=1),)
    ))
    eng = SNNStreamEngine(
        _params(), CFG, num_slots=1, chunk_steps=5,
        injector=inj, fault_checks=False,
    )
    res = eng.run([StreamRequest(spikes=_train(0))])[0]
    assert res.disposition == "ok"  # nothing noticed...
    assert eng.metrics.get("engine.requests.quarantined").value == 0
    # ...but the slot's folded membrane-sum accumulator is poisoned
    assert not np.all(np.isfinite(eng._slot_memsum[0]))
