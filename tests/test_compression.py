"""Gradient compression with error feedback: bias vanishes over steps and
training converges like the uncompressed optimizer."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import compression
from repro.optim import sgd
from repro.optim.adam import apply_updates


def test_int8_quant_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (128,)))
    codes, scale = compression.quantize_int8(x)
    back = compression.dequantize_int8(codes, scale)
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) / 2 + 1e-6


def test_error_feedback_carries_residual():
    g = {"w": jnp.asarray([1e-4, 2e-4, -1e-4])}  # tiny grads -> coarse grid
    e0 = {"w": jnp.zeros(3)}
    deq, err = compression.compress_tree(g, e0)
    # whatever was lost is carried
    np.testing.assert_allclose(
        np.asarray(deq["w"] + err["w"]), np.asarray(g["w"]), rtol=1e-6
    )


def test_compressed_sgd_converges_on_quadratic():
    """min ||x - t||^2: EF-compressed SGD reaches the optimum."""
    t = jnp.asarray(np.random.default_rng(1).normal(0, 1, (32,)))

    def loss(x):
        return jnp.sum((x - t) ** 2)

    opt_c = compression.compressed(sgd(0.05, momentum=0.0))
    x = jnp.zeros(32)
    state = opt_c.init(x)
    for _ in range(200):
        g = jax.grad(loss)(x)
        upd, state = opt_c.update(g, state)
        x = apply_updates(x, upd)
    assert float(loss(x)) < 1e-3


def test_compression_tracks_uncompressed_trajectory():
    t = jnp.asarray(np.random.default_rng(2).normal(0, 1, (16,)))

    def loss(x):
        return jnp.sum((x - t) ** 2)

    xs = {}
    for name, opt in [
        ("plain", sgd(0.1, momentum=0.0)),
        ("ef", compression.compressed(sgd(0.1, momentum=0.0))),
    ]:
        x = jnp.zeros(16)
        state = opt.init(x)
        for _ in range(50):
            g = jax.grad(loss)(x)
            upd, state = opt.update(g, state)
            x = apply_updates(x, upd)
        xs[name] = x
    np.testing.assert_allclose(
        np.asarray(xs["ef"]), np.asarray(xs["plain"]), atol=5e-2
    )
