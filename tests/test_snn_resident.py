"""Device-resident spike residency of the SNN stream engine.

Pins down the two invariants the resident tick loop rests on:

1. **Ring-buffer parity.** Chunks produced by ``dynamic_slice`` over the
   per-slot event rings (staged once at admission) bit-match the PR-4
   host-assembled path — dense (Tc, S, K) chunks rebuilt on the host and
   event-extracted per chunk — across staggered ``slot_done`` offsets
   (mixed window lengths), mid-flight admits, slot reuse over stale ring
   contents, ring growth, and both chunk backends.  ``step_events`` is
   per-step independent, so slicing a staged table at step ``d`` must
   equal extracting step ``d`` on the fly; these tests fail if that
   property (or the ring's masking of stale/out-of-window steps) breaks.

2. **Steady-state transfer discipline.** Under
   ``jax.transfer_guard("disallow")`` a steady-state ``_tick`` performs
   no implicit transfer at all — scheduling metadata lives on device —
   and exactly one explicit D2H transfer, the stats fetch.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import snn
from repro.events import aer, runtime
from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

CFG = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=20)


def _params(seed=0):
    return snn.init_params(jax.random.PRNGKey(seed), CFG)


def _train(rate, seed, T=None):
    rng = np.random.default_rng(seed)
    T = T or CFG.num_steps
    return (rng.random((T, CFG.layer_sizes[0])) < rate).astype(np.float32)


def _host_assembly_oracle(params, train, Tc, *, backend="jnp",
                          capacities=None):
    """PR-4's serving hot path, verbatim: per chunk, assemble a dense
    host-side plane from the train at the slot's done offset, upload it,
    and let ``run_chunk`` re-extract layer-0 events.  Returns the
    per-request accumulators exactly as the engine builds them (device
    f32 chunk reductions accumulated in host f64)."""
    cfg = CFG
    T = train.shape[0]
    states = runtime.init_states(cfg, 1)
    counts = np.zeros(cfg.layer_sizes[-1], np.float64)
    memsum = np.zeros(cfg.layer_sizes[-1], np.float64)
    events = np.zeros(cfg.num_layers, np.float64)
    done = 0
    while done < T:
        take = min(Tc, T - done)
        chunk = np.zeros((Tc, 1, cfg.layer_sizes[0]), np.float32)
        chunk[:take, 0] = train[done : done + take]
        states, out_mem, out_spikes, ev = runtime.run_chunk(
            params,
            states,
            jnp.asarray(chunk),
            cfg,
            capacities=capacities,
            backend=backend,
        )
        m = (np.arange(Tc) < take).astype(np.float32)
        counts += np.asarray(
            jnp.sum(out_spikes * m[:, None, None], axis=0)
        )[0]
        memsum += np.asarray(
            jnp.sum(out_mem * m[:, None, None], axis=0)
        )[0]
        events += np.asarray(jnp.sum(ev * m[:, None, None], axis=0))[:, 0]
        done += take
    pred = int(np.argmax(counts + 1e-6 * memsum))
    return counts, events, pred


@pytest.mark.parametrize("backend", ["jnp", "fused"])
def test_ring_slices_bitmatch_host_assembly_oracle(backend):
    """Staggered windows + mid-flight admits + slot reuse: every
    request's counts/events/prediction bit-match the host-assembly
    oracle.  Mixed T's stagger the slots' done offsets within one chunk
    dispatch; the T=9 request reuses a slot whose ring still holds a
    longer train's tail (stale steps must stay silenced); admits land
    while other slots are mid-window."""
    params = _params()
    Ts = [20, 9, 13, 20, 7, 16]
    trains = {i: _train(0.25, i, T) for i, T in enumerate(Ts)}
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=5,
                          backend=backend)
    eng.submit(StreamRequest(spikes=trains[0], num_steps=Ts[0]))
    eng.submit(StreamRequest(spikes=trains[1], num_steps=Ts[1]))
    results = eng.poll()  # both slots mid-window ...
    results += eng.poll()
    for i in (2, 3):  # ... when more work arrives
        eng.submit(StreamRequest(spikes=trains[i], num_steps=Ts[i]))
    results += eng.poll()
    for i in (4, 5):
        eng.submit(StreamRequest(spikes=trains[i], num_steps=Ts[i]))
    results += eng.drain()
    assert sorted(r.request_id for r in results) == list(range(len(Ts)))
    for r in results:
        counts, events, pred = _host_assembly_oracle(
            params, trains[r.request_id], eng.Tc, backend=backend
        )
        np.testing.assert_array_equal(r.spike_counts, counts)
        np.testing.assert_array_equal(r.events_per_layer, events)
        assert r.prediction == pred


@pytest.mark.parametrize("backend", ["jnp", "fused"])
def test_ring_parity_with_tuned_capacity(backend):
    """Same parity under a truncating layer-0 capacity: admission-time
    staging and per-chunk extraction must truncate identically."""
    params = _params()
    caps = (32, CFG.layer_sizes[1])  # tight enough to truncate at 25%
    trains = [_train(0.25, 10 + s) for s in range(3)]
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=7,
                          backend=backend, capacities=caps)
    results = eng.run([StreamRequest(spikes=t) for t in trains])
    for r in results:
        counts, events, pred = _host_assembly_oracle(
            params, trains[r.request_id], eng.Tc, backend=backend,
            capacities=caps,
        )
        np.testing.assert_array_equal(r.spike_counts, counts)
        np.testing.assert_array_equal(r.events_per_layer, events)
        assert r.prediction == pred


def test_ring_grows_for_longer_windows():
    """A request longer than the allocated ring triggers a one-time
    device-side reallocation; staged trains in other slots survive and
    results still bit-match the oracle."""
    params = _params()
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=5)
    short = _train(0.3, 0)  # T=20 (the initial ring size)
    long = _train(0.3, 1, T=33)
    eng.submit(StreamRequest(spikes=short))
    eng.poll()  # short staged + mid-window when the ring grows
    eng.submit(StreamRequest(spikes=long, num_steps=33))
    results = eng.drain()
    assert eng._ring_steps == 33
    by_id = {r.request_id: r for r in results}
    for rid, train in ((0, short), (1, long)):
        counts, events, pred = _host_assembly_oracle(
            params, train, eng.Tc
        )
        np.testing.assert_array_equal(by_id[rid].spike_counts, counts)
        np.testing.assert_array_equal(by_id[rid].events_per_layer, events)


def test_image_requests_encode_on_device_deterministically():
    """Rate-coded image requests never build a host-side train; two
    engines with the same seed must produce identical results (the
    device-side encode consumes the same PRNG stream)."""
    img = np.linspace(0, 1, CFG.layer_sizes[0]).astype(np.float32)
    a = SNNStreamEngine(_params(), CFG, num_slots=1, chunk_steps=5, seed=7)
    b = SNNStreamEngine(_params(), CFG, num_slots=1, chunk_steps=5, seed=7)
    ra = a.run([StreamRequest(image=img)])[0]
    rb = b.run([StreamRequest(image=img)])[0]
    np.testing.assert_array_equal(ra.spike_counts, rb.spike_counts)
    np.testing.assert_array_equal(ra.events_per_layer, rb.events_per_layer)
    assert 0.0 < ra.spike_rate < 1.0


def test_non_integer_spike_trains_rejected_at_submit():
    """The staging format is int8 event magnitudes; a float-valued train
    must fail loudly at submit, not quantize silently."""
    eng = SNNStreamEngine(_params(), CFG, num_slots=1)
    bad = _train(0.3, 0) * 0.5
    with pytest.raises(ValueError, match="integer-valued"):
        eng.submit(StreamRequest(spikes=bad))
    # signed unit polarities (DVS) are fine
    signed = _train(0.3, 1) - _train(0.3, 2)
    eng.submit(StreamRequest(spikes=signed))
    assert len(eng.drain()) == 1


def test_step_table_roundtrip():
    """encode_step_table <-> step_table_to_dense is lossless at full
    capacity, int16 addresses and all."""
    train = _train(0.4, 3)
    table = runtime.encode_step_table(
        jnp.asarray(train), CFG.layer_sizes[0]
    )
    assert table.addrs.dtype == jnp.int16
    assert table.values.dtype == jnp.int8
    dense = np.asarray(
        aer.step_table_to_dense(table, CFG.layer_sizes[0])
    )
    np.testing.assert_array_equal(dense, train)


# ------------------------------------------------ transfer discipline
def test_steady_tick_single_host_transfer(monkeypatch):
    """Steady-state ``_tick``: zero implicit transfers (everything the
    chunk consumes is device-resident) and exactly one explicit D2H —
    the retired chunk's stats fetch.  ``transfer_guard("disallow")``
    fails the test on any implicit H2D (e.g. a host-assembled chunk or
    host-side scheduling masks sneaking back in)."""
    eng = SNNStreamEngine(_params(), CFG, num_slots=2, chunk_steps=5,
                          backend="jnp")
    for s in range(2):
        eng.submit(StreamRequest(spikes=_train(0.3, s)))
    # admission + compile + first dispatch happen outside the guard
    # (admission legitimately uploads each train once, explicitly)
    eng.poll()

    fetches = {"n": 0}
    real_get = jax.device_get

    def counting_get(x):
        fetches["n"] += 1
        return real_get(x)

    monkeypatch.setattr(jax, "device_get", counting_get)
    with jax.transfer_guard("disallow"):
        eng.poll()  # steady state: dispatch chunk N+1, retire chunk N
    assert fetches["n"] == 1

    # and the admission path itself stays guard-clean: uploads are
    # explicit device_puts, never implicit conversions
    eng.submit(StreamRequest(spikes=_train(0.3, 9)))
    with jax.transfer_guard("disallow"):
        eng.drain()
