"""Cross-cutting hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st
from jax.sharding import Mesh

from repro.core import coding, energy, neuron
from repro.distributed import partitioning as pt


def _mesh(shape, axes):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)


@settings(max_examples=40, deadline=None)
@given(
    dims=st.lists(st.integers(1, 512), min_size=1, max_size=4),
    names=st.lists(
        st.sampled_from(
            ["batch", "embed", "heads", "mlp", "vocab", "seq", None]
        ),
        min_size=1, max_size=4,
    ),
    dshape=st.sampled_from([(2, 4), (4, 2), (8, 1)]),
)
def test_spec_always_valid(dims, names, dshape):
    """Invariants of spec_for on arbitrary shapes/axes:
    1. every assigned mesh axis divides its dim,
    2. no mesh axis is used twice,
    3. spec rank never exceeds array rank."""
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    mesh = _mesh(dshape, ("data", "model"))
    spec = pt.spec_for(dims, names, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = []
    for dim, part in zip(dims, tuple(spec) + (None,) * (n - len(spec))):
        parts = (
            () if part is None
            else (part,) if isinstance(part, str) else tuple(part)
        )
        total = int(np.prod([sizes[p] for p in parts])) if parts else 1
        assert dim % total == 0
        used.extend(parts)
    assert len(used) == len(set(used))
    assert len(spec) <= n


@settings(max_examples=20, deadline=None)
@given(
    T=st.integers(1, 40),
    beta=st.floats(0.01, 0.99),
    amp=st.floats(0.0, 3.0),
)
def test_membrane_bounded_by_geometric_sum(T, beta, amp):
    """|U| <= amp / (1 - beta) for constant input of magnitude amp
    (before reset, the LIF integrator's fixed-point bound)."""
    cfg = neuron.NeuronConfig(kind="lif")
    cur = jnp.full((T, 1), amp)
    _, state = neuron.run_neuron(
        cfg, cur, beta=jnp.asarray(beta), threshold=jnp.asarray(1e9)
    )
    bound = amp / (1.0 - beta) + 1e-4
    assert abs(float(state.u[0])) <= bound


@settings(max_examples=20, deadline=None)
@given(
    rates=st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3),
    scale=st.floats(0.1, 0.9),
)
def test_energy_monotone_in_spike_rates(rates, scale):
    """Event-driven energy is monotone: scaling all rates down never
    increases energy (the hardware's core economic property)."""
    hi = energy.snn_inference_ops((256, 64, 2), 10, rates)
    lo = energy.snn_inference_ops(
        (256, 64, 2), 10, [r * scale for r in rates]
    )
    assert lo.energy_pj() <= hi.energy_pj() + 1e-9


@settings(max_examples=15, deadline=None)
@given(
    p=st.floats(0.0, 1.0),
    T=st.integers(2, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_ttfs_never_more_spikes_than_rate_expectation(p, T, seed):
    """TTFS emits <= 1 spike; rate coding emits ~p*T — the §3.2 energy
    ordering holds pointwise."""
    x = jnp.asarray([p])
    ttfs = float(coding.ttfs_encode(x, T).sum())
    det = float(coding.rate_encode_deterministic(x, T).sum())
    assert ttfs <= 1.0
    assert ttfs <= det + 1e-9 or p * T < 1.0


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4096))
def test_accumulator_bits_monotone(fan_in):
    from repro.core import quant

    b = quant.accumulator_bits(fan_in)
    assert b >= 17
    assert quant.accumulator_bits(fan_in * 2) >= b
