"""Observability layer: histogram accuracy vs numpy (example + property
tests), NaN/inf quarantine, deterministic snapshot export, metrics
registry semantics, span lifecycle invariants on a live engine, Chrome
trace JSON round-trip, and the dispatch-attribution probe."""

import json
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import snn
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    TraceRecorder,
    dispatch_attribution,
    tick_instrumentation_cost_us,
)
from repro.obs.metrics import percentile_tolerance
from repro.serving.snn_engine import SNNStreamEngine, StreamRequest
from tests._hypothesis_compat import given, settings, st

CFG = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=20)


def _params(seed=0):
    return snn.init_params(jax.random.PRNGKey(seed), CFG)


def _train(rate, seed, T=None):
    rng = np.random.default_rng(seed)
    T = T or CFG.num_steps
    return (rng.random((T, CFG.layer_sizes[0])) < rate).astype(np.float32)


# ------------------------------------------------------------ histograms
@pytest.mark.parametrize(
    "dist",
    ["lognormal", "uniform", "exponential"],
)
@pytest.mark.parametrize("q", [50, 90, 99])
def test_histogram_percentiles_vs_numpy(dist, q):
    """p50/p90/p99 within one log-bucket ratio of numpy on known
    distributions spanning several decades."""
    rng = np.random.default_rng(7)
    if dist == "lognormal":
        xs = rng.lognormal(mean=-5.0, sigma=1.5, size=20_000)
    elif dist == "uniform":
        xs = rng.uniform(1e-4, 1e-1, size=20_000)
    else:
        xs = rng.exponential(scale=3e-3, size=20_000)
    h = Histogram("t", lo=1e-7, hi=1e3, buckets_per_decade=16)
    for x in xs:
        h.record(x)
    est = h.percentile(q)
    true = float(np.percentile(xs, q))
    tol = percentile_tolerance(16) * 1.01  # one bucket ratio + epsilon
    assert true / tol <= est <= true * tol, (dist, q, est, true)


def test_histogram_exact_moments_and_accounting():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=0.0, sigma=2.0, size=5000)
    xs[0] = 1e-9  # underflow
    xs[1] = 1e9  # overflow
    h = Histogram("t", lo=1e-6, hi=1e6, buckets_per_decade=8)
    for x in xs:
        h.record(x)
    snap = h.snapshot()
    assert snap["count"] == len(xs)
    assert snap["sum"] == pytest.approx(xs.sum())
    assert snap["min"] == pytest.approx(xs.min())
    assert snap["max"] == pytest.approx(xs.max())
    # every recorded value is accounted for, exactly
    bucket_total = sum(c for _, c in snap["buckets"])
    assert (
        snap["underflow"] + snap["overflow"] + bucket_total
        == snap["count"]
    )
    assert snap["underflow"] >= 1 and snap["overflow"] >= 1
    # percentiles are monotone and clamped to observed range
    p = [h.percentile(q) for q in (1, 25, 50, 75, 90, 99, 100)]
    assert all(a <= b + 1e-12 for a, b in zip(p, p[1:]))
    assert snap["min"] <= p[0] and p[-1] <= snap["max"]


def test_histogram_nan_inf_quarantined():
    """Non-finite values land in the separate ``invalid`` tally and
    never touch count/sum/min/max/buckets — one diverged-loss NaN must
    not poison the mean forever or bisect into bucket 0."""
    h = Histogram("t", lo=1e-3, hi=1e3)
    h.record(1.0)
    h.record(float("nan"))
    h.record(float("inf"))
    h.record(float("-inf"))
    h.record(2.0)
    snap = h.snapshot()
    assert snap["invalid"] == 3
    assert snap["count"] == 2
    assert snap["sum"] == pytest.approx(3.0)
    assert snap["mean"] == pytest.approx(1.5)
    assert math.isfinite(snap["min"]) and math.isfinite(snap["max"])
    assert snap["underflow"] == 0  # NaN did not bisect into bucket 0
    assert sum(c for _, c in snap["buckets"]) == 2
    assert 1.0 <= h.percentile(50) <= 2.0
    h.reset()
    assert h.invalid == 0 and h.snapshot()["invalid"] == 0


def test_write_json_is_deterministic(tmp_path):
    """Two registries holding identical data but built in different
    insertion orders must serialize byte-identically (CI sidecars diff
    across runs)."""
    def build(order):
        reg = MetricsRegistry()
        for name in order:
            if name == "z.h":
                h = reg.histogram("z.h", lo=1e-3, hi=1e3)
            elif name == "a.c":
                reg.counter("a.c")
            else:
                reg.gauge("m.g")
        reg.counter("a.c").inc(3)
        reg.gauge("m.g").set(7)
        h = reg.get("z.h")
        for v in (0.01, 0.5, 12.0, 700.0):
            h.record(v)
        return reg

    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    build(["z.h", "a.c", "m.g"]).write_json(p1)
    build(["m.g", "a.c", "z.h"]).write_json(p2)
    b1, b2 = p1.read_bytes(), p2.read_bytes()
    assert b1 == b2
    doc = json.loads(b1)
    # nested keys are sorted too
    assert list(doc) == sorted(doc)
    assert list(doc["z.h"]) == sorted(doc["z.h"])


@settings(max_examples=60, deadline=None)
@given(
    xs=st.lists(
        st.floats(min_value=1e-3, max_value=1e3,
                  allow_nan=False, allow_infinity=False),
        min_size=1, max_size=400,
    ),
    q=st.integers(min_value=1, max_value=100),
)
def test_histogram_percentile_property(xs, q):
    """Property: for in-range value streams the estimated percentile
    stays within the documented one-log-bucket relative-error bound of
    the *exact* (nearest-rank) percentile numpy computes over the same
    values."""
    bpd = 16
    h = Histogram("t", lo=1e-4, hi=1e4, buckets_per_decade=bpd)
    for x in xs:
        h.record(x)
    est = h.percentile(q)
    # exact nearest-rank percentile (the definition the histogram
    # documents): the ceil(q/100 * n)-th smallest value
    xs_sorted = np.sort(np.asarray(xs))
    target = max(1, int(math.ceil(q / 100.0 * len(xs))))
    true = float(xs_sorted[target - 1])
    tol = percentile_tolerance(bpd) * (1 + 1e-9)
    assert true / tol <= est <= true * tol, (q, est, true)


def test_histogram_percentile_reset_mid_stream():
    """Percentiles after a reset reflect only post-reset values."""
    h = Histogram("t", lo=1e-3, hi=1e3, buckets_per_decade=16)
    for _ in range(100):
        h.record(100.0)
    h.reset()
    for _ in range(50):
        h.record(0.1)
    tol = percentile_tolerance(16) * (1 + 1e-9)
    for q in (50, 90, 99):
        est = h.percentile(q)
        assert 0.1 / tol <= est <= 0.1 * tol, (q, est)


def test_histogram_all_underflow_and_all_overflow():
    """Degenerate streams: everything below lo -> percentiles collapse
    to the observed min; everything above hi -> observed max."""
    h = Histogram("t", lo=1.0, hi=10.0)
    for v in (1e-4, 1e-3, 1e-2):
        h.record(v)
    assert h.snapshot()["underflow"] == 3
    for q in (1, 50, 99):
        assert h.percentile(q) == pytest.approx(1e-4)  # exact min
    h2 = Histogram("t2", lo=1.0, hi=10.0)
    for v in (100.0, 200.0, 300.0):
        h2.record(v)
    assert h2.snapshot()["overflow"] == 3
    for q in (1, 50, 99):
        assert h2.percentile(q) == pytest.approx(300.0)  # exact max


def test_histogram_empty_and_reset():
    h = Histogram("t", lo=1e-3, hi=1e3)
    assert h.percentile(50) == 0.0
    snap = h.snapshot()
    assert snap["count"] == 0 and snap["p99"] == 0.0
    h.record(1.0)
    assert h.count == 1
    h.reset()
    assert h.count == 0 and h.sum == 0.0 and h.percentile(99) == 0.0


def test_counter_gauge_and_registry():
    reg = MetricsRegistry()
    c = reg.counter("a.b.c")
    c.inc()
    c.inc(2.5)
    assert reg.counter("a.b.c") is c and c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("a.b.g")
    g.set(7)
    assert g.value == 7.0
    with pytest.raises(TypeError):
        reg.gauge("a.b.c")  # kind mismatch is loud
    h = reg.histogram("x.h", lo=1e-3, hi=1e3)
    h.record(0.5)
    # prefix reset: only the a.b.* instruments zero
    reg.reset(prefix="a.b.")
    assert c.value == 0.0 and g.value == 0.0 and h.count == 1
    snap = reg.snapshot()
    assert set(snap) == {"a.b.c", "a.b.g", "x.h"}
    assert snap["a.b.c"]["type"] == "counter"
    assert snap["x.h"]["type"] == "histogram"
    json.dumps(snap)  # snapshot is JSON-able as-is


# ------------------------------------------------------------------ trace
def test_trace_ring_is_bounded_and_ordered():
    rec = TraceRecorder(capacity=8)
    for i in range(20):
        rec.span(f"s{i}", float(i), float(i) + 0.5, track="t")
    spans = rec.spans()
    assert len(spans) == 8  # oldest fell off the back
    assert [s.name for s in spans] == [f"s{i}" for i in range(12, 20)]
    with pytest.raises(ValueError):
        rec.span("bad", 2.0, 1.0)  # t1 < t0 rejected
    rec.enabled = False
    rec.span("off", 0.0, 1.0)
    assert len(rec) == 8


def test_chrome_trace_round_trip(tmp_path):
    rec = TraceRecorder()
    rec.span("work", 1.0, 1.5, track="tick", args={"n": 3})
    rec.span("chunk", 1.1, 1.4, track="slot0", cat="request")
    rec.instant("done", 1.6, track="slot0")
    path = tmp_path / "trace.json"
    rec.write(path)
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    # metadata names the process and each track-thread
    names = {
        e["args"]["name"] for e in evs if e["name"] == "thread_name"
    }
    assert names == {"tick", "slot0"}
    assert any(e["name"] == "process_name" for e in evs)
    spans = [e for e in evs if e.get("ph") == "X"]
    inst = [e for e in evs if e.get("ph") == "i"]
    assert len(spans) == 2 and len(inst) == 1
    by_name = {e["name"]: e for e in spans}
    # timestamps shift to a common zero, microsecond units
    assert by_name["work"]["ts"] == pytest.approx(0.0)
    assert by_name["work"]["dur"] == pytest.approx(0.5e6)
    assert by_name["chunk"]["ts"] == pytest.approx(0.1e6)
    assert by_name["work"]["args"] == {"n": 3}
    # one pid, distinct tids per track
    assert by_name["work"]["tid"] != by_name["chunk"]["tid"]


# ------------------------------------------- engine lifecycle invariants
def test_engine_span_lifecycle_invariants():
    """Every completed request leaves a full span lifecycle in the ring:
    queue -> stage -> >=1 chunk -> complete, with monotonic timestamps
    all ordered within the request."""
    params = _params()
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=6)
    n_req = 5
    rids = [
        eng.submit(StreamRequest(spikes=_train(0.3, s)))
        for s in range(n_req)
    ]
    eng.drain()
    spans = eng.trace.spans()
    assert all(
        s.t1 is None or s.t1 >= s.t0 for s in spans
    )  # monotonic within every span
    for rid in rids:
        mine = [
            s for s in spans if s.args and s.args.get("rid") == rid
        ]
        kinds = [s.name for s in mine]
        assert "submit" in kinds
        assert "queue" in kinds
        assert "stage" in kinds
        assert "complete" in kinds
        assert kinds.count("chunk") >= 1
        by = {s.name: s for s in mine}
        queue, stage = by["queue"], by["stage"]
        chunks = [s for s in mine if s.name == "chunk"]
        complete = by["complete"]
        # lifecycle ordering: submit == queue start <= queue end ==
        # stage start <= every chunk <= complete
        assert queue.t0 <= queue.t1 <= stage.t0 <= stage.t1
        for c in chunks:
            assert stage.t1 <= c.t1 <= complete.t0
        assert queue.t0 == by["submit"].t0
        # completion args carry the result-facing fields
        assert complete.args["latency_ms"] > 0
        assert complete.args["energy_pj"] > 0
    # tick-phase spans exist on their own track
    assert any(s.track == "tick" and s.name == "dispatch" for s in spans)
    assert any(s.track == "tick" and s.name == "host_prep" for s in spans)


def test_engine_metrics_snapshot_consistency():
    params = _params()
    eng = SNNStreamEngine(params, CFG, num_slots=2, chunk_steps=5)
    eng.run(
        [StreamRequest(spikes=_train(0.3, s), deadline_s=1e4)
         for s in range(4)]
        + [StreamRequest(spikes=_train(0.3, 9), deadline_s=0.0)]
    )
    snap = eng.metrics_snapshot()
    lat = snap["engine.request.latency_s"]
    assert lat["count"] == 5
    assert 0 < lat["p50"] <= lat["p90"] <= lat["p99"]
    assert snap["engine.request.queue_wait_s"]["count"] == 5
    assert snap["engine.request.energy_pj"]["count"] == 5
    assert snap["engine.requests.completed"]["value"] == 5
    assert snap["engine.requests.deadline_missed"]["value"] == 1
    assert snap["engine.episode.deadline_misses"]["value"] == 1
    # tick histograms agree with the derived breakdown
    tb = eng.tick_breakdown()
    disp = snap["engine.tick.dispatch_s"]
    assert tb["ticks"] == disp["count"] > 0
    assert tb["dispatch_us"] == pytest.approx(
        disp["sum"] / disp["count"] * 1e6
    )
    # per-request energy instrument sums to the results' total
    assert snap["engine.request.energy_pj"]["sum"] > 0


def test_wall_s_resets_per_episode():
    """Regression: wall_s was initialized in __init__ but never reset in
    _begin_episode, so a mid-episode events_per_sec() read could see the
    previous episode's denominator.  It now lives in the episode-scoped
    registry prefix and zeroes when a new episode opens."""
    eng = SNNStreamEngine(_params(), CFG, num_slots=1, chunk_steps=5)
    assert eng.wall_s == 0.0
    eng.run([StreamRequest(spikes=_train(0.4, 0))])
    first = eng.wall_s
    assert first > 0
    # next submit opens a fresh episode: the stale wall time is gone
    eng.submit(StreamRequest(spikes=_train(0.4, 1)))
    assert eng.wall_s == 0.0
    eng.poll()
    assert eng.wall_s == 0.0  # still open -> still no final wall time
    eng.drain()
    assert eng.wall_s > 0 and eng.wall_s is not first


# -------------------------------------------------------------- profiler
def test_dispatch_attribution_probe():
    f = jax.jit(lambda x: jnp.tanh(x @ x.T).sum())
    x = jnp.ones((256, 256))
    att = dispatch_attribution(f, x, warmup=1, iters=3)
    assert att["host_enqueue_us"] > 0
    assert att["device_wait_us"] >= 0
    assert att["total_us"] >= att["host_enqueue_us"]
    assert att["total_us"] == pytest.approx(
        att["host_enqueue_us"] + att["device_wait_us"]
    )
    assert 0.0 <= att["device_wait_frac"] <= 1.0
    assert "dominates" in att["verdict"]


def test_tick_instrumentation_cost_is_small():
    """The per-tick obs recording cost must be microseconds — far under
    the <2% tick budget stream_bench enforces against measured ticks."""
    us = tick_instrumentation_cost_us(num_slots=4, reps=500)
    assert 0 < us < 500  # generous CI-machine bound; typical is ~10us
