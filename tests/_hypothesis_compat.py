"""Graceful degradation when ``hypothesis`` is not installed.

Property-test modules import ``given``/``settings``/``st`` from here.  With
hypothesis available they are the real thing; without it each ``@given``
test collects normally but skips at run time, so the rest of the module
(parametrized example tests) still executes.  This keeps the tier-1 suite
green on minimal containers while CI (which installs requirements.txt)
runs the full property sweep.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal container: skip property tests only
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert stand-in for a hypothesis strategy object."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # Plain-signature wrapper: pytest must not try to inject the
            # strategy parameters as fixtures.
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
