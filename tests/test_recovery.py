"""Crash-safe state: engine snapshot/warm-restart parity, deadline-aware
slot preemption, integrity-verified fallback, SIGKILL chaos, and
full-state training resume.

The bit-exactness contract under test: a warm-restarted engine (or a
checkpoint-resumed training run) must be indistinguishable from an
uninterrupted one — same spike counts, same events, same energy, same
params — not merely "close"."""

import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

import jax

from repro.core import snn
from repro.faults import Fault, FaultInjector, FaultSchedule, corrupt_checkpoint
from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

CFG = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=12)
REPO = os.path.join(os.path.dirname(__file__), "..")


def _params(seed=0):
    return snn.init_params(jax.random.PRNGKey(seed), CFG)


def _train(rate, seed):
    rng = np.random.default_rng(seed)
    return (rng.random((CFG.num_steps, CFG.layer_sizes[0])) < rate).astype(
        np.float32
    )


def _mk(params, backend="jnp", **kw):
    return SNNStreamEngine(
        params, CFG, num_slots=2, chunk_steps=5, seed=0, backend=backend,
        **kw,
    )


def _by_rid(results):
    return {r.request_id: r for r in results}


def _assert_result_equal(a, b):
    np.testing.assert_array_equal(a.spike_counts, b.spike_counts)
    np.testing.assert_array_equal(a.events_per_layer, b.events_per_layer)
    assert a.prediction == b.prediction
    assert a.energy_pj == b.energy_pj
    assert a.steps == b.steps


# ------------------------------------------------- snapshot / warm restart
@pytest.mark.parametrize("backend", ["jnp", "fused"])
def test_snapshot_warm_restart_is_bit_exact(tmp_path, backend):
    """Snapshot an engine with windows in flight, restore into a fresh
    engine, finish — results must be bit-identical to an uninterrupted
    run, for both chunk backends."""
    params = _params()
    trains = [_train(0.3, s) for s in range(6)]
    oracle = _by_rid(
        _mk(params, backend).run([StreamRequest(spikes=t) for t in trains])
    )

    eng1 = _mk(params, backend)
    for t in trains:
        eng1.submit(StreamRequest(spikes=t))
    early = []
    for _ in range(3):  # leave slots mid-window and requests queued
        early.extend(eng1.poll())
    assert not eng1.idle()
    path = eng1.snapshot(str(tmp_path / "snap"))

    eng2 = _mk(params, backend)
    eng2.restore(path)
    late = eng2.drain()
    got = _by_rid(early + late)
    assert sorted(got) == sorted(oracle)
    for rid in oracle:
        _assert_result_equal(got[rid], oracle[rid])


def test_snapshot_preserves_queue_order_and_deadlines(tmp_path):
    """Queued (not yet admitted) requests survive the snapshot with
    their priority/EDF order and deadline budgets intact."""
    params = _params()
    eng1 = _mk(params)
    eng1.submit(StreamRequest(spikes=_train(0.3, 0)))
    eng1.submit(StreamRequest(spikes=_train(0.3, 1)))
    eng1.poll()  # both admitted
    # queue: a low-priority early submit and a high-priority later one
    eng1.submit(StreamRequest(spikes=_train(0.3, 2), priority=0))
    eng1.submit(StreamRequest(spikes=_train(0.3, 3), priority=5,
                              deadline_s=30.0))
    path = eng1.snapshot(str(tmp_path / "snap"))

    eng2 = _mk(params)
    eng2.restore(path)
    assert eng2.queue_depth() == 2
    results = eng2.drain()
    got = _by_rid(results)
    # the high-priority request (rid 3) must be admitted before rid 2,
    # despite being submitted after it
    assert got[3].queue_wait_s < got[2].queue_wait_s
    assert got[3].deadline_s == pytest.approx(30.0, abs=1.0)
    assert not got[3].deadline_missed


def test_restore_geometry_mismatch_raises(tmp_path):
    params = _params()
    eng = _mk(params)
    eng.submit(StreamRequest(spikes=_train(0.3, 0)))
    eng.poll()
    path = eng.snapshot(str(tmp_path / "snap"))
    other = SNNStreamEngine(params, CFG, num_slots=3, chunk_steps=5)
    with pytest.raises(ValueError, match="geometry"):
        other.restore(path)


def test_restore_rejects_non_snapshot_dir(tmp_path):
    from repro.checkpoint import publish_array_dir

    p = publish_array_dir(
        str(tmp_path), "notasnap",
        {"a0": np.zeros(4, np.float32)}, {"kind": "something_else"},
    )
    with pytest.raises(ValueError, match="not an engine snapshot"):
        _mk(_params()).restore(p)


def test_snapshot_auto_rotation_and_corrupt_fallback(tmp_path):
    """The keep-N snapshot rotation falls back past a byte-corrupted
    newest snapshot — loudly, with the fallback counter bumped — and the
    restored engine still finishes every request correctly."""
    params = _params()
    trains = [_train(0.3, s) for s in range(4)]
    oracle = _by_rid(
        _mk(params).run([StreamRequest(spikes=t) for t in trains])
    )

    eng1 = _mk(params)
    for t in trains:
        eng1.submit(StreamRequest(spikes=t))
    eng1.poll()
    eng1.snapshot_auto(str(tmp_path))
    eng1.poll()
    eng1.snapshot_auto(str(tmp_path))
    snaps = sorted(d for d in os.listdir(tmp_path) if d.startswith("snap_"))
    assert snaps == ["snap_000001", "snap_000002"]

    corrupt_checkpoint(str(tmp_path))  # hits the newest in the rotation
    eng2 = _mk(params)
    with pytest.warns(UserWarning, match="falling back"):
        restored = eng2.restore_latest_snapshot(str(tmp_path))
    assert restored is not None and restored.endswith("snap_000001")
    snap = eng2.metrics.snapshot()
    assert snap["engine.faults.checkpoint_fallback"]["value"] == 1

    got = _by_rid(eng2.drain())
    assert sorted(got) == sorted(oracle)
    for rid in oracle:
        _assert_result_equal(got[rid], oracle[rid])


def test_snapshot_auto_keep_n_prunes(tmp_path):
    eng = _mk(_params())
    eng.submit(StreamRequest(spikes=_train(0.3, 0)))
    for _ in range(5):
        eng.poll()
        eng.snapshot_auto(str(tmp_path), keep_n=3)
    snaps = sorted(d for d in os.listdir(tmp_path) if d.startswith("snap_"))
    assert len(snaps) == 3
    assert snaps[-1] == "snap_000005"


def test_restore_latest_snapshot_empty_dir_is_none(tmp_path):
    eng = _mk(_params())
    assert eng.restore_latest_snapshot(str(tmp_path / "nothere")) is None


# ------------------------------------------------- deadline-aware preemption
def test_preemption_parks_loosest_and_stays_bit_exact():
    """A tighter-deadline arrival with no free slot parks the loosest
    resident window mid-window; both the urgent and the parked-then-
    resumed windows finish bit-identically to an unpreempted run."""
    params = _params()
    trains = [_train(0.3, s) for s in range(3)]
    oracle = _by_rid(
        _mk(params).run([StreamRequest(spikes=t) for t in trains])
    )

    eng = _mk(params, preempt=True)
    eng.submit(StreamRequest(spikes=trains[0]))
    eng.submit(StreamRequest(spikes=trains[1], deadline_s=1e4))
    eng.poll()  # both slots resident, mid-window
    eng.submit(StreamRequest(spikes=trains[2], priority=5, deadline_s=0.5))
    eng.poll()
    # rid 0 (no deadline, priority 0) is the loosest -> parked
    assert eng.preempt_parked_depth() == 1
    stall = eng.stall_snapshot()
    assert stall["preempt_parked_depth"] == 1
    assert stall["preempt_parked"][0]["rid"] == 0
    assert 0 < stall["preempt_parked"][0]["done"] < CFG.num_steps
    diag = eng.health()["diagnosis"]
    assert "preempt_thrash" in diag and "preempt_parked_depth" in diag

    got = _by_rid(eng.drain())
    snap = eng.metrics.snapshot()
    assert snap["engine.preempt.parked"]["value"] >= 1
    assert snap["engine.preempt.resumed"]["value"] >= 1
    assert snap["engine.preempt.park_s"]["count"] >= 1
    assert snap["engine.preempt.restore_s"]["count"] >= 1
    assert sorted(got) == sorted(oracle)
    for rid in oracle:
        _assert_result_equal(got[rid], oracle[rid])


def test_no_preemption_without_flag():
    """Default engines never park a resident window, whatever arrives."""
    params = _params()
    eng = _mk(params)  # preempt=False
    eng.submit(StreamRequest(spikes=_train(0.3, 0)))
    eng.submit(StreamRequest(spikes=_train(0.3, 1)))
    eng.poll()
    eng.submit(StreamRequest(spikes=_train(0.3, 2), priority=9,
                             deadline_s=0.01))
    eng.drain()
    assert eng.metrics.snapshot()["engine.preempt.parked"]["value"] == 0


def test_preemption_ties_do_not_thrash():
    """An arrival with the same urgency as every resident slot must not
    preempt (strictly-tighter rule): parking a window to admit an equal
    one would swap forever."""
    params = _params()
    eng = _mk(params, preempt=True)
    eng.submit(StreamRequest(spikes=_train(0.3, 0), priority=5))
    eng.submit(StreamRequest(spikes=_train(0.3, 1), priority=5))
    eng.poll()
    eng.submit(StreamRequest(spikes=_train(0.3, 2), priority=5))
    eng.drain()
    assert eng.metrics.snapshot()["engine.preempt.parked"]["value"] == 0


def test_preempted_state_survives_snapshot(tmp_path):
    """A snapshot taken while a window sits in the preemption parking
    buffer carries it across the restart."""
    params = _params()
    trains = [_train(0.3, s) for s in range(3)]
    oracle = _by_rid(
        _mk(params).run([StreamRequest(spikes=t) for t in trains])
    )
    eng1 = _mk(params, preempt=True)
    eng1.submit(StreamRequest(spikes=trains[0]))
    eng1.submit(StreamRequest(spikes=trains[1], deadline_s=1e4))
    eng1.poll()
    eng1.submit(StreamRequest(spikes=trains[2], priority=5, deadline_s=5.0))
    eng1.poll()
    assert eng1.preempt_parked_depth() == 1
    path = eng1.snapshot(str(tmp_path / "snap"))

    eng2 = _mk(params, preempt=True)
    eng2.restore(path)
    assert eng2.preempt_parked_depth() == 1
    got = _by_rid(eng2.drain())
    assert sorted(got) == sorted(oracle)
    for rid in oracle:
        _assert_result_equal(got[rid], oracle[rid])


# ------------------------------------------------------- SIGKILL chaos
_KILL_CKPT_SCRIPT = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(sys.argv[1], keep_n=3)
    step = 0
    while True:
        step += 1
        mgr.save(step, {
            "w": np.full((512, 64), float(step), np.float32),
            "step": np.asarray(step, np.int64),
        })
        print(step, flush=True)
""")


@pytest.mark.slow
def test_sigkill_mid_save_never_corrupts_restore_latest(tmp_path):
    """SIGKILL a process that checkpoints in a tight loop, at staggered
    moments; restore_latest in the survivor must always produce a
    self-consistent tree (every leaf from the same step) without a
    single integrity fallback — the atomic tmp-dir+rename contract."""
    from repro.checkpoint import CheckpointManager

    for trial, extra_delay in enumerate((0.0, 0.05, 0.15)):
        d = str(tmp_path / f"trial{trial}")
        proc = subprocess.Popen(
            [sys.executable, "-c", _KILL_CKPT_SCRIPT, d],
            cwd=REPO, stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
        )
        try:
            proc.stdout.readline()  # first save landed
            time.sleep(extra_delay)
        finally:
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=60)
        assert proc.returncode == -signal.SIGKILL

        mgr = CheckpointManager(d)
        like = {
            "w": np.zeros((512, 64), np.float32),
            "step": np.asarray(0, np.int64),
        }
        step, tree = mgr.restore_latest(like)
        assert step is not None, "at least one save was published"
        assert mgr.fallbacks == 0, "published checkpoints must be intact"
        np.testing.assert_array_equal(
            tree["w"], np.full((512, 64), float(step), np.float32)
        )
        assert int(tree["step"]) == step
        # any orphaned .tmp_* partial save was GC'd by restore_latest
        assert not [
            f for f in os.listdir(d) if f.startswith(".tmp_")
        ]


_KILL_ENGINE_SCRIPT = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from repro.core import snn
    from repro.faults import Fault, FaultInjector, FaultSchedule
    from repro.serving.snn_engine import SNNStreamEngine, StreamRequest

    snap_dir = sys.argv[1]
    cfg = snn.SNNConfig(layer_sizes=(64, 24, 2), num_steps=12)
    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    # kill at tick 2: every window is still mid-flight (nothing has been
    # delivered to the doomed client), so the last snapshot carries the
    # complete outstanding set
    injector = FaultInjector(FaultSchedule(
        faults=(Fault(tick=2, kind="process_kill"),)
    ))
    eng = SNNStreamEngine(
        params, cfg, num_slots=2, chunk_steps=5, seed=0, backend="jnp",
        injector=injector,
    )
    for s in range(4):
        r = np.random.default_rng(s)
        eng.submit(StreamRequest(spikes=(
            r.random((12, 64)) < 0.3).astype(np.float32)))
    while not eng.idle():
        eng.snapshot_auto(snap_dir)   # snapshot BEFORE the tick: the
        eng.poll()                    # kill at tick 3 loses nothing
    print("ENGINE_FINISHED_WITHOUT_KILL", flush=True)
""")


@pytest.mark.slow
def test_process_kill_then_warm_restart_parity(tmp_path):
    """End-to-end kill-and-resume: a serving process SIGKILLs itself
    mid-run via the process_kill fault; the survivor warm-restarts from
    the snapshot rotation and finishes all four windows bit-identically
    to a run that was never killed.  (Results already delivered to the
    dead client are gone by design — the kill tick is chosen before the
    first completion, so recovery must reproduce all four.)"""
    snap_dir = str(tmp_path / "snaps")
    proc = subprocess.run(
        [sys.executable, "-c", _KILL_ENGINE_SCRIPT, snap_dir],
        cwd=REPO, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == -signal.SIGKILL, proc.stderr
    assert "ENGINE_FINISHED_WITHOUT_KILL" not in proc.stdout

    params = _params()
    trains = [_train(0.3, s) for s in range(4)]
    oracle = _by_rid(
        _mk(params).run([StreamRequest(spikes=t) for t in trains])
    )
    eng = _mk(params)
    restored = eng.restore_latest_snapshot(snap_dir)
    assert restored is not None
    got = _by_rid(eng.drain())
    assert sorted(got) == sorted(oracle)
    for rid in oracle:
        _assert_result_equal(got[rid], oracle[rid])


def test_process_kill_fault_kind_validates():
    """The new fault kinds are schedulable records like any other."""
    f = Fault(tick=2, kind="process_kill")
    assert f in FaultSchedule(faults=(f,)).faults
    with pytest.raises(ValueError, match="needs path"):
        FaultInjector(FaultSchedule(
            faults=(Fault(tick=0, kind="corrupt_checkpoint"),)
        )).begin_tick(None, 0)


def test_corrupt_checkpoint_fault_carries_forward_until_save(tmp_path):
    """A corrupt_checkpoint fault scheduled before any save exists is
    carried forward, then fires on the first published save."""
    inj = FaultInjector(FaultSchedule(faults=(
        Fault(tick=0, kind="corrupt_checkpoint", path=str(tmp_path)),
    )))
    assert inj.begin_tick(None, 0) == []          # nothing to corrupt yet
    assert len(inj._pending) == 1
    from repro.checkpoint import publish_array_dir

    publish_array_dir(
        str(tmp_path), "snap_000001",
        {"a0": np.arange(32, dtype=np.float32)}, {"kind": "x"},
    )
    applied = inj.begin_tick(None, 1)
    assert applied and applied[0]["kind"] == "corrupt_checkpoint"
    assert applied[0]["path"].endswith("arrays.npz")


# ------------------------------------------------- training full-state resume
@pytest.mark.slow
def test_train_resume_is_bit_exact(tmp_path):
    """train(6) == train(3) / kill / restore / train(3): params, opt
    state, PRNG stream, step counter and telemetry counters all resume
    exactly (ckpt_every=3, data stream fast-forwarded via start_step)."""
    from repro.sparse_train import trainer as ev

    tcfg = ev.EventTrainConfig(image_hw=16, num_steps=6, hidden=16)

    def make(ckpt_dir, every):
        return ev.EventTrainer(
            tcfg, energy_lambda=0.01, ckpt_dir=ckpt_dir, ckpt_every=every,
            seed=0,
        )

    # uninterrupted reference: 6 steps straight through
    t_ref = make(str(tmp_path / "ref"), 100)
    s_ref = t_ref.init_state(jax.random.PRNGKey(0))
    s_ref, _ = t_ref.run(s_ref, ev.dvs_batches(0, 4, tcfg), 6)

    # interrupted: 3 steps, then a fresh trainer restores and finishes
    d = str(tmp_path / "resume")
    t1 = make(d, 3)
    s1 = t1.init_state(jax.random.PRNGKey(0))
    s1, _ = t1.run(s1, ev.dvs_batches(0, 4, tcfg), 3)
    steps_after_3 = t1.metrics.counter("train.steps").value

    t2 = make(d, 3)  # simulated restart: no shared python state
    s2 = t2.restore_or_init(jax.random.PRNGKey(1))  # key unused on restore
    assert int(s2.step) == 3
    assert t2.metrics.counter("train.steps").value == steps_after_3
    assert t2.metrics.counter("train.energy_pj.total").value == pytest.approx(
        t1.metrics.counter("train.energy_pj.total").value
    )
    s2, _ = t2.run(
        s2, ev.dvs_batches(0, 4, tcfg, start_step=int(s2.step)), 3
    )

    assert int(s_ref.step) == int(s2.step) == 6
    ref_leaves = jax.tree_util.tree_leaves(s_ref.params)
    got_leaves = jax.tree_util.tree_leaves(s2.params)
    for a, b in zip(ref_leaves, got_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(
        jax.tree_util.tree_leaves(s_ref.opt_state),
        jax.tree_util.tree_leaves(s2.opt_state),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_resume_falls_back_past_corrupt_checkpoint(tmp_path):
    """Byte-corrupting the newest training checkpoint degrades the
    recovery point (previous keep-N save) instead of crashing resume."""
    from repro.sparse_train import trainer as ev

    tcfg = ev.EventTrainConfig(image_hw=16, num_steps=6, hidden=16)
    d = str(tmp_path / "ck")
    t1 = ev.EventTrainer(tcfg, ckpt_dir=d, ckpt_every=2, seed=0)
    s1 = t1.init_state(jax.random.PRNGKey(0))
    t1.run(s1, ev.dvs_batches(0, 4, tcfg), 4)
    assert t1.ckpt.all_steps() == [2, 4]

    corrupt_checkpoint(d)  # newest (step 4)
    t2 = ev.EventTrainer(tcfg, ckpt_dir=d, ckpt_every=2, seed=0)
    with pytest.warns(UserWarning, match="falling back"):
        s2 = t2.restore_or_init(jax.random.PRNGKey(1))
    assert int(s2.step) == 2
    assert t2.ckpt.fallbacks == 1
