"""Checkpoint manager: atomicity, keep-N, auto-resume, structure checks,
checksum-verified integrity with fallback, and orphan tmp-dir GC."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptError,
    CheckpointManager,
    gc_orphan_tmpdirs,
    load_array_dir,
    publish_array_dir,
)
from repro.faults import corrupt_checkpoint


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "step": jnp.asarray(seed, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(3)
    mgr.save(3, tree)
    step, restored = mgr.restore_latest(_tree(0))
    assert step == 3
    np.testing.assert_allclose(
        np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"])
    )
    assert int(restored["step"]) == 3


def test_keep_n_garbage_collection(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_restore_latest_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    step, restored = mgr.restore_latest(_tree())
    assert step is None and restored is None


def test_corrupt_partial_checkpoint_ignored(tmp_path):
    """A crash mid-write leaves a dir without manifest; it must be skipped
    (atomicity contract)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1))
    os.makedirs(tmp_path / "step_0000000002")  # no manifest -> partial
    assert mgr.latest_step() == 1
    step, _ = mgr.restore_latest(_tree())
    assert step == 1


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = {"params": {"w": jnp.zeros((8, 4))}, "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError, match="mismatch"):
        mgr.restore(1, bad)


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, _tree(7))
    mgr.wait()
    assert mgr.latest_step() == 7


def test_checksum_detects_byte_corruption(tmp_path):
    """A byte-flipped arrays.npz must surface as CheckpointCorruptError,
    never load garbage."""
    d = str(tmp_path / "ck")
    publish_array_dir(
        str(tmp_path), "ck",
        {"a0": np.arange(64, dtype=np.float32)}, {"step": 1},
    )
    _, manifest = load_array_dir(d)
    assert "checksums" in manifest
    corrupt_checkpoint(d, nbytes=4)
    with pytest.raises(CheckpointCorruptError):
        load_array_dir(d)


def test_manifest_checksum_detects_swapped_arrays(tmp_path):
    """A structurally-valid npz with the wrong payload (torn copy, a
    stale file restored over a new manifest) is caught by the manifest
    crc32, not the zip container's own CRC."""
    d = str(tmp_path / "ck")
    publish_array_dir(
        str(tmp_path), "ck",
        {"a0": np.arange(64, dtype=np.float32)}, {"step": 1},
    )
    np.savez(
        os.path.join(d, "arrays.npz"), a0=np.zeros(64, dtype=np.float32)
    )
    with pytest.raises(CheckpointCorruptError, match="checksum"):
        load_array_dir(d)


def test_restore_latest_falls_back_on_corruption(tmp_path):
    """Corrupting the newest checkpoint must fall back to the previous
    intact one — loudly, with the fallback counter bumped."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    corrupt_checkpoint(str(tmp_path))  # hits the newest (step 2)
    with pytest.warns(UserWarning, match="fall"):
        step, restored = mgr.restore_latest(_tree(0))
    assert step == 1
    assert int(restored["step"]) == 1
    assert mgr.fallbacks == 1


def test_restore_latest_all_corrupt_gives_cold_start(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1))
    corrupt_checkpoint(str(tmp_path))
    with pytest.warns(UserWarning):
        step, restored = mgr.restore_latest(_tree(0))
    assert step is None and restored is None
    assert mgr.fallbacks == 1


def test_close_joins_async_thread(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(5, _tree(5))
    mgr.close()
    assert mgr.latest_step() == 5
    assert mgr._thread is None or not mgr._thread.is_alive()


def test_context_manager_joins_async_thread(tmp_path):
    with CheckpointManager(str(tmp_path), async_save=True) as mgr:
        mgr.save(9, _tree(9))
    assert CheckpointManager(str(tmp_path)).latest_step() == 9


def test_orphan_tmpdir_gc(tmp_path):
    """A crash mid-publish leaves a .tmp_* dir; latest_step() must both
    ignore and remove it."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1))
    orphan = tmp_path / ".tmp_dead"
    orphan.mkdir()
    (orphan / "arrays.npz").write_bytes(b"partial")
    with pytest.warns(UserWarning, match="orphan"):
        assert mgr.latest_step() == 1
    assert not orphan.exists()


def test_gc_orphan_tmpdirs_helper(tmp_path):
    (tmp_path / ".tmp_x").mkdir()
    (tmp_path / "keep").mkdir()
    removed = gc_orphan_tmpdirs(str(tmp_path))
    assert len(removed) == 1
    assert (tmp_path / "keep").exists()
    assert not (tmp_path / ".tmp_x").exists()


def test_elastic_restore_dtype_cast(tmp_path):
    """Restore casts to the target tree's dtypes (elastic/mixed-precision
    resume)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    like = {
        "params": {
            "w": jnp.zeros((8, 4), jnp.bfloat16),
            "b": jnp.zeros((4,)),
        },
        "step": jnp.zeros((), jnp.int32),
    }
    restored = mgr.restore(1, like)
    assert restored["params"]["w"].dtype == jnp.bfloat16
