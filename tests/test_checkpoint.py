"""Checkpoint manager: atomicity, keep-N, auto-resume, structure checks."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "step": jnp.asarray(seed, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(3)
    mgr.save(3, tree)
    step, restored = mgr.restore_latest(_tree(0))
    assert step == 3
    np.testing.assert_allclose(
        np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"])
    )
    assert int(restored["step"]) == 3


def test_keep_n_garbage_collection(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_restore_latest_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    step, restored = mgr.restore_latest(_tree())
    assert step is None and restored is None


def test_corrupt_partial_checkpoint_ignored(tmp_path):
    """A crash mid-write leaves a dir without manifest; it must be skipped
    (atomicity contract)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1))
    os.makedirs(tmp_path / "step_0000000002")  # no manifest -> partial
    assert mgr.latest_step() == 1
    step, _ = mgr.restore_latest(_tree())
    assert step == 1


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = {"params": {"w": jnp.zeros((8, 4))}, "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError, match="mismatch"):
        mgr.restore(1, bad)


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, _tree(7))
    mgr.wait()
    assert mgr.latest_step() == 7


def test_elastic_restore_dtype_cast(tmp_path):
    """Restore casts to the target tree's dtypes (elastic/mixed-precision
    resume)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    like = {
        "params": {
            "w": jnp.zeros((8, 4), jnp.bfloat16),
            "b": jnp.zeros((4,)),
        },
        "step": jnp.zeros((), jnp.int32),
    }
    restored = mgr.restore(1, like)
    assert restored["params"]["w"].dtype == jnp.bfloat16
