"""Partitioning rules: divisibility fallbacks, axis dedup, cache axes."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import partitioning as pt


def _mesh(shape, axes):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)


MESH = _mesh((2, 4), ("data", "model"))
POD = _mesh((2, 2, 2), ("pod", "data", "model"))


def test_basic_tp_fsdp_spec():
    spec = pt.spec_for((64, 16, 128), ("embed", "heads", "head_dim"), MESH)
    assert spec == P("data", "model")


def test_divisibility_fallback_replicates():
    # 7 heads not divisible by model=4 -> replicated
    spec = pt.spec_for((64, 7, 128), ("embed", "heads", "head_dim"), MESH)
    assert spec == P("data")


def test_axis_never_used_twice():
    # expert and mlp both want "model"; expert wins (first dim)
    spec = pt.spec_for((8, 64, 32), ("expert", "embed", "mlp"), MESH)
    assert spec == P("model", "data")


def test_batch_uses_pod_and_data():
    spec = pt.spec_for((32, 128), ("batch", "act_seq"), POD)
    assert spec == P(("pod", "data"))


def test_batch_prefix_fallback():
    # batch=2 divisible by pod(2) but not pod*data(4) -> prefix ("pod",)
    spec = pt.spec_for((2, 128), ("batch", "act_seq"), POD)
    assert spec == P("pod")


def test_batch_one_replicated():
    spec = pt.spec_for((1, 128), ("batch", "act_seq"), POD)
    assert spec == P()


def test_rules_override():
    rules = pt.PartitionRules().override(act_seq=("data",))
    spec = pt.spec_for((4, 64), ("batch", "act_seq"), MESH, rules)
    # batch falls back: 4 % data(2) == 0 -> data taken; act_seq wants data
    # but it is used -> replicated
    assert spec == P("data")


def test_cache_logical_axes_detects_stacked_layers():
    import jax.numpy as jnp

    shapes = {
        "main": {
            "b0": {
                "k": jax.ShapeDtypeStruct((4, 2, 8, 2, 16), jnp.bfloat16),
                "v": jax.ShapeDtypeStruct((4, 2, 8, 2, 16), jnp.bfloat16),
            }
        }
    }
    axes = pt.cache_logical_axes(shapes)
    assert axes["main"]["b0"]["k"] == (
        "layers", "batch", "seq", "kv", "head_dim",
    )


def test_tree_specs_on_param_tree():
    import jax.numpy as jnp

    shapes = {"w": jax.ShapeDtypeStruct((64, 16, 32), jnp.float32)}
    axes = {"w": ("embed", "heads", "head_dim")}
    specs = pt.tree_specs(shapes, axes, MESH)
    assert specs["w"] == P("data", "model")


def test_constrain_noop_outside_context():
    import jax.numpy as jnp

    x = jnp.ones((4, 4))
    y = pt.constrain(x, ("batch", "embed_act"))
    assert y is x
