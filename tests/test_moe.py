"""MoE dispatch correctness: grouped-capacity einsum vs dense reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.config import ModelConfig

RNG = np.random.default_rng(3)


def _cfg(**kw):
    base = dict(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=16,
        vocab_size=64, num_experts=4, num_experts_per_tok=2,
        capacity_factor=1000.0, moe_group_size=8, dtype="float32",
        mlp_kind="swiglu",
    )
    base.update(kw)
    return ModelConfig(**base)


def _dense_reference(p, x, cfg):
    """Loop-over-experts oracle (no capacity, exact top-k combine)."""
    B, S, D = x.shape
    logits = x @ p["router"]
    w, idx = moe.router_weights(logits, cfg)
    out = np.zeros((B, S, D), np.float32)
    for b in range(B):
        for s in range(S):
            acc = np.zeros(D, np.float32)
            for j in range(cfg.num_experts_per_tok):
                e = int(idx[b, s, j])
                xe = np.asarray(x[b, s])
                up = xe @ np.asarray(p["w_up"][e])
                gate = xe @ np.asarray(p["w_gate"][e])
                h = (gate / (1 + np.exp(-gate))) * up  # silu(gate)*up
                acc += float(w[b, s, j]) * (h @ np.asarray(p["w_down"][e]))
            out[b, s] = acc
    return out


@pytest.mark.parametrize("order", ["topk_then_softmax", "softmax_then_topk"])
def test_moe_matches_dense_reference_no_drop(order):
    cfg = _cfg(router_softmax_order=order)
    p, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(0, 0.5, (2, 8, 32)).astype(np.float32))
    got, aux = moe.moe_forward(p, x, cfg)
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    assert float(aux["moe_dropped_frac"]) == 0.0


def test_capacity_drops_overflow():
    cfg = _cfg(capacity_factor=0.25)  # tiny capacity -> forced drops
    p, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(0, 0.5, (2, 16, 32)).astype(np.float32))
    _, aux = moe.moe_forward(p, x, cfg)
    assert float(aux["moe_dropped_frac"]) > 0.0


def test_group_size_divides_tokens():
    cfg = _cfg()
    assert moe.group_size(cfg, 24) in (8,)
    assert moe.group_size(cfg, 7) == 7
    assert moe.group_size(_cfg(moe_group_size=512), 128) == 128


def test_dropped_frac_monotone_in_capacity():
    p, _ = moe.moe_init(jax.random.PRNGKey(0), _cfg())
    x = jnp.asarray(RNG.normal(0, 0.5, (2, 16, 32)).astype(np.float32))
    drops = []
    for cf in (0.25, 0.5, 1.0, 2.0):
        _, aux = moe.moe_forward(p, x, _cfg(capacity_factor=cf))
        drops.append(float(aux["moe_dropped_frac"]))
    assert all(a >= b - 1e-9 for a, b in zip(drops, drops[1:]))


def test_aux_loss_uniform_router_is_one():
    """Switch aux loss equals ~1.0 for a perfectly uniform router."""
    cfg = _cfg(num_experts_per_tok=1)
    p, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform logits
    x = jnp.asarray(RNG.normal(0, 0.5, (4, 8, 32)).astype(np.float32))
    _, aux = moe.moe_forward(p, x, cfg)
    # me = 1/N per expert (ties broken deterministically may skew; allow slack)
    assert 0.5 < float(aux["moe_aux_loss"]) < 2.0
