"""Per-arch smoke tests (assignment f): every assigned architecture
instantiates a REDUCED same-family config and runs one forward/train step
on CPU, asserting output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models.model import CLIP_EMBED_DIM, Model


def _batch(cfg, B=2, L=16, seed=0):
    rng = np.random.default_rng(seed)
    shape = (B, L, cfg.num_codebooks) if cfg.num_codebooks else (B, L)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, shape).astype(np.int32))
    batch = {"tokens": toks, "targets": toks}
    if cfg.num_image_tokens:
        batch["img_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_image_tokens, CLIP_EMBED_DIM))
            .astype(np.float32)
        )
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = configs.get(arch).reduced()
    model = Model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    # params/axes trees align
    assert jax.tree_util.tree_structure(params) == jax.tree_util.tree_structure(
        axes,
        is_leaf=lambda t: isinstance(t, tuple)
        and all(isinstance(x, (str, type(None))) for x in t),
    )
    batch = _batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["tokens"]) > 0

    # one SGD-flavoured train step: params change, loss stays finite
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(
        float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0, arch
    params2 = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = model.loss(params2, batch)
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_full_config_matches_assignment(arch):
    """The FULL configs carry the published numbers (spot checks)."""
    cfg = configs.get(arch)
    expected = {
        "mixtral-8x7b": dict(num_layers=32, d_model=4096, num_heads=32,
                             num_kv_heads=8, d_ff=14336, vocab_size=32000,
                             num_experts=8, num_experts_per_tok=2,
                             attention_kind="swa"),
        "granite-moe-1b-a400m": dict(num_layers=24, d_model=1024,
                                     num_heads=16, num_kv_heads=8, d_ff=512,
                                     vocab_size=49155, num_experts=32,
                                     num_experts_per_tok=8),
        "mamba2-130m": dict(num_layers=24, d_model=768, vocab_size=50280,
                            ssm_state=128),
        "stablelm-1.6b": dict(num_layers=24, d_model=2048, num_heads=32,
                              num_kv_heads=32, d_ff=5632, vocab_size=100352),
        "codeqwen1.5-7b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=32, d_ff=13440, vocab_size=92416),
        "yi-34b": dict(num_layers=60, d_model=7168, num_heads=56,
                       num_kv_heads=8, d_ff=20480, vocab_size=64000),
        "minicpm3-4b": dict(num_layers=62, d_model=2560, num_heads=40,
                            d_ff=6400, vocab_size=73448, mla=True),
        "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                                  num_kv_heads=1, d_ff=7680,
                                  vocab_size=256000, family="hybrid"),
        "phi-3-vision-4.2b": dict(num_layers=32, d_model=3072, num_heads=32,
                                  num_kv_heads=32, d_ff=8192,
                                  vocab_size=32064, num_image_tokens=576),
        "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048,
                                num_codebooks=4),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_in_right_ballpark():
    """Full-config param counts match the advertised model sizes."""
    expect = {
        "mixtral-8x7b": (45e9, 48e9),  # 46.7B total (8x7B shares attn)
        "yi-34b": (33e9, 36e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "stablelm-1.6b": (1.4e9, 1.9e9),
        "musicgen-medium": (1.3e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = Model(configs.get(arch)).param_count()
        assert lo < n < hi, (arch, n)
