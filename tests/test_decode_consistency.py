"""End-to-end serving correctness: prefill + step decode reproduces the
teacher-forced forward logits for one representative arch per family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models.model import CLIP_EMBED_DIM, Model

ARCHS = [
    "stablelm-1.6b",       # dense MHA + partial rope + layernorm + bias
    "mixtral-8x7b",        # MoE + SWA ring cache
    "minicpm3-4b",         # MLA compressed cache
    "mamba2-130m",         # SSM recurrent cache
    "recurrentgemma-2b",   # hybrid RG-LRU + local attn
    "musicgen-medium",     # codebooks + sinusoidal PE
]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = configs.get(arch).reduced()
    if cfg.num_experts:
        cfg = dataclasses.replace(
            cfg, capacity_factor=100.0, moe_group_size=16
        )
    m = Model(cfg)
    params, _ = m.init(jax.random.PRNGKey(1))
    B, L = 2, 20
    rng = np.random.default_rng(1)
    shape = (B, L, cfg.num_codebooks) if cfg.num_codebooks else (B, L)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, shape).astype(np.int32))
    batch = {"tokens": toks, "targets": toks}
    if cfg.num_image_tokens:
        batch["img_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_image_tokens, CLIP_EMBED_DIM))
            .astype(np.float32)
        )

    # teacher-forced logits
    x = m._inputs(params, batch)
    pos = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), (B, x.shape[1])
    )
    h, _ = m.backbone(params, x, pos)
    if cfg.num_image_tokens:
        h = h[:, cfg.num_image_tokens:]
    ref = np.asarray(m._head(params, h), np.float32)

    Lp = L - 4
    pre = dict(batch)
    pre["tokens"] = toks[:, :Lp]
    pre.pop("targets")
    logits, cache = m.prefill(params, pre, cache_len=x.shape[1] + 8)
    errs = [np.max(np.abs(np.asarray(logits) - ref[:, Lp - 1]))]
    offset = cfg.num_image_tokens
    for t in range(Lp, L):
        tok_t = toks[:, t : t + 1]
        p_t = jnp.full((B,), t + offset, jnp.int32)
        logits, cache = m.decode_step(params, tok_t, p_t, cache)
        errs.append(np.max(np.abs(np.asarray(logits) - ref[:, t])))
    assert max(errs) < 5e-4, (arch, errs)
