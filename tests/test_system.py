"""End-to-end system test: the paper's pipeline (synthetic collision data
-> rate coding -> LIF SNN -> Adam training) reaches high accuracy, and the
hardware (Pallas/Q1.15) inference path agrees with the trained float model.

This is the 'does the whole reproduction hang together' test; the full-
scale run lives in examples/collision_avoidance.py and benchmarks/.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coding, snn
from repro.data import collision
from repro.optim import adam, chain_clip
from repro.optim.adam import apply_updates

jax.devices()  # lock single-device before any launch import side effects


CFG = snn.SNNConfig(layer_sizes=(256, 64, 2), num_steps=10, dropout_rate=0.2)
DATA = collision.CollisionConfig(
    image_hw=16, num_train=512, num_test=128, seed=0
)


@pytest.fixture(scope="module")
def trained():
    trx, trY, tex, teY = collision.generate(DATA)
    key = jax.random.PRNGKey(0)
    params = snn.init_params(key, CFG)
    opt = chain_clip(adam(5e-4), 1.0)  # paper: Adam, lr 5e-4
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y, key):
        ekey, dkey = jax.random.split(key)
        spikes = coding.rate_encode(ekey, x, CFG.num_steps)
        (l, aux), g = jax.value_and_grad(snn.loss_fn, has_aux=True)(
            params, spikes, y, CFG, train=True, dropout_key=dkey
        )
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, l, aux

    for epoch in range(8):
        for x, y in collision.batches(trx, trY, 64, seed=epoch):
            key, sk = jax.random.split(key)
            params, state, loss, aux = step(params, state, x, y, sk)
    return params, (trx, trY, tex, teY)


def _accuracy(params, x, y, key, cfg=CFG):
    spikes = coding.rate_encode(
        key, jnp.asarray(x.reshape(len(x), -1)), cfg.num_steps
    )
    _, aux = snn.loss_fn(params, spikes, jnp.asarray(y), cfg, train=False)
    return float(aux["accuracy"])


def test_training_reaches_high_accuracy(trained):
    params, (trx, trY, tex, teY) = trained
    acc_train = _accuracy(params, trx[:256], trY[:256], jax.random.PRNGKey(1))
    acc_test = _accuracy(params, tex, teY, jax.random.PRNGKey(2))
    # paper reports 92-93% train / ~85% test on DroNet; our synthetic
    # analog must clear a conservative bar
    assert acc_train > 0.85, acc_train
    assert acc_test > 0.80, acc_test


def test_q115_quantized_model_keeps_accuracy(trained):
    params, (_, _, tex, teY) = trained
    cfgq = dataclasses.replace(CFG, quant_q115=True)
    key = jax.random.PRNGKey(3)
    spikes = coding.rate_encode(
        key, jnp.asarray(tex.reshape(len(tex), -1)), CFG.num_steps
    )
    _, aux_f = snn.loss_fn(params, spikes, jnp.asarray(teY), CFG, train=False)
    _, aux_q = snn.loss_fn(params, spikes, jnp.asarray(teY), cfgq, train=False)
    assert float(aux_q["accuracy"]) > float(aux_f["accuracy"]) - 0.05


def test_hardware_path_agrees_with_float_model(trained):
    """Pallas spike_matmul + lif_fused inference == float graph with
    Q1.15-quantized weights, end to end on real trained weights."""
    from repro.kernels import ops

    params, (_, _, tex, teY) = trained
    x = jnp.asarray(tex[:32].reshape(32, -1))
    spikes = coding.rate_encode_deterministic(x, CFG.num_steps)

    # hardware path, layer by layer
    h = spikes
    for i in range(CFG.num_layers):
        lp = params[f"layer{i}"]
        h = ops.snn_layer_forward(
            h, lp["w"], lp["b"],
            snn.effective_beta(lp), lp["threshold"],
        )
    counts_hw = np.asarray(jnp.sum(h, axis=0))

    # float path with fake-quant weights (QAT view of the same hardware)
    cfgq = dataclasses.replace(CFG, quant_q115=True)
    _, out_spk = snn.forward(params, spikes, cfgq, train=False)
    counts_f = np.asarray(jnp.sum(out_spk, axis=0))
    assert (counts_hw.argmax(-1) == counts_f.argmax(-1)).mean() > 0.95


def test_refractory_system_variant_trains(trained):
    """§4.2.2 variant: enabling the 5-step refractory period still yields a
    working classifier (accuracy above chance by a wide margin)."""
    params, (trx, trY, _, _) = trained
    cfg5 = dataclasses.replace(CFG, refractory_steps=5)
    acc = _accuracy(params, trx[:256], trY[:256], jax.random.PRNGKey(5), cfg5)
    assert acc > 0.7
