"""GPipe pipeline over placeholder devices (subprocess: needs >1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_forward, make_pipe_mesh

    S, M, mb, d = 4, 8, 2, 16
    mesh = make_pipe_mesh(S)
    rng = np.random.default_rng(0)
    stage_w = jnp.asarray(rng.normal(0, 0.5, (S, d, d)).astype(np.float32))
    xs = jnp.asarray(rng.normal(0, 1, (M, mb, d)).astype(np.float32))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    pipe = pipeline_forward(stage_fn, mesh, "pipe")
    got = pipe(stage_w, xs)  # leaves are (S, ...) stage-stacked

    want = xs
    for s in range(S):
        want = jnp.tanh(want @ stage_w[s])
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-5, err
    print("PIPELINE_OK", err)
    """
)


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))
        ),
        env=env, timeout=300,
    )
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
