"""Q1.15 fixed-point tests (paper §4.3) incl. hypothesis properties."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import quant


def test_q115_range():
    f = quant.Q1_15
    assert f.total_bits == 16
    assert f.min_val == -1.0
    assert abs(f.max_val - (1 - 2**-15)) < 1e-12
    assert f.storage_dtype == jnp.int16


def test_paper_28bit_accumulator():
    """Paper: fan-in 4096 adder tree -> '28-bit intermediate result'."""
    assert quant.accumulator_bits(4096, quant.Q1_15) == 28


@settings(max_examples=50, deadline=None)
@given(st.floats(-1.0, 1.0 - 2**-15))
def test_roundtrip_error_bounded(x):
    codes = quant.quantize(jnp.asarray([x]))
    back = float(quant.dequantize(codes)[0])
    # half an LSB, plus the float32 representation error of the f64 input
    assert abs(back - x) <= 2**-16 + abs(x) * 2**-23 + 1e-12


@settings(max_examples=50, deadline=None)
@given(st.floats(-4.0, 4.0))
def test_fake_quant_matches_true_path(x):
    """fake_quant (QAT/pjit path) is bit-exact with quantize->dequantize."""
    fq = float(quant.fake_quant(jnp.asarray([x]))[0])
    tq = float(quant.dequantize(quant.quantize(jnp.asarray([x])))[0])
    assert fq == tq


def test_saturation():
    codes = quant.quantize(jnp.asarray([5.0, -5.0]))
    np.testing.assert_array_equal(np.asarray(codes), [32767, -32768])


def test_quant_params_only_floats():
    tree = {"w": jnp.asarray([0.1234567]), "i": jnp.asarray([3], jnp.int32)}
    out = quant.quant_params(tree)
    assert out["i"].dtype == jnp.int32
    assert abs(float(out["w"][0]) - 0.1234567) < 2**-15


def test_fake_quant_gradient_straight_through():
    import jax

    g = jax.grad(lambda x: jnp.sum(quant.fake_quant(x)))(jnp.asarray([0.3]))
    np.testing.assert_allclose(np.asarray(g), [1.0])
