"""Unit tests for LIF / Lapicque dynamics (paper Eqs. 1-2/4, §4.2.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import neuron


def _run(kind="lif", refrac=0, reset="zero", currents=None, beta=0.9, thr=1.0):
    cfg = neuron.NeuronConfig(
        kind=kind, reset=reset, refractory_steps=refrac
    )
    spikes, state = neuron.run_neuron(
        cfg, currents, beta=jnp.asarray(beta), threshold=jnp.asarray(thr)
    )
    return np.asarray(spikes), state


def test_lif_decay_no_input():
    """With zero input the membrane decays geometrically (beta factor)."""
    cfg = neuron.NeuronConfig(kind="lif")
    st = neuron.NeuronState(u=jnp.ones((1,)), refrac=jnp.zeros((1,), jnp.int32))
    st, _ = neuron.neuron_step(
        cfg, st, jnp.zeros((1,)), beta=jnp.asarray(0.5), threshold=jnp.asarray(10.0)
    )
    assert np.allclose(st.u, 0.5)
    st, _ = neuron.neuron_step(
        cfg, st, jnp.zeros((1,)), beta=jnp.asarray(0.5), threshold=jnp.asarray(10.0)
    )
    assert np.allclose(st.u, 0.25)


def test_lapicque_integrates_without_leak():
    """Lapicque (Eq. 1): pure integrator, no decay."""
    cur = jnp.full((10, 1), 0.3)
    spikes, state = _run("lapicque", currents=cur, thr=100.0)
    assert np.allclose(state.u, 3.0, atol=1e-6)
    assert spikes.sum() == 0


def test_lif_threshold_and_reset_zero():
    """Eq. 2: on spike the membrane resets to zero."""
    cur = jnp.concatenate([jnp.full((1, 1), 2.0), jnp.zeros((3, 1))])
    spikes, state = _run("lif", currents=cur, thr=1.0, beta=0.9)
    assert spikes[0, 0] == 1.0  # immediate spike (2.0 > 1.0)
    # after reset-to-zero and zero input, u stays 0
    assert np.allclose(state.u, 0.0, atol=1e-6)


def test_reset_subtract():
    cur = jnp.full((1, 1), 1.5)
    cfg = neuron.NeuronConfig(kind="lif", reset="subtract")
    st = neuron.init_state((1,))
    st, spk = neuron.neuron_step(
        cfg, st, cur[0], beta=jnp.asarray(0.9), threshold=jnp.asarray(1.0)
    )
    assert spk[0] == 1.0
    assert np.allclose(st.u, 0.5)  # 1.5 - thr


def test_refractory_suppresses_firing():
    """Paper §4.2.2: after a spike the neuron is silent for R steps."""
    T = 12
    cur = jnp.full((T, 1), 2.0)  # would fire every step without refractory
    spikes_no, _ = _run("lif", refrac=0, currents=cur)
    spikes_r5, _ = _run("lif", refrac=5, currents=cur)
    assert spikes_no.sum() == T
    # with refractory 5: fires at t=0, 6, ... -> every 6th step
    fired = np.where(spikes_r5[:, 0] > 0)[0]
    assert fired[0] == 0
    assert np.all(np.diff(fired) >= 6)


def test_spike_rate_monotone_in_current():
    """Stronger input -> higher firing rate (sanity of dynamics)."""
    T = 50
    rates = []
    for amp in (0.2, 0.5, 1.0):
        cur = jnp.full((T, 1), amp)
        spikes, _ = _run("lif", currents=cur, thr=1.0, beta=0.8)
        rates.append(spikes.mean())
    assert rates[0] <= rates[1] <= rates[2]
    assert rates[2] > 0


def test_surrogate_gradient_nonzero_near_threshold():
    """BPTT trainability: dL/dbeta exists and is finite."""

    def loss(beta):
        cfg = neuron.NeuronConfig(kind="lif")
        cur = jnp.full((5, 4), 0.6)
        spikes, _ = neuron.run_neuron(
            cfg, cur, beta=beta, threshold=jnp.asarray(1.0)
        )
        return jnp.sum(spikes)

    g = jax.grad(loss)(jnp.asarray(0.9))
    assert np.isfinite(g)
    assert g != 0.0


@pytest.mark.parametrize("surr", ["atan", "fast_sigmoid", "boxcar"])
def test_surrogates_forward_exact(surr):
    from repro.core import surrogate

    fn = surrogate.get(surr)
    x = jnp.asarray([-1.0, -0.01, 0.0, 0.01, 1.0])
    np.testing.assert_array_equal(fn(x), (x >= 0).astype(jnp.float32))
